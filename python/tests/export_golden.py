"""Export fixed-seed golden vectors for the Rust host compute plane.

Runs the authoritative Python model (``compile.model``) on a small
deterministic 2-layer batch and writes every input and expected output
to ``rust/tests/data/golden_model.txt``. The Rust integration test
``rust/tests/golden_model.rs`` replays the same batch through the host
backend (`model::host`) and asserts forward logits, masked-mean loss,
flat gradients, and the post-Adam parameters agree within 1e-5 — the
cross-language parity contract behind `GnnModel`.

Regenerate with:

    cd python && python3 tests/export_golden.py

The file format is line oriented: ``name: v v v ...`` with %.9g floats
(ints print exactly), row-major flattening. Padded-block convention: a
neighbor slot is a real edge iff its weight is nonzero, which is how the
Rust side reconstructs its unpadded CSR ``HostBlock``s.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelDims, forward, loss_and_metrics, param_shapes, train_step

jax.config.update("jax_platform_name", "cpu")

DIMS = ModelDims(layers=2, d_in=6, hidden=8, classes=5)
K = 3  # fanout cap per block
N = (5, 12, 20)  # layer widths: seeds, mid, input frontier
LR = 0.05
SEED = 7  # chosen so the untrained correct-count is nonzero

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "data", "golden_model.txt")


def make_batch(rng):
    """Deterministic padded batch with prefix-nesting self indices."""
    feats = rng.standard_normal((N[DIMS.layers], DIMS.d_in)).astype(np.float32)
    blocks = []
    for l in range(DIMS.layers):
        n_dst, n_src = N[l], N[l + 1]
        nbr_idx = rng.integers(0, n_src, size=(n_dst, K)).astype(np.int32)
        deg = rng.integers(0, K + 1, size=n_dst)
        deg[0] = 0  # keep one isolated seed so zero-degree rows are covered
        nbr_w = np.zeros((n_dst, K), np.float32)
        self_w = np.zeros(n_dst, np.float32)
        for i in range(n_dst):
            inv = np.float32(1.0) / np.float32(deg[i] + 1.0)
            nbr_w[i, : deg[i]] = inv
            self_w[i] = inv
        self_idx = np.arange(n_dst, dtype=np.int32)
        blocks.append((nbr_idx, nbr_w, self_idx, self_w))
    labels = rng.integers(0, DIMS.classes, size=N[0]).astype(np.int32)
    mask = np.ones(N[0], np.float32)
    return feats, blocks, labels, mask


def make_params(rng):
    return [
        (rng.standard_normal(shape) * 0.25).astype(np.float32)
        for _name, shape in param_shapes(DIMS)
    ]


def emit(f, name, arr):
    vals = np.asarray(arr).reshape(-1)
    f.write(name + ": " + " ".join("%.9g" % float(v) for v in vals) + "\n")


def main():
    rng = np.random.default_rng(SEED)
    feats, blocks, labels, mask = make_batch(rng)
    params = make_params(rng)

    jp = [jnp.asarray(p) for p in params]
    jblocks = [tuple(jnp.asarray(x) for x in b) for b in blocks]
    jfeats, jlabels, jmask = jnp.asarray(feats), jnp.asarray(labels), jnp.asarray(mask)

    logits = forward(jp, jfeats, jblocks, DIMS)
    loss, correct = loss_and_metrics(jp, jfeats, jblocks, jlabels, jmask, DIMS)
    grads = jax.grad(
        lambda ps: loss_and_metrics(ps, jfeats, jblocks, jlabels, jmask, DIMS)[0]
    )(jp)

    zeros = [jnp.zeros_like(p) for p in jp]
    new_params, _, _, t, step_loss, _ = train_step(
        jp, zeros, zeros, jnp.float32(0.0), jfeats, jblocks, jlabels, jmask, LR, DIMS
    )
    assert float(t) == 1.0
    assert abs(float(step_loss) - float(loss)) < 1e-7

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write("# golden vectors from python/tests/export_golden.py (seed %d)\n" % SEED)
        emit(f, "dims", [DIMS.layers, DIMS.d_in, DIMS.hidden, DIMS.classes])
        emit(f, "k", [K])
        emit(f, "n", list(N))
        emit(f, "lr", [LR])
        emit(f, "feats", feats)
        for l, (nbr_idx, nbr_w, self_idx, self_w) in enumerate(blocks):
            emit(f, "block%d_nbr_idx" % l, nbr_idx)
            emit(f, "block%d_nbr_w" % l, nbr_w)
            emit(f, "block%d_self_idx" % l, self_idx)
            emit(f, "block%d_self_w" % l, self_w)
        emit(f, "labels", labels)
        for i, p in enumerate(params):
            emit(f, "param%d" % i, p)
        emit(f, "logits", logits)
        emit(f, "loss", [float(loss)])
        emit(f, "correct", [float(correct)])
        for i, g in enumerate(grads):
            emit(f, "grad%d" % i, g)
        for i, p in enumerate(new_params):
            emit(f, "new_param%d" % i, p)
    print("wrote %s" % os.path.normpath(OUT))


if __name__ == "__main__":
    main()
