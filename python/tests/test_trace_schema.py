"""Schema checker for the Rust flight recorder's Chrome trace export.

The `--trace` flag on `engine` / `train` / `serve` writes a Chrome
trace-event JSON array (viewable at chrome://tracing or
ui.perfetto.dev). This checker pins the exporter's contract:

* the file is a JSON **array** of event objects;
* every event carries the required keys with sane types ("X" complete
  events additionally carry a non-negative integer `dur`);
* per `(pid, tid)` track, timestamps are **monotone non-decreasing** in
  file order (the exporter sorts each track);
* duration-begin/end events ("B"/"E"), if any appear, pair up like a
  stack per track with matching names. The current exporter emits only
  "X" events, so the pairing check passes vacuously — but the checker
  stays honest if streaming B/E output is ever added.

Usable both as a pytest module and as a CLI for the CI smoke job:

    python3 python/tests/test_trace_schema.py trace.json

Exits non-zero listing every violation.
"""

import json
import sys
from collections import defaultdict

REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def check_events(events):
    """Return a list of human-readable violations (empty == valid)."""
    problems = []
    if not isinstance(events, list):
        return [f"top level must be a JSON array, got {type(events).__name__}"]
    last_ts = {}
    stacks = defaultdict(list)
    for i, ev in enumerate(events):
        where = f"event {i}"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in ev]
        if missing:
            problems.append(f"{where}: missing keys {missing}")
            continue
        if not isinstance(ev["ts"], int) or ev["ts"] < 0:
            problems.append(f"{where}: ts must be a non-negative integer µs")
            continue
        track = (ev["pid"], ev["tid"])
        if track in last_ts and ev["ts"] < last_ts[track]:
            problems.append(
                f"{where}: ts {ev['ts']} < previous {last_ts[track]} "
                f"on track {track} — per-track order broken"
            )
        last_ts[track] = ev["ts"]
        ph = ev["ph"]
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                problems.append(f"{where}: 'X' event needs non-negative integer dur")
        elif ph == "B":
            stacks[track].append((ev["name"], i))
        elif ph == "E":
            if not stacks[track]:
                problems.append(f"{where}: 'E' with no open 'B' on track {track}")
            else:
                name, opened = stacks[track].pop()
                # Chrome allows nameless E; a named one must match its B.
                if "name" in ev and ev["name"] != name:
                    problems.append(
                        f"{where}: 'E' named {ev['name']!r} closes 'B' "
                        f"{name!r} from event {opened}"
                    )
    for track, stack in sorted(stacks.items()):
        for name, opened in stack:
            problems.append(
                f"track {track}: 'B' {name!r} (event {opened}) never closed"
            )
    return problems


def check_file(path):
    with open(path, "r", encoding="utf-8") as f:
        try:
            events = json.load(f)
        except json.JSONDecodeError as e:
            return [f"not valid JSON: {e}"], 0
    return check_events(events), len(events) if isinstance(events, list) else 0


# ---- pytest surface ---------------------------------------------------


def _x(name, ts, dur, tid=0, pid=0, **args):
    ev = {"name": name, "cat": "t", "ph": "X", "ts": ts, "dur": dur,
          "pid": pid, "tid": tid}
    ev.update(args)
    return ev


def test_valid_x_only_trace_passes():
    events = [
        _x("sample", 0, 5, tid=0),
        _x("cache_fill", 5, 3, tid=0),
        _x("sample", 2, 4, tid=1),  # other track may start earlier
        _x("fabric_all_to_all", 8, 1, tid=0),
    ]
    assert check_events(events) == []


def test_non_array_top_level_fails():
    assert check_events({"traceEvents": []})
    assert check_events("[]")


def test_missing_keys_and_bad_types_fail():
    assert any("missing keys" in p for p in check_events([{"ph": "X"}]))
    bad_ts = dict(_x("s", 0, 1), ts=-3)
    assert any("non-negative" in p for p in check_events([bad_ts]))
    no_dur = {k: v for k, v in _x("s", 0, 1).items() if k != "dur"}
    assert any("dur" in p for p in check_events([no_dur]))


def test_per_track_timestamp_regression_fails():
    events = [_x("a", 10, 1, tid=2), _x("b", 4, 1, tid=2)]
    problems = check_events(events)
    assert any("per-track order broken" in p for p in problems)


def test_begin_end_pairing_is_enforced():
    ok = [
        {"name": "step", "ph": "B", "ts": 0, "pid": 0, "tid": 0},
        {"name": "inner", "ph": "B", "ts": 1, "pid": 0, "tid": 0},
        {"name": "inner", "ph": "E", "ts": 2, "pid": 0, "tid": 0},
        {"name": "step", "ph": "E", "ts": 3, "pid": 0, "tid": 0},
    ]
    assert check_events(ok) == []
    dangling = ok[:2]
    assert any("never closed" in p for p in check_events(dangling))
    orphan = [ok[2]]
    assert any("no open 'B'" in p for p in check_events(orphan))
    crossed = [ok[0], dict(ok[2], ts=1)]
    assert any("closes 'B'" in p for p in check_events(crossed))


def test_round_trip_through_json(tmp_path):
    path = tmp_path / "trace.json"
    path.write_text(json.dumps([_x("sample", 0, 2, batch=0, seq=0, bytes=64)]))
    problems, n = check_file(str(path))
    assert problems == [] and n == 1


# ---- CLI surface (the CI smoke job) -----------------------------------


def main(argv):
    if len(argv) != 2:
        print(f"usage: {argv[0]} trace.json", file=sys.stderr)
        return 2
    problems, n = check_file(argv[1])
    if problems:
        for p in problems:
            print(f"TRACE SCHEMA: {p}", file=sys.stderr)
        return 1
    print(f"trace schema OK: {n} events in {argv[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
