"""Layer-2 model correctness: forward vs pure-jnp reference, gradient
sanity (finite differences), Adam step behavior, and loss descent on a
planted micro-task through the exact flat AOT calling convention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import gather_agg_ref
from compile.model import (
    ModelDims,
    flat_forward,
    flat_input_specs,
    flat_train_step,
    forward,
    init_params,
    loss_and_metrics,
    param_shapes,
    train_step,
)

jax.config.update("jax_platform_name", "cpu")

DIMS = ModelDims(layers=2, d_in=6, hidden=8, classes=4)


def tiny_batch(rng, dims=DIMS, n=(5, 12, 20), k=3):
    """Hand-rolled 2-layer padded batch with prefix-nesting semantics."""
    feats = rng.standard_normal((n[2], dims.d_in)).astype(np.float32)
    blocks = []
    for l in range(dims.layers):
        n_dst, n_src = n[l], n[l + 1]
        nbr_idx = rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32)
        deg = rng.integers(0, k, size=n_dst)
        nbr_w = np.zeros((n_dst, k), np.float32)
        self_w = np.zeros(n_dst, np.float32)
        for i in range(n_dst):
            inv = 1.0 / (deg[i] + 1.0)
            nbr_w[i, : deg[i]] = inv
            self_w[i] = inv
        self_idx = np.arange(n_dst, dtype=np.int32)  # prefix nesting
        blocks.append(tuple(jnp.asarray(x) for x in (nbr_idx, nbr_w, self_idx, self_w)))
    labels = rng.integers(0, dims.classes, size=n[0]).astype(np.int32)
    mask = np.ones(n[0], np.float32)
    return jnp.asarray(feats), blocks, jnp.asarray(labels), jnp.asarray(mask)


def ref_forward(params, feats, blocks, dims):
    h = feats
    for l in range(dims.layers - 1, -1, -1):
        ni, nw, si, sw = blocks[l]
        agg = gather_agg_ref(h, ni, nw, si, sw)
        d = dims.layers - 1 - l
        h = agg @ params[2 * d] + params[2 * d + 1]
        if l != 0:
            h = jnp.maximum(h, 0.0)
    return h


def test_forward_matches_pure_jnp_reference():
    rng = np.random.default_rng(0)
    feats, blocks, _, _ = tiny_batch(rng)
    params = init_params(DIMS, jax.random.PRNGKey(1))
    got = forward(params, feats, blocks, DIMS)
    want = ref_forward(params, feats, blocks, DIMS)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_loss_masking():
    rng = np.random.default_rng(1)
    feats, blocks, labels, mask = tiny_batch(rng)
    params = init_params(DIMS, jax.random.PRNGKey(2))
    full, _ = loss_and_metrics(params, feats, blocks, labels, mask, DIMS)
    half_mask = mask.at[0].set(0.0)
    half, _ = loss_and_metrics(params, feats, blocks, labels, half_mask, DIMS)
    assert np.isfinite(full) and np.isfinite(half)
    assert not np.allclose(full, half), "masking a row must change the loss"


def test_gradients_match_finite_differences():
    rng = np.random.default_rng(2)
    feats, blocks, labels, mask = tiny_batch(rng)
    params = init_params(DIMS, jax.random.PRNGKey(3))

    def loss_of(ps):
        return loss_and_metrics(ps, feats, blocks, labels, mask, DIMS)[0]

    grads = jax.grad(loss_of)(params)
    eps = 1e-3
    # probe a handful of coordinates of w0
    w0 = params[0]
    for (i, j) in [(0, 0), (2, 3), (5, 1)]:
        bumped = [p for p in params]
        bumped[0] = w0.at[i, j].add(eps)
        up = loss_of(bumped)
        bumped[0] = w0.at[i, j].add(-eps)
        down = loss_of(bumped)
        fd = (up - down) / (2 * eps)
        assert abs(fd - grads[0][i, j]) < 5e-3, (i, j, fd, grads[0][i, j])


def test_train_step_descends_and_learns():
    rng = np.random.default_rng(3)
    feats, blocks, labels, mask = tiny_batch(rng)
    params = init_params(DIMS, jax.random.PRNGKey(4))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.asarray(0.0)
    first = None
    jit_step = jax.jit(
        lambda p, m, v, s: train_step(p, m, v, s, feats, blocks, labels, mask, 0.05, DIMS))
    for it in range(120):
        params, m, v, step, loss, correct = jit_step(params, m, v, step)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))
    assert float(correct) >= 0.8 * float(mask.sum()), "should overfit 5 labels"
    assert float(step) == 120.0


def test_flat_convention_roundtrip():
    """flat_train_step(flat inputs) == train_step(structured inputs)."""
    dims = DIMS
    rng = np.random.default_rng(4)
    feats, blocks, labels, mask = tiny_batch(rng)
    params = init_params(dims, jax.random.PRNGKey(5))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    flat = (
        list(params) + list(m) + list(v) + [jnp.asarray(0.0), feats]
        + [x for blk in blocks for x in blk]
        + [labels, mask, jnp.asarray(0.05)]
    )
    flat_out = flat_train_step(dims, *flat)
    s_params, s_m, s_v, s_t, s_loss, s_correct = train_step(
        params, m, v, jnp.asarray(0.0), feats, blocks, labels, mask, 0.05, dims)
    n = 2 * dims.layers
    for a, b in zip(flat_out[:n], s_params):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    np.testing.assert_allclose(flat_out[3 * n + 1], s_loss, rtol=1e-6)
    np.testing.assert_allclose(flat_out[3 * n + 2], s_correct, rtol=1e-6)


def test_flat_input_specs_counts():
    dims = ModelDims(layers=3, d_in=16, hidden=32, classes=8)
    caps = {"k": 40, "n": [32, 512, 2048, 2048]}
    train_specs = flat_input_specs(dims, caps, "train")
    fwd_specs = flat_input_specs(dims, caps, "forward")
    # train: 3*6 params/m/v + step + feats + 12 block tensors + labels+mask+lr
    assert len(train_specs) == 18 + 1 + 1 + 12 + 3
    assert len(fwd_specs) == 6 + 1 + 12
    assert train_specs[19].shape == (2048, 16)


def test_flat_forward_shapes():
    dims = DIMS
    rng = np.random.default_rng(5)
    feats, blocks, _, _ = tiny_batch(rng)
    params = init_params(dims, jax.random.PRNGKey(6))
    flat = list(params) + [feats] + [x for blk in blocks for x in blk]
    (logits,) = flat_forward(dims, *flat)
    assert logits.shape == (5, dims.classes)


def test_param_shapes_order():
    dims = ModelDims(layers=3, d_in=10, hidden=20, classes=5)
    names = [n for n, _ in param_shapes(dims)]
    assert names == ["w0", "b0", "w1", "b1", "w2", "b2"]
    shapes = dict(param_shapes(dims))
    assert shapes["w0"] == (10, 20)
    assert shapes["w2"] == (20, 5)
