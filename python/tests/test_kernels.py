"""Kernel vs oracle: the core L1 correctness signal.

Hypothesis sweeps shapes/weights for both the single-block (AOT) and
tiled (TPU-schedule) variants of each kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.gather_agg import (
    gather_agg,
    gather_agg_tiled,
    vmem_bytes_per_step as agg_vmem,
)
from compile.kernels.matmul import (
    matmul,
    matmul_tiled,
    mxu_utilization_estimate,
    vmem_bytes_per_step as mm_vmem,
)
from compile.kernels.ref import gather_agg_ref, gcn_layer_ref, matmul_ref

jax.config.update("jax_platform_name", "cpu")


def make_agg_inputs(rng, n_src, n_dst, k, d, pad_fraction=0.3):
    h = rng.standard_normal((n_src, d)).astype(np.float32)
    nbr_idx = rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32)
    nbr_w = rng.random((n_dst, k)).astype(np.float32)
    # zero out a fraction of slots (padding) and whole rows
    mask = rng.random((n_dst, k)) < pad_fraction
    nbr_w[mask] = 0.0
    self_idx = rng.integers(0, n_src, size=(n_dst,)).astype(np.int32)
    self_w = rng.random((n_dst,)).astype(np.float32)
    dead_rows = rng.random(n_dst) < 0.1
    nbr_w[dead_rows, :] = 0.0
    self_w[dead_rows] = 0.0
    return h, nbr_idx, nbr_w, self_idx, self_w


@settings(max_examples=25, deadline=None)
@given(
    n_src=st.integers(4, 200),
    n_dst_raw=st.integers(1, 150),
    k=st.integers(1, 12),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_agg_matches_ref(n_src, n_dst_raw, k, d, seed):
    rng = np.random.default_rng(seed)
    inputs = make_agg_inputs(rng, n_src, n_dst_raw, k, d)
    got = gather_agg(*[jnp.asarray(x) for x in inputs])
    want = gather_agg_ref(*[jnp.asarray(x) for x in inputs])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    tiles=st.integers(1, 4),
    block_rows=st.sampled_from([8, 32, 128]),
    k=st.integers(1, 10),
    d=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_agg_tiled_matches_ref(tiles, block_rows, k, d, seed):
    n_dst = tiles * block_rows
    rng = np.random.default_rng(seed)
    inputs = make_agg_inputs(rng, max(4, n_dst), n_dst, k, d)
    args = [jnp.asarray(x) for x in inputs]
    got = gather_agg_tiled(*args, block_rows=block_rows)
    want = gather_agg_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gather_agg_dtype_bf16():
    rng = np.random.default_rng(0)
    h, ni, nw, si, sw = make_agg_inputs(rng, 64, 32, 5, 16)
    got = gather_agg(
        jnp.asarray(h, jnp.bfloat16), jnp.asarray(ni),
        jnp.asarray(nw, jnp.bfloat16), jnp.asarray(si), jnp.asarray(sw, jnp.bfloat16))
    want = gather_agg_ref(
        jnp.asarray(h, jnp.bfloat16), jnp.asarray(ni),
        jnp.asarray(nw, jnp.bfloat16), jnp.asarray(si), jnp.asarray(sw, jnp.bfloat16))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    k=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_ref(mi, ni, k, seed):
    bm, bn = 32, 32
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((mi * bm, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, ni * bn)), jnp.float32)
    got = matmul_tiled(x, w, block_m=bm, block_n=bn)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_gcn_layer_composition():
    rng = np.random.default_rng(7)
    h, ni, nw, si, sw = make_agg_inputs(rng, 100, 40, 6, 24)
    w = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    args = [jnp.asarray(x) for x in (h, ni, nw, si, sw)]
    got = jnp.maximum(matmul(gather_agg(*args), w) + b, 0.0)
    want = gcn_layer_ref(*args, w, b, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_estimates_sane():
    # the shipped tiled config must fit a TPU core's ~16 MiB VMEM
    assert agg_vmem(128, 40, 768) < 16 * 2**20
    assert mm_vmem(128, 128, 768) < 16 * 2**20
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert 0.0 < mxu_utilization_estimate(100, 128, 64) < 1.0


def test_padding_rows_produce_zero():
    rng = np.random.default_rng(3)
    h, ni, nw, si, sw = make_agg_inputs(rng, 32, 16, 4, 8)
    nw[5, :] = 0.0
    sw[5] = 0.0
    out = np.asarray(gather_agg(*[jnp.asarray(x) for x in (h, ni, nw, si, sw)]))
    np.testing.assert_allclose(out[5], np.zeros(8), atol=0)
