"""Layer-2 model: an L-layer GCN over fixed-fanout padded blocks, with
softmax-CE loss and a fused Adam train step.

The batch layout is the contract with the Rust block builder
(``rust/src/sampling/block.rs``): for layer l (0 = output layer), the
destination rows are a **prefix** of the source rows of layer l+1, so
hidden states chain without re-gathering. All shapes are static (padded
to the caps in ``aot.CONFIGS``); padding rows have zero weights and are
masked out of the loss.

Exported entry points (AOT-lowered by ``aot.py``):

* :func:`train_step` — params/opt-state in, params/opt-state + loss +
  correct-count out. One PJRT execution per minibatch; Python never runs
  at training time.
* :func:`forward` — logits for evaluation batches.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.gather_agg import gather_agg
from .kernels.matmul import matmul


class ModelDims(NamedTuple):
    layers: int
    d_in: int
    hidden: int
    classes: int


def param_shapes(dims: ModelDims):
    """Ordered (name, shape) list — the flat AOT calling convention."""
    shapes = []
    d_prev = dims.d_in
    for l in range(dims.layers):
        d_out = dims.classes if l == dims.layers - 1 else dims.hidden
        shapes.append((f"w{l}", (d_prev, d_out)))
        shapes.append((f"b{l}", (d_out,)))
        d_prev = d_out
    return shapes


def init_params(dims: ModelDims, key):
    """Glorot-ish init, matching what the Rust trainer seeds via AOT'd
    `init` is unnecessary — Rust materializes these shapes itself from
    the manifest and a host RNG; this initializer is for python tests."""
    params = []
    for _name, shape in param_shapes(dims):
        if len(shape) == 2:
            key, sub = jax.random.split(key)
            scale = (2.0 / (shape[0] + shape[1])) ** 0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def forward(params, feats, blocks, dims: ModelDims):
    """GCN forward over an MFG.

    ``blocks`` is a list of L tuples (nbr_idx, nbr_w, self_idx, self_w),
    index l connecting layer l (dst) to layer l+1 (src); layer L's source
    rows are ``feats``. Iterates deepest-first.
    """
    h = feats
    for l in range(dims.layers - 1, -1, -1):
        nbr_idx, nbr_w, self_idx, self_w = blocks[l]
        agg = gather_agg(h, nbr_idx, nbr_w, self_idx, self_w)
        # block index l counts from the *output* (l=0) toward the inputs
        # (l=L-1), params are ordered input-first: depth d = L-1-l.
        d = dims.layers - 1 - l
        w, b = params[2 * d], params[2 * d + 1]
        h = matmul(agg, w) + b
        if l != 0:
            h = jnp.maximum(h, 0.0)
    return h  # [n0, classes] logits


def loss_and_metrics(params, feats, blocks, labels, label_mask, dims: ModelDims):
    """Masked mean cross-entropy + correct-prediction count."""
    logits = forward(params, feats, blocks, dims)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(label_mask.sum(), 1.0)
    loss = -(picked * label_mask).sum() / denom
    correct = ((jnp.argmax(logits, axis=-1) == labels) * label_mask).sum()
    return loss, correct


def train_step(params, m_state, v_state, step, feats, blocks, labels, label_mask,
               lr, dims: ModelDims, beta1=0.9, beta2=0.999, eps=1e-8):
    """One fused SGD step: grads + Adam update.

    Returns (new_params, new_m, new_v, new_step, loss, correct).
    ``step`` is the 1-based Adam timestep (f32 scalar, incremented here).
    """
    def loss_fn(ps):
        return loss_and_metrics(ps, feats, blocks, labels, label_mask, dims)

    (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    t = step + 1.0
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    new_params, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, m_state, v_state):
        m2 = beta1 * m + (1.0 - beta1) * g
        v2 = beta2 * v + (1.0 - beta2) * (g * g)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        new_params.append(p - lr * update)
        new_m.append(m2)
        new_v.append(v2)
    return new_params, new_m, new_v, t, loss, correct


# ---------------------------------------------------------------------------
# Flat calling convention for AOT export.
#
# Input order:  params (2L) | m (2L) | v (2L) | step | feats
#               | per-layer blocks L x (nbr_idx, nbr_w, self_idx, self_w)
#               | labels | label_mask | lr
# Output order: params (2L) | m (2L) | v (2L) | step | loss | correct
# ---------------------------------------------------------------------------

def flat_train_step(dims: ModelDims, *flat):
    n = 2 * dims.layers
    params = list(flat[0:n])
    m_state = list(flat[n:2 * n])
    v_state = list(flat[2 * n:3 * n])
    i = 3 * n
    step = flat[i]; i += 1
    feats = flat[i]; i += 1
    blocks = []
    for _ in range(dims.layers):
        blocks.append(tuple(flat[i:i + 4]))
        i += 4
    labels = flat[i]; i += 1
    label_mask = flat[i]; i += 1
    lr = flat[i]; i += 1
    assert i == len(flat), (i, len(flat))
    new_params, new_m, new_v, t, loss, correct = train_step(
        params, m_state, v_state, step, feats, blocks, labels, label_mask, lr, dims)
    return tuple(new_params + new_m + new_v + [t, loss, correct])


def flat_forward(dims: ModelDims, *flat):
    """Input order: params (2L) | feats | blocks (4L)."""
    n = 2 * dims.layers
    params = list(flat[0:n])
    i = n
    feats = flat[i]; i += 1
    blocks = []
    for _ in range(dims.layers):
        blocks.append(tuple(flat[i:i + 4]))
        i += 4
    assert i == len(flat)
    return (forward(params, feats, blocks, dims),)


def flat_input_specs(dims: ModelDims, caps, mode: str):
    """ShapeDtypeStructs matching the flat calling convention.

    ``caps`` = dict with keys "k" and "n" (list of L+1 layer caps),
    mirroring Rust's ShapeCaps. ``mode`` in {"train", "forward"}.
    """
    f32 = jnp.float32
    i32 = jnp.int32
    k = caps["k"]
    n = caps["n"]
    L = dims.layers
    s = jax.ShapeDtypeStruct
    specs = []
    pshapes = [shape for _n, shape in param_shapes(dims)]
    specs += [s(sh, f32) for sh in pshapes]
    if mode == "train":
        specs += [s(sh, f32) for sh in pshapes]  # m
        specs += [s(sh, f32) for sh in pshapes]  # v
        specs.append(s((), f32))  # step
    specs.append(s((n[L], dims.d_in), f32))  # feats
    for l in range(L):
        specs.append(s((n[l], k), i32))
        specs.append(s((n[l], k), f32))
        specs.append(s((n[l],), i32))
        specs.append(s((n[l],), f32))
    if mode == "train":
        specs.append(s((n[0],), i32))  # labels
        specs.append(s((n[0],), f32))  # label_mask
        specs.append(s((), f32))  # lr
    return specs
