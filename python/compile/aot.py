"""AOT compiler: lower the GCN train/eval graphs to HLO **text** and
emit ``artifacts/manifest.json``.

HLO text (not serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each config freezes the padded tensor caps (negotiated with Rust's
``estimate_caps`` — the caps below dominate the measured maxima printed
by ``cargo test --test integration_sampling caps_report``, rounded up to
multiples of 128 for the tiled-kernel story). The manifest is the single
source of truth for shapes: the Rust trainer reads it and refuses batches
that do not fit.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
(idempotent; `make artifacts` wires the dependency tracking).
"""

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelDims, flat_forward, flat_input_specs, flat_train_step

# ---------------------------------------------------------------------------
# Artifact configs. n = per-layer vertex caps [n0 .. nL]; k = fanout slots.
# Caps dominate the LABOR-0 maxima measured on the synthetic datasets with
# 1.25x margin (caps_report); training always uses LABOR-0 (paper's main
# sampler). Caps may exceed |V| (pure padding).
# ---------------------------------------------------------------------------
CONFIGS = {
    "tiny-b32": {
        "dataset": "tiny",
        "batch": 32,
        "dims": {"layers": 3, "d_in": 16, "hidden": 32, "classes": 8},
        "caps": {"k": 40, "n": [32, 512, 2048, 2048]},
        "lr": 1e-2,
    },
    "conv-b256": {
        "dataset": "conv",
        "batch": 256,
        "dims": {"layers": 3, "d_in": 64, "hidden": 64, "classes": 16},
        "caps": {"k": 40, "n": [256, 3200, 9600, 12032]},
        "lr": 1e-3,
    },
    "conv-b1024": {
        "dataset": "conv",
        "batch": 1024,
        "dims": {"layers": 3, "d_in": 64, "hidden": 64, "classes": 16},
        "caps": {"k": 40, "n": [1024, 8192, 12032, 12032]},
        "lr": 1e-3,
    },
    # Block-diagonal merge of 4 independent b=256 batches (Independent
    # Minibatching with gradient averaging, Figure 9's baseline): caps are
    # ~4x the per-256 maxima because duplicates are NOT deduplicated.
    "conv-indep4": {
        "dataset": "conv",
        "batch": 1024,
        "dims": {"layers": 3, "d_in": 64, "hidden": 64, "classes": 16},
        "caps": {"k": 40, "n": [1024, 10240, 30720, 46080]},
        "lr": 1e-3,
    },
    "papers-b256": {
        "dataset": "papers-s",
        "batch": 256,
        "dims": {"layers": 3, "d_in": 128, "hidden": 64, "classes": 32},
        "caps": {"k": 40, "n": [256, 4224, 26624, 93184]},
        "lr": 1e-3,
    },
    "papers-b1024": {
        "dataset": "papers-s",
        "batch": 1024,
        "dims": {"layers": 3, "d_in": 128, "hidden": 64, "classes": 32},
        "caps": {"k": 40, "n": [1024, 13056, 58368, 136704]},
        "lr": 1e-3,
    },
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(name: str, cfg: dict):
    dims = ModelDims(**cfg["dims"])
    caps = cfg["caps"]
    train_specs = flat_input_specs(dims, caps, "train")
    fwd_specs = flat_input_specs(dims, caps, "forward")

    def train_fn(*flat):
        return flat_train_step(dims, *flat)

    def fwd_fn(*flat):
        return flat_forward(dims, *flat)

    train_hlo = to_hlo_text(jax.jit(train_fn).lower(*train_specs))
    fwd_hlo = to_hlo_text(jax.jit(fwd_fn).lower(*fwd_specs))
    return train_hlo, fwd_hlo, len(train_specs), len(fwd_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single config")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {"format": 1, "configs": {}}
    for name, cfg in CONFIGS.items():
        if args.only and name != args.only:
            continue
        train_hlo, fwd_hlo, n_train_in, n_fwd_in = lower_config(name, cfg)
        train_path = out / f"{name}.train.hlo.txt"
        fwd_path = out / f"{name}.forward.hlo.txt"
        train_path.write_text(train_hlo)
        fwd_path.write_text(fwd_hlo)
        manifest["configs"][name] = {
            **cfg,
            "train_hlo": train_path.name,
            "forward_hlo": fwd_path.name,
            "num_train_inputs": n_train_in,
            "num_forward_inputs": n_fwd_in,
            "train_sha256": hashlib.sha256(train_hlo.encode()).hexdigest()[:16],
            "forward_sha256": hashlib.sha256(fwd_hlo.encode()).hexdigest()[:16],
        }
        print(f"lowered {name}: train {len(train_hlo)//1024} KiB, "
              f"forward {len(fwd_hlo)//1024} KiB, {n_train_in} train inputs")
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
