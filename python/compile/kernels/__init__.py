"""Layer-1 Pallas kernels.

Two kernels implement the GNN hot spots:

* ``gather_agg`` — fixed-fanout neighborhood aggregation (the SpMM the
  paper's feature/forward stages spend their time in), reformulated as
  gather + masked mean so it maps onto TPU-friendly regular access (see
  DESIGN.md section "Hardware-Adaptation").
* ``matmul`` — the per-layer feature transform, tiled for the MXU.

Every kernel has a ``*_ref`` oracle in :mod:`ref` (pure jnp) and both a
single-block variant (used in the AOT artifacts — XLA:CPU fuses it well)
and a tiled variant whose BlockSpecs document the real-TPU schedule;
pytest sweeps both against the oracle.
"""

from . import gather_agg, matmul, ref  # noqa: F401
