"""Fixed-fanout gather + masked-mean aggregation as a Pallas kernel.

The paper's hot spot is sparse neighborhood aggregation (SpMM). GPUs run
it as gather/scatter; TPUs hate scatter, so the Rust block builder emits
a **fixed-fanout dense layout** (every destination has exactly k neighbor
slots, padded slots carry weight 0) and this kernel becomes a regular
gather + weighted reduction — MXU/VPU friendly, no atomics, no sorting.

Two variants:

* :func:`gather_agg` — single-block pallas_call (grid=()). This is what
  the AOT artifacts embed: with ``interpret=True`` it lowers to the same
  HLO ops XLA:CPU fuses into the surrounding graph, keeping the request
  path fast while still exercising the pallas_call machinery.
* :func:`gather_agg_tiled` — destination axis blocked with ``BlockSpec``;
  the source matrix stays unblocked (ANY/HBM in the TPU mapping) and each
  grid step gathers its tile's rows into VMEM. This documents the real
  TPU schedule; DESIGN.md section 8 derives its VMEM footprint:
  ``block_rows*(k+1)*d*4 + block_rows*d*4`` bytes of VMEM per step.

Both are asserted against :func:`ref.gather_agg_ref` by hypothesis sweeps
in ``python/tests/test_kernels.py``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(h_ref, nbr_idx_ref, nbr_w_ref, self_idx_ref, self_w_ref, o_ref):
    """Single-block body: whole arrays are resident."""
    h = h_ref[...]
    nbr_idx = nbr_idx_ref[...]
    nbr_w = nbr_w_ref[...]
    self_idx = self_idx_ref[...]
    self_w = self_w_ref[...]
    gathered = jnp.take(h, nbr_idx, axis=0)  # [n_dst, k, d]
    agg = jnp.einsum("nkd,nk->nd", gathered, nbr_w)
    o_ref[...] = agg + jnp.take(h, self_idx, axis=0) * self_w[:, None]


@jax.custom_vjp
def gather_agg(h, nbr_idx, nbr_w, self_idx, self_w):
    """Aggregate neighbor rows of ``h``: see ``ref.gather_agg_ref``.

    Reverse-mode AD is provided by a custom VJP (`pallas_call` has no
    automatic transpose): ∂h is the transposed aggregation — a
    scatter-add, which on TPU would be the one genuinely scatter-shaped
    op of the pipeline (XLA lowers `.at[].add` to a sorted segment
    reduction there); ∂nbr_w/∂self_w are row-dot-products.
    """
    return _gather_agg_impl(h, nbr_idx, nbr_w, self_idx, self_w)


def _gather_agg_impl(h, nbr_idx, nbr_w, self_idx, self_w, *, interpret=True):
    n_dst = nbr_idx.shape[0]
    d = h.shape[1]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n_dst, d), h.dtype),
        interpret=interpret,
    )(h, nbr_idx, nbr_w, self_idx, self_w)


def _gather_agg_fwd(h, nbr_idx, nbr_w, self_idx, self_w):
    out = _gather_agg_impl(h, nbr_idx, nbr_w, self_idx, self_w)
    return out, (h, nbr_idx, nbr_w, self_idx, self_w)


def _gather_agg_bwd(res, g):
    h, nbr_idx, nbr_w, self_idx, self_w = res
    # ∂h: scatter-add the weighted output cotangents back to source rows.
    dh = jnp.zeros_like(h)
    dh = dh.at[nbr_idx].add(g[:, None, :] * nbr_w[:, :, None])
    dh = dh.at[self_idx].add(g * self_w[:, None])
    # ∂weights: dot of cotangent with the gathered rows.
    dnbr_w = jnp.einsum("nd,nkd->nk", g, jnp.take(h, nbr_idx, axis=0))
    dself_w = jnp.einsum("nd,nd->n", g, jnp.take(h, self_idx, axis=0))
    zero_i = lambda x: np.zeros(x.shape, dtype=jax.dtypes.float0)
    return dh, zero_i(nbr_idx), dnbr_w, zero_i(self_idx), dself_w


gather_agg.defvjp(_gather_agg_fwd, _gather_agg_bwd)


def _tiled_kernel(h_ref, nbr_idx_ref, nbr_w_ref, self_idx_ref, self_w_ref, o_ref):
    """Tiled body: one destination tile per grid step.

    ``h_ref`` is the *whole* source matrix (no index_map ⇒ identity block
    covering the array; on TPU this operand would live in ANY/HBM and the
    gathers below become DMA row fetches into VMEM).
    """
    h = h_ref[...]
    nbr_idx = nbr_idx_ref[...]  # [bm, k]
    nbr_w = nbr_w_ref[...]
    gathered = jnp.take(h, nbr_idx, axis=0)  # [bm, k, d]
    agg = jnp.einsum("nkd,nk->nd", gathered, nbr_w)
    o_ref[...] = agg + jnp.take(h, self_idx_ref[...], axis=0) * self_w_ref[...][:, None]


def gather_agg_tiled(h, nbr_idx, nbr_w, self_idx, self_w, *, block_rows=128, interpret=True):
    """Tiled variant: grid over destination tiles of ``block_rows`` rows.

    Requires ``n_dst % block_rows == 0`` (the Rust cap planner rounds the
    layer caps up to the tile size).
    """
    n_dst, k = nbr_idx.shape
    d = h.shape[1]
    assert n_dst % block_rows == 0, (n_dst, block_rows)
    grid = (n_dst // block_rows,)
    return pl.pallas_call(
        _tiled_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(h.shape, lambda i: (0, 0)),  # whole source matrix
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dst, d), h.dtype),
        interpret=interpret,
    )(h, nbr_idx, nbr_w, self_idx, self_w)


@functools.cache
def vmem_bytes_per_step(block_rows: int, k: int, d: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate of one tiled grid step (DESIGN.md §8):
    gathered tile [bm, k, d] + output tile [bm, d] + index/weight tiles.
    """
    gathered = block_rows * k * d * dtype_bytes
    out = block_rows * d * dtype_bytes
    idx_w = block_rows * k * (4 + dtype_bytes) + block_rows * (4 + dtype_bytes)
    return gathered + out + idx_w
