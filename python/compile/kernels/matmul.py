"""Feature-transform matmul as a Pallas kernel.

The GCN layer's dense half (``agg @ W``). The tiled variant blocks M and
N for the MXU (128x128 systolic array) with the full K panel resident —
K <= 768 for every model config here, so an (bm, K) x (K, bn) step fits
VMEM comfortably (see ``vmem_bytes_per_step``). bf16 inputs with f32
accumulation is the MXU-native mix; the CPU artifacts stay f32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@jax.custom_vjp
def matmul(x, w):
    """Single-block pallas matmul (AOT-artifact variant).

    The custom VJP routes both gradient matmuls back through the same
    pallas kernel — forward *and* backward hot paths are kernel-owned.
    """
    return _matmul_impl(x, w)


def _matmul_impl(x, w, *, interpret=True):
    m, _ = x.shape
    _, n = w.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


def _matmul_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_tiled(x, w, *, block_m=128, block_n=128, interpret=True):
    """MXU-tiled matmul: grid over (M/bm, N/bn), K unblocked.

    Requires M % bm == 0 and N % bn == 0 (cap planner guarantees).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0, (x.shape, w.shape)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


@functools.cache
def vmem_bytes_per_step(block_m: int, block_n: int, k: int, dtype_bytes: int = 4) -> int:
    """VMEM per tiled step: x tile + w tile + out tile."""
    return (block_m * k + k * block_n + block_m * block_n) * dtype_bytes


@functools.cache
def mxu_utilization_estimate(block_m: int, block_n: int, k: int) -> float:
    """Fraction of MXU peak achievable by one (bm, K)x(K, bn) step,
    assuming the 128x128 systolic array: full when all dims >= 128 and
    multiples of 128; fractional otherwise (padding waste).
    """
    eff = 1.0
    for dim in (block_m, block_n, k):
        pad = ((dim + 127) // 128) * 128
        eff *= dim / pad
    return eff
