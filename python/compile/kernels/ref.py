"""Pure-jnp oracles for the Pallas kernels and the full GCN layer.

These are the correctness ground truth: every kernel variant and the
whole AOT'd model are asserted allclose against these in
``python/tests``.
"""

import jax.numpy as jnp


def gather_agg_ref(h, nbr_idx, nbr_w, self_idx, self_w):
    """Fixed-fanout masked-mean aggregation.

    out[i] = sum_j nbr_w[i, j] * h[nbr_idx[i, j]] + self_w[i] * h[self_idx[i]]

    Args:
      h:        [n_src, d] source-row features.
      nbr_idx:  [n_dst, k] int32 indices into h (0 where padded).
      nbr_w:    [n_dst, k] f32 weights (0 where padded).
      self_idx: [n_dst]    int32 self index into h.
      self_w:   [n_dst]    f32 self weight (0 for padding rows).

    Returns:
      [n_dst, d] aggregated features.
    """
    gathered = h[nbr_idx]  # [n_dst, k, d]
    agg = jnp.einsum("nkd,nk->nd", gathered, nbr_w)
    return agg + h[self_idx] * self_w[:, None]


def matmul_ref(x, w):
    """Plain matmul oracle, f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def gcn_layer_ref(h, nbr_idx, nbr_w, self_idx, self_w, weight, bias, relu=True):
    """One full GCN layer: aggregate then transform.

    This is the composition the AOT model runs per layer; used to check
    kernel composition (agg -> matmul -> bias -> relu) end to end.
    """
    agg = gather_agg_ref(h, nbr_idx, nbr_w, self_idx, self_w)
    out = matmul_ref(agg, weight) + bias
    return jnp.maximum(out, 0.0) if relu else out
