//! End-to-end driver (EXPERIMENTS.md §E2E): trains the paper's 3-layer
//! GCN on the `conv` synthetic corpus for several hundred steps through
//! the full stack — pipeline stream → fixed-fanout padded blocks → PJRT
//! execution of the AOT'd JAX+Pallas train step — logging the loss curve
//! and final quality, then repeats a short large-scale run on `papers-s`
//! (222k vertices) to prove the big-graph path composes.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end [-- steps]
//! ```

use coopgnn::pipeline::PipelineBuilder;
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{Kappa, SamplerKind};
use coopgnn::train::Trainer;
use std::io::Write;
use std::path::Path;

fn main() -> coopgnn::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Path::new("artifacts"))?;
    std::fs::create_dir_all("results")?;

    // ---- phase 1: full training run on `conv` -------------------------
    let pipe = PipelineBuilder::new()
        .dataset("conv")
        .sampler(SamplerKind::Labor0)
        .kappa(Kappa::Finite(16))
        .seed(42)
        .build()?;
    let ds = &pipe.ds;
    let mut opts = pipe.trainer_options();
    opts.lr = Some(0.01);
    let mut trainer = Trainer::new(&rt, &manifest, "conv-b256", ds, &opts)?;
    println!(
        "[conv] |V|={} |E|={} params={} batch={} steps={steps}",
        ds.graph.num_vertices(),
        ds.graph.num_edges(),
        trainer.state.num_scalars(),
        trainer.batch()
    );
    let mut csv = std::fs::File::create("results/e2e_loss.csv")?;
    writeln!(csv, "step,loss,batch_acc,val_acc,val_f1,ms_per_step")?;
    let t0 = std::time::Instant::now();
    let mut window = Vec::new();
    for step in 1..=steps {
        let t = std::time::Instant::now();
        let s = trainer.step()?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        window.push(s.loss);
        if step % 25 == 0 {
            let val = trainer.evaluate(&ds.val, 1234)?;
            let avg_loss: f32 = window.iter().sum::<f32>() / window.len() as f32;
            window.clear();
            writeln!(
                csv,
                "{step},{avg_loss:.4},{:.4},{:.4},{:.4},{ms:.1}",
                s.acc, val.accuracy, val.macro_f1
            )?;
            println!(
                "[conv] step {step:>5} loss(avg25) {avg_loss:.4} val-acc {:.4} val-F1 {:.4} ({ms:.0} ms/step)",
                val.accuracy, val.macro_f1
            );
        }
    }
    let test = trainer.evaluate(&ds.test, 1234)?;
    println!(
        "[conv] done in {:.1}s — test acc {:.4}, macro-F1 {:.4} (loss curve: results/e2e_loss.csv)",
        t0.elapsed().as_secs_f64(),
        test.accuracy,
        test.macro_f1
    );

    // ---- phase 2: large-graph smoke (papers-s, 222k vertices) ---------
    let big_steps = (steps / 10).max(5);
    let big_pipe = PipelineBuilder::new()
        .dataset("papers-s")
        .sampler(SamplerKind::Labor0)
        .seed(42)
        .build()?;
    let ds_big = &big_pipe.ds;
    let mut big_opts = big_pipe.trainer_options();
    big_opts.lr = Some(0.003);
    let mut big = Trainer::new(&rt, &manifest, "papers-b256", ds_big, &big_opts)?;
    println!(
        "[papers-s] |V|={} |E|={} params={} steps={big_steps}",
        ds_big.graph.num_vertices(),
        ds_big.graph.num_edges(),
        big.state.num_scalars()
    );
    let t1 = std::time::Instant::now();
    let mut first = None;
    let mut last = 0.0f32;
    for step in 1..=big_steps {
        let s = big.step()?;
        if first.is_none() {
            first = Some(s.loss);
        }
        last = s.loss;
        println!(
            "[papers-s] step {step:>3} loss {:.4} |S^3|={} ({:.0} ms sample, {:.0} ms exec)",
            s.loss, s.input_vertices, s.sample_ms, s.exec_ms
        );
    }
    println!(
        "[papers-s] done in {:.1}s — loss {:.4} -> {last:.4}",
        t1.elapsed().as_secs_f64(),
        first.unwrap_or(0.0)
    );
    Ok(())
}
