//! Quickstart: stand up a pipeline, train the AOT-compiled GCN for a
//! hundred steps, evaluate. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use coopgnn::pipeline::PipelineBuilder;
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{Kappa, SamplerKind};
use coopgnn::train::Trainer;
use std::path::Path;

fn main() -> coopgnn::Result<()> {
    // 1. One builder call: a synthetic power-law dataset (a scaled twin
    //    of the paper's `flickr`; see `coopgnn info` for the registry)
    //    with the paper's LABOR-0 sampler and κ=4 dependent minibatches
    //    (§3.2 — better cache locality, same convergence).
    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .sampler(SamplerKind::Labor0)
        .kappa(Kappa::Finite(4))
        .seed(42)
        .build()?;
    println!(
        "dataset: |V|={} |E|={} d={} classes={} train={}",
        pipe.ds.graph.num_vertices(),
        pipe.ds.graph.num_edges(),
        pipe.ds.feat_dim,
        pipe.ds.num_classes,
        pipe.ds.train.len()
    );

    // 2. The PJRT runtime + the AOT'd train/forward executables.
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Path::new("artifacts"))?;

    // 3. A trainer consuming the pipeline's stream.
    let mut opts = pipe.trainer_options();
    opts.lr = Some(0.02);
    let mut trainer = Trainer::new(&rt, &manifest, "tiny-b32", &pipe.ds, &opts)?;
    println!("model: {} parameters", trainer.state.num_scalars());

    // 4. Train.
    for step in 1..=150 {
        let s = trainer.step()?;
        if step % 25 == 0 {
            println!("step {step:>4}  loss {:.4}  batch-acc {:.3}", s.loss, s.acc);
        }
    }

    // 5. Evaluate.
    let val = trainer.evaluate(&pipe.ds.val, 7)?;
    let test = trainer.evaluate(&pipe.ds.test, 7)?;
    println!("val  acc {:.4}  macro-F1 {:.4}", val.accuracy, val.macro_f1);
    println!("test acc {:.4}  macro-F1 {:.4}", test.accuracy, test.macro_f1);
    Ok(())
}
