//! Dependent minibatching (§3.2) in action: sweep κ on one pipeline and
//! watch the LRU vertex-embedding cache miss rate fall (the Figure 5a
//! effect) without changing any single batch's distribution.
//!
//! ```sh
//! cargo run --release --example dependent_cache -- [dataset] [batch]
//! ```

use coopgnn::coop::engine::Mode;
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::sampling::Kappa;

fn main() -> coopgnn::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds_name = args.first().map(|s| s.as_str()).unwrap_or("flickr-s");
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1024);

    let mut pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .mode(Mode::Independent)
        .num_pes(1)
        .warmup_batches(6)
        .measure_batches(12)
        .seed(11)
        .build()?;
    pipe.cfg.batch_per_pe = batch.min(pipe.ds.train.len());
    pipe.cfg.cache_per_pe = Some(pipe.ds.cache_size);
    println!(
        "{ds_name}: |V|={} |E|/|V|={:.1}, cache={} rows, b={batch}, LABOR-0",
        pipe.ds.graph.num_vertices(),
        pipe.ds.graph.avg_degree(),
        pipe.ds.cache_size
    );
    println!("{:<8} {:>10} {:>12} {:>12}", "kappa", "miss rate", "misses/b", "requested/b");
    let mut baseline = None;
    for kappa in [
        Kappa::Finite(1),
        Kappa::Finite(4),
        Kappa::Finite(16),
        Kappa::Finite(64),
        Kappa::Finite(256),
        Kappa::Infinite,
    ] {
        pipe.cfg.kappa = kappa;
        let r = pipe.engine_report();
        if baseline.is_none() {
            baseline = Some(r.cache_miss_rate);
        }
        println!(
            "{:<8} {:>10.4} {:>12.0} {:>12.0}   ({:.2}x better than κ=1)",
            kappa.label(),
            r.cache_miss_rate,
            r.feat_misses,
            r.feat_requested,
            baseline.unwrap() / r.cache_miss_rate.max(1e-9)
        );
    }
    Ok(())
}
