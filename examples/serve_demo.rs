//! Serving-plane demo: the same online request stream served twice —
//! once with the naive fixed-size batcher, once with the SLO-aware
//! adaptive batcher — over cooperative multi-PE batching. Run with:
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```
//!
//! Everything is virtual time (integer µs, bit-reproducible at the
//! seed): the adaptive batcher spends latency headroom under the p99
//! SLO to grow batches, and the paper's concavity turns that into fewer
//! data-plane bytes per request than the fixed baseline at the same
//! offered load.

use coopgnn::coop::engine::Mode;
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::serve::{BatcherKind, ServeConfig};

fn main() -> coopgnn::Result<()> {
    // One pipeline: the tiny test dataset, 2 cooperative PEs. The
    // serving plane reuses its partition, feature store, row caches,
    // and fabric through `EngineStream::batch_for_seeds`.
    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .num_pes(2)
        .seed(42)
        .build()?;
    println!(
        "serving {}: |V|={}, {} PEs, cooperative batching, 20k req/s against a 30 ms p99 SLO\n",
        pipe.ds.name,
        pipe.ds.graph.num_vertices(),
        pipe.cfg.num_pes
    );

    let mut bytes = Vec::new();
    for batcher in [BatcherKind::Fixed, BatcherKind::Adaptive] {
        let scfg = ServeConfig {
            rate_per_s: 20_000.0,
            slo_us: 30_000,
            batcher,
            duration_batches: 12,
            fixed_batch_per_pe: 16,
            ..Default::default()
        };
        let out = pipe.server(scfg)?.run();
        println!("--- {} batcher ---", batcher.name());
        println!("{}\n", out.report);
        bytes.push(out.report.bytes_per_req());
    }
    let (fixed, adaptive) = (bytes[0], bytes[1]);
    println!(
        "adaptive vs fixed bytes/request: {adaptive:.0} vs {fixed:.0} ({:.2}x less data \
         movement at equal offered load — the paper's concave |S^L(n)| cashing out online)",
        fixed / adaptive.max(1.0)
    );
    Ok(())
}
