//! Cooperative vs Independent minibatching, side by side (a Table 4-style
//! comparison on one system preset). One `PipelineBuilder` call stands up
//! the workload; only `cfg.mode` is toggled between the two reports.
//!
//! ```sh
//! cargo run --release --example coop_vs_indep -- [dataset] [pes] [batch]
//! ```
//! Defaults: tiny, 4 PEs, b=64 (use `papers-s 4 1024` for the paper-scale
//! run; takes ~1 min of sampling).

use coopgnn::coop::engine::Mode;
use coopgnn::costmodel::{estimate, ModelCost, PRESETS};
use coopgnn::pipeline::PipelineBuilder;

fn main() -> coopgnn::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds_name = args.first().map(|s| s.as_str()).unwrap_or("tiny");
    let pes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    let mut pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .num_pes(pes)
        .batch_per_pe(batch)
        .warmup_batches(3)
        .measure_batches(6)
        .seed(7)
        .build()?;
    pipe.cfg.cache_per_pe = Some((pipe.ds.cache_size / pes).max(64));
    let preset = PRESETS.iter().find(|p| p.num_pes == pes).unwrap_or(&PRESETS[0]);
    let model = ModelCost::gcn(pipe.ds.feat_dim, 256);

    println!(
        "{ds_name}: |V|={} |E|={}, {pes} PEs, b={batch}/PE (global {})",
        pipe.ds.graph.num_vertices(),
        pipe.ds.graph.num_edges(),
        batch * pes
    );
    println!(
        "system preset {} (γ={} α={} β={} GB/s)\n",
        preset.name, preset.gamma, preset.alpha, preset.beta
    );

    let mut totals = Vec::new();
    for mode in [Mode::Independent, Mode::Cooperative] {
        pipe.cfg.mode = mode;
        let r = pipe.engine_report();
        let t = estimate(&r, preset, &model, pipe.ds.feat_dim);
        println!("== {} ==", r.mode);
        let s_per_layer: Vec<u64> = r.s.iter().map(|x| *x as u64).collect();
        println!("  per-PE |S^l| (max, avg/batch): {s_per_layer:?}");
        if mode == Mode::Independent {
            println!("  duplication factor @ layer L: {:.2}x", r.dup_factor);
        } else {
            let cross: Vec<u64> = r.cross.iter().map(|x| *x as u64).collect();
            println!("  fabric ids cross/batch: {cross:?}");
        }
        println!("  cache miss rate: {:.3}", r.cache_miss_rate);
        println!(
            "  est. ms/batch: sampling {:.2} + feature(cache) {:.2} + F/B {:.2} = {:.2}",
            t.sampling_ms,
            t.feature_cache_ms,
            t.fb_ms,
            t.total_ms()
        );
        totals.push(t.total_ms());
    }
    println!(
        "\ncooperative improvement: {:.0}% (paper Table 5 shape: grows with P)",
        (totals[0] / totals[1] - 1.0) * 100.0
    );
    Ok(())
}
