//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate.
//!
//! This workspace builds in environments with **no crates.io access**, so
//! the real `anyhow` cannot be resolved. The subset implemented here is
//! exactly what the `coopgnn` crate uses:
//!
//! * [`Error`] — a string-backed, `Send + Sync` error value with `Display`
//!   (the `{e:#}` alternate form prints the same message) and `Debug`.
//! * [`Result`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the standard constructor macros
//!   with inline format captures.
//! * A blanket `From<E: std::error::Error>` so `?` converts `io::Error`
//!   and friends, and a [`Context`] extension trait for `Result`/`Option`.
//!
//! Swapping back to the real crate is a one-line `Cargo.toml` change; no
//! call sites need to change.

use std::fmt;

/// String-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend context, mirroring `anyhow::Error::context` semantics
    /// (outermost context first).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps the blanket conversion below coherent (same trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Include the source chain, innermost last.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/a8f2")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format_and_capture() {
        let x = 7;
        let e = anyhow!("value {x} and {}", 8);
        assert_eq!(e.to_string(), "value 7 and 8");
        let plain = anyhow!("plain");
        assert_eq!(format!("{plain:#}"), "plain");

        fn bails() -> Result<()> {
            bail!("bad {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "bad 1");

        fn ensures(ok: bool) -> Result<u32> {
            ensure!(ok, "must hold, got {ok}");
            Ok(3)
        }
        assert_eq!(ensures(true).unwrap(), 3);
        assert_eq!(ensures(false).unwrap_err().to_string(), "must hold, got false");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}
