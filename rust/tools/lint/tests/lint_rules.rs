//! Fixture-driven rule tests (one firing + one clean case per rule)
//! and the whole-tree gate: the repository itself must lint clean.

use std::path::Path;

use coopgnn_lint::config::{parse_ledger_registry, repo_config, RepoConfig};
use coopgnn_lint::rules;
use coopgnn_lint::{collect_rs_files, Finding, SourceFile};

fn fixture(name: &str, content: &str) -> SourceFile {
    SourceFile::from_str(name, content)
}

// ---- rule 1: wallclock ------------------------------------------------

#[test]
fn wallclock_fixture_fires() {
    let f = fixture(
        "fixtures/wallclock_fire.rs",
        include_str!("fixtures/wallclock_fire.rs"),
    );
    let out = rules::wallclock::check(&f, repo_config().wallclock_allow);
    assert_eq!(out.len(), 1, "{out:?}");
    assert!(out[0].msg.contains("Instant::now"));
}

#[test]
fn wallclock_fixture_clean() {
    let f = fixture(
        "fixtures/wallclock_clean.rs",
        include_str!("fixtures/wallclock_clean.rs"),
    );
    assert!(rules::wallclock::check(&f, repo_config().wallclock_allow).is_empty());
}

// ---- rule 2: ambient-rng ----------------------------------------------

#[test]
fn rng_fixture_fires() {
    let f = fixture("fixtures/rng_fire.rs", include_str!("fixtures/rng_fire.rs"));
    let out = rules::rng::check(&f);
    assert_eq!(out.len(), 2, "thread_rng line and rand::random line: {out:?}");
}

#[test]
fn rng_fixture_clean() {
    let f = fixture("fixtures/rng_clean.rs", include_str!("fixtures/rng_clean.rs"));
    assert!(rules::rng::check(&f).is_empty());
}

// ---- rule 3: unordered ------------------------------------------------

#[test]
fn unordered_fixture_fires() {
    let f = fixture(
        "fixtures/unordered_fire.rs",
        include_str!("fixtures/unordered_fire.rs"),
    );
    let out = rules::unordered::check(&f);
    assert_eq!(out.len(), 1, "{out:?}");
    assert!(out[0].msg.contains("counts"));
}

#[test]
fn unordered_fixture_clean() {
    let f = fixture(
        "fixtures/unordered_clean.rs",
        include_str!("fixtures/unordered_clean.rs"),
    );
    let out = rules::unordered::check(&f);
    assert!(out.is_empty(), "sort idiom + documented waiver must pass: {out:?}");
    assert!(f.annotation_findings().is_empty());
}

// ---- rule 4: ledger ---------------------------------------------------

fn ledger_spec(file: &str) -> coopgnn_lint::config::LedgerSpec {
    coopgnn_lint::config::LedgerSpec {
        strukt: "Traffic".to_string(),
        decl_file: file.to_string(),
        merge_fns: vec![(file.to_string(), "merge".to_string())],
    }
}

#[test]
fn ledger_fixture_fires_on_dropped_field() {
    let f = fixture(
        "fixtures/ledger_fire.rs",
        include_str!("fixtures/ledger_fire.rs"),
    );
    let out = rules::ledger::check(&[f], &[ledger_spec("fixtures/ledger_fire.rs")]);
    assert_eq!(out.len(), 1, "{out:?}");
    assert!(
        out[0].msg.contains("Traffic.inter_bytes"),
        "the field dropped from merge() must be named: {}",
        out[0].msg
    );
}

#[test]
fn ledger_fixture_clean() {
    let f = fixture(
        "fixtures/ledger_clean.rs",
        include_str!("fixtures/ledger_clean.rs"),
    );
    let out = rules::ledger::check(&[f], &[ledger_spec("fixtures/ledger_clean.rs")]);
    assert!(out.is_empty(), "waived + merged fields must pass: {out:?}");
}

// ---- rule 4: ledger registry parsing ----------------------------------

/// End-to-end over a fixture that carries its own `LEDGER_STRUCTS`
/// table: the specs come out of the declaration, and the dropped field
/// the table points at fires.
#[test]
fn registry_fixture_parses_and_fires() {
    let f = fixture(
        "fixtures/registry_fire.rs",
        include_str!("fixtures/registry_fire.rs"),
    );
    let specs = parse_ledger_registry(&f).expect("registry table must parse");
    assert_eq!(specs.len(), 1);
    assert_eq!(specs[0].strukt, "Traffic");
    let out = rules::ledger::check(&[f], &specs);
    assert_eq!(out.len(), 1, "{out:?}");
    assert!(out[0].msg.contains("Traffic.inter_bytes"), "{}", out[0].msg);
}

#[test]
fn registry_fixture_parses_and_is_clean() {
    let f = fixture(
        "fixtures/registry_clean.rs",
        include_str!("fixtures/registry_clean.rs"),
    );
    let specs = parse_ledger_registry(&f).expect("registry table must parse");
    assert_eq!(specs.len(), 1);
    let out = rules::ledger::check(&[f], &specs);
    assert!(out.is_empty(), "{out:?}");
}

/// The real registry must parse and name exactly the structs the
/// runtime registers (the list the lint used to hardcode).
#[test]
fn real_registry_declares_the_tracked_structs() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..");
    let cfg = repo_config();
    let reg = SourceFile::load(&root, cfg.ledger_registry).expect("registry file");
    let specs = parse_ledger_registry(&reg).expect("registry table must parse");
    let names: Vec<&str> = specs.iter().map(|s| s.strukt.as_str()).collect();
    assert_eq!(
        names,
        [
            "PeWork",
            "EngineReport",
            "LoadStats",
            "PeLoad",
            "ParallelStepStats",
            "ParallelRunReport",
            "BatchExecution",
            "BatchRecord",
        ],
        "LEDGER_STRUCTS drifted from the eight tracked counter structs"
    );
    for s in &specs {
        assert!(!s.merge_fns.is_empty(), "{} has no merge fns", s.strukt);
    }
}

// ---- rule 5: flags ----------------------------------------------------

fn flags_cfg(spec: &'static str) -> RepoConfig {
    RepoConfig {
        scan_dirs: &[],
        skip: &[],
        wallclock_allow: &[],
        ledger_registry: "unused-in-flags-tests.rs",
        flags_spec_file: spec,
        flags_scan: match spec {
            "fixtures/flags_fire.rs" => &["fixtures/flags_fire.rs"],
            _ => &["fixtures/flags_clean.rs"],
        },
        flags_builtin: &["help"],
    }
}

#[test]
fn flags_fixture_fires_both_directions() {
    let f = fixture(
        "fixtures/flags_fire.rs",
        include_str!("fixtures/flags_fire.rs"),
    );
    let out = rules::flags::check(&[f], &flags_cfg("fixtures/flags_fire.rs"));
    assert_eq!(out.len(), 2, "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("--qps")), "unregistered literal");
    assert!(out.iter().any(|f| f.msg.contains("--dry-run")), "unconsumed key");
}

#[test]
fn flags_fixture_clean() {
    let f = fixture(
        "fixtures/flags_clean.rs",
        include_str!("fixtures/flags_clean.rs"),
    );
    let out = rules::flags::check(&[f], &flags_cfg("fixtures/flags_clean.rs"));
    assert!(out.is_empty(), "{out:?}");
}

// ---- the tree itself --------------------------------------------------

/// Mirror of the binary's scan: the repository must lint clean. Any
/// new violation fails `cargo test` even before the CI lint job runs.
#[test]
fn tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..");
    let cfg = repo_config();
    let rels = collect_rs_files(&root, cfg.scan_dirs, cfg.skip);
    assert!(
        rels.len() > 20,
        "scan found only {} files — tree layout changed?",
        rels.len()
    );
    let files: Vec<SourceFile> = rels
        .iter()
        .map(|rel| SourceFile::load(&root, rel).expect(rel))
        .collect();

    let mut findings: Vec<Finding> = Vec::new();
    for f in &files {
        findings.extend(f.annotation_findings());
        findings.extend(rules::wallclock::check(f, cfg.wallclock_allow));
        findings.extend(rules::rng::check(f));
        findings.extend(rules::unordered::check(f));
    }
    let reg = files
        .iter()
        .find(|f| f.rel == cfg.ledger_registry)
        .expect("ledger registry file must be in the scanned tree");
    match parse_ledger_registry(reg) {
        Ok(specs) => findings.extend(rules::ledger::check(&files, &specs)),
        Err(e) => findings.push(e),
    }
    findings.extend(rules::flags::check(&files, &cfg));

    let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "tree has lint findings:\n{}", report.join("\n"));
}
