// FIXTURE (ambient-rng, firing): entropy-seeded randomness.
pub fn pick(n: usize) -> usize {
    let mut rng = rand::thread_rng();
    let r: f64 = rand::random();
    (r * n as f64) as usize + rng.gen_range(0..1)
}
