// FIXTURE (wallclock, clean): decisions on the virtual integer-µs clock.
pub fn admit(now_us: u64, batch_open_us: u64) -> bool {
    now_us.saturating_sub(batch_open_us) > 500
}
