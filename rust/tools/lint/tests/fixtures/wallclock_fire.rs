// FIXTURE (wallclock, firing): wall-clock read in a serve decision path.
pub fn admit(batch_open_since: std::time::Instant) -> bool {
    let now = std::time::Instant::now();
    now.duration_since(batch_open_since).as_micros() > 500
}
