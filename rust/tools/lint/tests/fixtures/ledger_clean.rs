// FIXTURE (ledger, clean): every counter reaches the merge point; the
// debug-only field carries a documented waiver.
pub struct Traffic {
    pub intra_bytes: u64,
    pub inter_bytes: u64,
    pub batches: usize,
    // lint:allow(ledger, reason = "debug-only mirror; asserted equal in tests")
    pub check_bytes: u64,
    pub rows: Vec<f32>,
}

pub fn merge(src: &Traffic, dst: &mut Traffic) {
    dst.intra_bytes += src.intra_bytes;
    dst.inter_bytes += src.inter_bytes;
    dst.batches += src.batches;
}
