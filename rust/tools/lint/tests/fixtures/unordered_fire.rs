// FIXTURE (unordered, firing): hash-map iteration feeding a payload.
pub fn pack(counts: HashMap<u32, u32>) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    for (k, v) in &counts {
        out.push((*k, *v));
    }
    out
}
