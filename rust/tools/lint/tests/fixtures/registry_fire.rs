//! FIRING fixture for the registry-driven ledger rule: the file
//! carries its own `LEDGER_STRUCTS` declaration (the shape
//! `parse_ledger_registry` reads), and the struct it registers has a
//! numeric field — `inter_bytes` — that the paired `merge` never
//! references. Parsing must succeed and the check must fire.

pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl {
        strukt: "Traffic",
        decl_file: "fixtures/registry_fire.rs",
        merge_fns: &[("fixtures/registry_fire.rs", "merge")],
    },
];

pub struct Traffic {
    pub bytes: u64,
    pub inter_bytes: u64,
}

pub fn merge(total: &mut Traffic, part: &Traffic) {
    total.bytes += part.bytes;
}
