//! CLEAN fixture for the registry-driven ledger rule: a well-formed
//! `LEDGER_STRUCTS` declaration whose registered struct merges every
//! numeric field. Parsing must succeed and the check must stay silent.

pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl {
        strukt: "Traffic",
        decl_file: "fixtures/registry_clean.rs",
        merge_fns: &[("fixtures/registry_clean.rs", "merge")],
    },
];

pub struct Traffic {
    pub bytes: u64,
    pub inter_bytes: u64,
}

pub fn merge(total: &mut Traffic, part: &Traffic) {
    total.bytes += part.bytes;
    total.inter_bytes += part.inter_bytes;
}
