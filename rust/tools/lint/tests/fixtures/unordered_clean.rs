// FIXTURE (unordered, clean): collect-then-sort plus a documented waiver.
pub fn pack(counts: HashMap<u32, u32>) -> Vec<(u32, u32)> {
    let mut out: Vec<(u32, u32)> = counts.iter().map(|(&k, &v)| (k, v)).collect();
    out.sort_unstable();
    out
}

pub fn total(counts: HashMap<u32, u32>) -> u64 {
    // lint:allow(unordered, reason = "commutative integer sum; order cannot matter")
    counts.values().map(|&v| v as u64).sum()
}
