// FIXTURE (flags, clean): every literal is registered, every key consumed.
fn spec() {
    val("dataset", "tiny");
    switch("dry-run");
}

fn run(args: &Args) {
    let d = args.get("dataset");
    if args.is_set("dry-run") {
        println!("usage: serve --dataset NAME [--dry-run] (--help for more)");
    }
    let _ = d;
}
