// FIXTURE (ledger, firing): `inter_bytes` was added to the counter
// struct but never wired into `merge` — the report column silently
// reads zero. This is the exact regression class the rule targets.
pub struct Traffic {
    pub intra_bytes: u64,
    pub inter_bytes: u64,
    pub batches: usize,
}

pub fn merge(src: &Traffic, dst: &mut Traffic) {
    dst.intra_bytes += src.intra_bytes;
    dst.batches += src.batches;
}
