// FIXTURE (flags, firing): `--qps` is mentioned but never registered;
// `dry-run` is registered but never consumed.
fn spec() {
    val("dataset", "tiny");
    switch("dry-run");
}

fn run(args: &Args) {
    let d = args.get("dataset");
    println!("usage: serve --dataset NAME --qps N");
    let _ = d;
}
