// FIXTURE (ambient-rng, clean): every stream derives from the pipeline seed.
pub fn pick(seed: u64, pe: usize, n: usize) -> usize {
    let mut rng = Pcg64::new(pe_seed(seed, pe));
    (rng.next_u64() % n as u64) as usize
}
