//! Repo-specific configuration: which files may touch the wall clock,
//! which counter structs pair with which merge functions, where the
//! flag registry lives. Everything is a plain `&'static` table so the
//! whole policy is reviewable in one screen.

/// One counter-struct / merge-function pairing for the ledger rule:
/// every numeric field of `strukt` (declared in `decl_file`) must be
/// referenced in at least one of `merge_fns` (`(file, fn-name)`).
#[derive(Clone, Copy, Debug)]
pub struct LedgerSpec {
    pub strukt: &'static str,
    pub decl_file: &'static str,
    pub merge_fns: &'static [(&'static str, &'static str)],
}

/// The policy for the coopgnn tree.
pub struct RepoConfig {
    /// Directories scanned (relative to the repo root).
    pub scan_dirs: &'static [&'static str],
    /// Path prefixes excluded from scanning.
    pub skip: &'static [&'static str],
    /// Files (path suffix/prefix match) allowed to read the wall clock.
    pub wallclock_allow: &'static [&'static str],
    /// Ledger pairings (rule 4).
    pub ledgers: &'static [LedgerSpec],
    /// File holding the `ArgSpec` tables (`val("key", …)` lines).
    pub flags_spec_file: &'static str,
    /// Files/dirs whose `--flag` literals are checked against the spec.
    pub flags_scan: &'static [&'static str],
    /// Flags the parser hardcodes outside any spec table.
    pub flags_builtin: &'static [&'static str],
}

pub fn repo_config() -> RepoConfig {
    RepoConfig {
        scan_dirs: &["rust/src", "rust/tests", "rust/benches", "rust/examples"],
        // vendor/ is third-party; tools/ is this lint (its fixtures
        // contain deliberate violations).
        skip: &["rust/vendor/", "rust/tools/"],
        wallclock_allow: &[
            // timing-only utility modules: Timer / bench_ms live here
            "rust/src/util/stats.rs",
            // phase metrics recorder (wall columns of the reports)
            "rust/src/metrics.rs",
            // host-model kernel profiling (compute_ms breakdowns)
            "rust/src/model/host.rs",
            // outer CLI timers around whole subcommands
            "rust/src/main.rs",
            // benches are timing harnesses by definition
            "rust/benches/",
        ],
        ledgers: &[
            LedgerSpec {
                strukt: "PeWork",
                decl_file: "rust/src/pipeline/stream.rs",
                merge_fns: &[
                    ("rust/src/coop/engine.rs", "reduce"),
                    ("rust/src/train/parallel.rs", "run"),
                    // modeled per-PE service time reads `dim`
                    ("rust/src/serve/executor.rs", "pe_us"),
                ],
            },
            LedgerSpec {
                strukt: "EngineReport",
                decl_file: "rust/src/coop/engine.rs",
                merge_fns: &[("rust/src/coop/engine.rs", "finalize")],
            },
            LedgerSpec {
                strukt: "LoadStats",
                decl_file: "rust/src/coop/feature_loader.rs",
                merge_fns: &[("rust/src/coop/feature_loader.rs", "from_loads")],
            },
            LedgerSpec {
                strukt: "PeLoad",
                decl_file: "rust/src/coop/feature_loader.rs",
                merge_fns: &[("rust/src/coop/feature_loader.rs", "from_loads")],
            },
            LedgerSpec {
                strukt: "ParallelStepStats",
                decl_file: "rust/src/train/parallel.rs",
                merge_fns: &[("rust/src/train/parallel.rs", "run")],
            },
            LedgerSpec {
                strukt: "ParallelRunReport",
                decl_file: "rust/src/train/parallel.rs",
                merge_fns: &[("rust/src/train/parallel.rs", "run")],
            },
            LedgerSpec {
                strukt: "BatchExecution",
                decl_file: "rust/src/serve/executor.rs",
                // the dispatch path is where an executor counter either
                // reaches the ledger or is silently dropped — exactly
                // the class that lost `fabric_inter_bytes` in PR 8
                merge_fns: &[("rust/src/serve/mod.rs", "try_dispatch")],
            },
            LedgerSpec {
                strukt: "BatchRecord",
                decl_file: "rust/src/serve/report.rs",
                merge_fns: &[
                    ("rust/src/serve/report.rs", "record_batch"),
                    ("rust/src/serve/report.rs", "summarize"),
                ],
            },
        ],
        flags_spec_file: "rust/src/main.rs",
        flags_scan: &["rust/src/main.rs", "rust/src/repro/"],
        flags_builtin: &["help"],
    }
}
