//! Repo-specific configuration: which files may touch the wall clock,
//! where the ledger registry and flag registry live. Everything is a
//! plain `&'static` table so the whole policy is reviewable in one
//! screen — except the ledger pairings, which are **parsed out of the
//! tree's own registry declaration**
//! (`rust/src/obs/registry.rs::LEDGER_STRUCTS`) so the lint list and
//! the runtime registry can never drift apart.

use crate::{Finding, SourceFile};

/// One counter-struct / merge-function pairing for the ledger rule:
/// every numeric field of `strukt` (declared in `decl_file`) must be
/// referenced in at least one of `merge_fns` (`(file, fn-name)`).
/// Owned strings because the pairings are parsed from the registry
/// source at lint time, not compiled in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LedgerSpec {
    pub strukt: String,
    pub decl_file: String,
    pub merge_fns: Vec<(String, String)>,
}

/// The policy for the coopgnn tree.
pub struct RepoConfig {
    /// Directories scanned (relative to the repo root).
    pub scan_dirs: &'static [&'static str],
    /// Path prefixes excluded from scanning.
    pub skip: &'static [&'static str],
    /// Files (path suffix/prefix match) allowed to read the wall clock.
    pub wallclock_allow: &'static [&'static str],
    /// File declaring `LEDGER_STRUCTS`, the single source of truth for
    /// the ledger rule's pairings (rule 4); parsed by
    /// [`parse_ledger_registry`].
    pub ledger_registry: &'static str,
    /// File holding the `ArgSpec` tables (`val("key", …)` lines).
    pub flags_spec_file: &'static str,
    /// Files/dirs whose `--flag` literals are checked against the spec.
    pub flags_scan: &'static [&'static str],
    /// Flags the parser hardcodes outside any spec table.
    pub flags_builtin: &'static [&'static str],
}

pub fn repo_config() -> RepoConfig {
    RepoConfig {
        scan_dirs: &["rust/src", "rust/tests", "rust/benches", "rust/examples"],
        // vendor/ is third-party; tools/ is this lint (its fixtures
        // contain deliberate violations).
        skip: &["rust/vendor/", "rust/tools/"],
        wallclock_allow: &[
            // timing-only utility modules: Timer / bench_ms live here
            "rust/src/util/stats.rs",
            // the obs plane's single wall-clock capture shim; every
            // other module takes ms through WallClock values, never
            // Instant directly
            "rust/src/obs/wall.rs",
            // host-model kernel profiling (compute_ms breakdowns)
            "rust/src/model/host.rs",
            // outer CLI timers around whole subcommands
            "rust/src/main.rs",
            // benches are timing harnesses by definition
            "rust/benches/",
        ],
        ledger_registry: "rust/src/obs/registry.rs",
        flags_spec_file: "rust/src/main.rs",
        flags_scan: &["rust/src/main.rs", "rust/src/repro/"],
        flags_builtin: &["help"],
    }
}

/// Parse the `LEDGER_STRUCTS` declaration table out of the registry
/// source: the slice of lines from the line containing
/// `LEDGER_STRUCTS` to the standalone `];` terminator, split on
/// `LedgerDecl`, with quoted string literals read positionally — first
/// the struct name, then its declaring file, then `(file, fn)` pairs.
/// Anything that does not parse (no table, unterminated, an entry with
/// fewer than four strings or an odd merge list) is a loud finding, not
/// a silently shorter lint.
pub fn parse_ledger_registry(file: &SourceFile) -> Result<Vec<LedgerSpec>, Finding> {
    let err = |line: usize, msg: String| Finding {
        rule: crate::rules::ledger::RULE,
        file: file.rel.clone(),
        line,
        msg,
    };
    let Some(start) = file.code.iter().position(|l| l.contains("LEDGER_STRUCTS")) else {
        return Err(err(1, "no `LEDGER_STRUCTS` declaration found in the registry".into()));
    };
    let Some(len) = file.code[start..].iter().position(|l| l.trim() == "];") else {
        return Err(err(
            start + 1,
            "`LEDGER_STRUCTS` has no standalone `];` terminator".into(),
        ));
    };
    let table = file.code[start..start + len].join("\n");
    // Entries open with `LedgerDecl {`; the declaration line's type
    // annotation (`&[LedgerDecl]`) carries no brace and is not one.
    let mut specs = Vec::new();
    for (i, entry) in table.split("LedgerDecl {").skip(1).enumerate() {
        let strings = quoted_strings(entry);
        if strings.len() < 4 || strings.len() % 2 != 0 {
            return Err(err(
                start + 1,
                format!(
                    "`LEDGER_STRUCTS` entry #{} has {} string literals — expected \
                     struct, decl file, then (file, fn) pairs",
                    i + 1,
                    strings.len()
                ),
            ));
        }
        specs.push(LedgerSpec {
            strukt: strings[0].clone(),
            decl_file: strings[1].clone(),
            merge_fns: strings[2..]
                .chunks(2)
                .map(|p| (p[0].clone(), p[1].clone()))
                .collect(),
        });
    }
    if specs.is_empty() {
        return Err(err(start + 1, "`LEDGER_STRUCTS` declares no entries".into()));
    }
    Ok(specs)
}

/// Every `"..."` literal in `text`, in order. The registry table is
/// comment-stripped before it gets here, so naive quote pairing is
/// exact (no escapes appear in path/identifier literals).
fn quoted_strings(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(open) = rest.find('"') {
        let after = &rest[open + 1..];
        let Some(close) = after.find('"') else { break };
        out.push(after[..close].to_string());
        rest = &after[close + 1..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const REGISTRY: &str = r#"
pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl {
        strukt: "PeWork",
        decl_file: "rust/src/pipeline/stream.rs",
        merge_fns: &[
            ("rust/src/coop/engine.rs", "reduce"),
            ("rust/src/train/parallel.rs", "run"),
        ],
    },
    LedgerDecl {
        strukt: "EngineReport",
        decl_file: "rust/src/coop/engine.rs",
        merge_fns: &[("rust/src/coop/engine.rs", "finalize")],
    },
];
"#;

    #[test]
    fn registry_table_parses_positionally() {
        let f = SourceFile::from_str("rust/src/obs/registry.rs", REGISTRY);
        let specs = parse_ledger_registry(&f).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].strukt, "PeWork");
        assert_eq!(specs[0].decl_file, "rust/src/pipeline/stream.rs");
        assert_eq!(
            specs[0].merge_fns,
            vec![
                ("rust/src/coop/engine.rs".to_string(), "reduce".to_string()),
                ("rust/src/train/parallel.rs".to_string(), "run".to_string()),
            ]
        );
        assert_eq!(specs[1].strukt, "EngineReport");
        assert_eq!(specs[1].merge_fns.len(), 1);
    }

    #[test]
    fn missing_table_is_a_loud_error() {
        let f = SourceFile::from_str("rust/src/obs/registry.rs", "pub struct Registry {}\n");
        let e = parse_ledger_registry(&f).unwrap_err();
        assert!(e.msg.contains("no `LEDGER_STRUCTS`"));
    }

    #[test]
    fn odd_string_count_is_a_loud_error() {
        let broken = r#"
pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl { strukt: "PeWork", decl_file: "a.rs", merge_fns: &[("b.rs",)] },
];
"#;
        let f = SourceFile::from_str("rust/src/obs/registry.rs", broken);
        let e = parse_ledger_registry(&f).unwrap_err();
        assert!(e.msg.contains("string literals"));
    }

    #[test]
    fn unterminated_table_is_a_loud_error() {
        let f = SourceFile::from_str(
            "rust/src/obs/registry.rs",
            "pub const LEDGER_STRUCTS: &[LedgerDecl] = &[\n    LedgerDecl { }\n",
        );
        let e = parse_ledger_registry(&f).unwrap_err();
        assert!(e.msg.contains("terminator"));
    }

    #[test]
    fn comments_inside_the_table_are_ignored() {
        let commented = r#"
pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl {
        strukt: "PeWork", // the per-PE "work" ledger
        decl_file: "rust/src/pipeline/stream.rs",
        merge_fns: &[("rust/src/coop/engine.rs", "reduce")],
    },
];
"#;
        let f = SourceFile::from_str("rust/src/obs/registry.rs", commented);
        let specs = parse_ledger_registry(&f).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].merge_fns.len(), 1, "comment text must not add strings");
    }
}
