//! # coopgnn-lint — the invariant lint plane
//!
//! Every bit-identity claim in this repository (serial == threaded
//! engine trajectories, prefetch on/off equality, replication r ∈ {1,2,4}
//! at `to_bits`-equal losses, the serve plane's reproducible virtual-time
//! ledgers) rests on hand-maintained source invariants. With no Rust
//! toolchain in the dev container, these rules are the only scalable
//! defense against the regressions that silently void those claims:
//!
//! 1. **wallclock** — `Instant::now` / `SystemTime` may appear only in
//!    allowlisted timing-only modules; never in `serve/`, `sampling/`,
//!    or `coop/` decision paths (the serve plane runs on a virtual
//!    integer-µs clock precisely so its ledgers replay bit-exactly).
//! 2. **ambient-rng** — `thread_rng` / `rand::random` / entropy seeding
//!    are forbidden everywhere; all randomness must derive from the
//!    pipeline seed streams (`pe_seed`, `Pcg64`, counter hashes).
//! 3. **unordered** — iterating a `HashMap` / `HashSet` is forbidden
//!    unless the site sorts immediately afterwards or carries a
//!    `// lint:allow(unordered, reason = "...")` annotation; iteration
//!    order would otherwise feed fabric payloads and counters.
//! 4. **ledger** — every numeric field of the registered counter
//!    structs must be referenced in its paired merge/accumulate
//!    function, catching "added a counter, forgot to aggregate". The
//!    struct list is parsed from the tree's own registry declaration
//!    (`rust/src/obs/registry.rs::LEDGER_STRUCTS`), so the runtime
//!    registry and this rule share one source of truth.
//! 5. **flags** — every `--flag` string literal in `main.rs` / `repro/`
//!    must name a key registered in the strict `ArgSpec` tables, and
//!    every registered key must be consumed outside its spec line.
//!
//! The binary (`cargo run -p coopgnn-lint`) prints findings as
//! `file:line: [rule] message` and exits nonzero on any finding.
//!
//! ## Allow annotations
//!
//! A finding is suppressed by `// lint:allow(<rule>, reason = "...")`
//! on the same line or the line directly above. The reason is
//! mandatory: an allow without one is itself reported (the annotation
//! is a documented waiver, not an off switch).

use std::path::{Path, PathBuf};

pub mod config;
pub mod rules;

/// The rule names an allow annotation may reference.
pub const RULE_NAMES: &[&str] =
    &["wallclock", "ambient-rng", "unordered", "ledger", "flags"];

/// One lint violation, reported as `file:line: [rule] msg`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One `lint:allow` annotation, resolved to the lines it covers.
#[derive(Clone, Debug)]
struct Allow {
    rule: String,
    /// 1-indexed line the annotation sits on; it covers this line and
    /// the next (so a standalone comment shields the statement below).
    line: usize,
    has_reason: bool,
}

/// A source file loaded for linting: raw lines, comment-stripped lines,
/// and its allow annotations.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Path relative to the repository root, `/`-separated (stable in
    /// findings and config matching across platforms).
    pub rel: String,
    pub lines: Vec<String>,
    /// `lines` with `//` comments removed (string-literal aware).
    pub code: Vec<String>,
    allows: Vec<Allow>,
}

impl SourceFile {
    pub fn from_str(rel: &str, content: &str) -> SourceFile {
        let lines: Vec<String> = content.lines().map(|l| l.to_string()).collect();
        let code: Vec<String> = lines.iter().map(|l| strip_comment(l)).collect();
        let allows = parse_allows(&lines);
        SourceFile { rel: rel.to_string(), lines, code, allows }
    }

    pub fn load(root: &Path, rel: &str) -> std::io::Result<SourceFile> {
        let content = std::fs::read_to_string(root.join(rel))?;
        Ok(SourceFile::from_str(rel, &content))
    }

    /// Is `rule` waived at 1-indexed `line`?
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows
            .iter()
            .any(|a| a.rule == rule && a.has_reason && (a.line == line || a.line + 1 == line))
    }

    /// Malformed annotations are findings themselves: unknown rule
    /// names and missing reasons would otherwise rot silently.
    pub fn annotation_findings(&self) -> Vec<Finding> {
        let mut out = Vec::new();
        for a in &self.allows {
            if !RULE_NAMES.contains(&a.rule.as_str()) {
                out.push(Finding {
                    rule: "allow-syntax",
                    file: self.rel.clone(),
                    line: a.line,
                    msg: format!(
                        "lint:allow names unknown rule `{}` (known: {})",
                        a.rule,
                        RULE_NAMES.join(", ")
                    ),
                });
            }
            if !a.has_reason {
                out.push(Finding {
                    rule: "allow-syntax",
                    file: self.rel.clone(),
                    line: a.line,
                    msg: format!(
                        "lint:allow({}) without a reason — write \
                         lint:allow({}, reason = \"...\")",
                        a.rule, a.rule
                    ),
                });
            }
        }
        out
    }
}

/// Strip a `//` comment from one line, ignoring `//` inside string
/// literals. Good enough for line-level pattern rules; raw strings and
/// block comments are rare in this tree and handled conservatively
/// (a `/*` leaves the rest of the line intact, which only errs toward
/// reporting).
pub fn strip_comment(line: &str) -> String {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut in_char = false;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if in_str {
            if b == b'\\' {
                i += 1; // skip the escaped byte
            } else if b == b'"' {
                in_str = false;
            }
        } else if in_char {
            if b == b'\\' {
                i += 1;
            } else if b == b'\'' {
                in_char = false;
            }
        } else if b == b'"' {
            in_str = true;
        } else if b == b'\'' {
            // `'x'` or `'\n'` is a char literal; `'a` (lifetime) is not.
            let is_char_lit = (i + 2 < bytes.len() && bytes[i + 2] == b'\'')
                || (i + 1 < bytes.len() && bytes[i + 1] == b'\\');
            if is_char_lit {
                in_char = true;
            }
        } else if b == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
            return line[..i].to_string();
        }
        i += 1;
    }
    line.to_string()
}

/// Parse every `lint:allow(rule[, reason = "..."])` in the file.
fn parse_allows(lines: &[String]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let mut rest = line.as_str();
        while let Some(pos) = rest.find("lint:allow(") {
            let after = &rest[pos + "lint:allow(".len()..];
            let Some(close) = after.find(')') else { break };
            let inner = &after[..close];
            let (rule, has_reason) = match inner.split_once(',') {
                Some((r, tail)) => {
                    let tail = tail.trim();
                    let reason_ok = tail.strip_prefix("reason")
                        .map(|t| t.trim_start().starts_with('='))
                        .unwrap_or(false)
                        && tail.contains('"');
                    (r.trim().to_string(), reason_ok)
                }
                None => (inner.trim().to_string(), false),
            };
            out.push(Allow { rule, line: idx + 1, has_reason });
            rest = &after[close..];
        }
    }
    out
}

/// True if `text[pos..]` starts an occurrence of `needle` that is not
/// embedded in a larger identifier (word-boundary on both sides).
pub fn word_at(text: &str, pos: usize, needle: &str) -> bool {
    let bytes = text.as_bytes();
    if pos + needle.len() > bytes.len() || &text[pos..pos + needle.len()] != needle {
        return false;
    }
    let before_ok = pos == 0 || !is_ident(bytes[pos - 1]);
    let after = pos + needle.len();
    let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
    before_ok && after_ok
}

/// Does `text` contain `needle` as a whole word (not inside a larger
/// identifier)?
pub fn contains_word(text: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(off) = text[start..].find(needle) {
        let pos = start + off;
        if word_at(text, pos, needle) {
            return true;
        }
        start = pos + 1;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Extract a brace-matched item body starting at the first line for
/// which `start` returns true. Returns (1-indexed start line, body
/// lines) or None.
pub fn brace_matched<'a, F>(lines: &'a [String], start: F) -> Option<(usize, Vec<&'a str>)>
where
    F: Fn(&str) -> bool,
{
    let mut depth: i64 = 0;
    let mut on = false;
    let mut opened = false;
    let mut first = 0;
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if !on && start(line) {
            on = true;
            first = idx + 1;
        }
        if on {
            out.push(line.as_str());
            for b in line.bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                return Some((first, out));
            }
        }
    }
    if on {
        Some((first, out))
    } else {
        None
    }
}

/// Recursively collect `.rs` files under `root/<sub>` as root-relative
/// `/`-separated paths, sorted for deterministic reports. `skip`
/// entries are path prefixes (relative, `/`-separated).
pub fn collect_rs_files(root: &Path, subs: &[&str], skip: &[&str]) -> Vec<String> {
    let mut out = Vec::new();
    for sub in subs {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(root, &dir, skip, &mut out);
        }
    }
    out.sort();
    out
}

fn walk(root: &Path, dir: &Path, skip: &[&str], out: &mut Vec<String>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        let rel = match p.strip_prefix(root) {
            Ok(r) => r.to_string_lossy().replace('\\', "/"),
            Err(_) => continue,
        };
        if skip.iter().any(|s| rel.starts_with(s)) {
            continue;
        }
        if p.is_dir() {
            walk(root, &p, skip, out);
        } else if rel.ends_with(".rs") {
            out.push(rel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_comment_respects_strings() {
        assert_eq!(strip_comment("let a = 1; // note"), "let a = 1; ");
        assert_eq!(strip_comment(r#"let s = "no // comment";"#), r#"let s = "no // comment";"#);
        assert_eq!(strip_comment("x.iter() // lint sees code only"), "x.iter() ");
    }

    #[test]
    fn allow_parsing_and_scope() {
        let f = SourceFile::from_str(
            "t.rs",
            "// lint:allow(unordered, reason = \"canonical already\")\n\
             for k in m.keys() {}\n\
             for k in m.keys() {}\n",
        );
        assert!(f.allowed("unordered", 1));
        assert!(f.allowed("unordered", 2), "allow covers the next line");
        assert!(!f.allowed("unordered", 3), "allow does not leak further");
        assert!(f.annotation_findings().is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let f = SourceFile::from_str("t.rs", "// lint:allow(unordered)\nlet x = 1;\n");
        let fs = f.annotation_findings();
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("without a reason"));
        assert!(!f.allowed("unordered", 2), "reasonless allow must not suppress");
    }

    #[test]
    fn unknown_rule_is_a_finding() {
        let f = SourceFile::from_str("t.rs", "// lint:allow(speed, reason = \"x\")\n");
        let fs = f.annotation_findings();
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("unknown rule"));
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("let dim = 4;", "dim"));
        assert!(!contains_word("let dims = 4;", "dim"));
        assert!(!contains_word("radim", "dim"));
        assert!(contains_word("w.dim as usize", "dim"));
    }

    #[test]
    fn brace_matching_extracts_whole_fn() {
        let src: Vec<String> = "fn f() {\n  if x {\n    y();\n  }\n}\nfn g() {}\n"
            .lines()
            .map(|s| s.to_string())
            .collect();
        let (start, body) = brace_matched(&src, |l| l.contains("fn f")).unwrap();
        assert_eq!(start, 1);
        assert_eq!(body.len(), 5, "inner closing brace must not end the body");
    }
}
