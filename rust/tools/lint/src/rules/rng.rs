//! Rule 2 — **ambient-rng**: all randomness must derive from the
//! pipeline seed (`pe_seed` splits, `Pcg64` streams, LABOR counter
//! hashes). Entropy-seeded or thread-local RNGs make every trajectory
//! claim unreproducible, so they are forbidden everywhere — there is
//! no allowlist, only the (reason-carrying) annotation escape.

use crate::{contains_word, Finding, SourceFile};

const PATTERNS: &[&str] = &["thread_rng", "from_entropy", "OsRng", "getrandom"];
pub const RULE: &str = "ambient-rng";

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, code) in file.code.iter().enumerate() {
        let line = idx + 1;
        let mut hit = PATTERNS.iter().find(|p| contains_word(code, p)).copied();
        // `rand::random` has no single-identifier form
        if hit.is_none() && code.contains("rand::random") {
            hit = Some("rand::random");
        }
        if let Some(p) = hit {
            if !file.allowed(RULE, line) {
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line,
                    msg: format!(
                        "`{p}` is ambient randomness — derive every stream from \
                         the pipeline seed instead"
                    ),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_on_thread_rng_and_entropy() {
        let f = SourceFile::from_str(
            "rust/src/x.rs",
            "let mut r = thread_rng();\nlet s = StdRng::from_entropy();\n",
        );
        assert_eq!(check(&f).len(), 2);
    }

    #[test]
    fn seeded_streams_are_clean() {
        let f = SourceFile::from_str(
            "rust/src/x.rs",
            "let mut r = Pcg64::new(seed);\nlet s = pe_seed(seed, pe);\n",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn identifier_containing_pattern_is_clean() {
        // `my_thread_rng_doc` is not a call to thread_rng
        let f = SourceFile::from_str("rust/src/x.rs", "let my_thread_rng_doc = 1;\n");
        assert!(check(&f).is_empty());
    }
}
