//! Rule 4 — **ledger**: counter conservation. Every byte/count ledger
//! in this tree flows through a merge point — `PeWork` through the
//! engine's `reduce`, `LoadStats`/`PeLoad` through
//! `FeatureTraffic::from_loads`, the serve executor's `BatchExecution`
//! through the server's dispatch path into `BatchRecord`, and so on.
//! A field added to the struct but not to its merge function silently
//! zeros a report column (PR 8's `inter_*` split made this the single
//! most likely regression). The rule parses the struct's numeric
//! fields and demands each is referenced in at least one paired merge
//! function; waive a deliberate non-ledger field with
//! `// lint:allow(ledger, reason = "...")` on its declaration.
//!
//! The struct/merge pairings are not hardcoded here: they are parsed
//! from the tree's own registry declaration,
//! `rust/src/obs/registry.rs::LEDGER_STRUCTS`, by
//! [`crate::config::parse_ledger_registry`] — one list serves both the
//! runtime registry and this rule.

use crate::config::LedgerSpec;
use crate::{brace_matched, contains_word, Finding, SourceFile};

pub const RULE: &str = "ledger";

/// Scalar/vector counter types; `f32` scalars are model stats, still
/// counters. Payload vectors (`Vec<f32>` rows, `Vec<u8>` wire bytes)
/// and `Option<..>` attachments are not ledger columns.
const NUMERIC: &[&str] = &["u16", "u32", "u64", "usize", "i32", "i64", "f32", "f64"];
const NUMERIC_VEC: &[&str] = &["Vec<u32>", "Vec<u64>", "Vec<usize>", "Vec<f64>"];

pub fn check(files: &[SourceFile], specs: &[LedgerSpec]) -> Vec<Finding> {
    let mut out = Vec::new();
    for spec in specs {
        let Some(decl) = files.iter().find(|f| f.rel == spec.decl_file) else {
            out.push(missing(spec, format!("declaration file `{}` not found", spec.decl_file)));
            continue;
        };
        let Some((struct_line, fields)) = struct_fields(decl, spec.strukt) else {
            out.push(missing(
                spec,
                format!("struct `{}` not found in `{}`", spec.strukt, spec.decl_file),
            ));
            continue;
        };
        // union of all paired merge-fn bodies
        let mut merged = String::new();
        for (file, fname) in &spec.merge_fns {
            let Some(f) = files.iter().find(|f| &f.rel == file) else {
                out.push(missing(spec, format!("merge file `{file}` not found")));
                continue;
            };
            match fn_body(f, fname) {
                Some(body) => {
                    merged.push_str(&body);
                    merged.push('\n');
                }
                None => out.push(missing(
                    spec,
                    format!("merge fn `{fname}` not found in `{file}`"),
                )),
            }
        }
        if merged.is_empty() {
            continue;
        }
        for (line, name) in fields {
            if contains_word(&merged, &name) || decl.allowed(RULE, line) {
                continue;
            }
            let fns: Vec<String> =
                spec.merge_fns.iter().map(|(f, n)| format!("{n} ({f})")).collect();
            out.push(Finding {
                rule: RULE,
                file: spec.decl_file.clone(),
                line,
                msg: format!(
                    "`{}.{}` is never referenced in its merge path [{}] — \
                     aggregate it or annotate the field with a reason",
                    spec.strukt,
                    name,
                    fns.join(", ")
                ),
            });
        }
        let _ = struct_line;
    }
    out
}

fn missing(spec: &LedgerSpec, msg: String) -> Finding {
    Finding { rule: RULE, file: spec.decl_file.clone(), line: 1, msg }
}

/// (1-indexed decl line, field name) for every numeric field of
/// `strukt` in `decl`.
fn struct_fields(decl: &SourceFile, strukt: &str) -> Option<(usize, Vec<(usize, String)>)> {
    let header = format!("struct {strukt}");
    let (start, body) =
        brace_matched(&decl.code, |l| l.contains(&header) && crate::contains_word(l, strukt))?;
    let mut fields = Vec::new();
    for (off, line) in body.iter().enumerate() {
        let trimmed = line.trim_start();
        let decl_part = trimmed.strip_prefix("pub ").unwrap_or(trimmed);
        let Some((name, ty)) = decl_part.split_once(':') else { continue };
        let name = name.trim();
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            continue;
        }
        let ty = ty.trim().trim_end_matches(',');
        let numeric = NUMERIC.iter().any(|n| ty == *n)
            || NUMERIC_VEC.iter().any(|n| ty.starts_with(n));
        if numeric {
            fields.push((start + off, name.to_string()));
        }
    }
    Some((start, fields))
}

/// Brace-matched body of `fn name(` in `file` (first match wins; the
/// config names are unique per file by construction).
fn fn_body(file: &SourceFile, fname: &str) -> Option<String> {
    let needle = format!("fn {fname}");
    let (_, body) = brace_matched(&file.code, |l| {
        if let Some(pos) = l.find(&needle) {
            // reject `fn summarize_reduces...` when looking for `summarize`
            let after = pos + needle.len();
            l.as_bytes()
                .get(after)
                .map(|b| !(b.is_ascii_alphanumeric() || *b == b'_'))
                .unwrap_or(true)
        } else {
            false
        }
    })?;
    Some(body.join("\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> LedgerSpec {
        LedgerSpec {
            strukt: "Stats".into(),
            decl_file: "src/stats.rs".into(),
            merge_fns: vec![("src/stats.rs".into(), "merge".into())],
        }
    }

    #[test]
    fn dropped_field_fires() {
        let f = SourceFile::from_str(
            "src/stats.rs",
            "pub struct Stats {\n    pub a: u64,\n    pub b: u64,\n}\n\
             fn merge(s: &Stats, t: &mut Stats) {\n    t.a += s.a;\n}\n",
        );
        let out = check(&[f], &[spec()]);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("Stats.b"));
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn fully_merged_struct_is_clean() {
        let f = SourceFile::from_str(
            "src/stats.rs",
            "pub struct Stats {\n    pub a: u64,\n    pub b: f64,\n    pub rows: Vec<f32>,\n}\n\
             fn merge(s: &Stats, t: &mut Stats) {\n    t.a += s.a;\n    t.b += s.b;\n}\n",
        );
        assert!(check(&[f], &[spec()]).is_empty(), "payload Vec<f32> is not a counter");
    }

    #[test]
    fn annotated_field_is_waived() {
        let f = SourceFile::from_str(
            "src/stats.rs",
            "pub struct Stats {\n    pub a: u64,\n\
             \x20   // lint:allow(ledger, reason = \"debug-only; asserted in tests\")\n\
             \x20   pub b: u64,\n}\n\
             fn merge(s: &Stats, t: &mut Stats) {\n    t.a += s.a;\n}\n",
        );
        assert!(check(&[f], &[spec()]).is_empty());
    }

    #[test]
    fn missing_merge_fn_is_reported() {
        let f = SourceFile::from_str("src/stats.rs", "pub struct Stats {\n    pub a: u64,\n}\n");
        let out = check(&[f], &[spec()]);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("not found"));
    }
}
