//! Rule 3 — **unordered**: `HashMap` / `HashSet` iteration order is
//! arbitrary (and, under `RandomState`, differs between *runs*). Any
//! iteration over a hash collection that feeds a fabric payload, a
//! counter, or a report column breaks the serial==threaded /
//! prefetch / replication bit-identity suites. Lookups (`get`,
//! `contains`, `insert`, `entry`, `len`) are fine; iteration is
//! flagged unless the site sorts the collected result within the next
//! few lines or carries `// lint:allow(unordered, reason = "...")`.
//! Order-sensitive maps belong in `BTreeMap` / sorted vectors.

use crate::{contains_word, Finding, SourceFile};

pub const RULE: &str = "unordered";

/// How many lines after an iteration a `.sort` still counts as
/// "immediately sorted" (the collect-then-sort idiom).
const SORT_LOOKAHEAD: usize = 3;

const ITER_CALLS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
];

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let tracked = tracked_names(file);
    if tracked.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (idx, code) in file.code.iter().enumerate() {
        let line = idx + 1;
        for name in &tracked {
            if !contains_word(code, name) {
                continue;
            }
            if !(iterates(code, name) || for_loop_over(code, name)) {
                continue;
            }
            if sorted_nearby(file, idx) || file.allowed(RULE, line) {
                continue;
            }
            out.push(Finding {
                rule: RULE,
                file: file.rel.clone(),
                line,
                msg: format!(
                    "iteration over hash collection `{name}` — order is \
                     nondeterministic; use BTreeMap/BTreeSet, sort the \
                     collected result, or annotate with a reason"
                ),
            });
            break;
        }
    }
    out
}

/// Variable / field names bound to a `HashMap` or `HashSet` anywhere in
/// the file (declaration, field, or turbofish collect on the same line).
fn tracked_names(file: &SourceFile) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for code in &file.code {
        if !(code.contains("HashMap") || code.contains("HashSet")) {
            continue;
        }
        // `let [mut] name ...` — local binding
        if let Some(pos) = code.find("let ") {
            let rest = code[pos + 4..].trim_start().trim_start_matches("mut ");
            if let Some(name) = leading_ident(rest) {
                push_unique(&mut out, name);
                continue;
            }
        }
        // `name: HashMap<...>` — struct field or typed parameter
        if let Some(colon) = code.find(':') {
            let head = code[..colon].trim_end();
            if let Some(name) = trailing_ident(head) {
                push_unique(&mut out, name);
            }
        }
    }
    out
}

fn push_unique(out: &mut Vec<String>, name: String) {
    if !name.is_empty() && !out.contains(&name) {
        out.push(name);
    }
}

fn leading_ident(s: &str) -> Option<String> {
    let end = s
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .unwrap_or(s.len());
    if end == 0 {
        None
    } else {
        Some(s[..end].to_string())
    }
}

fn trailing_ident(s: &str) -> Option<String> {
    let start = s
        .rfind(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .map(|p| p + 1)
        .unwrap_or(0);
    if start >= s.len() {
        None
    } else {
        Some(s[start..].to_string())
    }
}

/// `name.iter()` / `self.name.keys()` / `name.drain(..)` on this line?
/// A dotted access through another object (`w.name.iter()`) is a
/// *different* variable that happens to share the tracked name — only
/// bare and `self.`-qualified uses count.
fn iterates(code: &str, name: &str) -> bool {
    let mut start = 0;
    while let Some(off) = code[start..].find(name) {
        let pos = start + off;
        if crate::word_at(code, pos, name) && !foreign_field(code, pos) {
            let after = &code[pos + name.len()..];
            if ITER_CALLS.iter().any(|c| after.starts_with(c)) {
                return true;
            }
        }
        start = pos + 1;
    }
    false
}

/// Is the occurrence at `pos` a field access on something other than
/// `self` (preceded by `.` but not by `self.`)?
fn foreign_field(code: &str, pos: usize) -> bool {
    pos > 0
        && code.as_bytes()[pos - 1] == b'.'
        && !(pos >= 5 && &code[pos - 5..pos] == "self.")
}

/// `for x in &name` / `for (k, v) in name` / `for x in &mut name`?
fn for_loop_over(code: &str, name: &str) -> bool {
    let Some(for_pos) = code.find("for ") else { return false };
    let Some(in_off) = code[for_pos..].find(" in ") else { return false };
    let expr = code[for_pos + in_off + 4..].trim_start();
    let expr = expr
        .trim_start_matches('&')
        .trim_start_matches("mut ")
        .trim_start_matches("self.");
    // the loop expression must BE the collection (not `name.iter()...`,
    // which `iterates` already covers, and not `vec_of(name)`)
    match expr.split(|c: char| !(c.is_ascii_alphanumeric() || c == '_')).next() {
        Some(first) => {
            first == name && {
                let rest = &expr[first.len()..];
                rest.trim_start().starts_with('{') || rest.trim_end().is_empty() || rest.starts_with(' ')
            }
        }
        None => false,
    }
}

/// Is there a `.sort` within the lookahead window after line `idx`
/// (0-indexed)? Covers `visits.iter().map(..).collect()` followed by
/// `ranked.sort_unstable..` — the canonical-ordering idiom.
fn sorted_nearby(file: &SourceFile, idx: usize) -> bool {
    file.code
        .iter()
        .skip(idx)
        .take(SORT_LOOKAHEAD + 1)
        .any(|l| l.contains(".sort"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_direct_iteration() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut m: HashMap<u32, u32> = HashMap::new();\n\
             for (k, v) in &m {\n    use_it(k, v);\n}\n",
        );
        assert_eq!(check(&f).len(), 1);
        assert_eq!(check(&f)[0].line, 2);
    }

    #[test]
    fn flags_method_iteration() {
        let f = SourceFile::from_str(
            "t.rs",
            "let seen = std::collections::HashSet::with_capacity(8);\n\
             let total: usize = seen.iter().count();\n",
        );
        assert_eq!(check(&f).len(), 1);
    }

    #[test]
    fn lookups_are_clean() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut m: HashMap<u32, u32> = HashMap::new();\n\
             m.insert(1, 2);\n\
             let v = m.get(&1);\n\
             if m.contains_key(&1) { ok(); }\n\
             let n = m.len();\n\
             let e = m.entry(3).or_insert(0);\n",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn immediate_sort_is_clean() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut visits: HashMap<u32, u32> = HashMap::new();\n\
             let mut ranked: Vec<(u32, u32)> =\n\
                 visits.iter().map(|(&v, &c)| (c, v)).collect();\n\
             ranked.sort_unstable_by(|a, b| b.cmp(a));\n",
        );
        assert!(check(&f).is_empty(), "collect-then-sort is the canonical idiom");
    }

    #[test]
    fn btree_is_untracked() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut m: BTreeMap<u32, u32> = BTreeMap::new();\nfor (k, v) in &m {}\n",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn annotation_waives_with_reason() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut m: HashMap<u32, u32> = HashMap::new();\n\
             // lint:allow(unordered, reason = \"feeds a commutative integer sum\")\n\
             let s: u64 = m.values().map(|&v| v as u64).sum();\n",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn foreign_field_access_is_clean() {
        // `w.hot` is a Vec field on another object; the local `hot`
        // HashSet is only probed with contains()
        let f = SourceFile::from_str(
            "t.rs",
            "let hot: HashSet<u32> = w.hot.iter().copied().collect();\n\
             if hot.contains(&v) { hits += 1; }\n",
        );
        assert!(check(&f).is_empty(), "w.hot is not the tracked HashSet");
    }

    #[test]
    fn self_field_iteration_still_fires() {
        let f = SourceFile::from_str(
            "t.rs",
            "struct S { hot: HashSet<u32> }\n\
             fn f(s: &S) { for v in self.hot.iter() { go(v); } }\n",
        );
        assert_eq!(check(&f).len(), 1);
    }

    #[test]
    fn vec_with_similar_name_is_clean() {
        let f = SourceFile::from_str(
            "t.rs",
            "let mut m: HashMap<u32, u32> = HashMap::new();\n\
             let ms: Vec<u32> = Vec::new();\n\
             for x in &ms {}\n",
        );
        assert!(check(&f).is_empty(), "word boundary must separate m from ms");
    }
}
