//! The five invariant rules. Per-file rules (`wallclock`, `rng`,
//! `unordered`) take one [`crate::SourceFile`]; repo-level rules
//! (`ledger`, `flags`) take the whole file set plus configuration.

pub mod flags;
pub mod ledger;
pub mod rng;
pub mod unordered;
pub mod wallclock;
