//! Rule 5 — **flags**: the CLI registry and its users must agree.
//! Direction A: every `--flag` literal appearing in `main.rs` or the
//! repro drivers must be a key registered in the `ArgSpec` tables
//! (`val("key", ..)` / `switch("key", ..)` lines) or a parser builtin.
//! Direction B: every registered key must actually be consumed — the
//! quoted key must appear on at least one non-spec line of the scanned
//! files. A flag parsed but never read, or documented but never
//! parsed, is exactly the drift this rule pins.

use crate::config::RepoConfig;
use crate::{Finding, SourceFile};

pub const RULE: &str = "flags";

pub fn check(files: &[SourceFile], cfg: &RepoConfig) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(spec_file) = files.iter().find(|f| f.rel == cfg.flags_spec_file) else {
        out.push(Finding {
            rule: RULE,
            file: cfg.flags_spec_file.to_string(),
            line: 1,
            msg: "flag spec file not found".to_string(),
        });
        return out;
    };

    // registry: (key, 1-indexed spec line)
    let specs = spec_keys(spec_file);
    let registered: Vec<&str> = specs.iter().map(|(k, _)| k.as_str()).collect();

    let scanned: Vec<&SourceFile> = files
        .iter()
        .filter(|f| {
            cfg.flags_scan
                .iter()
                .any(|s| f.rel == *s || f.rel.starts_with(s))
        })
        .collect();

    // Direction A: every `--literal` must be registered or builtin.
    for file in &scanned {
        for (idx, code) in file.code.iter().enumerate() {
            let line = idx + 1;
            for lit in dash_literals(code) {
                if registered.contains(&lit.as_str())
                    || cfg.flags_builtin.contains(&lit.as_str())
                    || file.allowed(RULE, line)
                {
                    continue;
                }
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line,
                    msg: format!(
                        "`--{lit}` is not a registered key in `{}` — register it \
                         or fix the literal",
                        cfg.flags_spec_file
                    ),
                });
            }
        }
    }

    // Direction B: every registered key must be consumed somewhere
    // outside the spec tables.
    for (key, spec_line) in &specs {
        let quoted = format!("\"{key}\"");
        let consumed = scanned.iter().any(|file| {
            file.code.iter().any(|code| {
                if file.rel == cfg.flags_spec_file && is_spec_line(code) {
                    return false;
                }
                code.contains(&quoted)
            })
        });
        if !consumed && !spec_file.allowed(RULE, *spec_line) {
            out.push(Finding {
                rule: RULE,
                file: cfg.flags_spec_file.to_string(),
                line: *spec_line,
                msg: format!(
                    "flag `--{key}` is registered but never consumed in {:?}",
                    cfg.flags_scan
                ),
            });
        }
    }
    out
}

fn is_spec_line(code: &str) -> bool {
    code.contains("val(\"") || code.contains("switch(\"")
}

/// Keys from `val("key", ..)` / `switch("key", ..)` lines.
fn spec_keys(file: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (idx, code) in file.code.iter().enumerate() {
        for opener in ["val(\"", "switch(\""] {
            let mut start = 0;
            while let Some(off) = code[start..].find(opener) {
                let key_start = start + off + opener.len();
                if let Some(end) = code[key_start..].find('"') {
                    let key = code[key_start..key_start + end].to_string();
                    if !key.is_empty() && !out.iter().any(|(k, _)| k == &key) {
                        out.push((key, idx + 1));
                    }
                    start = key_start + end + 1;
                } else {
                    break;
                }
            }
        }
    }
    out
}

/// `--flag` tokens on a line: `--` followed by an ascii-lowercase
/// letter, munching `[a-z0-9-]` maximally. Table rules (`----`) and
/// numeric ranges never start with a letter, so they don't match.
fn dash_literals(code: &str) -> Vec<String> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < bytes.len() {
        if bytes[i] == b'-' && bytes[i + 1] == b'-' && bytes[i + 2].is_ascii_lowercase() {
            // not part of a longer dash run (`---flag`, table rules)
            if i > 0 && bytes[i - 1] == b'-' {
                i += 1;
                continue;
            }
            let mut j = i + 2;
            while j < bytes.len()
                && (bytes[j].is_ascii_lowercase() || bytes[j].is_ascii_digit() || bytes[j] == b'-')
            {
                j += 1;
            }
            let lit = code[i + 2..j].trim_end_matches('-').to_string();
            out.push(lit);
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RepoConfig;

    fn cfg() -> RepoConfig {
        RepoConfig {
            scan_dirs: &[],
            skip: &[],
            wallclock_allow: &[],
            ledger_registry: "unused-in-flags-tests.rs",
            flags_spec_file: "src/main.rs",
            flags_scan: &["src/main.rs", "src/repro/"],
            flags_builtin: &["help"],
        }
    }

    #[test]
    fn unregistered_literal_fires() {
        let spec = SourceFile::from_str(
            "src/main.rs",
            "val(\"dataset\", \"tiny\");\nlet d = args.get(\"dataset\");\n",
        );
        let repro = SourceFile::from_str(
            "src/repro/run.rs",
            "println!(\"use --dataset or --unknown-flag\");\n",
        );
        let out = check(&[spec, repro], &cfg());
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("--unknown-flag"));
    }

    #[test]
    fn unconsumed_key_fires() {
        let spec = SourceFile::from_str(
            "src/main.rs",
            "val(\"dataset\", \"tiny\");\nswitch(\"dry-run\");\nlet d = args.get(\"dataset\");\n",
        );
        let out = check(&[spec], &cfg());
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("--dry-run"));
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn registered_and_consumed_is_clean() {
        let spec = SourceFile::from_str(
            "src/main.rs",
            "val(\"dataset\", \"tiny\");\nlet d = args.get(\"dataset\");\n\
             println!(\"try --dataset tiny or --help\");\n",
        );
        assert!(check(&[spec], &cfg()).is_empty());
    }

    #[test]
    fn table_rules_and_dash_runs_do_not_match() {
        assert!(dash_literals("+----+----+").is_empty());
        assert!(dash_literals("// ------------").is_empty());
        assert_eq!(dash_literals("use --cache-policy here"), vec!["cache-policy"]);
        assert_eq!(dash_literals("--a --b2"), vec!["a", "b2"]);
    }
}
