//! Rule 1 — **wallclock**: real time is a nondeterminism source. The
//! engine's decisions, the samplers, and the entire serve plane run on
//! seeds and a virtual integer-µs clock so that two runs of the same
//! config are bit-identical; a stray `Instant::now()` in a decision
//! path (batch admission, cache policy, sampler) silently voids that.
//! Timing-only modules (the `Timer` utility, phase metrics, kernel
//! profiling, outer CLI timers, benches) are allowlisted — their
//! readings only ever land in `wall_*` report columns, never in
//! control flow.

use crate::{Finding, SourceFile};

const PATTERNS: &[&str] = &["Instant::now", "SystemTime"];
pub const RULE: &str = "wallclock";

pub fn check(file: &SourceFile, allow_files: &[&str]) -> Vec<Finding> {
    let exempt = allow_files
        .iter()
        .any(|a| file.rel.starts_with(a) || file.rel.ends_with(a));
    if exempt {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (idx, code) in file.code.iter().enumerate() {
        let line = idx + 1;
        for p in PATTERNS {
            if code.contains(p) && !file.allowed(RULE, line) {
                out.push(Finding {
                    rule: RULE,
                    file: file.rel.clone(),
                    line,
                    msg: format!(
                        "`{p}` outside the allowlisted timing modules — decision \
                         paths must use the virtual clock / seeded streams"
                    ),
                });
                break; // one finding per line is enough
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_outside_allowlist() {
        let f = SourceFile::from_str("rust/src/serve/batcher.rs", "let t = Instant::now();\n");
        assert_eq!(check(&f, &["rust/src/util/stats.rs"]).len(), 1);
    }

    #[test]
    fn allowlisted_file_is_exempt() {
        let f = SourceFile::from_str("rust/src/util/stats.rs", "let t = Instant::now();\n");
        assert!(check(&f, &["rust/src/util/stats.rs"]).is_empty());
    }

    #[test]
    fn comments_do_not_fire() {
        let f = SourceFile::from_str("rust/src/serve/mod.rs", "// no Instant::now here\n");
        assert!(check(&f, &[]).is_empty());
    }

    #[test]
    fn annotation_waives() {
        let f = SourceFile::from_str(
            "rust/src/serve/mod.rs",
            "// lint:allow(wallclock, reason = \"measured wall only lands in a log line\")\n\
             let t = std::time::Instant::now();\n",
        );
        assert!(check(&f, &[]).is_empty());
    }
}
