//! `coopgnn-lint` — run the five invariant rules over the tree and
//! exit nonzero on any finding. Blocking in CI ahead of build+test.
//!
//! Usage: `cargo run -p coopgnn-lint [-- --root PATH]`
//! (default root is the current directory; CI runs it from the repo
//! root, `cargo run` from anywhere inside the workspace also works
//! because we fall back to walking up to the workspace `Cargo.toml`).

use std::path::{Path, PathBuf};

use coopgnn_lint::config::{parse_ledger_registry, repo_config};
use coopgnn_lint::rules;
use coopgnn_lint::{collect_rs_files, Finding, SourceFile};

fn main() {
    let root = parse_root();
    let cfg = repo_config();

    let rels = collect_rs_files(&root, cfg.scan_dirs, cfg.skip);
    if rels.is_empty() {
        eprintln!(
            "coopgnn-lint: no .rs files under {:?} in {} — wrong --root?",
            cfg.scan_dirs,
            root.display()
        );
        std::process::exit(2);
    }

    let mut files = Vec::new();
    for rel in &rels {
        match SourceFile::load(&root, rel) {
            Ok(f) => files.push(f),
            Err(e) => {
                eprintln!("coopgnn-lint: cannot read {rel}: {e}");
                std::process::exit(2);
            }
        }
    }

    let mut findings: Vec<Finding> = Vec::new();
    for f in &files {
        findings.extend(f.annotation_findings());
        findings.extend(rules::wallclock::check(f, cfg.wallclock_allow));
        findings.extend(rules::rng::check(f));
        findings.extend(rules::unordered::check(f));
    }
    // the ledger pairings come from the tree's own registry declaration
    // (LEDGER_STRUCTS); a registry that fails to parse is a finding
    match files.iter().find(|f| f.rel == cfg.ledger_registry) {
        Some(reg) => match parse_ledger_registry(reg) {
            Ok(specs) => findings.extend(rules::ledger::check(&files, &specs)),
            Err(e) => findings.push(e),
        },
        None => findings.push(Finding {
            rule: rules::ledger::RULE,
            file: cfg.ledger_registry.to_string(),
            line: 1,
            msg: "ledger registry file not found in the scanned tree".to_string(),
        }),
    }
    findings.extend(rules::flags::check(&files, &cfg));

    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));

    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "coopgnn-lint: {} files clean (wallclock, ambient-rng, unordered, ledger, flags)",
            files.len()
        );
    } else {
        println!("coopgnn-lint: {} finding(s) in {} files", findings.len(), files.len());
        std::process::exit(1);
    }
}

/// `--root PATH` if given; else the nearest ancestor of the current
/// directory containing a `rust/src` tree (so the tool runs correctly
/// from any workspace subdirectory).
fn parse_root() -> PathBuf {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--root" {
            if let Some(p) = args.get(i + 1) {
                return PathBuf::from(p);
            }
            eprintln!("coopgnn-lint: --root needs a path");
            std::process::exit(2);
        }
        i += 1;
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("rust/src").is_dir() {
            return dir;
        }
        if !pop(&mut dir) {
            return PathBuf::from(".");
        }
    }
}

fn pop(dir: &mut PathBuf) -> bool {
    let parent: Option<&Path> = dir.parent();
    match parent {
        Some(p) if p != dir.as_path() => {
            let p = p.to_path_buf();
            *dir = p;
            true
        }
        _ => false,
    }
}
