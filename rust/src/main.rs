//! `coopgnn` — the leader CLI.
//!
//! ```text
//! coopgnn repro <id|all> [--out DIR] [--quick] [--seed N]
//! coopgnn train [--dataset NAME] [--steps N] [--layers L] [--hidden H]
//!               [--fanout K | K,K,..] [--kappa K] [--sampler ns|labor0|labor*|rw]
//!               [--lr F] [--eval-every N]            # host backend (default)
//! coopgnn train --backend pjrt --config NAME [..]    # AOT/PJRT backend
//! coopgnn train --train-pes P [--mode coop|indep] [--batch B]
//!               [--allreduce naive|tree|ring|rsag|auto] [--replication r]
//!               [--intra-bw GBPS] [--inter-bw GBPS]
//!               [--trace FILE] [--metrics-out FILE]
//! coopgnn engine --mode coop|indep --dataset NAME --pes P [--batch B]
//!               [--kappa K] [--batches N] [--partitioner random|metis|ldg]
//!               [--exec serial|threaded] [--codec f32|fp16|int8] [--hot-mb N]
//!               [--replication r] [--trace FILE] [--metrics-out FILE]
//! coopgnn serve --rate R --slo-ms MS --batcher fixed|adaptive
//!               [--duration-batches N] [--pes P] [--mode coop|indep]
//!               [--trace FILE] [--metrics-out FILE]
//! coopgnn caps --dataset NAME --batch B [--sampler S]
//! coopgnn info
//! ```
//!
//! Every subcommand parses through `pipeline::args` (strict: unknown
//! flags and malformed values are errors) and constructs its run through
//! `pipeline::PipelineBuilder`. All seed defaults are
//! `pipeline::DEFAULT_SEED`.

// Allowlisted timing file (coopgnn-lint `wallclock` + clippy
// disallowed-methods): outer CLI timers around whole subcommands.
#![allow(clippy::disallowed_methods)]

use coopgnn::coop::all_to_all::AllReduceStrategy;
use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::feature::Codec;
use coopgnn::graph::datasets;
use coopgnn::obs::{LedgerSource, Registry, Trace, TraceBuffer};
use coopgnn::pipeline::args::{switch, val, ArgMap, ArgSpec};
use coopgnn::pipeline::{with_prefetch, Partitioner, PipelineBuilder, DEFAULT_SEED};
use coopgnn::repro::{self, Ctx};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{block, Kappa, SamplerConfig, SamplerKind};
use coopgnn::serve::{BatcherKind, ServeConfig, WorkloadKind};
use coopgnn::train::{StepStats, Trainer};
use std::path::PathBuf;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const REPRO_SPECS: &[ArgSpec] = &[
    val("out", "output directory (default: results)"),
    switch("quick", "reduced sweeps for smoke runs"),
    val("seed", "rng seed (default: pipeline::DEFAULT_SEED)"),
    val("artifacts", "AOT artifacts directory (default: artifacts)"),
    val("exec", "serial|threaded (default: threaded)"),
    val("codec", "f32|fp16|int8 feature-row storage/wire codec (default: f32)"),
    val("hot-mb", "hot-tier budget in MiB of decoded rows; 0 = untiered (default: 0)"),
    val("replication", "replica-group size r; must divide the PE count (default: 1)"),
    val("intra-bw", "intra-group link bandwidth in GB/s for the cost model (default: 600)"),
    val("inter-bw", "inter-group link bandwidth in GB/s for the cost model (default: 100)"),
];

const TRAIN_SPECS: &[ArgSpec] = &[
    val("backend", "host|pjrt single-PE compute backend (default: host, or pjrt when \
         --config is given; pjrt needs artifacts + a PJRT build)"),
    val("config", "artifact config name for the pjrt backend (default: tiny-b32)"),
    val("dataset", "registry dataset (default: tiny, or the config's dataset)"),
    val("steps", "training steps (default: 300)"),
    val("eval-every", "evaluation interval (default: 50)"),
    val("sampler", "ns|labor0|labor*|rw (default: labor0)"),
    val("kappa", "batch dependency K or `inf` (default: 1)"),
    val("fanout", "sampler fanout: one value or a per-layer comma list (default: 10)"),
    val("layers", "GNN layers for the host backend / --train-pes (default: 3)"),
    val("hidden", "hidden width of the layered model (default: 16)"),
    val("model-layers", "assert the model depth; must equal --layers (strict)"),
    val("lr", "learning-rate override (may be negative — rejected later)"),
    val("seed", "rng seed (default: pipeline::DEFAULT_SEED)"),
    val("artifacts", "AOT artifacts directory (default: artifacts)"),
    val("exec", "serial|threaded (default: threaded)"),
    val("prefetch", "0|1 double-buffer sampling+gather behind execution (default: 0)"),
    val("train-pes", "run the multi-PE training plane with N trainer replicas (host \
         compute + gradient all-reduce; needs no PJRT/artifacts)"),
    val("mode", "coop|indep minibatching for --train-pes (default: coop)"),
    val("batch", "per-PE batch size (--train-pes) or host-backend seed batch (default: 256)"),
    val("allreduce", "naive|tree|ring|rsag|auto gradient all-reduce strategy; auto picks \
         by the alpha-beta cost model (default: ring)"),
    val("codec", "f32|fp16|int8 feature-row storage/wire codec (default: f32)"),
    val("hot-mb", "hot-tier budget in MiB of decoded rows; 0 = untiered (default: 0)"),
    val("replication", "replica-group size r for --train-pes; must divide P (default: 1)"),
    val("intra-bw", "intra-group link bandwidth in GB/s for the cost model (default: 600)"),
    val("inter-bw", "inter-group link bandwidth in GB/s for the cost model (default: 100)"),
    val("trace", "write a Chrome trace-event JSON flight record to FILE (--train-pes)"),
    val("metrics-out", "write the run report as a Prometheus-style exposition to FILE"),
];

const ENGINE_SPECS: &[ArgSpec] = &[
    val("mode", "coop|indep (default: coop)"),
    val("dataset", "registry dataset (default: tiny)"),
    val("pes", "number of PEs (default: 4)"),
    val("batch", "per-PE batch size (default: 1024)"),
    val("cache", "LRU rows per PE; 0 = no cache, all accesses hit storage (default: derived)"),
    val("sampler", "ns|labor0|labor*|rw (default: labor0)"),
    val("kappa", "batch dependency K or `inf` (default: 1)"),
    val("fanout", "sampler fanout: one value or a per-layer comma list (default: 10)"),
    val("layers", "GNN layers (default: 3)"),
    val("partitioner", "random|metis|ldg (default: random)"),
    val("exec", "serial|threaded (default: threaded)"),
    val("prefetch", "0|1 double-buffer batch production (default: 0)"),
    val("warmup", "warmup batches (default: 4)"),
    val("batches", "measured batches (default: 8)"),
    val("seed", "rng seed (default: pipeline::DEFAULT_SEED)"),
    val("codec", "f32|fp16|int8 feature-row storage/wire codec (default: f32)"),
    val("hot-mb", "hot-tier budget in MiB of decoded rows; 0 = untiered (default: 0)"),
    val("replication", "replica-group size r; must divide the PE count (default: 1)"),
    val("trace", "write a Chrome trace-event JSON flight record to FILE"),
    val("metrics-out", "write the run report as a Prometheus-style exposition to FILE"),
];

const SERVE_SPECS: &[ArgSpec] = &[
    val("dataset", "registry dataset (default: tiny)"),
    val("pes", "number of PEs (default: 4)"),
    val("mode", "coop|indep minibatching of admitted batches (default: coop)"),
    val("exec", "serial|threaded (default: threaded)"),
    val("rate", "offered load, requests per virtual second (default: 2000)"),
    val("slo-ms", "p99 latency objective in virtual ms (default: 50)"),
    val("batcher", "fixed|adaptive admission policy (default: adaptive)"),
    val("duration-batches", "stop after N dispatched batches (default: 32)"),
    val("batch", "fixed baseline's per-PE batch size; adaptive cap = 4x (default: 32)"),
    val("workload", "open|closed arrival discipline (default: open)"),
    val("clients", "logical clients / closed-loop population (default: 64)"),
    val("hot", "probability a request targets the 5% hot set (default: 0.8)"),
    val("preset", "cost-model system: 4xA100|8xA100|16xV100 (default: 4xA100)"),
    val("kappa", "batch dependency K or `inf` for the samplers (default: 1)"),
    val("cache", "LRU rows per PE; 0 = no cache (default: derived)"),
    val("prefetch", "0|1 overlap batch t's predictions with batch t+1's admission (default: 0)"),
    val("seed", "rng seed (default: pipeline::DEFAULT_SEED)"),
    val("codec", "f32|fp16|int8 feature-row storage/wire codec (default: f32)"),
    val("hot-mb", "hot-tier budget in MiB of decoded rows; 0 = untiered (default: 0)"),
    val("replication", "replica-group size r; must divide the PE count (default: 1)"),
    val("trace", "write a Chrome trace-event JSON flight record to FILE"),
    val("metrics-out", "write the run report as a Prometheus-style exposition to FILE"),
];

const CAPS_SPECS: &[ArgSpec] = &[
    val("dataset", "registry dataset (default: tiny)"),
    val("batch", "batch size (default: 256)"),
    val("sampler", "ns|labor0|labor*|rw (default: labor0)"),
    val("trials", "estimation trials (default: 5)"),
    val("seed", "rng seed (default: pipeline::DEFAULT_SEED)"),
];

fn real_main() -> coopgnn::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    match cmd {
        "repro" => {
            let id = argv.get(1).map(|s| s.as_str()).unwrap_or("all");
            let rest = ArgMap::parse(argv.get(2..).unwrap_or(&[]), REPRO_SPECS)?;
            let (codec, hot_mb) = parse_storage(&rest)?;
            let ctx = Ctx {
                out: PathBuf::from(rest.get_or("out", "results")),
                quick: rest.has("quick"),
                seed: rest.or("seed", DEFAULT_SEED)?,
                artifacts: PathBuf::from(rest.get_or("artifacts", "artifacts")),
                exec: ExecMode::parse(rest.get_or("exec", "threaded"))
                    .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
                codec,
                hot_mb,
                replication: rest.or("replication", 1usize)?,
                intra_bw: rest.opt("intra-bw")?,
                inter_bw: rest.opt("inter-bw")?,
            };
            anyhow::ensure!(ctx.replication >= 1, "--replication must be >= 1");
            repro::run(id, &ctx)
        }
        "train" => cmd_train(&ArgMap::parse(&argv[1..], TRAIN_SPECS)?),
        "engine" => cmd_engine(&ArgMap::parse(&argv[1..], ENGINE_SPECS)?),
        "serve" => cmd_serve(&ArgMap::parse(&argv[1..], SERVE_SPECS)?),
        "caps" => cmd_caps(&ArgMap::parse(&argv[1..], CAPS_SPECS)?),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

/// Shared `--trace` / `--metrics-out` sinks for the traced subcommands
/// (engine, train --train-pes, serve): write the flight record as
/// Chrome trace-event JSON and/or the run report's gauges as a
/// Prometheus-style exposition through [`coopgnn::obs::Registry`].
fn write_obs_outputs(
    args: &ArgMap,
    buf: Option<&TraceBuffer>,
    report: &dyn LedgerSource,
) -> coopgnn::Result<()> {
    if let Some(path) = args.get("trace") {
        let buf = buf.ok_or_else(|| {
            anyhow::anyhow!("--trace was requested but the run produced no trace buffer")
        })?;
        std::fs::write(path, buf.to_chrome_json())
            .map_err(|e| anyhow::anyhow!("writing --trace {path}: {e}"))?;
        println!(
            "trace: {} spans over {} batches -> {path} (chrome://tracing, ui.perfetto.dev)",
            buf.span_count(),
            buf.batch_count()
        );
    }
    if let Some(path) = args.get("metrics-out") {
        let mut reg = Registry::new();
        reg.observe(report);
        std::fs::write(path, reg.to_prometheus())
            .map_err(|e| anyhow::anyhow!("writing --metrics-out {path}: {e}"))?;
        println!("metrics: {} exposition -> {path}", report.ledger_name());
    }
    Ok(())
}

/// Shared `--codec` / `--hot-mb` parse for the storage-aware
/// subcommands (engine, train, serve, repro).
fn parse_storage(args: &ArgMap) -> coopgnn::Result<(Codec, usize)> {
    let codec = Codec::parse(args.get_or("codec", "f32"))
        .ok_or_else(|| anyhow::anyhow!("bad --codec (f32|fp16|int8)"))?;
    Ok((codec, args.or("hot-mb", 0usize)?))
}

/// Parse `--fanout` as either one uniform value or a per-layer comma
/// list (`10,5,5`); length-vs-layers validation happens in
/// [`PipelineBuilder::build`].
fn parse_fanouts(s: &str) -> coopgnn::Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .map_err(|e| anyhow::anyhow!("bad --fanout entry `{t}`: {e}"))
        })
        .collect()
}

/// The multi-PE training plane (`--train-pes N`): per-PE layered-model
/// replicas over the engine stream, lockstep parameters via the fabric
/// gradient all-reduce — runs natively in this build (no PJRT, no
/// artifacts).
fn cmd_train_parallel(args: &ArgMap, pes: usize) -> coopgnn::Result<()> {
    anyhow::ensure!(pes >= 1, "--train-pes must be >= 1");
    let allreduce_arg = args.get_or("allreduce", "ring");
    let (codec, hot_mb) = parse_storage(args)?;
    let mut b = PipelineBuilder::new()
        .dataset(args.get_or("dataset", "tiny"))
        .codec(codec)
        .hot_mb(hot_mb)
        .mode(
            Mode::parse(args.get_or("mode", "coop"))
                .ok_or_else(|| anyhow::anyhow!("bad --mode (coop|indep)"))?,
        )
        .exec(
            ExecMode::parse(args.get_or("exec", "threaded"))
                .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        )
        .num_pes(pes)
        .batch_per_pe(args.or("batch", 256usize)?)
        .sampler(
            SamplerKind::parse(args.get_or("sampler", "labor0"))
                .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        )
        .kappa(
            Kappa::parse(args.get_or("kappa", "1"))
                .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        )
        .fanouts(&parse_fanouts(args.get_or("fanout", "10"))?)
        .layers(args.or("layers", 3usize)?)
        .hidden(args.or("hidden", 16usize)?)
        .replication(args.or("replication", 1usize)?)
        .seed(args.or("seed", DEFAULT_SEED)?);
    if let Some(gbps) = args.opt::<f64>("intra-bw")? {
        b = b.intra_bw(gbps);
    }
    if let Some(gbps) = args.opt::<f64>("inter-bw")? {
        b = b.inter_bw(gbps);
    }
    if let Some(ml) = args.opt::<usize>("model-layers")? {
        b = b.model_layers(ml);
    }
    let pipe = b.build()?;
    // `auto` resolves through the alpha-beta cost model against this
    // run's gradient payload and topology; named strategies are forced.
    let strategy = if allreduce_arg == "auto" {
        pipe.collective_for_grads()
    } else {
        AllReduceStrategy::parse(allreduce_arg)
            .ok_or_else(|| anyhow::anyhow!("bad --allreduce (naive|tree|ring|rsag|auto)"))?
    };
    let steps = args.or("steps", 300usize)?;
    let lr = args.or("lr", 0.05f32)?;
    anyhow::ensure!(lr > 0.0, "--lr must be positive");
    let prefetch = args.bool01("prefetch", false)?;
    let mut trainer = pipe.parallel_trainer(lr, strategy);
    if args.has("trace") {
        trainer.enable_trace();
    }
    println!(
        "multi-PE training plane: {} on {}, {} PEs x batch {} ({} exec, {} all-reduce{}, \
         replication {}{})",
        pipe.cfg.mode.name(),
        pipe.ds.name,
        pes,
        pipe.cfg.batch_per_pe,
        pipe.cfg.exec.name(),
        strategy.name(),
        if allreduce_arg == "auto" { " [auto]" } else { "" },
        pipe.cfg.replication,
        if prefetch { ", prefetch on" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let rep = if prefetch {
        with_prefetch(pipe.stream(), |s| trainer.run(s, steps, &pipe.ds.labels))
    } else {
        trainer.run(&mut pipe.stream(), steps, &pipe.ds.labels)
    };
    anyhow::ensure!(trainer.replicas_in_lockstep(), "replicas diverged (all-reduce bug)");
    let mut eval_stream = pipe.stream();
    let val_acc = trainer.evaluate(&mut eval_stream, &pipe.ds.val, &pipe.ds.labels);
    println!(
        "{} steps in {:.1}s: {:.2} ms/step (sample {:.2} + feature {:.2} + compute {:.2} + \
         all-reduce {:.2})",
        steps,
        t0.elapsed().as_secs_f64(),
        rep.ms_per_step,
        rep.sample_ms,
        rep.feature_ms,
        rep.compute_ms,
        rep.allreduce_ms
    );
    println!(
        "bytes/step: {:.1} KiB storage (β), {:.1} KiB feature fabric (α), {:.1} KiB activation \
         exchange, {:.1} KiB gradient all-reduce",
        rep.storage_bytes_per_step / 1024.0,
        rep.fabric_bytes_per_step / 1024.0,
        rep.act_bytes_per_step / 1024.0,
        rep.grad_bytes_per_step / 1024.0
    );
    println!(
        "inter-group bytes/step: {:.1} KiB feature + {:.1} KiB activation + {:.1} KiB \
         gradient ({} collective)",
        rep.fabric_inter_bytes_per_step / 1024.0,
        rep.act_inter_bytes_per_step / 1024.0,
        rep.grad_inter_bytes_per_step / 1024.0,
        rep.collective
    );
    println!(
        "loss {:.4} -> {:.4}, batch acc {:.3}, val acc {:.4} (replicas bit-identical: yes)",
        rep.first_loss, rep.last_loss, rep.last_acc, val_acc
    );
    println!(
        "stage hists (ms): sample p50 {:.3} / p99 {:.3}, compute p50 {:.3} / p99 {:.3}, \
         all-reduce p50 {:.3} / p99 {:.3}",
        trainer.stage_hists().sample_ms.quantile_mid(0.50),
        trainer.stage_hists().sample_ms.quantile_mid(0.99),
        trainer.stage_hists().compute_ms.quantile_mid(0.50),
        trainer.stage_hists().compute_ms.quantile_mid(0.99),
        trainer.stage_hists().allreduce_ms.quantile_mid(0.50),
        trainer.stage_hists().allreduce_ms.quantile_mid(0.99)
    );
    write_obs_outputs(args, trainer.trace().buffer(), &rep)
}

fn cmd_train(args: &ArgMap) -> coopgnn::Result<()> {
    // the train paths consume disjoint flag subsets; a flag the chosen
    // path would silently ignore is an error (the strict-args contract:
    // nothing defaults silently)
    if let Some(pes) = args.opt::<usize>("train-pes")? {
        for key in ["config", "eval-every", "artifacts", "backend"] {
            anyhow::ensure!(
                !args.has(key),
                "--{key} applies to the single-PE train path and is ignored with --train-pes; \
                 drop it"
            );
        }
        return cmd_train_parallel(args, pes);
    }
    for key in ["mode", "allreduce", "replication", "intra-bw", "inter-bw", "trace", "metrics-out"]
    {
        anyhow::ensure!(
            !args.has(key),
            "--{key} only applies to the multi-PE training plane; add --train-pes N"
        );
    }
    let backend = args.get_or("backend", if args.has("config") { "pjrt" } else { "host" });
    match backend {
        "host" => cmd_train_host(args),
        "pjrt" => cmd_train_pjrt(args),
        other => anyhow::bail!("bad --backend `{other}` (host|pjrt)"),
    }
}

/// Single-PE training on the host compute backend: the layered model
/// shape comes from the CLI (`--layers/--hidden`) and the dataset; no
/// PJRT runtime or AOT artifacts are involved.
fn cmd_train_host(args: &ArgMap) -> coopgnn::Result<()> {
    for key in ["config", "artifacts"] {
        anyhow::ensure!(
            !args.has(key),
            "--{key} belongs to the pjrt backend (add --backend pjrt, or drop --{key})"
        );
    }
    let (codec, hot_mb) = parse_storage(args)?;
    let mut b = PipelineBuilder::new()
        .dataset(args.get_or("dataset", "tiny"))
        .codec(codec)
        .hot_mb(hot_mb)
        .sampler(
            SamplerKind::parse(args.get_or("sampler", "labor0"))
                .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        )
        .kappa(
            Kappa::parse(args.get_or("kappa", "1"))
                .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        )
        .fanouts(&parse_fanouts(args.get_or("fanout", "10"))?)
        .layers(args.or("layers", 3usize)?)
        .hidden(args.or("hidden", 16usize)?)
        .seed(args.or("seed", DEFAULT_SEED)?)
        .exec(
            ExecMode::parse(args.get_or("exec", "threaded"))
                .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        );
    if let Some(ml) = args.opt::<usize>("model-layers")? {
        b = b.model_layers(ml);
    }
    let pipe = b.build()?;
    let prefetch = args.bool01("prefetch", false)?;
    let mut opts = pipe.trainer_options();
    opts.lr = args.opt("lr")?;
    let mut trainer = Trainer::new_host(
        &pipe.ds,
        args.or("batch", 256usize)?,
        pipe.cfg.layers,
        pipe.cfg.hidden,
        &opts,
    )?;
    let dims = trainer.dims();
    println!(
        "training host backend on {}: {} layers x hidden {} ({} params), {} train vertices, \
         batch {}{}",
        pipe.ds.name,
        dims.layers,
        dims.hidden,
        trainer.state.num_scalars(),
        pipe.ds.train.len(),
        trainer.batch(),
        if prefetch { " (prefetch: sampling+gather overlap execution)" } else { "" }
    );
    run_train_loop(
        &mut trainer,
        args.or("steps", 300usize)?,
        args.or("eval-every", 50usize)?,
        prefetch,
    )
}

/// Single-PE training through the PJRT/AOT backend: the model shape,
/// batch and caps come from the artifact config.
fn cmd_train_pjrt(args: &ArgMap) -> coopgnn::Result<()> {
    for key in ["batch", "layers", "hidden", "model-layers"] {
        anyhow::ensure!(
            !args.has(key),
            "--{key} is set by the artifact config on the pjrt backend; drop it"
        );
    }
    let fanouts = parse_fanouts(args.get_or("fanout", "10"))?;
    anyhow::ensure!(
        fanouts.len() == 1,
        "per-layer fanout lists apply to the host backend / --train-pes; the pjrt \
         backend takes one uniform --fanout"
    );
    let config = args.get_or("config", "tiny-b32").to_string();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&artifacts)?;
    let art = manifest.get(&config)?;
    let (codec, hot_mb) = parse_storage(args)?;
    let pipe = PipelineBuilder::new()
        .dataset(args.get_or("dataset", &art.dataset))
        .codec(codec)
        .hot_mb(hot_mb)
        .sampler(
            SamplerKind::parse(args.get_or("sampler", "labor0"))
                .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        )
        .kappa(
            Kappa::parse(args.get_or("kappa", "1"))
                .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        )
        .fanout(fanouts[0])
        .seed(args.or("seed", DEFAULT_SEED)?)
        .exec(
            ExecMode::parse(args.get_or("exec", "threaded"))
                .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        )
        .build()?;
    let prefetch = args.bool01("prefetch", false)?;
    let mut opts = pipe.trainer_options();
    opts.lr = args.opt("lr")?;
    let mut trainer = Trainer::new(&rt, &manifest, &config, &pipe.ds, &opts)?;
    println!(
        "training {config} on {}: {} params, {} train vertices, batch {}{}",
        pipe.ds.name,
        trainer.state.num_scalars(),
        pipe.ds.train.len(),
        trainer.batch(),
        if prefetch { " (prefetch: sampling+gather overlap execution)" } else { "" }
    );
    run_train_loop(
        &mut trainer,
        args.or("steps", 300usize)?,
        args.or("eval-every", 50usize)?,
        prefetch,
    )
}

/// Shared drive loop for the single-PE trainer: both backends step
/// through the same [`coopgnn::model::GnnModel`] surface, so the
/// reporting/eval cadence is backend-agnostic.
fn run_train_loop(
    trainer: &mut Trainer,
    steps: usize,
    eval_every: usize,
    prefetch: bool,
) -> coopgnn::Result<()> {
    anyhow::ensure!(eval_every >= 1, "--eval-every must be >= 1");
    let ds = trainer.ds;
    let mut report_step = |trainer: &mut Trainer,
                           step: usize,
                           s: StepStats|
     -> coopgnn::Result<()> {
        if step % eval_every == 0 || step == 1 || step == steps {
            let val = trainer.evaluate(&ds.val, 1234)?;
            println!(
                "step {step:>5}  loss {:.4}  batch-acc {:.3}  val-acc {:.4}  val-F1 {:.4}  \
                 [samp {:.1}ms pad {:.1}ms feat {:.1}ms exec {:.1}ms]",
                s.loss, s.acc, val.accuracy, val.macro_f1,
                s.sample_ms, s.pad_ms, s.feature_ms, s.exec_ms
            );
        }
        Ok(())
    };
    let t0 = std::time::Instant::now();
    if prefetch {
        // the trainer's own stream recipe (shared feature store), moved
        // onto a producer thread — trajectories are bit-identical to
        // prefetch=0 at the same seed (pipeline determinism tests)
        let stream = trainer.make_stream();
        with_prefetch(stream, |s| -> coopgnn::Result<()> {
            for step in 1..=steps {
                let stats = trainer.step_from(s)?;
                report_step(trainer, step, stats)?;
            }
            Ok(())
        })?;
    } else {
        for step in 1..=steps {
            let s = trainer.step()?;
            report_step(trainer, step, s)?;
        }
    }
    let test = trainer.evaluate(&ds.test, 1234)?;
    println!(
        "done in {:.1}s: test acc {:.4}, test F1 {:.4}",
        t0.elapsed().as_secs_f64(),
        test.accuracy,
        test.macro_f1
    );
    Ok(())
}

fn cmd_engine(args: &ArgMap) -> coopgnn::Result<()> {
    let (codec, hot_mb) = parse_storage(args)?;
    let mut b = PipelineBuilder::new()
        .dataset(args.get_or("dataset", "tiny"))
        .codec(codec)
        .hot_mb(hot_mb)
        .mode(
            Mode::parse(args.get_or("mode", "coop"))
                .ok_or_else(|| anyhow::anyhow!("bad --mode (coop|indep)"))?,
        )
        .exec(
            ExecMode::parse(args.get_or("exec", "threaded"))
                .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        )
        .num_pes(args.or("pes", 4usize)?)
        .batch_per_pe(args.or("batch", 1024usize)?)
        .partitioner(
            Partitioner::parse(args.get_or("partitioner", "random"))
                .ok_or_else(|| anyhow::anyhow!("bad --partitioner (random|metis|ldg)"))?,
        )
        .sampler(
            SamplerKind::parse(args.get_or("sampler", "labor0"))
                .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        )
        .kappa(
            Kappa::parse(args.get_or("kappa", "1"))
                .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        )
        .fanouts(&parse_fanouts(args.get_or("fanout", "10"))?)
        .layers(args.or("layers", 3usize)?)
        .replication(args.or("replication", 1usize)?)
        .prefetch(args.bool01("prefetch", false)?)
        .warmup_batches(args.or("warmup", 4usize)?)
        .measure_batches(args.or("batches", 8usize)?)
        .seed(args.or("seed", DEFAULT_SEED)?);
    if let Some(cache) = args.opt::<usize>("cache")? {
        b = b.cache_per_pe(cache);
    }
    let pipe = b.build()?;
    let mut trace = if args.has("trace") { Trace::on("engine") } else { Trace::Off };
    let r = pipe.engine_report_traced(&mut trace);
    println!(
        "mode={} exec={} PEs={} cross-edge-ratio={:.3}",
        r.mode,
        pipe.cfg.exec.name(),
        r.num_pes,
        pipe.part.cross_edge_ratio(&pipe.ds.graph)
    );
    println!("per-layer S (max/PE, avg): {:?}", r.s.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer E: {:?}", r.e.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer S~: {:?}", r.tilde.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer cross: {:?}", r.cross.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!(
        "feature: requested {:.0}/batch, misses {:.0}, fabric rows {:.0}, miss rate {:.4}",
        r.feat_requested, r.feat_misses, r.feat_fabric_rows, r.cache_miss_rate
    );
    println!(
        "feature bytes/batch: {:.1} KiB from storage (β), {:.1} KiB over fabric (α); \
         byte-derived miss rate {:.4}",
        r.feat_storage_bytes / 1024.0,
        r.feat_fabric_bytes / 1024.0,
        r.derived_miss_rate
    );
    println!(
        "fabric plane: replication {} — {:.1} KiB/batch total cross-PE (ids + rows), \
         {:.1} KiB inter-group",
        pipe.cfg.replication,
        r.total_cross_bytes() / 1024.0,
        r.feat_fabric_inter_bytes / 1024.0
    );
    println!(
        "storage plane: codec {} ({} B/row wire, {} B/row decoded); hot tier {} MiB — \
         {:.0} rows/batch ({:.1} KiB) served from PE memory (γ), hit rate {:.4}; \
         prefetched {:.0} rows/batch ({:.1} KiB)",
        pipe.feature_store().codec().name(),
        pipe.feature_store().row_bytes(),
        pipe.ds.feat_dim * 4,
        pipe.cfg.hot_mb,
        r.feat_hot_rows,
        r.feat_hot_bytes / 1024.0,
        r.hot_hit_rate,
        r.prefetch_rows,
        r.prefetch_bytes / 1024.0
    );
    println!("dup factor @L: {:.3}", r.dup_factor);
    println!(
        "CPU wall: sampling {:.2} ms/batch + feature {:.2} ms/batch (per-PE elapsed, summed; \
         includes exchange waits in threaded mode); batch wall {:.2} ms \
         (compare --exec serial vs threaded for the concurrency speedup)",
        r.wall_sampling_ms, r.wall_feature_ms, r.wall_batch_ms
    );
    write_obs_outputs(args, trace.buffer(), &r)
}

/// The online inference serving plane: a virtual-time simulation of
/// SLO-aware dynamic cooperative batching (`coopgnn serve`). Bit
/// reproducible at a fixed seed — `--exec`/`--prefetch` change real CPU
/// scheduling, never the ledger.
fn cmd_serve(args: &ArgMap) -> coopgnn::Result<()> {
    let (codec, hot_mb) = parse_storage(args)?;
    let mut b = PipelineBuilder::new()
        .dataset(args.get_or("dataset", "tiny"))
        .codec(codec)
        .hot_mb(hot_mb)
        .mode(
            Mode::parse(args.get_or("mode", "coop"))
                .ok_or_else(|| anyhow::anyhow!("bad --mode (coop|indep)"))?,
        )
        .exec(
            ExecMode::parse(args.get_or("exec", "threaded"))
                .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        )
        .num_pes(args.or("pes", 4usize)?)
        .replication(args.or("replication", 1usize)?)
        .kappa(
            Kappa::parse(args.get_or("kappa", "1"))
                .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        )
        .prefetch(args.bool01("prefetch", false)?)
        .seed(args.or("seed", DEFAULT_SEED)?);
    if let Some(cache) = args.opt::<usize>("cache")? {
        b = b.cache_per_pe(cache);
    }
    let pipe = b.build()?;
    let slo_ms = args.or("slo-ms", 50.0f64)?;
    anyhow::ensure!(slo_ms > 0.0, "--slo-ms must be positive");
    let scfg = ServeConfig {
        rate_per_s: args.or("rate", 2000.0f64)?,
        slo_us: (slo_ms * 1e3).round() as u64,
        batcher: BatcherKind::parse(args.get_or("batcher", "adaptive"))
            .ok_or_else(|| anyhow::anyhow!("bad --batcher (fixed|adaptive)"))?,
        duration_batches: args.or("duration-batches", 32usize)?,
        fixed_batch_per_pe: args.or("batch", 32usize)?,
        workload: WorkloadKind::parse(args.get_or("workload", "open"))
            .ok_or_else(|| anyhow::anyhow!("bad --workload (open|closed)"))?,
        clients: args.or("clients", 64usize)?,
        hot_prob: args.or("hot", 0.8f64)?,
        preset: coopgnn::costmodel::preset(args.get_or("preset", "4xA100"))
            .ok_or_else(|| anyhow::anyhow!("bad --preset (4xA100|8xA100|16xV100)"))?,
        ..ServeConfig::default()
    };
    println!(
        "serving {} with {} {}-PE batching: {} workload at {:.0} req/s, SLO {:.1} ms, \
         {} batcher, {} batches ({} exec{})",
        pipe.ds.name,
        pipe.cfg.mode.name(),
        pipe.cfg.num_pes,
        scfg.workload.name(),
        scfg.rate_per_s,
        slo_ms,
        scfg.batcher.name(),
        scfg.duration_batches,
        pipe.cfg.exec.name(),
        if pipe.cfg.prefetch { ", prediction prefetch on" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let out = pipe.server(scfg)?.run();
    println!("{}", out.report);
    println!(
        "(simulated in {:.2}s real time; executor CPU {:.1} ms — measured, never consulted \
         by the virtual clock)",
        t0.elapsed().as_secs_f64(),
        out.exec_wall_ms
    );
    // The serve trace is derived from the (bit-reproducible) ledger, so
    // it inherits the virtual-clock identity across --exec/--prefetch.
    let buf = if args.has("trace") { Some(out.ledger.trace()) } else { None };
    write_obs_outputs(args, buf.as_ref(), &out.report)
}

fn cmd_caps(args: &ArgMap) -> coopgnn::Result<()> {
    let kind = SamplerKind::parse(args.get_or("sampler", "labor0"))
        .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?;
    let pipe = PipelineBuilder::new()
        .dataset(args.get_or("dataset", "tiny"))
        .sampler(kind)
        .seed(args.or("seed", DEFAULT_SEED)?)
        .build()?;
    let batch = args.or("batch", 256usize)?;
    let cfg = SamplerConfig::default();
    let caps = block::estimate_caps(
        &cfg,
        kind,
        &pipe.ds.graph,
        &pipe.ds.train,
        batch,
        args.or("trials", 5usize)?,
        1.25,
        args.or("seed", DEFAULT_SEED)?,
    );
    println!(
        "dataset {} batch {batch} {}: k={} n={:?}",
        pipe.ds.name,
        kind.name(),
        caps.k,
        caps.n
    );
    Ok(())
}

fn cmd_info() -> coopgnn::Result<()> {
    println!("coopgnn — Cooperative Minibatching in GNNs (reproduction)");
    println!("\ndatasets:");
    for s in datasets::SPECS {
        println!(
            "  {:<10} |V|={:<8} deg={:<6.1} d={:<4} C={:<3} mirrors {}",
            s.name, s.num_vertices, s.avg_degree, s.feat_dim, s.num_classes, s.mirrors
        );
    }
    if let Ok(m) = Manifest::load(&PathBuf::from("artifacts")) {
        println!("\nartifact configs:");
        for c in &m.configs {
            println!(
                "  {:<14} dataset={:<9} b={:<5} dims=({},{},{}) caps k={} n={:?}",
                c.name, c.dataset, c.batch, c.d_in, c.hidden, c.classes, c.caps.k, c.caps.n
            );
        }
    } else {
        println!("\n(no artifacts/ yet — run `make artifacts`)");
    }
    if let Ok(rt) = Runtime::cpu() {
        println!("\nPJRT platform: {}", rt.platform());
    }
    Ok(())
}

fn print_usage() {
    println!(
        "coopgnn — Cooperative Minibatching in GNNs\n\
         \n\
         All runs are built through coopgnn::pipeline (one seed default: 0xC0FFEE);\n\
         unknown flags and malformed values are errors.\n\
         \n\
         USAGE:\n\
         \x20 coopgnn repro <fig3|table3|fig5|fig5a|fig5b|table4|table5|table6|table7|fig9|\n\
         \x20        scaling|end2end|serve|all> [--out DIR] [--quick] [--seed N]\n\
         \x20        [--artifacts DIR] [--exec serial|threaded] [--codec f32|fp16|int8]\n\
         \x20        [--hot-mb N] [--replication r] [--intra-bw GBPS] [--inter-bw GBPS]\n\
         \x20 coopgnn train [--backend host|pjrt] [--dataset NAME] [--steps N] [--kappa K|inf]\n\
         \x20        [--sampler ns|labor0|labor*|rw] [--fanout K|K,K,..] [--layers L] [--hidden H]\n\
         \x20        [--batch B] [--lr F] [--eval-every N] [--seed N] [--prefetch 0|1]\n\
         \x20        [--codec f32|fp16|int8] [--hot-mb N]\n\
         \x20        (host backend: layered GNN compute plane, no artifacts needed;\n\
         \x20         --backend pjrt --config NAME takes shape/batch from the artifact)\n\
         \x20 coopgnn train --train-pes P [--mode coop|indep] [--dataset NAME] [--batch B]\n\
         \x20        [--layers L] [--hidden H] [--fanout K|K,K,..]\n\
         \x20        [--allreduce naive|tree|ring|rsag|auto] [--replication r]\n\
         \x20        [--intra-bw GBPS] [--inter-bw GBPS]\n\
         \x20        [--steps N] [--lr F] [--prefetch 0|1] [--trace FILE] [--metrics-out FILE]\n\
         \x20        (multi-PE training plane: per-PE layered replicas + activation exchange +\n\
         \x20         fabric gradient all-reduce; --replication r serves same-group rows\n\
         \x20         locally and reduces gradients hierarchically; --allreduce auto picks\n\
         \x20         by the alpha-beta cost model)\n\
         \x20 coopgnn engine --mode coop|indep --dataset NAME --pes P [--batch B] [--kappa K]\n\
         \x20        [--partitioner random|metis|ldg] [--batches N] [--exec serial|threaded]\n\
         \x20        [--prefetch 0|1] [--codec f32|fp16|int8] [--hot-mb N] [--replication r]\n\
         \x20        [--trace FILE] [--metrics-out FILE]\n\
         \x20 coopgnn serve [--dataset NAME] [--pes P] [--mode coop|indep] [--rate R]\n\
         \x20        [--slo-ms MS] [--batcher fixed|adaptive] [--duration-batches N]\n\
         \x20        [--batch B] [--workload open|closed] [--kappa K] [--cache ROWS]\n\
         \x20        [--exec serial|threaded] [--prefetch 0|1] [--codec f32|fp16|int8]\n\
         \x20        [--hot-mb N] [--replication r] [--trace FILE] [--metrics-out FILE]\n\
         \x20        (online inference: virtual-time SLO-aware dynamic cooperative batching;\n\
         \x20         --trace writes the virtual-clock flight record, bit-identical across\n\
         \x20         --exec and --prefetch at a fixed seed)\n\
         \x20 coopgnn caps --dataset NAME --batch B [--sampler S]\n\
         \x20 coopgnn info"
    );
}
