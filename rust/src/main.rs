//! `coopgnn` — the leader CLI.
//!
//! ```text
//! coopgnn repro <id|all> [--out DIR] [--quick] [--seed N]
//! coopgnn train --config NAME [--dataset NAME] [--steps N] [--kappa K]
//!               [--sampler ns|labor0|labor*|rw] [--lr F] [--eval-every N]
//! coopgnn engine --mode coop|indep --dataset NAME --pes P [--batch B]
//!               [--kappa K] [--batches N] [--partitioner random|metis|ldg]
//!               [--exec serial|threaded]
//! coopgnn caps --dataset NAME --batch B [--sampler S]
//! coopgnn info
//! ```
//!
//! (Hand-rolled arg parsing — the offline build has no clap.)

use coopgnn::coop::engine::{run as engine_run, EngineConfig, ExecMode, Mode};
use coopgnn::graph::{datasets, partition};
use coopgnn::repro::{self, Ctx};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{block, Kappa, SamplerConfig, SamplerKind};
use coopgnn::train::{Trainer, TrainerOptions};
use std::collections::HashMap;
use std::path::PathBuf;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` and `--flag` style args after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                eprintln!("warning: ignoring stray argument {a}");
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn real_main() -> coopgnn::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    match cmd {
        "repro" => {
            let id = argv.get(1).map(|s| s.as_str()).unwrap_or("all");
            let rest = Args::parse(argv.get(2..).unwrap_or(&[]));
            let ctx = Ctx {
                out: PathBuf::from(rest.get_or("out", "results")),
                quick: rest.has("quick"),
                seed: rest.u64_or("seed", 0xC0FFEE),
                artifacts: PathBuf::from(rest.get_or("artifacts", "artifacts")),
                exec: ExecMode::parse(rest.get_or("exec", "threaded"))
                    .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
            };
            repro::run(id, &ctx)
        }
        "train" => cmd_train(&Args::parse(&argv[1..])),
        "engine" => cmd_engine(&Args::parse(&argv[1..])),
        "caps" => cmd_caps(&Args::parse(&argv[1..])),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

fn cmd_train(args: &Args) -> coopgnn::Result<()> {
    let config = args.get_or("config", "tiny-b32").to_string();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&artifacts)?;
    let art = manifest.get(&config)?;
    let ds_name = args.get_or("dataset", &art.dataset).to_string();
    let ds = datasets::build(&ds_name, args.u64_or("seed", 1))?;
    let steps = args.usize_or("steps", 300);
    let eval_every = args.usize_or("eval-every", 50);
    let opts = TrainerOptions {
        kind: SamplerKind::parse(args.get_or("sampler", "labor0"))
            .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        kappa: Kappa::parse(args.get_or("kappa", "1"))
            .ok_or_else(|| anyhow::anyhow!("bad --kappa"))?,
        fanout: args.usize_or("fanout", 10),
        seed: args.u64_or("seed", 0x7EA1),
        lr: args.get("lr").and_then(|v| v.parse().ok()),
        ..Default::default()
    };
    let mut trainer = Trainer::new(&rt, &manifest, &config, &ds, &opts)?;
    println!(
        "training {config} on {ds_name}: {} params, {} train vertices, batch {}",
        trainer.state.num_scalars(),
        ds.train.len(),
        trainer.art.batch
    );
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let s = trainer.step()?;
        if step % eval_every == 0 || step == 1 || step == steps {
            let val = trainer.evaluate(&ds.val, 1234)?;
            println!(
                "step {step:>5}  loss {:.4}  batch-acc {:.3}  val-acc {:.4}  val-F1 {:.4}  \
                 [samp {:.1}ms pad {:.1}ms feat {:.1}ms exec {:.1}ms]",
                s.loss, s.acc, val.accuracy, val.macro_f1,
                s.sample_ms, s.pad_ms, s.feature_ms, s.exec_ms
            );
        }
    }
    let test = trainer.evaluate(&ds.test, 1234)?;
    println!(
        "done in {:.1}s: test acc {:.4}, test F1 {:.4}",
        t0.elapsed().as_secs_f64(),
        test.accuracy,
        test.macro_f1
    );
    Ok(())
}

fn cmd_engine(args: &Args) -> coopgnn::Result<()> {
    let ds = datasets::build(args.get_or("dataset", "tiny"), args.u64_or("seed", 1))?;
    let pes = args.usize_or("pes", 4);
    let mode = match args.get_or("mode", "coop") {
        "coop" => Mode::Cooperative,
        "indep" => Mode::Independent,
        other => anyhow::bail!("bad --mode {other}"),
    };
    let part = match args.get_or("partitioner", "random") {
        "random" => partition::random(&ds.graph, pes, 1),
        "metis" => partition::multilevel(&ds.graph, pes, 1),
        "ldg" => partition::ldg(&ds.graph, pes, 1),
        other => anyhow::bail!("bad --partitioner {other}"),
    };
    let mut cfg = EngineConfig {
        mode,
        exec: ExecMode::parse(args.get_or("exec", "threaded"))
            .ok_or_else(|| anyhow::anyhow!("bad --exec (serial|threaded)"))?,
        num_pes: pes,
        batch_per_pe: args.usize_or("batch", 1024),
        cache_per_pe: args.usize_or("cache", ds.cache_size / pes.max(1)),
        kind: SamplerKind::parse(args.get_or("sampler", "labor0"))
            .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?,
        warmup_batches: args.usize_or("warmup", 4),
        measure_batches: args.usize_or("batches", 8),
        seed: args.u64_or("seed", 2),
        ..Default::default()
    };
    cfg.sampler.kappa =
        Kappa::parse(args.get_or("kappa", "1")).ok_or_else(|| anyhow::anyhow!("bad --kappa"))?;
    let r = engine_run(&ds, &part, &cfg);
    println!(
        "mode={} exec={} PEs={} cross-edge-ratio={:.3}",
        r.mode,
        cfg.exec.name(),
        r.num_pes,
        part.cross_edge_ratio(&ds.graph)
    );
    println!("per-layer S (max/PE, avg): {:?}", r.s.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer E: {:?}", r.e.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer S~: {:?}", r.tilde.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!("per-layer cross: {:?}", r.cross.iter().map(|x| *x as u64).collect::<Vec<_>>());
    println!(
        "feature: requested {:.0}/batch, misses {:.0}, fabric rows {:.0}, miss rate {:.4}",
        r.feat_requested, r.feat_misses, r.feat_fabric_rows, r.cache_miss_rate
    );
    println!("dup factor @L: {:.3}", r.dup_factor);
    println!(
        "CPU wall: sampling {:.2} ms/batch + feature {:.2} ms/batch (per-PE elapsed, summed; \
         includes exchange waits in threaded mode); batch wall {:.2} ms \
         (compare --exec serial vs threaded for the concurrency speedup)",
        r.wall_sampling_ms, r.wall_feature_ms, r.wall_batch_ms
    );
    Ok(())
}

fn cmd_caps(args: &Args) -> coopgnn::Result<()> {
    let ds = datasets::build(args.get_or("dataset", "tiny"), args.u64_or("seed", 1))?;
    let batch = args.usize_or("batch", 256);
    let kind = SamplerKind::parse(args.get_or("sampler", "labor0"))
        .ok_or_else(|| anyhow::anyhow!("bad --sampler"))?;
    let cfg = SamplerConfig::default();
    let caps = block::estimate_caps(
        &cfg,
        kind,
        &ds.graph,
        &ds.train,
        batch,
        args.usize_or("trials", 5),
        1.25,
        args.u64_or("seed", 42),
    );
    println!("dataset {} batch {batch} {}: k={} n={:?}", ds.name, kind.name(), caps.k, caps.n);
    Ok(())
}

fn cmd_info() -> coopgnn::Result<()> {
    println!("coopgnn — Cooperative Minibatching in GNNs (reproduction)");
    println!("\ndatasets:");
    for s in datasets::SPECS {
        println!(
            "  {:<10} |V|={:<8} deg={:<6.1} d={:<4} C={:<3} mirrors {}",
            s.name, s.num_vertices, s.avg_degree, s.feat_dim, s.num_classes, s.mirrors
        );
    }
    if let Ok(m) = Manifest::load(&PathBuf::from("artifacts")) {
        println!("\nartifact configs:");
        for c in &m.configs {
            println!(
                "  {:<14} dataset={:<9} b={:<5} dims=({},{},{}) caps k={} n={:?}",
                c.name, c.dataset, c.batch, c.d_in, c.hidden, c.classes, c.caps.k, c.caps.n
            );
        }
    } else {
        println!("\n(no artifacts/ yet — run `make artifacts`)");
    }
    if let Ok(rt) = Runtime::cpu() {
        println!("\nPJRT platform: {}", rt.platform());
    }
    Ok(())
}

fn print_usage() {
    println!(
        "coopgnn — Cooperative Minibatching in GNNs\n\
         \n\
         USAGE:\n\
         \x20 coopgnn repro <fig3|table3|fig5a|fig5b|table4|table5|table6|table7|fig9|scaling|all>\n\
         \x20        [--out DIR] [--quick] [--seed N] [--artifacts DIR] [--exec serial|threaded]\n\
         \x20 coopgnn train --config NAME [--steps N] [--kappa K|inf] [--sampler ns|labor0|labor*|rw]\n\
         \x20        [--lr F] [--eval-every N] [--seed N]\n\
         \x20 coopgnn engine --mode coop|indep --dataset NAME --pes P [--batch B] [--kappa K]\n\
         \x20        [--partitioner random|metis|ldg] [--batches N] [--exec serial|threaded]\n\
         \x20 coopgnn caps --dataset NAME --batch B [--sampler S]\n\
         \x20 coopgnn info"
    );
}
