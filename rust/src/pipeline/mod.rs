//! The unified minibatch pipeline — one construction path, one stream.
//!
//! The paper's central claim is that cooperative, independent, and
//! dependent (κ > 1) minibatching are interchangeable strategies over
//! the *same* stream of minibatches. This module is that claim as API:
//!
//! * [`args`] — the single strict `--key value` parse layer (unknown
//!   flags error with a listing; malformed values never silently
//!   default; negative numbers are values, not flags).
//! * [`PipelineConfig`] / [`PipelineBuilder`] — one typed, validated
//!   description of a run (dataset, PEs, mode, exec, partitioner,
//!   sampler, fanout, κ, cache, seed), replacing the per-stack config
//!   plumbing that used to be duplicated across `main.rs`, `repro::Ctx`,
//!   the benches, and the examples. All seed defaults funnel through
//!   [`DEFAULT_SEED`].
//! * [`MinibatchStream`] — `fn next_batch(&mut self) -> Minibatch`:
//!   per-PE MFG work plus feature/fabric traffic accounting **and the
//!   dense input-feature buffers** (real rows out of the partitioned
//!   [`crate::feature::FeatureStore`], through per-PE LRU row caches
//!   and, cooperatively, over the channel fabric). [`EngineStream`] is
//!   the thread-per-PE measurement stream `coop::engine::run` drains;
//!   [`TrainStream`] is the training front half (`Batching::Single`
//!   shared-coin global batches or `Batching::IndepMerged`
//!   block-diagonal merges) the `Trainer` consumes.
//! * [`prefetch`] — [`with_prefetch`] double-buffers any `Send` stream
//!   behind a producer thread (`--prefetch 1`): batch t+1's sampling +
//!   gathering overlaps batch t's consumption, bit-identically.
//!
//! [`EngineStream`] is also the **reusable service core**: besides the
//! training-shard `next_batch` path it exposes
//! [`EngineStream::batch_for_seeds`], which executes a batch for an
//! *explicit* per-PE seed assignment over the same persistent
//! samplers/caches/fabric — the entry point the serving plane
//! ([`crate::serve`], [`config::Pipeline::server`]) drives with online
//! request vertices.
//!
//! Every entry stack — CLI `engine`/`train`/`serve`, the repro
//! harnesses, `bench_coop`/`bench_train_step`/`bench_serve`, and all
//! examples — builds its run through here, so a new workload is a
//! one-line consumer change rather than a fifth stack.
//!
//! ```no_run
//! use coopgnn::coop::engine::Mode;
//! use coopgnn::pipeline::PipelineBuilder;
//!
//! let pipe = PipelineBuilder::new()
//!     .dataset("tiny")
//!     .mode(Mode::Cooperative)
//!     .num_pes(4)
//!     .batch_per_pe(64)
//!     .build()
//!     .unwrap();
//! let report = pipe.engine_report();
//! println!("per-PE |S^3| = {:.0}", report.s[3]);
//! ```

pub mod args;
pub mod config;
pub mod prefetch;
pub mod stream;
pub mod train_stream;

pub use config::{Partitioner, Pipeline, PipelineBuilder, PipelineConfig, DEFAULT_SEED};
pub use prefetch::{with_prefetch, PrefetchedStream};
pub use stream::{EngineStream, Minibatch, MinibatchStream, PeWork};
pub use train_stream::{sample_indep_parts, Batching, TrainStream, SEED_DRAW_SALT};
