//! `MinibatchStream` — the one seam every consumer pulls batches from.
//!
//! The paper's three strategies (independent, cooperative, dependent
//! κ > 1) are interchangeable policies over the *same* stream of
//! minibatches. This module makes that literal: a stream yields one
//! [`Minibatch`] per call — per-PE work records with feature/fabric
//! traffic accounting **and the dense input-feature buffers themselves**
//! (real bytes, pulled through per-PE row caches from the partitioned
//! [`crate::feature::FeatureStore`] and, in cooperative mode, over the
//! channel fabric) — and the consumers differ only in what they do with
//! it:
//!
//! * `coop::engine::run` drains a stream and reduces the per-PE records
//!   into an `EngineReport` (Tables 4–7, Figure 5);
//! * `train::Trainer` executes the merged MFG through the AOT train step,
//!   consuming the stream's pre-gathered feature buffer;
//! * benches time `next_batch` directly.
//!
//! [`EngineStream`] is the measurement stream: it owns the per-PE
//! samplers, seed-RNG streams, LRU row caches, the feature-store shards,
//! and (cooperative mode) the live channel fabric, and preserves the
//! engine's determinism contract — for a fixed seed,
//! [`ExecMode::Serial`] and [`ExecMode::Threaded`] yield bit-identical
//! counts, and both match the pre-stream PR-1 engine loops (tested in
//! `coop::engine`). Training streams live in [`super::train_stream`];
//! the double-buffered producer wrapper lives in [`super::prefetch`].

use crate::coop::all_to_all::{Exchange, Fabric, PeEndpoint, Topology};
use crate::coop::cache::LruCache;
use crate::coop::coop_sampler::{sample_cooperative, sample_cooperative_pe, PeLayer};
use crate::coop::engine::{EngineConfig, ExecMode, Mode};
use crate::coop::feature_loader::{load_cooperative, load_pe, load_pe_cooperative, PeLoad};
use crate::coop::indep::sample_independent;
use crate::feature::{FeatureStore, PartitionedFeatureStore};
use crate::graph::{Csr, Dataset, Partition, VertexId};
use crate::model::{blocks_from_mfg, CoopRoutes, HostBlock, PeCompute};
use crate::sampling::{Mfg, Sampler};
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;
use std::sync::Arc;

/// One PE's work record for one minibatch: the per-layer counts of the
/// paper's Table 1 plus feature/fabric traffic (counts *and* measured
/// bytes) and stage wall-clock.
#[derive(Clone, Debug, Default)]
pub struct PeWork {
    /// |S_p^l| for l in 0..=L (final entry = owned input vertices).
    pub counts_s: Vec<u64>,
    /// |E_p^l| for l in 0..L.
    pub counts_e: Vec<u64>,
    /// |S̃_p^{l+1}| for l in 0..L (cooperative; 0 for independent).
    pub counts_tilde: Vec<u64>,
    /// cross-PE portion c·|S̃_p^{l+1}| for l in 0..L.
    pub counts_cross: Vec<u64>,
    /// vertex rows requested through this PE's cache.
    pub requested: u64,
    /// cache misses (rows read from a store tier).
    pub misses: u64,
    /// feature rows crossing the fabric (cooperative; α bandwidth).
    pub fabric: u64,
    /// *wire* bytes of one encoded feature row for this stream (constant
    /// per stream; lets the reduction derive byte-based rates without
    /// the store).
    pub row_bytes: u64,
    /// floats per feature row (the decoded width consumers compute on —
    /// no longer derivable from `row_bytes` once a codec is active).
    pub dim: u64,
    /// wire bytes actually copied out of cold storage this batch (β).
    pub bytes_from_storage: u64,
    /// wire bytes that arrived over the fabric this batch (α).
    pub fabric_bytes: u64,
    /// wire bytes this PE's row sends pushed across a replica-group
    /// boundary (owner-side classified; equals the fabric-wide
    /// `fabric_bytes` when summed at replication 1).
    pub fabric_inter_bytes: u64,
    /// cache misses served by the store's hot tier this batch (γ).
    pub hot_rows: u64,
    /// decoded bytes those hot fills moved.
    pub hot_bytes: u64,
    /// rows the costmodel-driven prefetcher promoted into the hot tier
    /// ahead of this batch (charged to the stream's first record).
    pub prefetch_rows: u64,
    /// wire bytes those prefetch fetches pulled from cold storage.
    pub prefetch_bytes: u64,
    /// this PE's dense row-major input-feature buffer, in
    /// `feature_vertices` order (the payload consumers execute on).
    pub features: Option<Vec<f32>>,
    /// the vertex list `features` covers: `S^L` (independent) or sorted
    /// `S̃^L` (cooperative).
    pub feature_vertices: Option<Vec<VertexId>>,
    /// S_p^L vertex list (independent mode; feeds the duplication-factor
    /// union in the engine reduction).
    pub input_vertices: Option<Vec<VertexId>>,
    /// this PE's elapsed sampling time (includes exchange waits in
    /// threaded mode).
    pub samp_ms: f64,
    /// this PE's elapsed feature-loading time.
    pub feat_ms: f64,
    /// this PE's layered compute payload (blocks over `features`, plus
    /// activation routes in cooperative mode) — what the multi-PE
    /// training plane and the serving executor run the model on.
    /// `None` only for streams that never materialize per-PE work
    /// (e.g. the merged-MFG training stream, which carries the MFG
    /// itself instead).
    pub compute: Option<PeCompute>,
}

/// One minibatch pulled from a stream.
#[derive(Clone, Debug, Default)]
pub struct Minibatch {
    /// 0-based position in the stream.
    pub index: usize,
    /// one record per PE.
    pub per_pe: Vec<PeWork>,
    /// the merged global MFG, when the stream materializes one (training
    /// streams do; measurement streams yield counts only).
    pub merged: Option<Mfg>,
    /// wall-clock of the whole batch (all PEs, concurrent in threaded
    /// mode).
    pub wall_ms: f64,
}

/// A source of minibatches. Object-safe: consumers hold
/// `&mut dyn MinibatchStream` and stay agnostic of the strategy behind
/// it.
pub trait MinibatchStream {
    /// Produce the next minibatch, advancing all per-PE RNG/cache state.
    fn next_batch(&mut self) -> Minibatch;
    fn num_pes(&self) -> usize;
    fn layers(&self) -> usize;
    fn mode(&self) -> Mode;

    /// Tell the stream no further batch will be pulled. Inline streams
    /// have nothing to do (the default), but a consumer that knows it
    /// just pulled its last batch should call this before its tail work:
    /// [`super::prefetch::PrefetchedStream`] uses it to stop its
    /// producer thread at the next send instead of sampling + gathering
    /// batches nobody will consume. Calling [`next_batch`] after
    /// `finish` is a consumer bug (the prefetched stream panics).
    ///
    /// [`next_batch`]: MinibatchStream::next_batch
    fn finish(&mut self) {}
}

/// Per-PE seed RNG stream, split deterministically from the engine seed
/// (identical in serial and threaded modes).
pub(crate) fn pe_seed(seed: u64, pe: usize) -> u64 {
    seed ^ ((pe as u64 + 1) * 0x9E37)
}

/// Per-PE training shards. Coop: PE p draws seeds from train ∩ V_p
/// (Algorithm 1). Indep: the training set is sharded round-robin
/// (classic data parallelism).
pub(crate) fn make_shards(
    dataset: &Dataset,
    part: &Partition,
    mode: Mode,
    num_pes: usize,
) -> Vec<Vec<VertexId>> {
    match mode {
        Mode::Cooperative => {
            let mut by_owner: Vec<Vec<VertexId>> = vec![Vec::new(); num_pes];
            for &v in &dataset.train {
                by_owner[part.part_of(v)].push(v);
            }
            by_owner
        }
        Mode::Independent => {
            let mut shards: Vec<Vec<VertexId>> = vec![Vec::new(); num_pes];
            for (i, &v) in dataset.train.iter().enumerate() {
                shards[i % num_pes].push(v);
            }
            shards
        }
    }
}

/// Turn one PE's retained per-layer sample structure into the layered
/// compute payload: host blocks (CSR positions into each layer's tilde
/// with `1/(deg+1)` mean weights, the same convention as `Mfg::pad` /
/// [`HostBlock::from_mfg_layer`]) plus the activation-exchange routes.
/// `recv_src[l]` is layer `l`'s tilde ownership; `send_pos[l][q]` maps
/// requester `q`'s round-`l` inbox ids to rows of this PE's owned
/// `S_p^{l+1}` (sorted, so positions resolve by binary search).
pub(crate) fn coop_pe_compute(layers: usize, pe_layers: &[&PeLayer]) -> PeCompute {
    let blocks: Vec<HostBlock> = (0..layers)
        .map(|l| {
            let pl = pe_layers[l];
            let n_dst = pl.owned.len();
            let mut nbr_w = vec![0f32; pl.nbr_pos.len()];
            let mut self_w = Vec::with_capacity(n_dst);
            for i in 0..n_dst {
                let (s, e) = (pl.nbr_offsets[i] as usize, pl.nbr_offsets[i + 1] as usize);
                let inv = 1.0 / ((e - s) as f32 + 1.0);
                for w in &mut nbr_w[s..e] {
                    *w = inv;
                }
                self_w.push(inv);
            }
            HostBlock {
                n_dst,
                n_src: pl.tilde.len(),
                offsets: pl.nbr_offsets.clone(),
                nbr_pos: pl.nbr_pos.clone(),
                nbr_w,
                self_pos: pl.self_pos.clone(),
                self_w,
            }
        })
        .collect();
    let routes = CoopRoutes {
        recv_src: (0..layers - 1).map(|l| pe_layers[l].tilde_owner.clone()).collect(),
        send_pos: (0..layers - 1)
            .map(|l| {
                let owned_next = &pe_layers[l + 1].owned;
                pe_layers[l]
                    .inbox
                    .iter()
                    .map(|req| {
                        req.iter()
                            .map(|v| {
                                owned_next.binary_search(v).expect("inbox id is owned") as u32
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect(),
    };
    PeCompute { blocks, seeds: pe_layers[0].owned.clone(), routes: Some(routes) }
}

/// Assemble one PE's cooperative-mode work record from its per-layer
/// counts and its feature-loading result (owner-side storage pull +
/// requester-side fabric arrivals + the dense buffer). Shared by both
/// exec modes so the construction can never drift between them (stage
/// times are assigned by the caller).
pub(crate) fn coop_pe_work(
    layers: usize,
    pe_layers: &[&PeLayer],
    dim: u64,
    row_bytes: u64,
    load: PeLoad,
) -> PeWork {
    let mut counts_s: Vec<u64> = pe_layers.iter().map(|pl| pl.owned.len() as u64).collect();
    counts_s.push(load.requested);
    // equality at replication 1; with replica groups the same-group share
    // of the sampled cross count is mirror-served off the fabric
    debug_assert!(
        load.fabric_rows <= pe_layers[layers - 1].cross as u64,
        "measured fabric rows cannot exceed the sampled cross count"
    );
    PeWork {
        counts_s,
        counts_e: pe_layers.iter().map(|pl| pl.edges as u64).collect(),
        counts_tilde: pe_layers.iter().map(|pl| pl.tilde.len() as u64).collect(),
        counts_cross: pe_layers.iter().map(|pl| pl.cross as u64).collect(),
        requested: load.requested,
        misses: load.misses,
        fabric: load.fabric_rows,
        row_bytes,
        dim,
        bytes_from_storage: load.bytes_from_storage,
        fabric_bytes: load.fabric_bytes,
        fabric_inter_bytes: load.fabric_inter_bytes,
        hot_rows: load.hot_rows,
        hot_bytes: load.hot_bytes,
        prefetch_rows: 0,
        prefetch_bytes: 0,
        features: Some(load.features),
        feature_vertices: Some(pe_layers[layers - 1].tilde.clone()),
        input_vertices: None,
        samp_ms: 0.0,
        feat_ms: 0.0,
        compute: Some(coop_pe_compute(layers, pe_layers)),
    }
}

/// Assemble one PE's independent-mode work record from its private MFG
/// and feature-loading result (shared by both exec modes; `keep_inputs`
/// retains the S^L vertex list for the duplication-factor union).
pub(crate) fn indep_pe_work(
    mfg: &Mfg,
    layers: usize,
    keep_inputs: bool,
    dim: u64,
    row_bytes: u64,
    load: PeLoad,
) -> PeWork {
    PeWork {
        counts_s: mfg.vertex_counts().iter().map(|&c| c as u64).collect(),
        counts_e: mfg.edge_counts().iter().map(|&c| c as u64).collect(),
        counts_tilde: vec![0; layers],
        counts_cross: vec![0; layers],
        requested: load.requested,
        misses: load.misses,
        fabric: 0,
        row_bytes,
        dim,
        bytes_from_storage: load.bytes_from_storage,
        fabric_bytes: 0,
        fabric_inter_bytes: 0,
        hot_rows: load.hot_rows,
        hot_bytes: load.hot_bytes,
        prefetch_rows: 0,
        prefetch_bytes: 0,
        features: Some(load.features),
        feature_vertices: Some(mfg.input_vertices().to_vec()),
        input_vertices: if keep_inputs { Some(mfg.input_vertices().to_vec()) } else { None },
        samp_ms: 0.0,
        feat_ms: 0.0,
        compute: Some(PeCompute {
            blocks: blocks_from_mfg(mfg),
            seeds: mfg.seeds().to_vec(),
            routes: None,
        }),
    }
}

/// Pull one independent-mode PE's input rows through its cache into a
/// [`PeLoad`] (no fabric traffic). Shared with the PR-1 oracle loops in
/// `coop::engine::tests`.
pub(crate) fn load_indep_pe<S: FeatureStore + ?Sized>(
    vs: &[VertexId],
    cache: &mut LruCache,
    store: &S,
) -> PeLoad {
    let mut features = Vec::new();
    let stats = load_pe(vs, cache, store, &mut features);
    PeLoad {
        requested: stats.requested,
        misses: stats.misses,
        bytes_from_storage: stats.bytes_from_storage,
        hot_rows: stats.hot_rows,
        hot_bytes: stats.hot_bytes,
        fabric_rows: 0,
        fabric_bytes: 0,
        fabric_inter_bytes: 0,
        features,
    }
}

/// Converts a PE-thread panic into a fast process abort. `std::sync::
/// Barrier` has no poisoning and every surviving endpoint keeps live
/// `Sender` clones for all peers, so a single panicking PE would
/// otherwise leave the remaining threads blocked forever in `wait()` /
/// `recv()` — a silent CI hang instead of a failure. A panic inside a PE
/// thread is always a bug; after the default hook prints it, failing the
/// whole process immediately is strictly better than deadlock.
pub(crate) struct AbortOnPeerPanic;

impl Drop for AbortOnPeerPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!("engine: PE thread panicked; aborting to avoid deadlocking peer PEs");
            std::process::abort();
        }
    }
}

/// The measurement stream behind `coop::engine::run`: per-PE samplers,
/// deterministic seed-RNG streams, LRU row caches, the partitioned
/// feature store, and (cooperative + threaded) the live channel fabric,
/// all persistent across batches.
///
/// `ExecMode::Threaded` runs one scoped OS thread per PE *per batch*;
/// the per-PE state lives in the stream between calls, so the RNG/cache
/// sequences — and therefore every count — are bit-identical to the
/// serial loop and to the PR-1 thread-per-run engine.
pub struct EngineStream<'d> {
    mode: Mode,
    exec: ExecMode,
    layers: usize,
    batch_per_pe: usize,
    /// batches before this index are warmup: their S^L input-vertex
    /// lists are never reduced, so the stream skips retaining them.
    warmup_batches: usize,
    graph: &'d Csr,
    part: &'d Partition,
    store: Arc<dyn FeatureStore>,
    shards: Vec<Vec<VertexId>>,
    samplers: Vec<Sampler<'d>>,
    caches: Vec<LruCache>,
    seed_rngs: Vec<Pcg64>,
    /// replica-group layout shared by every fabric this stream builds.
    topo: Topology,
    /// live fabric endpoints (cooperative + threaded only).
    endpoints: Vec<Option<PeEndpoint>>,
    /// when set, each `next_batch` predicts the *next* batch's seed
    /// rows (exact — the per-PE seed RNG streams are deterministic) and
    /// promotes them into the store's hot tier under the costmodel's
    /// cold-bandwidth budget. A no-op for untiered stores.
    prefetch: bool,
    index: usize,
}

impl<'d> EngineStream<'d> {
    /// Build a stream over `dataset` with partition `part` (cooperative
    /// mode requires it; independent mode uses it to shard the training
    /// set and the feature store). Materializes the partitioned feature
    /// store — reuse one via [`EngineStream::with_store`] when standing
    /// up many streams over the same dataset + partition.
    pub fn new(dataset: &'d Dataset, part: &'d Partition, cfg: &EngineConfig) -> EngineStream<'d> {
        let store: Arc<dyn FeatureStore> = Arc::new(PartitionedFeatureStore::build(dataset, part));
        EngineStream::with_store(dataset, part, cfg, store)
    }

    /// Build a stream sharing an existing feature store (must have been
    /// built from the same `dataset` + `part`).
    pub fn with_store(
        dataset: &'d Dataset,
        part: &'d Partition,
        cfg: &EngineConfig,
        store: Arc<dyn FeatureStore>,
    ) -> EngineStream<'d> {
        assert_eq!(part.num_parts, cfg.num_pes, "partition/PE mismatch");
        assert!(cfg.sampler.layers >= 1, "engine needs at least one GNN layer");
        assert_eq!(store.dim(), dataset.feat_dim, "store/dataset row shape mismatch");
        let p = cfg.num_pes;
        let g = &dataset.graph;
        let codec = store.codec();
        let topo = Topology::new(p, cfg.replication);
        let endpoints: Vec<Option<PeEndpoint>> =
            if cfg.mode == Mode::Cooperative && cfg.exec == ExecMode::Threaded {
                Fabric::endpoints_with(topo).into_iter().map(Some).collect()
            } else {
                (0..p).map(|_| None).collect()
            };
        EngineStream {
            mode: cfg.mode,
            exec: cfg.exec,
            layers: cfg.sampler.layers,
            batch_per_pe: cfg.batch_per_pe,
            warmup_batches: cfg.warmup_batches,
            graph: g,
            part,
            store,
            shards: make_shards(dataset, part, cfg.mode, p),
            samplers: (0..p).map(|_| cfg.sampler.build(cfg.kind, g, cfg.seed)).collect(),
            caches: (0..p)
                .map(|_| {
                    // cache arenas hold whatever the store's wire format
                    // is — encoded rows shrink the resident footprint by
                    // the codec ratio
                    if codec == crate::feature::Codec::F32 {
                        LruCache::with_rows(cfg.cache_per_pe, dataset.feat_dim)
                    } else {
                        LruCache::with_encoded(cfg.cache_per_pe, dataset.feat_dim, codec)
                    }
                })
                .collect(),
            seed_rngs: (0..p).map(|pe| Pcg64::new(pe_seed(cfg.seed, pe))).collect(),
            topo,
            endpoints,
            prefetch: cfg.prefetch,
            index: 0,
        }
    }

    /// The feature store backing this stream.
    pub fn feature_store(&self) -> Arc<dyn FeatureStore> {
        Arc::clone(&self.store)
    }

    /// Assign a flat seed list to PEs the way this stream's mode
    /// requires: by vertex owner in cooperative mode (Algorithm 1's
    /// "each PE samples its seeds from V_p"), round-robin in
    /// independent mode. The companion of
    /// [`EngineStream::batch_for_seeds`] for callers (evaluation, the
    /// serving plane) holding a global vertex list.
    pub fn assign_seeds(&self, seeds: &[VertexId]) -> Vec<Vec<VertexId>> {
        match self.mode {
            Mode::Cooperative => {
                crate::coop::coop_sampler::partition_seeds(seeds, self.part)
            }
            Mode::Independent => {
                let p = self.samplers.len();
                let mut out: Vec<Vec<VertexId>> = vec![Vec::new(); p];
                for (i, &v) in seeds.iter().enumerate() {
                    out[i % p].push(v);
                }
                out
            }
        }
    }

    /// Draw this batch's per-PE seed vertices from the training shards
    /// (each PE's own seed-RNG stream; identical values in serial and
    /// threaded mode because every PE only ever touches its own RNG).
    fn draw_seeds(&mut self) -> Vec<Vec<VertexId>> {
        let b = self.batch_per_pe;
        self.shards
            .iter()
            .zip(self.seed_rngs.iter_mut())
            .map(|(shard, rng)| {
                let k = b.min(shard.len());
                rng.sample_distinct(shard.len(), k)
                    .into_iter()
                    .map(|i| shard[i as usize])
                    .collect()
            })
            .collect()
    }

    /// Produce one minibatch for an **explicit** per-PE seed assignment,
    /// advancing the per-PE sampler/cache/fabric state exactly like
    /// [`MinibatchStream::next_batch`] but leaving the training-shard
    /// seed RNGs untouched. This is the reusable service core of the
    /// engine: the serving plane ([`crate::serve`]) admits online
    /// requests, assigns each to a PE (by owner in cooperative mode,
    /// round-robin in independent mode), and executes the batch through
    /// this entry point — per-PE sampling, row-carrying fabric exchange,
    /// and LRU caches that stay warm *across* batches, exactly like
    /// κ-dependent minibatching.
    ///
    /// Cooperative mode requires `per_pe_seeds[p] ⊆ V_p` (asserted by
    /// the cooperative sampler's ownership invariant); both modes accept
    /// empty per-PE lists (a PE with no work still participates in every
    /// all-to-all round). Explicit-seed batches never feed the engine
    /// reduction's duplication-factor union, so the independent-mode
    /// `S^L` vertex lists are not retained (`PeWork::input_vertices`
    /// stays `None`).
    pub fn batch_for_seeds(&mut self, per_pe_seeds: Vec<Vec<VertexId>>) -> Minibatch {
        self.batch_inner(per_pe_seeds, false)
    }

    /// Predict the **next** batch's per-PE seed draws — exact, not
    /// heuristic: the per-PE seed-RNG streams are deterministic, so a
    /// clone of each replays tomorrow's `sample_distinct` today — and
    /// promote those rows into the store's hot tier, bounded by how many
    /// rows the costmodel says cold storage can deliver inside one
    /// prefetch window. Returns `(rows fetched, wire bytes pulled)`;
    /// both are 0 for untiered stores, so the default path only pays a
    /// cheap RNG replay.
    fn prefetch_next(&mut self) -> (u64, u64) {
        let b = self.batch_per_pe;
        let mut predicted: Vec<VertexId> = Vec::new();
        for (shard, rng) in self.shards.iter().zip(self.seed_rngs.iter()) {
            let mut probe = rng.clone();
            let k = b.min(shard.len());
            predicted.extend(
                probe.sample_distinct(shard.len(), k).into_iter().map(|i| shard[i as usize]),
            );
        }
        let budget = crate::costmodel::default_prefetch_row_budget(self.store.row_bytes());
        let rows = self.store.prefetch_into_hot(&predicted, budget);
        (rows, rows * self.store.row_bytes() as u64)
    }

    /// Shared core of [`MinibatchStream::next_batch`] and
    /// [`EngineStream::batch_for_seeds`]: `keep_inputs` retains each
    /// independent-mode PE's `S^L` list for the engine's
    /// duplication-factor union (measured training batches only).
    fn batch_inner(&mut self, per_pe_seeds: Vec<Vec<VertexId>>, keep_inputs: bool) -> Minibatch {
        assert_eq!(per_pe_seeds.len(), self.samplers.len(), "seed assignment/PE mismatch");
        let (per_pe, wall_ms) = match self.exec {
            ExecMode::Serial => {
                let wall = Timer::start();
                let per_pe = self.batch_serial(per_pe_seeds, keep_inputs);
                (per_pe, wall.elapsed_ms())
            }
            ExecMode::Threaded => self.batch_threaded(per_pe_seeds, keep_inputs),
        };
        let index = self.index;
        self.index += 1;
        Minibatch { index, per_pe, merged: None, wall_ms }
    }

    /// Single-threaded reference: all PEs' work inline, batch stage
    /// times assigned to the first record so the cross-PE sum keeps its
    /// meaning.
    fn batch_serial(&mut self, per_pe_seeds: Vec<Vec<VertexId>>, keep_inputs: bool) -> Vec<PeWork> {
        let p_count = self.samplers.len();
        let layers = self.layers;
        let row_bytes = self.store.row_bytes() as u64;
        let dim = self.store.dim() as u64;

        let (mut per_pe, samp_ms, feat_ms): (Vec<PeWork>, f64, f64) = match self.mode {
            Mode::Cooperative => {
                let t = Timer::start();
                let coop = sample_cooperative(
                    self.graph,
                    self.part,
                    &mut self.samplers,
                    &per_pe_seeds,
                    layers,
                );
                let samp_ms = t.elapsed_ms();
                let t = Timer::start();
                let tildes: Vec<Vec<VertexId>> =
                    coop.layers[layers - 1].iter().map(|pl| pl.tilde.clone()).collect();
                let mut row_fabric = Exchange::with_topology(self.topo);
                let loads = load_cooperative(
                    &tildes,
                    &coop.final_requests,
                    &coop.final_owned,
                    self.part,
                    &mut self.caches,
                    &*self.store,
                    &mut row_fabric,
                );
                let per_pe = loads
                    .into_iter()
                    .enumerate()
                    .map(|(p, load)| {
                        let pe_layers: Vec<&PeLayer> =
                            (0..layers).map(|l| &coop.layers[l][p]).collect();
                        coop_pe_work(layers, &pe_layers, dim, row_bytes, load)
                    })
                    .collect();
                (per_pe, samp_ms, t.elapsed_ms())
            }
            Mode::Independent => {
                let t = Timer::start();
                let s = sample_independent(&mut self.samplers, &per_pe_seeds);
                let samp_ms = t.elapsed_ms();
                let t = Timer::start();
                let per_pe = s
                    .per_pe
                    .iter()
                    .zip(self.caches.iter_mut())
                    .map(|(mfg, cache)| {
                        let load = load_indep_pe(mfg.input_vertices(), cache, &*self.store);
                        indep_pe_work(mfg, layers, keep_inputs, dim, row_bytes, load)
                    })
                    .collect();
                (per_pe, samp_ms, t.elapsed_ms())
            }
        };
        for s in self.samplers.iter_mut() {
            s.advance_batch();
        }
        per_pe[0].samp_ms = samp_ms;
        per_pe[0].feat_ms = feat_ms;
        per_pe
    }

    /// Thread-per-PE runtime: one scoped OS thread per PE for this
    /// batch; each owns its sampler, row cache, store shard, and fabric
    /// endpoint (all persistent in the stream between batches),
    /// exchanging ids — and feature-row payloads — over the live
    /// channels. Seeds arrive precomputed from the caller (drawn from
    /// the per-PE seed RNGs by [`MinibatchStream::next_batch`], or
    /// assigned explicitly by [`EngineStream::batch_for_seeds`]).
    ///
    /// Returns the per-PE records plus the batch wall-clock, measured
    /// from a start barrier inside the threads (max over PEs of
    /// barrier→done), so thread spawn/join overhead does not bias the
    /// threaded-vs-serial comparison — the same barrier-to-barrier
    /// semantics as the PR-1 thread-per-run engine.
    fn batch_threaded(
        &mut self,
        per_pe_seeds: Vec<Vec<VertexId>>,
        keep_inputs: bool,
    ) -> (Vec<PeWork>, f64) {
        let mode = self.mode;
        let layers = self.layers;
        let graph = self.graph;
        let part = self.part;
        let store: &dyn FeatureStore = &*self.store;
        let row_bytes = store.row_bytes() as u64;
        let dim = store.dim() as u64;
        let start = std::sync::Barrier::new(self.samplers.len());
        let start = &start;
        let results: Vec<(PeWork, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .samplers
                .iter_mut()
                .zip(self.caches.iter_mut())
                .zip(self.endpoints.iter_mut())
                .zip(per_pe_seeds)
                .map(|(((sampler, cache), ep), seeds)| {
                    scope.spawn(move || {
                        let _abort_guard = AbortOnPeerPanic;
                        // align all PEs so the wall timer sees the true
                        // concurrent latency of this batch
                        start.wait();
                        let wall = Timer::start();
                        let pw = match mode {
                            Mode::Cooperative => {
                                let ep = ep.as_mut().expect("coop threaded stream has endpoints");
                                let t = Timer::start();
                                let ps = sample_cooperative_pe(
                                    graph, part, sampler, ep, seeds, layers,
                                );
                                let samp_ms = t.elapsed_ms();
                                let t = Timer::start();
                                let load = load_pe_cooperative(
                                    ep,
                                    part,
                                    &ps.layers[layers - 1].tilde,
                                    &ps.final_owned,
                                    &ps.final_requests,
                                    cache,
                                    store,
                                );
                                let pe_layers: Vec<&PeLayer> = ps.layers.iter().collect();
                                let mut pw =
                                    coop_pe_work(layers, &pe_layers, dim, row_bytes, load);
                                pw.samp_ms = samp_ms;
                                pw.feat_ms = t.elapsed_ms();
                                pw
                            }
                            Mode::Independent => {
                                let t = Timer::start();
                                let mfg = sampler.sample_mfg(&seeds);
                                let samp_ms = t.elapsed_ms();
                                let t = Timer::start();
                                let load = load_indep_pe(mfg.input_vertices(), cache, store);
                                let mut pw =
                                    indep_pe_work(&mfg, layers, keep_inputs, dim, row_bytes, load);
                                pw.samp_ms = samp_ms;
                                pw.feat_ms = t.elapsed_ms();
                                pw
                            }
                        };
                        sampler.advance_batch();
                        (pw, wall.elapsed_ms())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("PE thread panicked"))
                .collect()
        });
        let wall_ms = results.iter().map(|(_, w)| *w).fold(0.0, f64::max);
        (results.into_iter().map(|(pw, _)| pw).collect(), wall_ms)
    }
}

impl MinibatchStream for EngineStream<'_> {
    fn next_batch(&mut self) -> Minibatch {
        // warmup batches are never reduced, so their S^L input-vertex
        // lists are not retained
        let measuring = self.index >= self.warmup_batches;
        let per_pe_seeds = self.draw_seeds();
        // between-batch serial point: promote the (exactly predicted)
        // next batch's seed rows into the hot tier before this batch's
        // gather — tier classification stays stable within the batch
        let (pf_rows, pf_bytes) = if self.prefetch { self.prefetch_next() } else { (0, 0) };
        let mut mb = self.batch_inner(per_pe_seeds, measuring);
        if pf_rows > 0 {
            mb.per_pe[0].prefetch_rows = pf_rows;
            mb.per_pe[0].prefetch_bytes = pf_bytes;
        }
        mb
    }

    fn num_pes(&self) -> usize {
        self.samplers.len()
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn mode(&self) -> Mode {
        self.mode
    }
}
