//! Training streams: the seed-drawing + MFG-sampling + feature-gathering
//! front half of a training step, behind [`MinibatchStream`].
//!
//! `Trainer` used to own this logic privately (a sampler, a seed RNG, a
//! `sample_indep_merged_mfg` fork, and a per-step feature-gather loop
//! that re-synthesized rows from the dataset); now both of its batching
//! strategies are [`TrainStream`] policies over the same stream seam:
//!
//! * [`Batching::Single`] — one shared-coin sampler over the global
//!   batch. By the coop-sampler determinism contract this is exactly the
//!   union Algorithm 1 computes, so it doubles as the *cooperative*
//!   convergence arm (Figure 9) and as classic 1-PE training.
//! * [`Batching::IndepMerged`] — P per-PE sub-batches sampled with
//!   independent RNGs and merged block-diagonally: bit-equivalent to P
//!   PEs computing privately and all-reducing gradients (the Figure 9
//!   independent baseline).
//!
//! Since the feature-plane refactor the stream also owns a
//! [`FeatureStore`] (single shard over the dataset) and
//! [`TrainStream::next_batch`] ships the dense input-feature buffer with
//! the MFG, so the trainer's compute half starts from pre-gathered bytes
//! — and, wrapped in [`super::prefetch::with_prefetch`], batch `t+1`'s
//! sampling + gathering overlaps batch `t`'s execution. The work record
//! reports its sampling and gather stages separately (`PeWork::samp_ms`
//! / `PeWork::feat_ms`), and the trainer keeps them separate in
//! `StepStats` (`sample_ms` vs `feature_ms`) so prefetch overlap is
//! attributed to the right stage.
//!
//! Seed-drawing matches the PR-1 `Trainer` exactly: the seed RNG is
//! `Pcg64::new(seed ^ `[`SEED_DRAW_SALT`]`)` and per-step sub-batch
//! sampler seeds follow the same formulas, so training trajectories are
//! unchanged at a fixed seed (tested in `tests/integration_pipeline.rs`).

use super::stream::{Minibatch, MinibatchStream, PeWork};
use crate::coop::engine::{ExecMode, Mode};
use crate::feature::{Codec, FeatureStore, PartitionedFeatureStore, Tier, TieredStore};
use crate::graph::{Csr, Dataset, VertexId};
use crate::sampling::{block, Mfg, Sampler, SamplerConfig, SamplerKind};
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;
use std::sync::Arc;

/// Salt mixed into the stream seed for the training-seed draw RNG —
/// the same constant the PR-1 `Trainer` used, kept so fixed-seed
/// trajectories survive the redesign.
pub const SEED_DRAW_SALT: u64 = 0x5EED;

/// How a [`TrainStream`] assembles the global minibatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Batching {
    /// One shared-coin sampler over the whole batch (cooperative
    /// semantics; the default, and the PR-1 `Trainer::step` behavior).
    Single,
    /// `pes` independently-seeded sub-batches merged block-diagonally
    /// (independent-minibatching semantics).
    IndepMerged { pes: usize },
}

/// A training minibatch stream bound to a dataset.
pub struct TrainStream<'d> {
    ds: &'d Dataset,
    kind: SamplerKind,
    cfg: SamplerConfig,
    /// global batch size (seeds per step).
    batch: usize,
    seed: u64,
    exec: ExecMode,
    batching: Batching,
    /// persistent dependent-RNG sampler (Single batching only).
    sampler: Option<Sampler<'d>>,
    /// materialized feature rows (single shard by default: training
    /// reads the whole matrix from "storage" every batch — there is no
    /// LRU tier on the training path; `--codec`/`--hot-mb` swap in a
    /// compressed [`TieredStore`] whose hot tier absorbs part of the
    /// traffic).
    store: Arc<dyn FeatureStore>,
    seed_rng: Pcg64,
    step: u64,
}

impl<'d> TrainStream<'d> {
    pub fn new(
        ds: &'d Dataset,
        kind: SamplerKind,
        cfg: SamplerConfig,
        batch: usize,
        seed: u64,
        exec: ExecMode,
        batching: Batching,
    ) -> TrainStream<'d> {
        TrainStream::with_codec(ds, kind, cfg, batch, seed, exec, batching, Codec::F32, 0)
    }

    /// [`TrainStream::new`] with an explicit storage recipe: the default
    /// `(F32, 0)` keeps the plain single-shard store (bit-identical to
    /// PR 6); any other codec or a nonzero hot budget builds a
    /// single-partition [`TieredStore`], so training reads quantized
    /// rows decoded on gather.
    #[allow(clippy::too_many_arguments)]
    pub fn with_codec(
        ds: &'d Dataset,
        kind: SamplerKind,
        cfg: SamplerConfig,
        batch: usize,
        seed: u64,
        exec: ExecMode,
        batching: Batching,
        codec: Codec,
        hot_mb: usize,
    ) -> TrainStream<'d> {
        let sampler = match batching {
            Batching::Single => Some(cfg.build(kind, &ds.graph, seed)),
            Batching::IndepMerged { .. } => None,
        };
        let store: Arc<dyn FeatureStore> = if codec == Codec::F32 && hot_mb == 0 {
            Arc::new(PartitionedFeatureStore::single_shard(ds))
        } else {
            Arc::new(TieredStore::single(ds, codec, hot_mb * (1 << 20)))
        };
        TrainStream {
            ds,
            kind,
            cfg,
            batch,
            seed,
            exec,
            batching,
            sampler,
            store,
            seed_rng: Pcg64::new(seed ^ SEED_DRAW_SALT),
            step: 0,
        }
    }

    pub fn batching(&self) -> Batching {
        self.batching
    }

    pub fn kind(&self) -> SamplerKind {
        self.kind
    }

    pub fn config(&self) -> SamplerConfig {
        self.cfg
    }

    /// The feature store backing this stream (shared with the trainer's
    /// evaluation path).
    pub fn feature_store(&self) -> Arc<dyn FeatureStore> {
        Arc::clone(&self.store)
    }

    /// A fresh stream with this stream's exact recipe — same dataset,
    /// sampler kind/config, batch, seed, exec mode, and batching — and
    /// **sharing its feature store** (no second materialization). The
    /// clone starts from step 0, so it yields the identical batch
    /// sequence this stream would have yielded from construction: wrap
    /// it in [`super::prefetch::with_prefetch`] to overlap production
    /// with consumption without risking recipe drift.
    pub fn fresh_clone(&self) -> TrainStream<'d> {
        TrainStream {
            ds: self.ds,
            kind: self.kind,
            cfg: self.cfg,
            batch: self.batch,
            seed: self.seed,
            exec: self.exec,
            batching: self.batching,
            sampler: match self.batching {
                Batching::Single => Some(self.cfg.build(self.kind, &self.ds.graph, self.seed)),
                Batching::IndepMerged { .. } => None,
            },
            store: Arc::clone(&self.store),
            seed_rng: Pcg64::new(self.seed ^ SEED_DRAW_SALT),
            step: 0,
        }
    }

    /// Draw the next training seed batch (uniform without replacement).
    pub fn next_seeds(&mut self) -> Vec<VertexId> {
        let b = self.batch.min(self.ds.train.len());
        self.seed_rng
            .sample_distinct(self.ds.train.len(), b)
            .into_iter()
            .map(|i| self.ds.train[i as usize])
            .collect()
    }

    /// Sample the global MFG for `seeds`, advancing per-batch RNG state.
    pub fn sample_on(&mut self, seeds: &[VertexId]) -> Mfg {
        self.step += 1;
        match self.batching {
            Batching::Single => {
                let sampler = self.sampler.as_mut().expect("Single batching owns a sampler");
                let mfg = sampler.sample_mfg(seeds);
                sampler.advance_batch();
                mfg
            }
            Batching::IndepMerged { pes } => {
                // fresh per-PE samplers every step, seeded from the
                // stream seed and the step index (the PR-1 Figure 9
                // recipe, verbatim)
                let batch_seed = self.seed ^ (self.step << 16);
                let parts = sample_indep_parts(
                    &self.ds.graph,
                    self.cfg,
                    self.kind,
                    seeds,
                    pes,
                    batch_seed,
                    self.exec,
                );
                block::merge_mfgs(&parts)
            }
        }
    }
}

impl MinibatchStream for TrainStream<'_> {
    fn next_batch(&mut self) -> Minibatch {
        let wall = Timer::start();
        let seeds = self.next_seeds();
        let mfg = self.sample_on(&seeds);
        let samp_ms = wall.elapsed_ms();
        // gather the dense input-feature buffer the train step executes
        // on — every row comes off the store (β): the training path has
        // no cache tier, so requested == misses by definition
        let t = Timer::start();
        let inputs = mfg.input_vertices().to_vec();
        let mut features = Vec::new();
        self.store.gather(&inputs, &mut features);
        let feat_ms = t.elapsed_ms();
        let wall_ms = wall.elapsed_ms();
        let layers = self.cfg.layers;
        let dim = self.store.dim() as u64;
        let row_bytes = self.store.row_bytes() as u64;
        let n = inputs.len() as u64;
        // rows the hot tier serves decoded never touch storage — split
        // the β charge accordingly (0 hot rows for the default store)
        let hot = inputs.iter().filter(|&&v| self.store.tier_of(v) == Tier::Hot).count() as u64;
        let work = PeWork {
            counts_s: mfg.vertex_counts().iter().map(|&c| c as u64).collect(),
            counts_e: mfg.edge_counts().iter().map(|&c| c as u64).collect(),
            counts_tilde: vec![0; layers],
            counts_cross: vec![0; layers],
            requested: n,
            misses: n,
            fabric: 0,
            dim,
            row_bytes,
            bytes_from_storage: (n - hot) * row_bytes,
            fabric_bytes: 0,
            hot_rows: hot,
            hot_bytes: hot * dim * 4,
            prefetch_rows: 0,
            prefetch_bytes: 0,
            features: Some(features),
            feature_vertices: Some(inputs),
            input_vertices: None,
            samp_ms,
            feat_ms,
            // the merged MFG itself travels in `Minibatch::merged`; the
            // trainer builds blocks from it directly
            compute: None,
        };
        let index = (self.step - 1) as usize;
        Minibatch { index, per_pe: vec![work], merged: Some(mfg), wall_ms }
    }

    fn num_pes(&self) -> usize {
        match self.batching {
            Batching::Single => 1,
            Batching::IndepMerged { pes } => pes,
        }
    }

    fn layers(&self) -> usize {
        self.cfg.layers
    }

    fn mode(&self) -> Mode {
        match self.batching {
            Batching::Single => Mode::Cooperative,
            Batching::IndepMerged { .. } => Mode::Independent,
        }
    }
}

/// Sample the `p` per-PE sub-batches of one Independent-Minibatching
/// global step — the core of [`Batching::IndepMerged`], also driven
/// directly by `benches/bench_train_step.rs` so stream and bench cannot
/// drift.
///
/// PE `i`'s sampler is seeded `batch_seed ^ ((i+1) << 32)` in **both**
/// exec modes, so the result is bit-identical regardless of scheduling;
/// only the wall-clock changes (tested below).
pub fn sample_indep_parts(
    graph: &Csr,
    cfg: SamplerConfig,
    kind: SamplerKind,
    seeds: &[VertexId],
    p: usize,
    batch_seed: u64,
    exec: ExecMode,
) -> Vec<Mfg> {
    let per = seeds.len() / p;
    let pe_sample = |i: usize, chunk: &[VertexId]| -> Mfg {
        let mut s = cfg.build(kind, graph, batch_seed ^ ((i as u64 + 1) << 32));
        s.sample_mfg(chunk)
    };
    match exec {
        ExecMode::Serial => {
            (0..p).map(|i| pe_sample(i, &seeds[i * per..(i + 1) * per])).collect()
        }
        ExecMode::Threaded => std::thread::scope(|scope| {
            let pe_sample = &pe_sample;
            let handles: Vec<_> = (0..p)
                .map(|i| {
                    let chunk = &seeds[i * per..(i + 1) * per];
                    scope.spawn(move || pe_sample(i, chunk))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("PE sampling thread panicked"))
                .collect()
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn indep_parts_serial_and_threaded_bit_identical() {
        let g = generate::chung_lu(2000, 12.0, 2.4, 5);
        let cfg = SamplerConfig::default();
        let seeds: Vec<VertexId> = (0..256).collect();
        for kind in [SamplerKind::Labor0, SamplerKind::Neighbor] {
            let a = sample_indep_parts(&g, cfg, kind, &seeds, 4, 77, ExecMode::Serial);
            let b = sample_indep_parts(&g, cfg, kind, &seeds, 4, 77, ExecMode::Threaded);
            assert_eq!(a.len(), b.len());
            for (pe, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.layer_vertices, y.layer_vertices, "{kind:?} PE{pe} vertices");
                for (l, (ex, ey)) in x.layer_edges.iter().zip(&y.layer_edges).enumerate() {
                    assert_eq!(ex.offsets, ey.offsets, "{kind:?} PE{pe} L{l} offsets");
                    assert_eq!(ex.nbr_local, ey.nbr_local, "{kind:?} PE{pe} L{l} edges");
                }
            }
            let ma = block::merge_mfgs(&a);
            let mb = block::merge_mfgs(&b);
            assert_eq!(ma.layer_vertices, mb.layer_vertices, "{kind:?} merged");
        }
    }

    #[test]
    fn single_stream_yields_merged_mfg_with_features() {
        let ds = crate::graph::datasets::build("tiny", 3).unwrap();
        let cfg = SamplerConfig::default();
        let mut s = TrainStream::new(
            &ds,
            SamplerKind::Labor0,
            cfg,
            32,
            7,
            ExecMode::Serial,
            Batching::Single,
        );
        let store = s.feature_store();
        let mb = s.next_batch();
        let mfg = mb.merged.expect("train streams materialize the MFG");
        assert_eq!(mfg.seeds().len(), 32);
        assert_eq!(mb.per_pe.len(), 1);
        let work = &mb.per_pe[0];
        assert_eq!(work.counts_s.len(), cfg.layers + 1);
        assert!(work.requested > 0);
        // the shipped buffer covers S^L, row-for-row from the store
        let feats = work.features.as_ref().expect("train stream gathers features");
        let vs = work.feature_vertices.as_ref().unwrap();
        assert_eq!(vs.as_slice(), mfg.input_vertices());
        assert_eq!(feats.len(), vs.len() * store.dim());
        assert_eq!(work.bytes_from_storage, work.requested * work.row_bytes);
        let mut want = Vec::new();
        store.gather(vs, &mut want);
        assert_eq!(feats, &want, "shipped bytes == store rows");
    }

    #[test]
    fn codec_stream_trains_on_decoded_quantized_rows() {
        // same recipe, two storage configs: the quantized stream samples
        // the identical batch (storage never touches RNG state), ships
        // near-identical decoded features, and charges wire bytes split
        // across the hot/cold tiers
        let ds = crate::graph::datasets::build("tiny", 3).unwrap();
        let cfg = SamplerConfig::default();
        let mk = |codec, hot_mb| {
            TrainStream::with_codec(
                &ds,
                SamplerKind::Labor0,
                cfg,
                32,
                7,
                ExecMode::Serial,
                Batching::Single,
                codec,
                hot_mb,
            )
        };
        let a = mk(Codec::F32, 0).next_batch();
        let b = mk(Codec::Int8, 1).next_batch();
        let (wa, wb) = (&a.per_pe[0], &b.per_pe[0]);
        assert_eq!(wa.feature_vertices, wb.feature_vertices, "sampling must not see storage");
        assert_eq!(wb.row_bytes as usize, ds.feat_dim + 5, "int8 wire rows");
        assert_eq!(
            wb.bytes_from_storage,
            (wb.misses - wb.hot_rows) * wb.row_bytes,
            "cold charge excludes hot fills"
        );
        assert_eq!(wb.hot_bytes, wb.hot_rows * ds.feat_dim as u64 * 4);
        let (fa, fb) = (wa.features.as_ref().unwrap(), wb.features.as_ref().unwrap());
        assert_eq!(fa.len(), fb.len());
        let worst =
            fa.iter().zip(fb).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(worst > 0.0, "int8 must actually quantize");
        assert!(worst < 0.01, "int8 decode drifted {worst} from f32 truth");
    }
}
