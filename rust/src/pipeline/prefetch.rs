//! Double-buffered stream production: sample + gather batch `t+1` on a
//! producer thread while the consumer (engine reduction or
//! `Trainer::step_from`) executes batch `t`.
//!
//! [`with_prefetch`] moves any `Send` [`MinibatchStream`] onto a scoped
//! producer thread feeding a **depth-1** rendezvous channel — classic
//! double buffering: at any moment one batch is being consumed while at
//! most one finished batch waits and the producer works on the next.
//! The consumer sees a [`PrefetchedStream`], itself a
//! [`MinibatchStream`], so every consumer is prefetch-agnostic.
//!
//! Determinism: the producer is the *same* stream advancing the same
//! RNG/cache state in the same order — prefetching changes only *when*
//! batches are computed, never *what* they contain, so reports and
//! training trajectories are bit-identical with the flag on or off
//! (asserted in `tests/integration_pipeline.rs` and the engine's
//! prefetch determinism test). After the consumer closure returns, the
//! producer may have run up to two batches past the last one consumed;
//! that tail state is discarded with the stream.
//!
//! This is the CLI `--prefetch {0,1}` pipeline flag
//! ([`crate::pipeline::PipelineConfig::prefetch`]).

use super::stream::{Minibatch, MinibatchStream};
use crate::coop::engine::Mode;
use std::sync::mpsc::{sync_channel, Receiver};

/// The consumer-side handle of a prefetching producer thread. Dropping
/// it (or returning from [`with_prefetch`]'s closure) stops the
/// producer at its next send.
pub struct PrefetchedStream {
    rx: Receiver<Minibatch>,
    num_pes: usize,
    layers: usize,
    mode: Mode,
}

impl MinibatchStream for PrefetchedStream {
    fn next_batch(&mut self) -> Minibatch {
        self.rx
            .recv()
            .expect("prefetch producer thread died (its panic is reported on stderr)")
    }

    fn num_pes(&self) -> usize {
        self.num_pes
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn mode(&self) -> Mode {
        self.mode
    }
}

/// Run `consume` against a double-buffered view of `stream`: a scoped
/// producer thread calls `stream.next_batch()` ahead of the consumer,
/// overlapping batch `t+1`'s sampling + feature gathering with batch
/// `t`'s processing. Returns the closure's result after joining the
/// producer.
pub fn with_prefetch<S, R>(mut stream: S, consume: impl FnOnce(&mut PrefetchedStream) -> R) -> R
where
    S: MinibatchStream + Send,
{
    let (num_pes, layers, mode) = (stream.num_pes(), stream.layers(), stream.mode());
    std::thread::scope(|scope| {
        // depth 1: one batch in flight at the consumer, one buffered,
        // one in production — the producer blocks in `send` beyond that
        let (tx, rx) = sync_channel::<Minibatch>(1);
        scope.spawn(move || {
            loop {
                let mb = stream.next_batch();
                if tx.send(mb).is_err() {
                    // consumer dropped its handle: done
                    break;
                }
            }
        });
        let mut handle = PrefetchedStream { rx, num_pes, layers, mode };
        let result = consume(&mut handle);
        drop(handle); // unblock + stop the producer before the scope joins it
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::{EngineConfig, ExecMode};
    use crate::graph::{datasets, partition};
    use crate::pipeline::EngineStream;

    fn cfg(exec: ExecMode) -> EngineConfig {
        EngineConfig {
            mode: Mode::Cooperative,
            exec,
            num_pes: 2,
            batch_per_pe: 16,
            cache_per_pe: 128,
            warmup_batches: 0,
            measure_batches: 3,
            seed: 33,
            ..Default::default()
        }
    }

    #[test]
    fn prefetched_batches_equal_inline_batches() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        for exec in [ExecMode::Serial, ExecMode::Threaded] {
            let c = cfg(exec);
            let mut inline = EngineStream::new(&ds, &part, &c);
            let direct: Vec<Minibatch> = (0..4).map(|_| inline.next_batch()).collect();

            let stream = EngineStream::new(&ds, &part, &c);
            let prefetched: Vec<Minibatch> =
                with_prefetch(stream, |s| (0..4).map(|_| s.next_batch()).collect());

            for (a, b) in direct.iter().zip(&prefetched) {
                assert_eq!(a.index, b.index);
                for (pa, pb) in a.per_pe.iter().zip(&b.per_pe) {
                    assert_eq!(pa.counts_s, pb.counts_s, "{exec:?} S");
                    assert_eq!(pa.misses, pb.misses, "{exec:?} misses");
                    assert_eq!(pa.bytes_from_storage, pb.bytes_from_storage, "{exec:?} bytes");
                    assert_eq!(pa.features, pb.features, "{exec:?} payload");
                }
            }
        }
    }

    #[test]
    fn consumer_can_stop_early_without_hanging() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let stream = EngineStream::new(&ds, &part, &cfg(ExecMode::Serial));
        // consume fewer batches than the producer would happily make —
        // with_prefetch must still join cleanly
        let first = with_prefetch(stream, |s| s.next_batch());
        assert_eq!(first.index, 0);
    }

    #[test]
    fn metadata_passes_through() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let stream = EngineStream::new(&ds, &part, &cfg(ExecMode::Serial));
        with_prefetch(stream, |s| {
            assert_eq!(s.num_pes(), 2);
            assert_eq!(s.layers(), 3);
            assert_eq!(s.mode(), Mode::Cooperative);
        });
    }
}
