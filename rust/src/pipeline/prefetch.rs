//! Double-buffered stream production: sample + gather batch `t+1` on a
//! producer thread while the consumer (engine reduction or
//! `Trainer::step_from`) executes batch `t`.
//!
//! [`with_prefetch`] moves any `Send` [`MinibatchStream`] onto a scoped
//! producer thread feeding a **depth-1** rendezvous channel — classic
//! double buffering: at any moment one batch is being consumed while at
//! most one finished batch waits and the producer works on the next.
//! The consumer sees a [`PrefetchedStream`], itself a
//! [`MinibatchStream`], so every consumer is prefetch-agnostic.
//!
//! Determinism: the producer is the *same* stream advancing the same
//! RNG/cache state in the same order — prefetching changes only *when*
//! batches are computed, never *what* they contain, so reports and
//! training trajectories are bit-identical with the flag on or off
//! (asserted in `tests/integration_pipeline.rs` and the engine's
//! prefetch determinism test).
//!
//! Tail discipline: a consumer that knows it just pulled its last batch
//! calls [`MinibatchStream::finish`] (the engine's `drain`, the
//! parallel trainer's `run`, and the CLI/bench loops all do). `finish`
//! drops the receiver and raises a stop flag, so the producer exits at
//! its next send — or at the loop top, before starting another
//! sample + gather — instead of burning up to two full batches that
//! nobody will consume. After `finish` returns, **at most one**
//! already-in-flight batch completes (asserted by batch counters in the
//! tests below). Returning from the closure without calling `finish`
//! still joins cleanly; it just forgoes the early stop.
//!
//! This is the CLI `--prefetch {0,1}` pipeline flag
//! ([`crate::pipeline::PipelineConfig::prefetch`]).

use super::stream::{Minibatch, MinibatchStream};
use crate::coop::engine::Mode;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

/// The consumer-side handle of a prefetching producer thread. Dropping
/// it (or calling [`MinibatchStream::finish`], which also stops the
/// producer from starting further batches) stops the producer at its
/// next send.
pub struct PrefetchedStream {
    /// `None` once finished — the drop is the signal that unblocks a
    /// producer waiting in `send`.
    rx: Option<Receiver<Minibatch>>,
    stop: Arc<AtomicBool>,
    num_pes: usize,
    layers: usize,
    mode: Mode,
}

impl MinibatchStream for PrefetchedStream {
    fn next_batch(&mut self) -> Minibatch {
        self.rx
            .as_ref()
            .expect("next_batch called on a finished prefetched stream")
            .recv()
            .expect("prefetch producer thread died (its panic is reported on stderr)")
    }

    fn num_pes(&self) -> usize {
        self.num_pes
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn mode(&self) -> Mode {
        self.mode
    }

    /// Stop the producer: raise the flag (checked before every
    /// production) and drop the receiver (fails any in-flight or future
    /// send). At most one batch already in production completes after
    /// this returns.
    fn finish(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.rx = None;
    }
}

/// Run `consume` against a double-buffered view of `stream`: a scoped
/// producer thread calls `stream.next_batch()` ahead of the consumer,
/// overlapping batch `t+1`'s sampling + feature gathering with batch
/// `t`'s processing. Returns the closure's result after joining the
/// producer (the handle is finished on the way out, so an early-exiting
/// consumer never hangs).
pub fn with_prefetch<S, R>(mut stream: S, consume: impl FnOnce(&mut PrefetchedStream) -> R) -> R
where
    S: MinibatchStream + Send,
{
    let (num_pes, layers, mode) = (stream.num_pes(), stream.layers(), stream.mode());
    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // depth 1: one batch in flight at the consumer, one buffered,
        // one in production — the producer blocks in `send` beyond that
        let (tx, rx) = sync_channel::<Minibatch>(1);
        let producer_stop = Arc::clone(&stop);
        scope.spawn(move || {
            loop {
                // checked before each sample + gather, so a finished
                // consumer stops production here rather than after one
                // more full batch
                if producer_stop.load(Ordering::SeqCst) {
                    break;
                }
                let mb = stream.next_batch();
                if tx.send(mb).is_err() {
                    // consumer dropped its handle: done
                    break;
                }
            }
        });
        let mut handle = PrefetchedStream { rx: Some(rx), stop, num_pes, layers, mode };
        let result = consume(&mut handle);
        handle.finish(); // no-op if the consumer already finished
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::{EngineConfig, ExecMode};
    use crate::graph::{datasets, partition};
    use crate::pipeline::EngineStream;
    use std::sync::atomic::AtomicUsize;

    fn cfg(exec: ExecMode) -> EngineConfig {
        EngineConfig {
            mode: Mode::Cooperative,
            exec,
            num_pes: 2,
            batch_per_pe: 16,
            cache_per_pe: 128,
            warmup_batches: 0,
            measure_batches: 3,
            seed: 33,
            ..Default::default()
        }
    }

    /// Counts how many productions *start* — the measure of tail waste.
    struct CountingStream<S> {
        inner: S,
        started: Arc<AtomicUsize>,
    }

    impl<S: MinibatchStream> MinibatchStream for CountingStream<S> {
        fn next_batch(&mut self) -> Minibatch {
            self.started.fetch_add(1, Ordering::SeqCst);
            self.inner.next_batch()
        }

        fn num_pes(&self) -> usize {
            self.inner.num_pes()
        }

        fn layers(&self) -> usize {
            self.inner.layers()
        }

        fn mode(&self) -> Mode {
            self.inner.mode()
        }
    }

    #[test]
    fn prefetched_batches_equal_inline_batches() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        for exec in [ExecMode::Serial, ExecMode::Threaded] {
            let c = cfg(exec);
            let mut inline = EngineStream::new(&ds, &part, &c);
            let direct: Vec<Minibatch> = (0..4).map(|_| inline.next_batch()).collect();

            let stream = EngineStream::new(&ds, &part, &c);
            let prefetched: Vec<Minibatch> =
                with_prefetch(stream, |s| (0..4).map(|_| s.next_batch()).collect());

            for (a, b) in direct.iter().zip(&prefetched) {
                assert_eq!(a.index, b.index);
                for (pa, pb) in a.per_pe.iter().zip(&b.per_pe) {
                    assert_eq!(pa.counts_s, pb.counts_s, "{exec:?} S");
                    assert_eq!(pa.misses, pb.misses, "{exec:?} misses");
                    assert_eq!(pa.bytes_from_storage, pb.bytes_from_storage, "{exec:?} bytes");
                    assert_eq!(pa.features, pb.features, "{exec:?} payload");
                }
            }
        }
    }

    #[test]
    fn consumer_can_stop_early_without_hanging() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let stream = EngineStream::new(&ds, &part, &cfg(ExecMode::Serial));
        // consume fewer batches than the producer would happily make —
        // with_prefetch must still join cleanly
        let first = with_prefetch(stream, |s| s.next_batch());
        assert_eq!(first.index, 0);
    }

    /// The tail-waste guarantee: once `finish` returns, at most one
    /// batch already in production completes — the producer never
    /// *starts* another sample + gather, even if the consumer lingers
    /// afterward (here: a deliberate sleep that would previously let it
    /// run two batches ahead).
    #[test]
    fn finish_stops_production_within_one_batch() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let started = Arc::new(AtomicUsize::new(0));
        let counting = CountingStream {
            inner: EngineStream::new(&ds, &part, &cfg(ExecMode::Serial)),
            started: Arc::clone(&started),
        };
        let consumed = 2usize;
        let at_finish = with_prefetch(counting, |s| {
            for _ in 0..consumed {
                s.next_batch();
            }
            s.finish();
            let snapshot = started.load(Ordering::SeqCst);
            // tail work after the last batch: with the stop flag up, the
            // producer must not start new batches during it
            std::thread::sleep(std::time::Duration::from_millis(30));
            snapshot
        });
        let total = started.load(Ordering::SeqCst);
        assert!(
            total <= at_finish + 1,
            "producer started {total} batches, but only {at_finish} had started \
             when finish() returned (+1 in-flight allowed)"
        );
        assert!(total >= consumed, "must have produced everything consumed");
    }

    #[test]
    #[should_panic(expected = "finished prefetched stream")]
    fn next_batch_after_finish_is_a_bug() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let stream = EngineStream::new(&ds, &part, &cfg(ExecMode::Serial));
        with_prefetch(stream, |s| {
            s.next_batch();
            s.finish();
            s.next_batch(); // panics
        });
    }

    #[test]
    fn metadata_passes_through() {
        let ds = datasets::build("tiny", 8).unwrap();
        let part = partition::random(&ds.graph, 2, 3);
        let stream = EngineStream::new(&ds, &part, &cfg(ExecMode::Serial));
        with_prefetch(stream, |s| {
            assert_eq!(s.num_pes(), 2);
            assert_eq!(s.layers(), 3);
            assert_eq!(s.mode(), Mode::Cooperative);
        });
    }
}
