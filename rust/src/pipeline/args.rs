//! The single `--key value` parse layer behind every CLI surface.
//!
//! Replaces the hand-rolled per-subcommand `Args` struct that used to
//! live in `main.rs`, fixing its two silent failure modes:
//!
//! * **unknown keys were swallowed** — `--batchs 12` went into a map
//!   nobody read and the run proceeded with the default. Here every
//!   subcommand declares its [`ArgSpec`] table and an unknown flag is a
//!   hard error listing the valid flags.
//! * **malformed and negative values** — a value that fails to parse
//!   used to fall back to the default without a word (`usize_or`
//!   swallowed the parse error); now it errors. Negative numbers
//!   (`--lr -0.5`) are recognised as values, never misread as flags.

use std::collections::HashMap;

/// Whether a flag carries a value (`--seed 7`) or is a bare switch
/// (`--quick`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    Value,
    Switch,
}

/// One legal flag of a subcommand: its key (without `--`), kind, and the
/// help line shown when parsing fails.
#[derive(Clone, Copy, Debug)]
pub struct ArgSpec {
    pub key: &'static str,
    pub kind: ArgKind,
    pub help: &'static str,
}

/// Declare a value-carrying flag.
pub const fn val(key: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { key, kind: ArgKind::Value, help }
}

/// Declare a bare switch.
pub const fn switch(key: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec { key, kind: ArgKind::Switch, help }
}

fn listing(specs: &[ArgSpec]) -> String {
    specs
        .iter()
        .map(|s| match s.kind {
            ArgKind::Value => format!("  --{} <value>  {}", s.key, s.help),
            ArgKind::Switch => format!("  --{}  {}", s.key, s.help),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Parsed flags, validated against an [`ArgSpec`] table.
#[derive(Clone, Debug, Default)]
pub struct ArgMap {
    flags: HashMap<String, String>,
}

impl ArgMap {
    /// Parse `--key value` / `--switch` tokens. Errors on: unknown keys
    /// (listing the valid ones), stray positional tokens, duplicate
    /// flags, and value flags with a missing value.
    pub fn parse(rest: &[String], specs: &[ArgSpec]) -> crate::Result<ArgMap> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let Some(key) = a.strip_prefix("--") else {
                anyhow::bail!(
                    "unexpected argument `{a}` (flags are --key value); valid flags:\n{}",
                    listing(specs)
                );
            };
            let Some(spec) = specs.iter().find(|s| s.key == key) else {
                anyhow::bail!("unknown flag --{key}; valid flags:\n{}", listing(specs));
            };
            let value = match spec.kind {
                ArgKind::Switch => {
                    i += 1;
                    "true".to_string()
                }
                ArgKind::Value => {
                    let Some(v) = rest.get(i + 1) else {
                        anyhow::bail!("flag --{key} requires a value");
                    };
                    // a following `--token` is the next flag, not a value;
                    // negative numbers (`-0.5`) carry a single dash and are
                    // consumed as ordinary values
                    if v.starts_with("--") {
                        anyhow::bail!("flag --{key} requires a value (found flag `{v}`)");
                    }
                    i += 2;
                    v.clone()
                }
            };
            if flags.insert(key.to_string(), value).is_some() {
                anyhow::bail!("flag --{key} given twice");
            }
        }
        Ok(ArgMap { flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Typed access: `Ok(None)` when absent, `Err` when present but
    /// malformed — a bad value never silently falls back to a default.
    pub fn opt<T: std::str::FromStr>(&self, key: &str) -> crate::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|_| {
                anyhow::anyhow!(
                    "invalid value `{raw}` for --{key} (expected {})",
                    std::any::type_name::<T>()
                )
            }),
        }
    }

    /// Typed access with a default for absent flags; malformed values
    /// still error.
    pub fn or<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T> {
        Ok(self.opt(key)?.unwrap_or(default))
    }

    /// The CLI's `0|1` toggle convention (`--prefetch 1`): strictly 0 or
    /// 1, anything else errors — `--prefetch yes` must not silently mean
    /// off.
    pub fn bool01(&self, key: &str, default: bool) -> crate::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("0") => Ok(false),
            Some("1") => Ok(true),
            Some(raw) => anyhow::bail!("invalid value `{raw}` for --{key} (expected 0 or 1)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: &[ArgSpec] = &[
        val("seed", "rng seed"),
        val("lr", "learning rate"),
        val("steps", "step count"),
        switch("quick", "reduced sweep"),
    ];

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_switches() {
        let m = ArgMap::parse(&args(&["--seed", "7", "--quick"]), SPECS).unwrap();
        assert_eq!(m.or::<u64>("seed", 0).unwrap(), 7);
        assert!(m.has("quick"));
        assert_eq!(m.or::<usize>("steps", 300).unwrap(), 300);
    }

    #[test]
    fn rejects_unknown_keys_with_listing() {
        let e = ArgMap::parse(&args(&["--sede", "7"]), SPECS).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown flag --sede"), "{msg}");
        assert!(msg.contains("--seed"), "listing must name valid flags: {msg}");
        assert!(msg.contains("--quick"), "listing must name valid flags: {msg}");
    }

    #[test]
    fn accepts_negative_numbers_as_values() {
        let m = ArgMap::parse(&args(&["--lr", "-0.5", "--steps", "-3"]), SPECS).unwrap();
        assert_eq!(m.opt::<f32>("lr").unwrap(), Some(-0.5));
        assert_eq!(m.opt::<i64>("steps").unwrap(), Some(-3));
    }

    #[test]
    fn rejects_malformed_values_instead_of_defaulting() {
        let m = ArgMap::parse(&args(&["--steps", "many"]), SPECS).unwrap();
        assert!(m.or::<usize>("steps", 300).is_err());
    }

    #[test]
    fn bool01_is_strict() {
        const B: &[ArgSpec] = &[val("prefetch", "0|1")];
        let m = ArgMap::parse(&args(&["--prefetch", "1"]), B).unwrap();
        assert!(m.bool01("prefetch", false).unwrap());
        let m = ArgMap::parse(&args(&["--prefetch", "0"]), B).unwrap();
        assert!(!m.bool01("prefetch", true).unwrap());
        let m = ArgMap::parse(&args(&[]), B).unwrap();
        assert!(m.bool01("prefetch", true).unwrap());
        let m = ArgMap::parse(&args(&["--prefetch", "yes"]), B).unwrap();
        assert!(m.bool01("prefetch", false).is_err(), "non-0|1 must error");
    }

    #[test]
    fn rejects_missing_value() {
        assert!(ArgMap::parse(&args(&["--seed"]), SPECS).is_err());
        assert!(ArgMap::parse(&args(&["--seed", "--quick"]), SPECS).is_err());
    }

    #[test]
    fn rejects_stray_positionals_and_duplicates() {
        assert!(ArgMap::parse(&args(&["stray"]), SPECS).is_err());
        assert!(ArgMap::parse(&args(&["--seed", "1", "--seed", "2"]), SPECS).is_err());
    }
}
