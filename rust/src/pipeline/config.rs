//! Typed pipeline configuration + builder — the one construction path
//! behind the CLI subcommands, the repro harnesses, the benches, and the
//! examples.

use super::stream::EngineStream;
use super::train_stream::Batching;
use crate::coop::all_to_all::{AllReduceStrategy, Topology};
use crate::coop::engine::{self, EngineConfig, EngineReport, ExecMode, Mode};
use crate::costmodel::{pick_collective, FabricModel};
use crate::feature::{Codec, FeatureStore, PartitionedFeatureStore, TieredStore};
use crate::graph::{datasets, partition, Csr, Dataset, Partition};
use crate::model::ModelDims;
use crate::sampling::{Kappa, SamplerConfig, SamplerKind, MAX_FANOUT_LAYERS};
use crate::train::{ParallelTrainer, TrainerOptions};
use std::sync::{Arc, Mutex};

/// The crate-wide default RNG seed.
///
/// Before the pipeline redesign every stack had its own default
/// (`repro` 0xC0FFEE, `train` mixed 1 and 0x7EA1, `engine` 1 and 2);
/// now everything that does not receive an explicit seed derives from
/// this one constant: the dataset generator, the partitioner, the per-PE
/// seed-RNG streams, and the sampler coins. Subcommand `--seed` flags
/// and explicit config fields still override it.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

/// Which 1-D graph partitioner assigns vertices to PEs (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// uniform random assignment (the paper's baseline).
    Random,
    /// multilevel coarsen–partition–refine ("metis" on the CLI).
    Multilevel,
    /// linear deterministic greedy streaming.
    Ldg,
}

impl Partitioner {
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Random => "random",
            Partitioner::Multilevel => "metis",
            Partitioner::Ldg => "ldg",
        }
    }

    pub fn parse(s: &str) -> Option<Partitioner> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(Partitioner::Random),
            "metis" | "multilevel" => Some(Partitioner::Multilevel),
            "ldg" => Some(Partitioner::Ldg),
            _ => None,
        }
    }

    pub fn build(&self, g: &Csr, num_parts: usize, seed: u64) -> Partition {
        match self {
            Partitioner::Random => partition::random(g, num_parts, seed),
            Partitioner::Multilevel => partition::multilevel(g, num_parts, seed),
            Partitioner::Ldg => partition::ldg(g, num_parts, seed),
        }
    }
}

/// Everything needed to stand up a minibatch pipeline: dataset, PE
/// topology, minibatching strategy, sampler, cache, and measurement
/// window. Validated by [`PipelineConfig::validate`]; constructed
/// fluently through [`PipelineBuilder`].
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// registry dataset name (see `coopgnn info`).
    pub dataset: String,
    pub mode: Mode,
    pub exec: ExecMode,
    pub num_pes: usize,
    /// replica-group size r (`--replication r`, default 1 = flat
    /// fabric). Groups of r consecutive PEs each hold a full replica of
    /// the group's feature shards (r× shard memory), so cooperative row
    /// requests resolve within the group and only the first copy per
    /// remote group crosses the slow inter-group link; gradient
    /// all-reduces run hierarchically. Must divide `num_pes`.
    pub replication: usize,
    /// intra-group link bandwidth override in GB/s (`--intra-bw`;
    /// `None` = the costmodel's default fast link).
    pub intra_bw: Option<f64>,
    /// inter-group link bandwidth override in GB/s (`--inter-bw`).
    pub inter_bw: Option<f64>,
    /// per-PE batch size b (global batch = b · P).
    pub batch_per_pe: usize,
    pub partitioner: Partitioner,
    pub kind: SamplerKind,
    /// per-layer sampler fanout: one entry = uniform across layers,
    /// otherwise exactly `layers` entries (entry `l` is hop `l` from the
    /// seeds). Validation rejects any other length — no silent
    /// truncation or padding.
    pub fanout: Vec<usize>,
    pub layers: usize,
    /// hidden width of the layered GNN this pipeline trains/serves
    /// (input and output widths come from the dataset).
    pub hidden: usize,
    /// optional model-depth assertion: when set it must equal `layers`
    /// (the sampled MFG depth *is* the model depth) — a strict-args
    /// guard against configs that assume they can differ.
    pub model_layers: Option<usize>,
    /// batch-dependency κ of paper §3.2 (1 = independent batches).
    pub kappa: Kappa,
    /// LRU rows per PE; `None` = dataset-derived
    /// (`ds.cache_size / num_pes`, floored at 64).
    pub cache_per_pe: Option<usize>,
    /// double-buffer the stream: a producer thread samples + gathers
    /// batch t+1 while the consumer processes batch t (`--prefetch 1`).
    /// Bit-identical results either way; only the overlap changes. With
    /// a tiered store it additionally arms the depth-1 costmodel
    /// prefetch seam (predicted next-batch seed rows promoted hot).
    pub prefetch: bool,
    /// at-rest / on-wire row codec (`--codec {f32,fp16,int8}`).
    /// [`Codec::F32`] keeps the PR-6 single-tier store and is
    /// bit-identical to it; any other codec (or `hot_mb > 0`) builds a
    /// [`TieredStore`].
    pub codec: Codec,
    /// hot-tier budget in MiB of decoded f32 rows (`--hot-mb N`); 0
    /// disables the hot tier.
    pub hot_mb: usize,
    pub warmup_batches: usize,
    pub measure_batches: usize,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        let s = SamplerConfig::default();
        PipelineConfig {
            dataset: "tiny".to_string(),
            mode: Mode::Independent,
            exec: ExecMode::Threaded,
            num_pes: 4,
            replication: 1,
            intra_bw: None,
            inter_bw: None,
            batch_per_pe: 1024,
            partitioner: Partitioner::Random,
            kind: SamplerKind::Labor0,
            fanout: vec![s.fanout],
            layers: s.layers,
            hidden: 16,
            model_layers: None,
            kappa: s.kappa,
            cache_per_pe: None,
            prefetch: false,
            codec: Codec::F32,
            hot_mb: 0,
            warmup_batches: 4,
            measure_batches: 16,
            seed: DEFAULT_SEED,
        }
    }
}

impl PipelineConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.num_pes >= 1, "pipeline needs at least one PE");
        anyhow::ensure!(self.replication >= 1, "replication factor must be >= 1");
        anyhow::ensure!(
            self.num_pes % self.replication == 0,
            "replication ({}) must divide the PE count ({})",
            self.replication,
            self.num_pes
        );
        anyhow::ensure!(self.batch_per_pe >= 1, "per-PE batch size must be >= 1");
        anyhow::ensure!(self.layers >= 1, "pipeline needs at least one GNN layer");
        anyhow::ensure!(!self.fanout.is_empty(), "sampler fanout list must not be empty");
        anyhow::ensure!(
            self.fanout.iter().all(|&k| k >= 1),
            "every sampler fanout must be >= 1 (got {:?})",
            self.fanout
        );
        anyhow::ensure!(
            self.fanout.len() == 1 || self.fanout.len() == self.layers,
            "fanout list must have one uniform entry or exactly one per layer \
             (got {} entries for {} layers)",
            self.fanout.len(),
            self.layers
        );
        anyhow::ensure!(
            self.fanout.len() <= MAX_FANOUT_LAYERS,
            "per-layer fanout supports at most {MAX_FANOUT_LAYERS} layers (got {})",
            self.fanout.len()
        );
        anyhow::ensure!(self.hidden >= 1, "model hidden width must be >= 1");
        if let Some(ml) = self.model_layers {
            anyhow::ensure!(
                ml == self.layers,
                "model depth ({ml}) must equal the sampled MFG depth ({}); \
                 set --model-layers equal to --layers or drop it",
                self.layers
            );
        }
        anyhow::ensure!(self.measure_batches >= 1, "need at least one measured batch");
        anyhow::ensure!(
            datasets::spec(&self.dataset).is_some(),
            "unknown dataset `{}`; registry: {:?}",
            self.dataset,
            datasets::SPECS.iter().map(|s| s.name).collect::<Vec<_>>()
        );
        Ok(())
    }

    pub fn sampler_config(&self) -> SamplerConfig {
        let mut fanouts = [0usize; MAX_FANOUT_LAYERS];
        if self.fanout.len() > 1 {
            for (slot, &k) in fanouts.iter_mut().zip(&self.fanout) {
                *slot = k;
            }
        }
        SamplerConfig {
            fanout: self.fanout[0],
            fanouts,
            layers: self.layers,
            kappa: self.kappa,
            ..Default::default()
        }
    }

    /// The layered-model shape this pipeline trains/serves: depth and
    /// hidden width from the config, input width and class count from
    /// the dataset — the one derivation every consumer (trainer,
    /// executor, benches) shares, so they cannot disagree.
    pub fn model_dims(&self, ds: &Dataset) -> ModelDims {
        ModelDims {
            layers: self.model_layers.unwrap_or(self.layers),
            d_in: ds.feat_dim,
            hidden: self.hidden,
            classes: ds.num_classes,
        }
    }

    /// Lower to the engine's config, resolving the dataset-derived cache
    /// default.
    pub fn engine_config(&self, ds: &Dataset) -> EngineConfig {
        EngineConfig {
            mode: self.mode,
            exec: self.exec,
            num_pes: self.num_pes,
            replication: self.replication,
            batch_per_pe: self.batch_per_pe,
            kind: self.kind,
            sampler: self.sampler_config(),
            cache_per_pe: self
                .cache_per_pe
                .unwrap_or_else(|| (ds.cache_size / self.num_pes).max(64)),
            prefetch: self.prefetch,
            warmup_batches: self.warmup_batches,
            measure_batches: self.measure_batches,
            seed: self.seed,
        }
    }

    /// The replica-group layout of this pipeline's fabrics.
    pub fn topology(&self) -> Topology {
        Topology::new(self.num_pes, self.replication)
    }

    /// The alpha-beta link model of this pipeline's fabric, with any
    /// `--intra-bw` / `--inter-bw` overrides applied.
    pub fn fabric_model(&self) -> FabricModel {
        FabricModel::with_bandwidths(self.intra_bw, self.inter_bw)
    }

    /// Trainer options mirroring this pipeline (sampler, κ, fanout,
    /// seed, exec; single-sampler batching). The AOT trainer pads to a
    /// uniform cap, so it takes the largest per-layer fanout.
    pub fn trainer_options(&self) -> TrainerOptions {
        TrainerOptions {
            kind: self.kind,
            kappa: self.kappa,
            fanout: self.fanout.iter().copied().max().unwrap_or(1),
            seed: self.seed,
            lr: None,
            exec: self.exec,
            batching: Batching::Single,
            codec: self.codec,
            hot_mb: self.hot_mb,
        }
    }
}

/// Fluent constructor for a [`Pipeline`]. Every setter has the
/// [`PipelineConfig`] field of the same name; [`PipelineBuilder::build`]
/// validates, generates the dataset, and partitions the graph.
#[derive(Clone, Debug, Default)]
pub struct PipelineBuilder {
    cfg: PipelineConfig,
}

impl PipelineBuilder {
    pub fn new() -> PipelineBuilder {
        PipelineBuilder::default()
    }

    pub fn dataset(mut self, name: &str) -> Self {
        self.cfg.dataset = name.to_string();
        self
    }

    pub fn mode(mut self, mode: Mode) -> Self {
        self.cfg.mode = mode;
        self
    }

    pub fn exec(mut self, exec: ExecMode) -> Self {
        self.cfg.exec = exec;
        self
    }

    pub fn num_pes(mut self, p: usize) -> Self {
        self.cfg.num_pes = p;
        self
    }

    pub fn batch_per_pe(mut self, b: usize) -> Self {
        self.cfg.batch_per_pe = b;
        self
    }

    /// Replica-group size r (must divide the PE count — validated at
    /// build time).
    pub fn replication(mut self, r: usize) -> Self {
        self.cfg.replication = r;
        self
    }

    /// Intra-group link bandwidth override in GB/s.
    pub fn intra_bw(mut self, gbps: f64) -> Self {
        self.cfg.intra_bw = Some(gbps);
        self
    }

    /// Inter-group link bandwidth override in GB/s.
    pub fn inter_bw(mut self, gbps: f64) -> Self {
        self.cfg.inter_bw = Some(gbps);
        self
    }

    pub fn partitioner(mut self, p: Partitioner) -> Self {
        self.cfg.partitioner = p;
        self
    }

    pub fn sampler(mut self, kind: SamplerKind) -> Self {
        self.cfg.kind = kind;
        self
    }

    /// Uniform fanout across every layer.
    pub fn fanout(mut self, k: usize) -> Self {
        self.cfg.fanout = vec![k];
        self
    }

    /// Per-layer fanout list (entry `l` = hop `l` from the seeds); must
    /// have exactly `layers` entries — validated at build time.
    pub fn fanouts(mut self, ks: &[usize]) -> Self {
        self.cfg.fanout = ks.to_vec();
        self
    }

    pub fn layers(mut self, l: usize) -> Self {
        self.cfg.layers = l;
        self
    }

    /// Hidden width of the layered model.
    pub fn hidden(mut self, h: usize) -> Self {
        self.cfg.hidden = h;
        self
    }

    /// Assert the model depth (must equal `layers`; build-time error
    /// otherwise).
    pub fn model_layers(mut self, l: usize) -> Self {
        self.cfg.model_layers = Some(l);
        self
    }

    pub fn kappa(mut self, kappa: Kappa) -> Self {
        self.cfg.kappa = kappa;
        self
    }

    pub fn cache_per_pe(mut self, rows: usize) -> Self {
        self.cfg.cache_per_pe = Some(rows);
        self
    }

    pub fn prefetch(mut self, on: bool) -> Self {
        self.cfg.prefetch = on;
        self
    }

    /// At-rest / on-wire row codec (default [`Codec::F32`]).
    pub fn codec(mut self, codec: Codec) -> Self {
        self.cfg.codec = codec;
        self
    }

    /// Hot-tier budget in MiB of decoded rows (default 0 = no hot tier).
    pub fn hot_mb(mut self, mb: usize) -> Self {
        self.cfg.hot_mb = mb;
        self
    }

    pub fn warmup_batches(mut self, n: usize) -> Self {
        self.cfg.warmup_batches = n;
        self
    }

    pub fn measure_batches(mut self, n: usize) -> Self {
        self.cfg.measure_batches = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Validate, build the dataset (seeded from `cfg.seed`), and
    /// partition the graph.
    pub fn build(self) -> crate::Result<Pipeline> {
        self.cfg.validate()?;
        let ds = datasets::build(&self.cfg.dataset, self.cfg.seed)?;
        let dims = self.cfg.model_dims(&ds);
        anyhow::ensure!(
            dims.d_in >= 1 && dims.classes >= 2,
            "dataset `{}` cannot drive the model: feat_dim={}, classes={}",
            self.cfg.dataset,
            dims.d_in,
            dims.classes
        );
        let part = self.cfg.partitioner.build(&ds.graph, self.cfg.num_pes, self.cfg.seed);
        Ok(Pipeline { cfg: self.cfg, ds, part, store: Mutex::new(None) })
    }
}

/// A built pipeline: validated config + generated dataset + partition.
///
/// `cfg` is public so sweeps (κ, cache size, mode, exec, batch window)
/// can retune between [`Pipeline::engine_report`] calls without
/// regenerating the dataset; anything that changes the partition
/// (PE count, partitioner) must go through the `set_*` helpers, which
/// also invalidate the cached feature store (its shard layout follows
/// the partition).
pub struct Pipeline {
    pub cfg: PipelineConfig,
    pub ds: Dataset,
    pub part: Partition,
    /// lazily-materialized feature store, shared by every stream this
    /// pipeline hands out (building one is an O(|V|·d) pass). The
    /// default config materializes the PR-6 [`PartitionedFeatureStore`];
    /// a non-f32 codec or a hot-tier budget materializes a
    /// [`TieredStore`] instead.
    store: Mutex<Option<Arc<dyn FeatureStore>>>,
}

impl Pipeline {
    /// The feature store for the current partition, materializing it on
    /// first use: plain partitioned f32 shards for the default config,
    /// a compressed [`TieredStore`] when `cfg.codec != F32` or
    /// `cfg.hot_mb > 0`.
    pub fn feature_store(&self) -> Arc<dyn FeatureStore> {
        let mut guard = self.store.lock().unwrap();
        guard
            .get_or_insert_with(|| {
                if self.cfg.codec == Codec::F32 && self.cfg.hot_mb == 0 {
                    Arc::new(PartitionedFeatureStore::build(&self.ds, &self.part))
                } else {
                    Arc::new(TieredStore::build(
                        &self.ds,
                        &self.part,
                        self.cfg.codec,
                        self.cfg.hot_mb * (1 << 20),
                    ))
                }
            })
            .clone()
    }

    /// A fresh measurement stream over the current config (sharing the
    /// pipeline's feature store).
    pub fn stream(&self) -> EngineStream<'_> {
        EngineStream::with_store(
            &self.ds,
            &self.part,
            &self.cfg.engine_config(&self.ds),
            self.feature_store(),
        )
    }

    /// Drain a fresh stream into the aggregated engine report
    /// (warmup + measure batches per the current config; double-buffered
    /// when `cfg.prefetch` is on).
    pub fn engine_report(&self) -> EngineReport {
        let cfg = self.cfg.engine_config(&self.ds);
        engine::run_stream(self.stream(), &cfg)
    }

    /// [`Pipeline::engine_report`] with a flight-recorder attached:
    /// measured batches emit per-PE stage spans into `trace`
    /// (`--trace` on the `engine` subcommand). The report is
    /// bit-identical to [`Pipeline::engine_report`] — spans are derived
    /// from the same per-batch ledgers the reduction consumes.
    pub fn engine_report_traced(
        &self,
        trace: &mut crate::obs::Trace,
    ) -> EngineReport {
        let cfg = self.cfg.engine_config(&self.ds);
        engine::run_stream_traced(self.stream(), &cfg, trace)
    }

    /// Trainer options mirroring this pipeline.
    pub fn trainer_options(&self) -> TrainerOptions {
        self.cfg.trainer_options()
    }

    /// The layered-model shape this pipeline trains/serves (see
    /// [`PipelineConfig::model_dims`]).
    pub fn model_dims(&self) -> ModelDims {
        self.cfg.model_dims(&self.ds)
    }

    /// The multi-PE training plane over this pipeline: one layered-model
    /// replica per PE (shape [`Pipeline::model_dims`], init from
    /// `cfg.seed`), gradient all-reduce in `cfg.exec`'s execution mode.
    /// Drive it with [`Pipeline::stream`] (optionally prefetch-wrapped);
    /// the stream and the trainer must agree on `num_pes` *and* depth,
    /// which this constructor guarantees.
    pub fn parallel_trainer(&self, lr: f32, strategy: AllReduceStrategy) -> ParallelTrainer {
        ParallelTrainer::with_topology(
            self.cfg.topology(),
            self.model_dims(),
            self.cfg.seed,
            lr,
            self.cfg.exec,
            strategy,
        )
    }

    /// The costmodel's all-reduce pick for this pipeline's gradient
    /// payload (the trainer's flat `[grads | loss | correct | n]`
    /// buffer) on the binding link class — how the CLI's
    /// `--allreduce auto` resolves before the trainer is built. The
    /// resolved choice lands in [`crate::train::ParallelRunReport`]'s
    /// `collective` column.
    pub fn collective_for_grads(&self) -> AllReduceStrategy {
        let payload = (self.model_dims().num_scalars() + 3) as u64 * 4;
        pick_collective(payload, &self.cfg.topology(), &self.cfg.fabric_model())
    }

    /// Change the replica-group size (the partition and feature store
    /// are unchanged: the shard layout stays P-way, replication only
    /// redirects which copies cross the slow link).
    pub fn set_replication(&mut self, r: usize) {
        assert!(r >= 1 && self.cfg.num_pes % r == 0, "replication must divide the PE count");
        self.cfg.replication = r;
    }

    /// Re-partition the current graph with a different partitioner.
    pub fn set_partitioner(&mut self, p: Partitioner) {
        self.cfg.partitioner = p;
        self.part = p.build(&self.ds.graph, self.cfg.num_pes, self.cfg.seed);
        *self.store.lock().unwrap() = None;
    }

    /// Change the PE count (re-partitions the graph).
    pub fn set_num_pes(&mut self, num_pes: usize) {
        self.cfg.num_pes = num_pes;
        self.part = self.cfg.partitioner.build(&self.ds.graph, num_pes, self.cfg.seed);
        *self.store.lock().unwrap() = None;
    }

    /// Change the row codec (invalidates the cached feature store — the
    /// shards must be re-encoded). Codec sweeps in the repro harnesses
    /// go through here rather than poking `cfg.codec` directly.
    pub fn set_codec(&mut self, codec: Codec) {
        self.cfg.codec = codec;
        *self.store.lock().unwrap() = None;
    }

    /// Change the hot-tier budget (invalidates the cached feature store).
    pub fn set_hot_mb(&mut self, mb: usize) {
        self.cfg.hot_mb = mb;
        *self.store.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_rejects_invalid_configs() {
        assert!(PipelineBuilder::new().dataset("no-such-dataset").build().is_err());
        assert!(PipelineBuilder::new().num_pes(0).build().is_err());
        assert!(PipelineBuilder::new().layers(0).build().is_err());
        assert!(PipelineBuilder::new().batch_per_pe(0).build().is_err());
        assert!(PipelineBuilder::new().measure_batches(0).build().is_err());
        assert!(PipelineBuilder::new().fanout(0).build().is_err());
        assert!(PipelineBuilder::new().hidden(0).build().is_err());
    }

    /// Strict model/sampler agreement: fanout lists must match the layer
    /// count exactly (no silent truncation or padding), and a declared
    /// model depth must equal the sampled depth.
    #[test]
    fn builder_rejects_model_shape_mismatches() {
        // 2 entries for 3 layers: neither uniform nor per-layer
        assert!(PipelineBuilder::new().layers(3).fanouts(&[10, 5]).build().is_err());
        assert!(PipelineBuilder::new().layers(2).fanouts(&[10, 0]).build().is_err());
        assert!(PipelineBuilder::new().layers(3).model_layers(2).build().is_err());
        // matching shapes are fine
        let pipe = PipelineBuilder::new()
            .layers(3)
            .fanouts(&[10, 5, 5])
            .model_layers(3)
            .hidden(8)
            .build()
            .unwrap();
        let sc = pipe.cfg.sampler_config();
        assert_eq!(sc.fanout_at(0), 10);
        assert_eq!(sc.fanout_at(1), 5);
        assert_eq!(sc.fanout_at(2), 5);
        assert_eq!(sc.max_fanout(), 10);
    }

    /// Model dims derive from config depth/width + dataset feature/class
    /// shape — one shared derivation for every consumer.
    #[test]
    fn model_dims_derive_from_config_and_dataset() {
        let pipe = PipelineBuilder::new().dataset("tiny").layers(2).hidden(12).build().unwrap();
        let dims = pipe.model_dims();
        assert_eq!(dims.layers, 2);
        assert_eq!(dims.hidden, 12);
        assert_eq!(dims.d_in, pipe.ds.feat_dim);
        assert_eq!(dims.classes, pipe.ds.num_classes);
        let pt = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        assert_eq!(pt.dims(), dims);
        assert_eq!(pt.num_pes(), pipe.cfg.num_pes);
    }

    #[test]
    fn replication_must_divide_pe_count() {
        assert!(PipelineBuilder::new().num_pes(4).replication(3).build().is_err());
        assert!(PipelineBuilder::new().num_pes(4).replication(0).build().is_err());
        let mut pipe =
            PipelineBuilder::new().dataset("tiny").num_pes(4).replication(2).build().unwrap();
        assert_eq!(pipe.cfg.topology().groups(), 2);
        assert_eq!(pipe.cfg.engine_config(&pipe.ds).replication, 2);
        // a small gradient payload on the default links is latency-bound
        assert_eq!(pipe.collective_for_grads(), AllReduceStrategy::Naive);
        pipe.set_replication(4);
        assert_eq!(pipe.cfg.topology().groups(), 1);
    }

    #[test]
    fn build_partitions_to_pe_count() {
        let pipe = PipelineBuilder::new().dataset("tiny").num_pes(3).build().unwrap();
        assert_eq!(pipe.part.num_parts, 3);
        assert_eq!(pipe.cfg.seed, DEFAULT_SEED);
    }

    #[test]
    fn set_num_pes_repartitions() {
        let mut pipe = PipelineBuilder::new().dataset("tiny").num_pes(2).build().unwrap();
        pipe.set_num_pes(5);
        assert_eq!(pipe.part.num_parts, 5);
        pipe.set_partitioner(Partitioner::Multilevel);
        assert_eq!(pipe.part.num_parts, 5);
    }

    #[test]
    fn codec_config_selects_store_kind() {
        // default config → PR-6 single-tier f32 store
        let pipe = PipelineBuilder::new().dataset("tiny").build().unwrap();
        let store = pipe.feature_store();
        assert_eq!(store.codec(), Codec::F32);
        assert_eq!(store.row_bytes(), pipe.ds.feat_dim * 4);
        // int8 + hot budget → tiered compressed store with wire row size
        let mut pipe2 =
            PipelineBuilder::new().dataset("tiny").codec(Codec::Int8).hot_mb(1).build().unwrap();
        let tiered = pipe2.feature_store();
        assert_eq!(tiered.codec(), Codec::Int8);
        assert_eq!(tiered.row_bytes(), pipe2.ds.feat_dim + 5);
        // set_codec invalidates the cached store
        pipe2.set_codec(Codec::F32);
        pipe2.set_hot_mb(0);
        assert_eq!(pipe2.feature_store().row_bytes(), pipe2.ds.feat_dim * 4);
    }

    #[test]
    fn cache_default_derives_from_dataset()  {
        let pipe = PipelineBuilder::new().dataset("tiny").num_pes(4).build().unwrap();
        let ec = pipe.cfg.engine_config(&pipe.ds);
        assert_eq!(ec.cache_per_pe, (pipe.ds.cache_size / 4).max(64));
        let pipe2 = PipelineBuilder::new().dataset("tiny").cache_per_pe(123).build().unwrap();
        assert_eq!(pipe2.cfg.engine_config(&pipe2.ds).cache_per_pe, 123);
    }
}
