//! Typed pipeline configuration + builder — the one construction path
//! behind the CLI subcommands, the repro harnesses, the benches, and the
//! examples.

use super::stream::EngineStream;
use super::train_stream::Batching;
use crate::coop::all_to_all::AllReduceStrategy;
use crate::coop::engine::{self, EngineConfig, EngineReport, ExecMode, Mode};
use crate::feature::PartitionedFeatureStore;
use crate::graph::{datasets, partition, Csr, Dataset, Partition};
use crate::sampling::{Kappa, SamplerConfig, SamplerKind};
use crate::train::{ParallelTrainer, TrainerOptions};
use std::sync::{Arc, Mutex};

/// The crate-wide default RNG seed.
///
/// Before the pipeline redesign every stack had its own default
/// (`repro` 0xC0FFEE, `train` mixed 1 and 0x7EA1, `engine` 1 and 2);
/// now everything that does not receive an explicit seed derives from
/// this one constant: the dataset generator, the partitioner, the per-PE
/// seed-RNG streams, and the sampler coins. Subcommand `--seed` flags
/// and explicit config fields still override it.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

/// Which 1-D graph partitioner assigns vertices to PEs (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// uniform random assignment (the paper's baseline).
    Random,
    /// multilevel coarsen–partition–refine ("metis" on the CLI).
    Multilevel,
    /// linear deterministic greedy streaming.
    Ldg,
}

impl Partitioner {
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Random => "random",
            Partitioner::Multilevel => "metis",
            Partitioner::Ldg => "ldg",
        }
    }

    pub fn parse(s: &str) -> Option<Partitioner> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(Partitioner::Random),
            "metis" | "multilevel" => Some(Partitioner::Multilevel),
            "ldg" => Some(Partitioner::Ldg),
            _ => None,
        }
    }

    pub fn build(&self, g: &Csr, num_parts: usize, seed: u64) -> Partition {
        match self {
            Partitioner::Random => partition::random(g, num_parts, seed),
            Partitioner::Multilevel => partition::multilevel(g, num_parts, seed),
            Partitioner::Ldg => partition::ldg(g, num_parts, seed),
        }
    }
}

/// Everything needed to stand up a minibatch pipeline: dataset, PE
/// topology, minibatching strategy, sampler, cache, and measurement
/// window. Validated by [`PipelineConfig::validate`]; constructed
/// fluently through [`PipelineBuilder`].
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// registry dataset name (see `coopgnn info`).
    pub dataset: String,
    pub mode: Mode,
    pub exec: ExecMode,
    pub num_pes: usize,
    /// per-PE batch size b (global batch = b · P).
    pub batch_per_pe: usize,
    pub partitioner: Partitioner,
    pub kind: SamplerKind,
    pub fanout: usize,
    pub layers: usize,
    /// batch-dependency κ of paper §3.2 (1 = independent batches).
    pub kappa: Kappa,
    /// LRU rows per PE; `None` = dataset-derived
    /// (`ds.cache_size / num_pes`, floored at 64).
    pub cache_per_pe: Option<usize>,
    /// double-buffer the stream: a producer thread samples + gathers
    /// batch t+1 while the consumer processes batch t (`--prefetch 1`).
    /// Bit-identical results either way; only the overlap changes.
    pub prefetch: bool,
    pub warmup_batches: usize,
    pub measure_batches: usize,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        let s = SamplerConfig::default();
        PipelineConfig {
            dataset: "tiny".to_string(),
            mode: Mode::Independent,
            exec: ExecMode::Threaded,
            num_pes: 4,
            batch_per_pe: 1024,
            partitioner: Partitioner::Random,
            kind: SamplerKind::Labor0,
            fanout: s.fanout,
            layers: s.layers,
            kappa: s.kappa,
            cache_per_pe: None,
            prefetch: false,
            warmup_batches: 4,
            measure_batches: 16,
            seed: DEFAULT_SEED,
        }
    }
}

impl PipelineConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.num_pes >= 1, "pipeline needs at least one PE");
        anyhow::ensure!(self.batch_per_pe >= 1, "per-PE batch size must be >= 1");
        anyhow::ensure!(self.layers >= 1, "pipeline needs at least one GNN layer");
        anyhow::ensure!(self.fanout >= 1, "sampler fanout must be >= 1");
        anyhow::ensure!(self.measure_batches >= 1, "need at least one measured batch");
        anyhow::ensure!(
            datasets::spec(&self.dataset).is_some(),
            "unknown dataset `{}`; registry: {:?}",
            self.dataset,
            datasets::SPECS.iter().map(|s| s.name).collect::<Vec<_>>()
        );
        Ok(())
    }

    pub fn sampler_config(&self) -> SamplerConfig {
        SamplerConfig {
            fanout: self.fanout,
            layers: self.layers,
            kappa: self.kappa,
            ..Default::default()
        }
    }

    /// Lower to the engine's config, resolving the dataset-derived cache
    /// default.
    pub fn engine_config(&self, ds: &Dataset) -> EngineConfig {
        EngineConfig {
            mode: self.mode,
            exec: self.exec,
            num_pes: self.num_pes,
            batch_per_pe: self.batch_per_pe,
            kind: self.kind,
            sampler: self.sampler_config(),
            cache_per_pe: self
                .cache_per_pe
                .unwrap_or_else(|| (ds.cache_size / self.num_pes).max(64)),
            prefetch: self.prefetch,
            warmup_batches: self.warmup_batches,
            measure_batches: self.measure_batches,
            seed: self.seed,
        }
    }

    /// Trainer options mirroring this pipeline (sampler, κ, fanout,
    /// seed, exec; single-sampler batching).
    pub fn trainer_options(&self) -> TrainerOptions {
        TrainerOptions {
            kind: self.kind,
            kappa: self.kappa,
            fanout: self.fanout,
            seed: self.seed,
            lr: None,
            exec: self.exec,
            batching: Batching::Single,
        }
    }
}

/// Fluent constructor for a [`Pipeline`]. Every setter has the
/// [`PipelineConfig`] field of the same name; [`PipelineBuilder::build`]
/// validates, generates the dataset, and partitions the graph.
#[derive(Clone, Debug, Default)]
pub struct PipelineBuilder {
    cfg: PipelineConfig,
}

impl PipelineBuilder {
    pub fn new() -> PipelineBuilder {
        PipelineBuilder::default()
    }

    pub fn dataset(mut self, name: &str) -> Self {
        self.cfg.dataset = name.to_string();
        self
    }

    pub fn mode(mut self, mode: Mode) -> Self {
        self.cfg.mode = mode;
        self
    }

    pub fn exec(mut self, exec: ExecMode) -> Self {
        self.cfg.exec = exec;
        self
    }

    pub fn num_pes(mut self, p: usize) -> Self {
        self.cfg.num_pes = p;
        self
    }

    pub fn batch_per_pe(mut self, b: usize) -> Self {
        self.cfg.batch_per_pe = b;
        self
    }

    pub fn partitioner(mut self, p: Partitioner) -> Self {
        self.cfg.partitioner = p;
        self
    }

    pub fn sampler(mut self, kind: SamplerKind) -> Self {
        self.cfg.kind = kind;
        self
    }

    pub fn fanout(mut self, k: usize) -> Self {
        self.cfg.fanout = k;
        self
    }

    pub fn layers(mut self, l: usize) -> Self {
        self.cfg.layers = l;
        self
    }

    pub fn kappa(mut self, kappa: Kappa) -> Self {
        self.cfg.kappa = kappa;
        self
    }

    pub fn cache_per_pe(mut self, rows: usize) -> Self {
        self.cfg.cache_per_pe = Some(rows);
        self
    }

    pub fn prefetch(mut self, on: bool) -> Self {
        self.cfg.prefetch = on;
        self
    }

    pub fn warmup_batches(mut self, n: usize) -> Self {
        self.cfg.warmup_batches = n;
        self
    }

    pub fn measure_batches(mut self, n: usize) -> Self {
        self.cfg.measure_batches = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Validate, build the dataset (seeded from `cfg.seed`), and
    /// partition the graph.
    pub fn build(self) -> crate::Result<Pipeline> {
        self.cfg.validate()?;
        let ds = datasets::build(&self.cfg.dataset, self.cfg.seed)?;
        let part = self.cfg.partitioner.build(&ds.graph, self.cfg.num_pes, self.cfg.seed);
        Ok(Pipeline { cfg: self.cfg, ds, part, store: Mutex::new(None) })
    }
}

/// A built pipeline: validated config + generated dataset + partition.
///
/// `cfg` is public so sweeps (κ, cache size, mode, exec, batch window)
/// can retune between [`Pipeline::engine_report`] calls without
/// regenerating the dataset; anything that changes the partition
/// (PE count, partitioner) must go through the `set_*` helpers, which
/// also invalidate the cached feature store (its shard layout follows
/// the partition).
pub struct Pipeline {
    pub cfg: PipelineConfig,
    pub ds: Dataset,
    pub part: Partition,
    /// lazily-materialized partitioned feature store, shared by every
    /// stream this pipeline hands out (building one is an O(|V|·d) pass).
    store: Mutex<Option<Arc<PartitionedFeatureStore>>>,
}

impl Pipeline {
    /// The partitioned feature store for the current partition,
    /// materializing it on first use.
    pub fn feature_store(&self) -> Arc<PartitionedFeatureStore> {
        let mut guard = self.store.lock().unwrap();
        guard
            .get_or_insert_with(|| Arc::new(PartitionedFeatureStore::build(&self.ds, &self.part)))
            .clone()
    }

    /// A fresh measurement stream over the current config (sharing the
    /// pipeline's feature store).
    pub fn stream(&self) -> EngineStream<'_> {
        EngineStream::with_store(
            &self.ds,
            &self.part,
            &self.cfg.engine_config(&self.ds),
            self.feature_store(),
        )
    }

    /// Drain a fresh stream into the aggregated engine report
    /// (warmup + measure batches per the current config; double-buffered
    /// when `cfg.prefetch` is on).
    pub fn engine_report(&self) -> EngineReport {
        let cfg = self.cfg.engine_config(&self.ds);
        engine::run_stream(self.stream(), &cfg)
    }

    /// Trainer options mirroring this pipeline.
    pub fn trainer_options(&self) -> TrainerOptions {
        self.cfg.trainer_options()
    }

    /// The multi-PE training plane over this pipeline: one trainer
    /// replica per PE (shape `feat_dim → num_classes`, init from
    /// `cfg.seed`), gradient all-reduce in `cfg.exec`'s execution mode.
    /// Drive it with [`Pipeline::stream`] (optionally prefetch-wrapped);
    /// the stream and the trainer must agree on `num_pes`, which this
    /// constructor guarantees.
    pub fn parallel_trainer(&self, lr: f32, strategy: AllReduceStrategy) -> ParallelTrainer {
        ParallelTrainer::new(
            self.cfg.num_pes,
            self.ds.feat_dim,
            self.ds.num_classes,
            self.cfg.seed,
            lr,
            self.cfg.exec,
            strategy,
        )
    }

    /// Re-partition the current graph with a different partitioner.
    pub fn set_partitioner(&mut self, p: Partitioner) {
        self.cfg.partitioner = p;
        self.part = p.build(&self.ds.graph, self.cfg.num_pes, self.cfg.seed);
        *self.store.lock().unwrap() = None;
    }

    /// Change the PE count (re-partitions the graph).
    pub fn set_num_pes(&mut self, num_pes: usize) {
        self.cfg.num_pes = num_pes;
        self.part = self.cfg.partitioner.build(&self.ds.graph, num_pes, self.cfg.seed);
        *self.store.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_rejects_invalid_configs() {
        assert!(PipelineBuilder::new().dataset("no-such-dataset").build().is_err());
        assert!(PipelineBuilder::new().num_pes(0).build().is_err());
        assert!(PipelineBuilder::new().layers(0).build().is_err());
        assert!(PipelineBuilder::new().batch_per_pe(0).build().is_err());
        assert!(PipelineBuilder::new().measure_batches(0).build().is_err());
    }

    #[test]
    fn build_partitions_to_pe_count() {
        let pipe = PipelineBuilder::new().dataset("tiny").num_pes(3).build().unwrap();
        assert_eq!(pipe.part.num_parts, 3);
        assert_eq!(pipe.cfg.seed, DEFAULT_SEED);
    }

    #[test]
    fn set_num_pes_repartitions() {
        let mut pipe = PipelineBuilder::new().dataset("tiny").num_pes(2).build().unwrap();
        pipe.set_num_pes(5);
        assert_eq!(pipe.part.num_parts, 5);
        pipe.set_partitioner(Partitioner::Multilevel);
        assert_eq!(pipe.part.num_parts, 5);
    }

    #[test]
    fn cache_default_derives_from_dataset()  {
        let pipe = PipelineBuilder::new().dataset("tiny").num_pes(4).build().unwrap();
        let ec = pipe.cfg.engine_config(&pipe.ds);
        assert_eq!(ec.cache_per_pe, (pipe.ds.cache_size / 4).max(64));
        let pipe2 = PipelineBuilder::new().dataset("tiny").cache_per_pe(123).build().unwrap();
        assert_eq!(pipe2.cfg.engine_config(&pipe2.ds).cache_per_pe, 123);
    }
}
