//! Table 7: per-PE sampled vertex/edge/communication counts with random
//! vs multilevel ("metis") partitioning, Independent vs Cooperative,
//! LABOR-0, P=4, b=1024 — max over PEs, averaged over batches, reported
//! in thousands like the paper.

use super::Ctx;
use crate::coop::engine::Mode;
use crate::pipeline::{Partitioner, PipelineBuilder};
use crate::util::csv::Table;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let ds_names: &[&str] = if ctx.quick { &["tiny"] } else { &["papers-s", "mag-s"] };
    let mut table = Table::new(
        "Table 7: per-PE counts (thousands; max over 4 PEs, avg over batches), LABOR-0, b=1024",
        &[
            "dataset", "part", "mode", "S3", "cS3~", "S3~", "E2", "S2", "cS2~", "S2~", "E1",
            "S1", "dup_L",
        ],
    );
    for ds_name in ds_names {
        let mut pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .exec(ctx.exec)
            .num_pes(4)
            .batch_per_pe(if ctx.quick { 32 } else { 1024 })
            .cache_per_pe(1024)
            .warmup_batches(1)
            .measure_batches(if ctx.quick { 2 } else { 6 })
            .seed(ctx.seed)
            .build()?;
        for (pname, partitioner) in
            [("random", Partitioner::Random), ("metis", Partitioner::Multilevel)]
        {
            pipe.set_partitioner(partitioner);
            for mode in [Mode::Independent, Mode::Cooperative] {
                // independent counts don't depend on partition quality —
                // print them only once (random row), like the paper
                if mode == Mode::Independent && pname == "metis" {
                    continue;
                }
                pipe.cfg.mode = mode;
                let r = pipe.engine_report();
                let k = |x: f64| format!("{:.2}", x / 1e3);
                table.push_row(&[
                    ds_name.to_string(),
                    pname.to_string(),
                    mode.name().to_string(),
                    k(r.s[3]),
                    k(r.cross.get(2).copied().unwrap_or(0.0)),
                    k(r.tilde.get(2).copied().unwrap_or(r.s[3])),
                    k(r.e[2]),
                    k(r.s[2]),
                    k(r.cross.get(1).copied().unwrap_or(0.0)),
                    k(r.tilde.get(1).copied().unwrap_or(r.s[2])),
                    k(r.e[1]),
                    k(r.s[1]),
                    format!("{:.2}", r.dup_factor),
                ]);
                println!("table7: {ds_name} {pname} {} done", mode.name());
            }
        }
    }
    table.write(&ctx.out, "table7")?;
    println!("{}", table.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table7_shapes() {
        let dir = std::env::temp_dir().join("coopgnn_table7_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("table7.csv")).unwrap();
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|s| s.to_string()).collect())
            .collect();
        assert_eq!(rows.len(), 3, "indep-random, coop-random, coop-metis");
        let s3 = |r: &Vec<String>| -> f64 { r[3].parse().unwrap() };
        let cross3 = |r: &Vec<String>| -> f64 { r[4].parse().unwrap() };
        let indep = &rows[0];
        let coop_rand = &rows[1];
        let coop_metis = &rows[2];
        // coop per-PE deepest-layer work < indep (the core claim)
        assert!(s3(coop_rand) < s3(indep), "coop S3 {coop_rand:?} vs indep {indep:?}");
        // partitioning reduces cross traffic
        assert!(cross3(coop_metis) <= cross3(coop_rand) * 1.05);
        std::fs::remove_dir_all(&dir).ok();
    }
}
