//! End-to-end multi-PE training: Independent vs Cooperative
//! Minibatching through the full plane — per-PE sampling, real feature
//! movement (storage β + fabric α), per-PE local gradients, gradient
//! all-reduce, lockstep Adam — reporting ms/step and bytes/step at
//! several PE counts.
//!
//! This is the paper's headline end-to-end comparison (up to 64%
//! speedup of Cooperative over Independent on multi-PE systems) run as
//! a measurement, not a model: both arms drive the same
//! [`crate::train::ParallelTrainer`] off the same
//! [`crate::pipeline::EngineStream`] seam, so the only
//! difference between rows is the minibatching strategy. The bytes/step
//! columns decompose the data plane the way Table 1 does — storage (β)
//! reads, feature rows over the fabric (α), gradient all-reduce
//! traffic, and (cooperative only) the per-layer hidden-activation
//! exchange of the layered compute plane — and the sanity column
//! confirms the two arms train (loss falls from the same replicated
//! init).
//!
//! Emits `<out>/end2end.csv` + `.md`. The lockstep/bit-identity
//! correctness properties behind this harness are tested in
//! `train::parallel` and asserted again in quick mode below.

use super::Ctx;
use crate::coop::all_to_all::AllReduceStrategy;
use crate::coop::engine::Mode;
use crate::pipeline::PipelineBuilder;
use crate::train::ParallelRunReport;
use crate::util::csv::{fmt_kib, fmt_ms, Table};

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_name, batch_per_pe, steps, pe_counts, lr): (_, usize, usize, &[usize], f32) =
        if ctx.quick {
            ("tiny", 32, 8, &[2, 4], 0.05)
        } else {
            ("flickr-s", 256, 16, &[2, 4, 8], 0.05)
        };
    let mut table = Table::new(
        "End-to-end multi-PE training: Independent vs Cooperative (ms/step, bytes/step)",
        &[
            "PEs",
            "mode",
            "ms_per_step",
            "sample_ms",
            "feature_ms",
            "compute_ms",
            "allreduce_ms",
            "storage_KiB_step",
            "fabric_KiB_step",
            "grad_KiB_step",
            "act_KiB_step",
            "loss_first",
            "loss_last",
            "coop_vs_indep",
            "inter_KiB_step",
            "collective",
            "sample_p50_ms",
            "sample_p99_ms",
            "compute_p50_ms",
            "compute_p99_ms",
            "allreduce_p50_ms",
            "allreduce_p99_ms",
        ],
    );
    for &p in pe_counts {
        // the requested replica-group size where the PE count allows it
        let r = if p % ctx.replication == 0 { ctx.replication } else { 1 };
        let mut per_mode: Vec<(Mode, ParallelRunReport, [f64; 6])> = Vec::new();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut b = PipelineBuilder::new()
                .dataset(ds_name)
                .mode(mode)
                .exec(ctx.exec)
                .num_pes(p)
                .replication(r)
                .batch_per_pe(batch_per_pe)
                .seed(ctx.seed);
            if let Some(gbps) = ctx.intra_bw {
                b = b.intra_bw(gbps);
            }
            if let Some(gbps) = ctx.inter_bw {
                b = b.inter_bw(gbps);
            }
            let pipe = b.build()?;
            let mut stream = pipe.stream();
            let mut trainer = pipe.parallel_trainer(lr, AllReduceStrategy::Ring);
            let rep = trainer.run(&mut stream, steps, &pipe.ds.labels);
            anyhow::ensure!(
                trainer.replicas_in_lockstep(),
                "end2end: {} {}-PE replicas diverged",
                mode.name(),
                p
            );
            // per-step stage distributions from the trainer's log-bucket
            // histograms (the mean columns hide tail skew; p50/p99 show it)
            let h = trainer.stage_hists();
            let hq = [
                h.sample_ms.quantile_mid(0.50),
                h.sample_ms.quantile_mid(0.99),
                h.compute_ms.quantile_mid(0.50),
                h.compute_ms.quantile_mid(0.99),
                h.allreduce_ms.quantile_mid(0.50),
                h.allreduce_ms.quantile_mid(0.99),
            ];
            per_mode.push((mode, rep, hq));
            println!("end2end: {} P={p} done ({:.2} ms/step)", mode.name(), rep.ms_per_step);
        }
        let indep_ms = per_mode[0].1.ms_per_step;
        for (mode, rep, hq) in &per_mode {
            let ratio = if *mode == Mode::Cooperative && rep.ms_per_step > 0.0 {
                format!("{:.2}x", indep_ms / rep.ms_per_step)
            } else {
                "-".to_string()
            };
            table.push_row(&[
                p.to_string(),
                mode.name().to_string(),
                fmt_ms(rep.ms_per_step),
                fmt_ms(rep.sample_ms),
                fmt_ms(rep.feature_ms),
                fmt_ms(rep.compute_ms),
                fmt_ms(rep.allreduce_ms),
                fmt_kib(rep.storage_bytes_per_step),
                fmt_kib(rep.fabric_bytes_per_step),
                fmt_kib(rep.grad_bytes_per_step),
                fmt_kib(rep.act_bytes_per_step),
                format!("{:.4}", rep.first_loss),
                format!("{:.4}", rep.last_loss),
                ratio,
                fmt_kib(total_inter_bytes(rep)),
                rep.collective.to_string(),
                fmt_ms(hq[0]),
                fmt_ms(hq[1]),
                fmt_ms(hq[2]),
                fmt_ms(hq[3]),
                fmt_ms(hq[4]),
                fmt_ms(hq[5]),
            ]);
        }
    }
    table.write(&ctx.out, "end2end")?;
    println!("{}", table.to_markdown());
    println!(
        "end2end: coop_vs_indep > 1.00x reproduces the paper's end-to-end speedup direction \
         (CPU-thread PEs; magnitudes are not calibrated to the paper's GPUs)"
    );
    if ctx.replication > 1 {
        replication_table(ctx, ds_name, *pe_counts.last().unwrap(), batch_per_pe, steps, lr)?;
    }
    Ok(())
}

/// The inter-group slice of every fabric ledger (feature rows +
/// activations + gradients), per step.
fn total_inter_bytes(rep: &ParallelRunReport) -> f64 {
    rep.fabric_inter_bytes_per_step + rep.act_inter_bytes_per_step + rep.grad_inter_bytes_per_step
}

/// One cooperative training run at replica-group size `r`; also returns
/// the costmodel's collective pick for the gradient payload (what
/// `--allreduce auto` would resolve to on this topology).
fn replicated_run(
    ctx: &Ctx,
    ds_name: &str,
    p: usize,
    r: usize,
    batch_per_pe: usize,
    steps: usize,
    lr: f32,
) -> crate::Result<(ParallelRunReport, AllReduceStrategy)> {
    let mut b = PipelineBuilder::new()
        .dataset(ds_name)
        .mode(Mode::Cooperative)
        .exec(ctx.exec)
        .num_pes(p)
        .replication(r)
        .batch_per_pe(batch_per_pe)
        .seed(ctx.seed);
    if let Some(gbps) = ctx.intra_bw {
        b = b.intra_bw(gbps);
    }
    if let Some(gbps) = ctx.inter_bw {
        b = b.inter_bw(gbps);
    }
    let pipe = b.build()?;
    let picked = pipe.collective_for_grads();
    let mut stream = pipe.stream();
    let mut trainer = pipe.parallel_trainer(lr, AllReduceStrategy::Ring);
    let rep = trainer.run(&mut stream, steps, &pipe.ds.labels);
    anyhow::ensure!(
        trainer.replicas_in_lockstep(),
        "end2end: {p}-PE r={r} replicas diverged"
    );
    Ok((rep, picked))
}

/// The communication-avoiding sweep: cooperative bytes/step at growing
/// replica-group sizes, same partition and seeds — the trajectory is
/// bit-identical across rows, only the ledger split moves.
fn replication_table(
    ctx: &Ctx,
    ds_name: &str,
    p: usize,
    batch_per_pe: usize,
    steps: usize,
    lr: f32,
) -> crate::Result<()> {
    let mut table = Table::new(
        "Communication-avoiding replication: cooperative inter-group bytes/step vs r",
        &[
            "PEs",
            "r",
            "inter_KiB_step",
            "fabric_inter_KiB",
            "act_inter_KiB",
            "grad_inter_KiB",
            "vs_r1",
            "loss_last",
            "auto_pick",
        ],
    );
    let mut base: Option<f64> = None;
    for r in [1usize, 2, 4] {
        if p % r != 0 {
            continue;
        }
        let (rep, picked) = replicated_run(ctx, ds_name, p, r, batch_per_pe, steps, lr)?;
        let inter = total_inter_bytes(&rep);
        let b = *base.get_or_insert(inter);
        table.push_row(&[
            p.to_string(),
            r.to_string(),
            fmt_kib(inter),
            fmt_kib(rep.fabric_inter_bytes_per_step),
            fmt_kib(rep.act_inter_bytes_per_step),
            fmt_kib(rep.grad_inter_bytes_per_step),
            if inter > 0.0 { format!("{:.2}x", b / inter) } else { "-".to_string() },
            format!("{:.4}", rep.last_loss),
            picked.name().to_string(),
        ]);
        println!("end2end: replication P={p} r={r} done");
    }
    table.write(&ctx.out, "end2end_replication")?;
    println!("{}", table.to_markdown());
    println!(
        "end2end: vs_r1 tracks the (P-1)/(P/r-1) inter-group reduction at bit-identical losses \
         (each group serves its replica's rows over the fast local links)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::ExecMode;

    /// The acceptance gate: the table exists with both modes at ≥ 2 PE
    /// counts, every measured cell is sane, and the serial run of the
    /// same config reproduces the threaded losses bit-for-bit (the
    /// Serial == Threaded trajectory contract, through the harness).
    #[test]
    fn end2end_quick_emits_comparison_table_and_is_exec_deterministic() {
        let dir = std::env::temp_dir().join("coopgnn_end2end_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("end2end.csv")).unwrap();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 4, "2 modes x 2 PE counts: {csv}");
        let mut pes_seen = std::collections::BTreeSet::new();
        for r in &rows {
            let cells: Vec<&str> = r.split(',').collect();
            pes_seen.insert(cells[0].to_string());
            let ms: f64 = cells[2].parse().unwrap();
            let storage: f64 = cells[7].parse().unwrap();
            let grad: f64 = cells[9].parse().unwrap();
            let act: f64 = cells[10].parse().unwrap();
            assert!(ms > 0.0, "ms/step must be measured: {r}");
            assert!(storage > 0.0, "storage bytes must move: {r}");
            assert!(grad > 0.0, "gradient bytes must move: {r}");
            if cells[1] == "Coop" {
                let fabric: f64 = cells[8].parse().unwrap();
                assert!(fabric > 0.0, "coop rows must ship fabric rows: {r}");
                assert!(act > 0.0, "coop rows must exchange hidden activations: {r}");
            } else {
                assert_eq!(act, 0.0, "independent rows exchange no activations: {r}");
            }
            // appended stage-histogram columns: parse, and each p99
            // bounds its p50 from above (quantile monotonicity)
            for (p50, p99) in [(16, 17), (18, 19), (20, 21)] {
                let lo: f64 = cells[p50].parse().unwrap();
                let hi: f64 = cells[p99].parse().unwrap();
                assert!(hi >= lo && lo >= 0.0, "hist percentile order: {r}");
            }
        }
        assert_eq!(pes_seen.len(), 2, "two PE counts required");

        let serial_ctx = Ctx {
            out: dir.join("serial"),
            quick: true,
            exec: ExecMode::Serial,
            ..Default::default()
        };
        run(&serial_ctx).unwrap();
        let serial_csv = std::fs::read_to_string(dir.join("serial/end2end.csv")).unwrap();
        let losses = |csv: &str| -> Vec<String> {
            csv.lines()
                .skip(1)
                .map(|l| {
                    let c: Vec<&str> = l.split(',').collect();
                    format!("{},{},{},{}", c[0], c[1], c[11], c[12])
                })
                .collect()
        };
        assert_eq!(
            losses(&csv),
            losses(&serial_csv),
            "serial and threaded end2end trajectories must match exactly"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The communication-avoiding acceptance gate: at 8 PEs, replica
    /// groups cut the inter-group fabric bytes/step by >= 1.8x (r=2)
    /// and >= 3.5x (r=4) vs the flat fabric, at a training trajectory
    /// that stays **bit-identical** — replication redirects copies onto
    /// fast local links, it never changes what is computed.
    #[test]
    fn replication_cuts_inter_bytes_at_identical_trajectories() {
        let ctx = Ctx::default();
        let (p, b, steps, lr) = (8usize, 96usize, 4usize, 0.05f32);
        let (r1, _) = replicated_run(&ctx, "tiny", p, 1, b, steps, lr).unwrap();
        let (r2, _) = replicated_run(&ctx, "tiny", p, 2, b, steps, lr).unwrap();
        let (r4, _) = replicated_run(&ctx, "tiny", p, 4, b, steps, lr).unwrap();
        for (r, rep) in [(2, &r2), (4, &r4)] {
            assert_eq!(
                r1.first_loss.to_bits(),
                rep.first_loss.to_bits(),
                "r={r}: first loss must be bit-identical to flat"
            );
            assert_eq!(
                r1.last_loss.to_bits(),
                rep.last_loss.to_bits(),
                "r={r}: last loss must be bit-identical to flat"
            );
        }
        // on the flat fabric every ledger's inter slice IS its cross total
        assert_eq!(r1.fabric_inter_bytes_per_step, r1.fabric_bytes_per_step);
        assert_eq!(r1.grad_inter_bytes_per_step, r1.grad_bytes_per_step);
        assert_eq!(r1.act_inter_bytes_per_step, r1.act_bytes_per_step);
        let (i1, i2, i4) =
            (total_inter_bytes(&r1), total_inter_bytes(&r2), total_inter_bytes(&r4));
        assert!(i1 > 0.0 && i2 > 0.0 && i4 > 0.0, "inter ledgers must be measured");
        assert!(i1 / i2 >= 1.8, "r=2 must cut inter bytes >= 1.8x: {i1:.0} vs {i2:.0}");
        assert!(i1 / i4 >= 3.5, "r=4 must cut inter bytes >= 3.5x: {i1:.0} vs {i4:.0}");
    }
}
