//! End-to-end multi-PE training: Independent vs Cooperative
//! Minibatching through the full plane — per-PE sampling, real feature
//! movement (storage β + fabric α), per-PE local gradients, gradient
//! all-reduce, lockstep Adam — reporting ms/step and bytes/step at
//! several PE counts.
//!
//! This is the paper's headline end-to-end comparison (up to 64%
//! speedup of Cooperative over Independent on multi-PE systems) run as
//! a measurement, not a model: both arms drive the same
//! [`crate::train::ParallelTrainer`] off the same
//! [`crate::pipeline::EngineStream`] seam, so the only
//! difference between rows is the minibatching strategy. The bytes/step
//! columns decompose the data plane the way Table 1 does — storage (β)
//! reads, feature rows over the fabric (α), gradient all-reduce
//! traffic, and (cooperative only) the per-layer hidden-activation
//! exchange of the layered compute plane — and the sanity column
//! confirms the two arms train (loss falls from the same replicated
//! init).
//!
//! Emits `<out>/end2end.csv` + `.md`. The lockstep/bit-identity
//! correctness properties behind this harness are tested in
//! `train::parallel` and asserted again in quick mode below.

use super::Ctx;
use crate::coop::all_to_all::AllReduceStrategy;
use crate::coop::engine::Mode;
use crate::pipeline::PipelineBuilder;
use crate::train::ParallelRunReport;
use crate::util::csv::Table;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_name, batch_per_pe, steps, pe_counts, lr): (_, usize, usize, &[usize], f32) =
        if ctx.quick {
            ("tiny", 32, 8, &[2, 4], 0.05)
        } else {
            ("flickr-s", 256, 16, &[2, 4, 8], 0.05)
        };
    let mut table = Table::new(
        "End-to-end multi-PE training: Independent vs Cooperative (ms/step, bytes/step)",
        &[
            "PEs",
            "mode",
            "ms_per_step",
            "sample_ms",
            "feature_ms",
            "compute_ms",
            "allreduce_ms",
            "storage_KiB_step",
            "fabric_KiB_step",
            "grad_KiB_step",
            "act_KiB_step",
            "loss_first",
            "loss_last",
            "coop_vs_indep",
        ],
    );
    for &p in pe_counts {
        let mut per_mode: Vec<(Mode, ParallelRunReport)> = Vec::new();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let pipe = PipelineBuilder::new()
                .dataset(ds_name)
                .mode(mode)
                .exec(ctx.exec)
                .num_pes(p)
                .batch_per_pe(batch_per_pe)
                .seed(ctx.seed)
                .build()?;
            let mut stream = pipe.stream();
            let mut trainer = pipe.parallel_trainer(lr, AllReduceStrategy::Ring);
            let rep = trainer.run(&mut stream, steps, &pipe.ds.labels);
            anyhow::ensure!(
                trainer.replicas_in_lockstep(),
                "end2end: {} {}-PE replicas diverged",
                mode.name(),
                p
            );
            per_mode.push((mode, rep));
            println!("end2end: {} P={p} done ({:.2} ms/step)", mode.name(), rep.ms_per_step);
        }
        let indep_ms = per_mode[0].1.ms_per_step;
        for (mode, rep) in &per_mode {
            let ratio = if *mode == Mode::Cooperative && rep.ms_per_step > 0.0 {
                format!("{:.2}x", indep_ms / rep.ms_per_step)
            } else {
                "-".to_string()
            };
            table.push_row(&[
                p.to_string(),
                mode.name().to_string(),
                format!("{:.2}", rep.ms_per_step),
                format!("{:.2}", rep.sample_ms),
                format!("{:.2}", rep.feature_ms),
                format!("{:.2}", rep.compute_ms),
                format!("{:.2}", rep.allreduce_ms),
                format!("{:.1}", rep.storage_bytes_per_step / 1024.0),
                format!("{:.1}", rep.fabric_bytes_per_step / 1024.0),
                format!("{:.1}", rep.grad_bytes_per_step / 1024.0),
                format!("{:.1}", rep.act_bytes_per_step / 1024.0),
                format!("{:.4}", rep.first_loss),
                format!("{:.4}", rep.last_loss),
                ratio,
            ]);
        }
    }
    table.write(&ctx.out, "end2end")?;
    println!("{}", table.to_markdown());
    println!(
        "end2end: coop_vs_indep > 1.00x reproduces the paper's end-to-end speedup direction \
         (CPU-thread PEs; magnitudes are not calibrated to the paper's GPUs)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::ExecMode;

    /// The acceptance gate: the table exists with both modes at ≥ 2 PE
    /// counts, every measured cell is sane, and the serial run of the
    /// same config reproduces the threaded losses bit-for-bit (the
    /// Serial == Threaded trajectory contract, through the harness).
    #[test]
    fn end2end_quick_emits_comparison_table_and_is_exec_deterministic() {
        let dir = std::env::temp_dir().join("coopgnn_end2end_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("end2end.csv")).unwrap();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), 4, "2 modes x 2 PE counts: {csv}");
        let mut pes_seen = std::collections::BTreeSet::new();
        for r in &rows {
            let cells: Vec<&str> = r.split(',').collect();
            pes_seen.insert(cells[0].to_string());
            let ms: f64 = cells[2].parse().unwrap();
            let storage: f64 = cells[7].parse().unwrap();
            let grad: f64 = cells[9].parse().unwrap();
            let act: f64 = cells[10].parse().unwrap();
            assert!(ms > 0.0, "ms/step must be measured: {r}");
            assert!(storage > 0.0, "storage bytes must move: {r}");
            assert!(grad > 0.0, "gradient bytes must move: {r}");
            if cells[1] == "Coop" {
                let fabric: f64 = cells[8].parse().unwrap();
                assert!(fabric > 0.0, "coop rows must ship fabric rows: {r}");
                assert!(act > 0.0, "coop rows must exchange hidden activations: {r}");
            } else {
                assert_eq!(act, 0.0, "independent rows exchange no activations: {r}");
            }
        }
        assert_eq!(pes_seen.len(), 2, "two PE counts required");

        let serial_ctx = Ctx {
            out: dir.join("serial"),
            quick: true,
            exec: ExecMode::Serial,
            ..Default::default()
        };
        run(&serial_ctx).unwrap();
        let serial_csv = std::fs::read_to_string(dir.join("serial/end2end.csv")).unwrap();
        let losses = |csv: &str| -> Vec<String> {
            csv.lines()
                .skip(1)
                .map(|l| {
                    let c: Vec<&str> = l.split(',').collect();
                    format!("{},{},{},{}", c[0], c[1], c[11], c[12])
                })
                .collect()
        };
        assert_eq!(
            losses(&csv),
            losses(&serial_csv),
            "serial and threaded end2end trajectories must match exactly"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
