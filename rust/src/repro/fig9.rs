//! Figure 9: convergence of Cooperative vs Independent minibatching at
//! identical global batch size.
//!
//! Both arms run through the same pipeline stream seam
//! (`pipeline::TrainStream`), differing only in the batching policy:
//! `Batching::Single` = one global MFG sampled with shared coins
//! (exactly the union Algorithm 1 computes — see coop_sampler tests);
//! `Batching::IndepMerged` = block-diagonal merge of P per-PE MFGs
//! sampled with *independent* RNGs, which is bit-equivalent to P PEs
//! computing privately and all-reducing gradients. Expected shape: the
//! loss/accuracy curves overlap within noise (paper Appendix A.9).

use super::Ctx;
use crate::pipeline::{Batching, PipelineBuilder};
use crate::runtime::{Manifest, Runtime};
use crate::sampling::SamplerKind;
use crate::train::Trainer;
use crate::util::csv::Table;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_name, coop_art, indep_art, p, steps, eval_every, (batch, layers, hidden)) =
        if ctx.quick {
            ("tiny", "tiny-b32", "tiny-b32", 2usize, 100usize, 25usize, (32usize, 2usize, 16usize))
        } else {
            ("conv", "conv-b1024", "conv-indep4", 4, 250, 25, (1024, 3, 32))
        };
    // training harness: the PJRT/AOT backend when runtime + artifacts
    // are present, the host layered backend otherwise — both arms train
    // for real either way
    let aot = match (Runtime::cpu(), Manifest::load(&ctx.artifacts)) {
        (Ok(rt), Ok(m)) => Some((rt, m)),
        (Err(e), _) | (_, Err(e)) => {
            println!("fig9: PJRT/AOT unavailable ({e}); using the host compute backend");
            None
        }
    };
    let pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .sampler(SamplerKind::Labor0)
        .exec(ctx.exec)
        .seed(ctx.seed)
        .build()?;
    let ds = &pipe.ds;
    let mut table = Table::new(
        "Figure 9: coop vs indep convergence, identical global batch",
        &["mode", "step", "train_loss", "val_acc", "val_f1"],
    );

    let mut finals = Vec::new();
    for (mode, art, batching) in [
        ("coop", coop_art, Batching::Single),
        ("indep", indep_art, Batching::IndepMerged { pes: p }),
    ] {
        let mut opts = pipe.trainer_options();
        opts.lr = Some(0.01);
        opts.batching = batching;
        let mut trainer = match &aot {
            Some((rt, manifest)) => Trainer::new(rt, manifest, art, ds, &opts)?,
            None => Trainer::new_host(ds, batch, layers, hidden, &opts)?,
        };
        let mut final_acc = 0.0;
        for step in 1..=steps {
            let stats = trainer.step()?;
            if step % eval_every == 0 || step == steps {
                let val = trainer.evaluate(&ds.val, 777)?;
                final_acc = val.accuracy;
                table.push_row(&[
                    mode.to_string(),
                    step.to_string(),
                    format!("{:.4}", stats.loss),
                    format!("{:.4}", val.accuracy),
                    format!("{:.4}", val.macro_f1),
                ]);
            }
        }
        finals.push((mode, final_acc));
        println!("fig9: {mode} done (final val acc {final_acc:.4})");
    }
    table.write(&ctx.out, "fig9")?;
    println!("{}", table.to_markdown());
    println!("fig9 finals: {finals:?} (expected: overlap within noise)");
    Ok(())
}
