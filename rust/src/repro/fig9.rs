//! Figure 9: convergence of Cooperative vs Independent minibatching at
//! identical global batch size.
//!
//! Cooperative = one global MFG sampled with shared coins (exactly the
//! union Algorithm 1 computes — see coop_sampler tests). Independent =
//! block-diagonal merge of P per-PE MFGs sampled with *independent*
//! RNGs, which is bit-equivalent to P PEs computing privately and
//! all-reducing gradients. Expected shape: the loss/accuracy curves
//! overlap within noise (paper Appendix A.9).

use super::Ctx;
use crate::graph::datasets;
use crate::runtime::{Manifest, Runtime};
use crate::sampling::SamplerKind;
use crate::train::{Trainer, TrainerOptions};
use crate::util::csv::Table;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_name, coop_art, indep_art, p, steps, eval_every) = if ctx.quick {
        ("tiny", "tiny-b32", "tiny-b32", 2usize, 100usize, 25usize)
    } else {
        ("conv", "conv-b1024", "conv-indep4", 4, 250, 25)
    };
    // training harness: skip cleanly when the execution runtime or the
    // AOT artifacts are unavailable (count-based harnesses still run)
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("fig9: skipped — {e}");
            return Ok(());
        }
    };
    let manifest = match Manifest::load(&ctx.artifacts) {
        Ok(m) => m,
        Err(e) => {
            println!("fig9: skipped — {e}");
            return Ok(());
        }
    };
    let ds = datasets::build(ds_name, ctx.seed)?;
    let mut table = Table::new(
        "Figure 9: coop vs indep convergence, identical global batch",
        &["mode", "step", "train_loss", "val_acc", "val_f1"],
    );

    let mut finals = Vec::new();
    for (mode, art) in [("coop", coop_art), ("indep", indep_art)] {
        let opts = TrainerOptions {
            kind: SamplerKind::Labor0,
            seed: ctx.seed,
            lr: Some(0.01),
            ..Default::default()
        };
        let mut trainer = Trainer::new(&rt, &manifest, art, &ds, &opts)?;
        let mut final_acc = 0.0;
        for step in 1..=steps {
            let seeds = trainer.next_seeds();
            let stats = if mode == "coop" {
                let mfg = trainer.sample_global_mfg(&seeds);
                trainer.step_on_mfg(&mfg)?
            } else {
                let mfg = trainer.sample_indep_merged_mfg(
                    &seeds,
                    p,
                    ctx.seed ^ (step as u64) << 16,
                );
                trainer.step_on_mfg(&mfg)?
            };
            if step % eval_every == 0 || step == steps {
                let val = trainer.evaluate(&ds.val, 777)?;
                final_acc = val.accuracy;
                table.push_row(&[
                    mode.to_string(),
                    step.to_string(),
                    format!("{:.4}", stats.loss),
                    format!("{:.4}", val.accuracy),
                    format!("{:.4}", val.macro_f1),
                ]);
            }
        }
        finals.push((mode, final_acc));
        println!("fig9: {mode} done (final val acc {final_acc:.4})");
    }
    table.write(&ctx.out, "fig9")?;
    println!("{}", table.to_markdown());
    println!("fig9 finals: {finals:?} (expected: overlap within noise)");
    Ok(())
}
