//! Figures 3 and 6: monotonicity of work and concavity of E[|S³|].
//!
//! Sweeps the batch size for node- and edge-prediction workloads across
//! all four samplers and reports `E[|S³|]/|S⁰|` (work ratio) and
//! `E[|S³|]` (subgraph size). Asserts the theorem shapes: ratios are
//! monotonically nonincreasing (Thm 3.1) and counts concave (Thm 3.2),
//! within sampling noise.

use super::Ctx;
use crate::pipeline::PipelineBuilder;
use crate::sampling::{edge_pred, RwParams, SamplerConfig, SamplerKind};
use crate::util::csv::Table;
use crate::util::rng::Pcg64;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_names, batches, trials, walks): (&[&str], Vec<usize>, usize, usize) = if ctx.quick {
        (&["flickr-s"], vec![256, 1024, 4096], 1, 10)
    } else {
        (
            &["flickr-s", "yelp-s", "reddit-s", "papers-s"],
            vec![64, 256, 1024, 4096, 16384],
            3,
            25,
        )
    };
    let mut table = Table::new(
        "Figures 3/6: work per epoch vs batch size (L=3, k=10)",
        &["dataset", "task", "sampler", "batch", "E[S3]", "ratio", "monotone_ok", "concave_ok"],
    );
    for ds_name in ds_names {
        let ds = PipelineBuilder::new().dataset(ds_name).seed(ctx.seed).build()?.ds;
        // edge prediction needs an undirected view
        let und = ds.graph.to_undirected();
        for task in ["node", "edge"] {
            for kind in SamplerKind::ALL {
                let cfg = SamplerConfig {
                    rw: RwParams { num_walks: walks, ..Default::default() },
                    ..Default::default()
                };
                let mut prev_ratio = f64::INFINITY;
                let mut counts: Vec<(usize, f64)> = Vec::new();
                for &b in &batches {
                    let mut acc = 0.0;
                    for t in 0..trials {
                        let g = if task == "edge" { &und } else { &ds.graph };
                        let mut sampler =
                            cfg.build(kind, g, ctx.seed ^ ((t as u64 + 1) << 24));
                        let mut rng = Pcg64::new(ctx.seed ^ (b as u64) ^ (t as u64) << 8);
                        let seeds: Vec<u32> = if task == "node" {
                            rng.sample_distinct(g.num_vertices(), b.min(g.num_vertices()))
                        } else {
                            let samples = edge_pred::sample_edges(g, b / 3 + 1, &mut rng);
                            edge_pred::seeds_of(&samples).into_iter().take(b).collect()
                        };
                        let mfg = sampler.sample_mfg(&seeds);
                        acc += mfg.input_vertices().len() as f64;
                    }
                    let e_s3 = acc / trials as f64;
                    let ratio = e_s3 / b as f64;
                    let monotone_ok = ratio <= prev_ratio * 1.08; // noise slack
                    counts.push((b, e_s3));
                    let concave_ok = check_concave(&counts);
                    table.push_row(&[
                        ds_name.to_string(),
                        task.to_string(),
                        kind.name().to_string(),
                        b.to_string(),
                        format!("{e_s3:.0}"),
                        format!("{ratio:.2}"),
                        monotone_ok.to_string(),
                        concave_ok.to_string(),
                    ]);
                    prev_ratio = ratio;
                }
            }
            println!("fig3: {ds_name}/{task} done");
        }
        // durable partial results: dataset sweeps are minutes each
        table.write(&ctx.out, "fig3")?;
    }
    println!("{}", table.to_markdown());
    Ok(())
}

/// Discrete concavity check on (batch, count) points: successive secant
/// slopes must not increase (with noise slack).
fn check_concave(points: &[(usize, f64)]) -> bool {
    if points.len() < 3 {
        return true;
    }
    let slope = |a: (usize, f64), b: (usize, f64)| (b.1 - a.1) / (b.0 as f64 - a.0 as f64);
    let mut prev = f64::INFINITY;
    for w in points.windows(2) {
        let s = slope(w[0], w[1]);
        if s > prev * 1.10 + 1e-9 {
            return false;
        }
        prev = s;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concavity_checker() {
        // perfectly concave
        assert!(check_concave(&[(1, 10.0), (2, 18.0), (4, 30.0), (8, 44.0)]));
        // convex violation
        assert!(!check_concave(&[(1, 1.0), (2, 2.0), (4, 10.0), (8, 40.0)]));
        // short series trivially pass
        assert!(check_concave(&[(1, 5.0)]));
    }

    #[test]
    fn quick_run_flickr() {
        let dir = std::env::temp_dir().join("coopgnn_fig3_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        assert!(dir.join("fig3.csv").exists());
        let csv = std::fs::read_to_string(dir.join("fig3.csv")).unwrap();
        // 1 dataset x 2 tasks x 4 samplers x 3 batches + header
        assert_eq!(csv.lines().count(), 1 + 2 * 4 * 3);
        // every row must report monotone_ok=true
        for line in csv.lines().skip(1) {
            assert!(line.contains("true"), "shape violated: {line}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
