//! Online serving scenario matrix: Independent vs Cooperative batching
//! × fixed vs adaptive admission, at several PE counts, under equal
//! offered load.
//!
//! This is the serving-plane counterpart of `repro end2end`: every arm
//! drives the same virtual-time [`crate::serve::Server`] over the same
//! seeded workload (open-loop Poisson with a hot-set mix), so the only
//! differences between rows are the minibatching mode and the admission
//! policy. The table's claim, and this PR's acceptance gate, is the
//! paper's concavity made operational: the **adaptive cooperative** arm
//! moves fewer data-plane bytes per request than the **fixed
//! independent** arm at the same offered load — bigger shared batches
//! (concave |S^L|) plus ownership-deduplicated loading plus caches that
//! stay warm across request batches.
//!
//! Emits `<out>/serve.csv` + `.md`. Latencies are virtual milliseconds
//! (integer-µs clock, modeled service times — bit-reproducible; see
//! `tests/integration_serve.rs` for the determinism gates).

use super::Ctx;
use crate::coop::engine::Mode;
use crate::feature::Codec;
use crate::pipeline::PipelineBuilder;
use crate::serve::{BatcherKind, ServeConfig, ServeReport};
use crate::util::csv::{fmt_kib, fmt_ms, Table};

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    type Scenario = (&'static str, f64, u64, usize, usize, &'static [usize]);
    let (ds_name, rate, slo_us, fixed_per_pe, duration, pe_counts): Scenario =
        if ctx.quick {
            ("tiny", 20_000.0, 30_000, 16, 10, &[2])
        } else {
            ("flickr-s", 20_000.0, 50_000, 64, 24, &[2, 4])
        };
    let mut table = Table::new(
        "Online serving: indep vs coop x fixed vs adaptive (equal offered load)",
        &[
            "PEs",
            "mode",
            "batcher",
            "served",
            "mean_batch",
            "p50_ms",
            "p90_ms",
            "p99_ms",
            "req_per_s",
            "storage_KiB_req",
            "fabric_KiB_req",
            "fabric_inter_KiB_req",
            "bytes_per_req",
            "slo_viol_pct",
            "coop_adaptive_vs_indep_fixed_bytes",
            "codec",
            "queue_p50_ms",
            "queue_p99_ms",
            "service_p50_ms",
            "service_p99_ms",
        ],
    );
    for &p in pe_counts {
        let mut reports: Vec<(Mode, BatcherKind, ServeReport)> = Vec::new();
        for mode in [Mode::Independent, Mode::Cooperative] {
            for batcher in [BatcherKind::Fixed, BatcherKind::Adaptive] {
                let pipe = PipelineBuilder::new()
                    .dataset(ds_name)
                    .mode(mode)
                    .exec(ctx.exec)
                    .num_pes(p)
                    .seed(ctx.seed)
                    .codec(ctx.codec)
                    .hot_mb(ctx.hot_mb)
                    .build()?;
                let scfg = ServeConfig {
                    rate_per_s: rate,
                    slo_us,
                    batcher,
                    duration_batches: duration,
                    fixed_batch_per_pe: fixed_per_pe,
                    ..Default::default()
                };
                let out = pipe.server(scfg)?.run();
                println!(
                    "serve: {} {} P={p} done ({} requests, p99 {:.2} ms, {:.0} B/req)",
                    mode.name(),
                    batcher.name(),
                    out.report.served,
                    out.report.p99_ms,
                    out.report.bytes_per_req()
                );
                reports.push((mode, batcher, out.report));
            }
        }
        // the acceptance ratio: fixed-independent bytes/request over
        // adaptive-cooperative bytes/request (> 1.0 = coop+adaptive wins)
        let indep_fixed = reports[0].2.bytes_per_req();
        let coop_adaptive = reports[3].2.bytes_per_req();
        for (mode, batcher, r) in &reports {
            let ratio = if *mode == Mode::Cooperative
                && *batcher == BatcherKind::Adaptive
                && coop_adaptive > 0.0
            {
                format!("{:.2}x", indep_fixed / coop_adaptive)
            } else {
                "-".to_string()
            };
            table.push_row(&[
                p.to_string(),
                mode.name().to_string(),
                batcher.name().to_string(),
                r.served.to_string(),
                format!("{:.1}", r.mean_batch),
                fmt_ms(r.p50_ms),
                fmt_ms(r.p90_ms),
                fmt_ms(r.p99_ms),
                format!("{:.0}", r.requests_per_s),
                fmt_kib(r.storage_bytes_per_req),
                fmt_kib(r.fabric_bytes_per_req),
                fmt_kib(r.fabric_inter_bytes_per_req),
                format!("{:.0}", r.bytes_per_req()),
                format!("{:.2}", r.slo_violation_rate * 100.0),
                ratio,
                ctx.codec.name().to_string(),
                fmt_ms(r.queue_p50_ms),
                fmt_ms(r.queue_p99_ms),
                fmt_ms(r.service_p50_ms),
                fmt_ms(r.service_p99_ms),
            ]);
        }
    }
    // Codec sweep — the storage plane's serving acceptance gate. A
    // saturated fixed cooperative arm (offered load far above service
    // capacity) admits every batch at exactly its cap, in arrival order,
    // so the admitted request sets are identical across codecs and any
    // bytes/request difference is purely the wire format. int8 rows
    // (dim + 5 bytes) must cut bytes/request >= 3x vs f32 (dim x 4).
    let p = pe_counts[0];
    for codec in Codec::all() {
        let pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(Mode::Cooperative)
            .exec(ctx.exec)
            .num_pes(p)
            .seed(ctx.seed)
            .codec(codec)
            .hot_mb(ctx.hot_mb)
            .build()?;
        let scfg = ServeConfig {
            rate_per_s: 50_000.0,
            slo_us,
            batcher: BatcherKind::Fixed,
            duration_batches: duration,
            fixed_batch_per_pe: fixed_per_pe,
            ..Default::default()
        };
        let out = pipe.server(scfg)?.run();
        let r = out.report;
        println!(
            "serve codec sweep: {} P={p} done ({} requests, {:.0} B/req)",
            codec.name(),
            r.served,
            r.bytes_per_req()
        );
        table.push_row(&[
            p.to_string(),
            "Coop".to_string(),
            "fixed-sat".to_string(),
            r.served.to_string(),
            format!("{:.1}", r.mean_batch),
            fmt_ms(r.p50_ms),
            fmt_ms(r.p90_ms),
            fmt_ms(r.p99_ms),
            format!("{:.0}", r.requests_per_s),
            fmt_kib(r.storage_bytes_per_req),
            fmt_kib(r.fabric_bytes_per_req),
            fmt_kib(r.fabric_inter_bytes_per_req),
            format!("{:.0}", r.bytes_per_req()),
            format!("{:.2}", r.slo_violation_rate * 100.0),
            "-".to_string(),
            codec.name().to_string(),
            fmt_ms(r.queue_p50_ms),
            fmt_ms(r.queue_p99_ms),
            fmt_ms(r.service_p50_ms),
            fmt_ms(r.service_p99_ms),
        ]);
    }
    table.write(&ctx.out, "serve")?;
    println!("{}", table.to_markdown());
    println!(
        "serve: the coop_adaptive_vs_indep_fixed_bytes column > 1.00x is the paper's \
         concavity operating online — cooperative dedup + SLO-deadline batching + warm \
         cross-batch caches move fewer bytes per request at equal offered load"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: the matrix exists (both modes × both
    /// batchers), every measured cell is sane, and the adaptive
    /// cooperative arm beats the fixed independent arm on bytes per
    /// request at equal offered load.
    #[test]
    fn serve_quick_emits_matrix_and_adaptive_coop_wins_bytes() {
        let dir = std::env::temp_dir().join("coopgnn_repro_serve_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("serve.csv")).unwrap();
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.to_string()).collect())
            .collect();
        assert_eq!(rows.len(), 7, "2 modes x 2 batchers at 1 PE count + 3 codec-sweep rows: {csv}");
        let mut bytes = std::collections::HashMap::new();
        for r in &rows[..4] {
            let served: u64 = r[3].parse().unwrap();
            let p99: f64 = r[7].parse().unwrap();
            let b_req: f64 = r[12].parse().unwrap();
            assert!(served > 0, "every arm serves requests: {r:?}");
            assert!(p99 > 0.0, "latencies are measured: {r:?}");
            assert!(b_req > 0.0, "bytes move: {r:?}");
            if r[1] == "Coop" {
                let fabric: f64 = r[10].parse().unwrap();
                assert!(fabric > 0.0, "coop arms ship fabric rows: {r:?}");
                // conservation: the inter slice can never exceed the
                // fabric total it was carved from
                let inter: f64 = r[11].parse().unwrap();
                assert!(inter <= fabric + 1e-9, "inter slice exceeds fabric total: {r:?}");
            }
            // appended phase-waterfall columns: parse, p99 bounds p50
            for (p50, p99) in [(16, 17), (18, 19)] {
                let lo: f64 = r[p50].parse().unwrap();
                let hi: f64 = r[p99].parse().unwrap();
                assert!(hi >= lo && lo >= 0.0, "waterfall percentile order: {r:?}");
            }
            let service_p50: f64 = r[18].parse().unwrap();
            assert!(service_p50 > 0.0, "service phase must take time: {r:?}");
            bytes.insert((r[1].clone(), r[2].clone()), b_req);
        }
        let indep_fixed = bytes[&("Indep".to_string(), "fixed".to_string())];
        let coop_adaptive = bytes[&("Coop".to_string(), "adaptive".to_string())];
        assert!(
            coop_adaptive < indep_fixed,
            "adaptive cooperative must beat fixed independent on bytes/request: \
             {coop_adaptive} vs {indep_fixed}"
        );
        // the codec sweep: saturated fixed coop arm per codec, identical
        // admitted request sets, int8 cutting wire bytes/request >= 3x
        let sweep = &rows[4..];
        let mut by_codec = std::collections::HashMap::new();
        for r in sweep {
            assert_eq!(r[2], "fixed-sat", "sweep rows use the saturated fixed arm: {r:?}");
            assert_eq!(
                r[3], sweep[0][3],
                "admitted request sets must be codec-invariant: {r:?}"
            );
            by_codec.insert(r[15].clone(), r[12].parse::<f64>().unwrap());
        }
        let (f32b, fp16b, int8b) = (by_codec["f32"], by_codec["fp16"], by_codec["int8"]);
        assert!(
            f32b >= 3.0 * int8b,
            "int8 must cut bytes/request >= 3x vs f32: {f32b} vs {int8b}"
        );
        assert!(fp16b < f32b, "fp16 must move fewer wire bytes than f32");
        std::fs::remove_dir_all(&dir).ok();
    }
}
