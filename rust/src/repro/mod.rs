//! Repro harnesses — one per table/figure of the paper's evaluation.
//!
//! Every harness writes `<out>/<id>.csv` + `<out>/<id>.md` and prints the
//! table; EXPERIMENTS.md records paper-vs-measured for each. See
//! DESIGN.md §6 for the experiment index.
//!
//! | id        | paper artifact                 | harness |
//! |-----------|--------------------------------|---------|
//! | `fig3`    | Fig. 3 + Fig. 6 work curves    | [`fig3`] |
//! | `table3`  | Table 3 + Fig. 4/8 κ-F1        | [`table3`] |
//! | `fig5a`   | Fig. 5a 1-PE miss rates        | [`fig5`] |
//! | `fig5b`   | Fig. 5b 4-PE coop miss rates   | [`fig5`] |
//! | `table4`  | Table 4 stage times            | [`table4`] |
//! | `table5`  | Table 5 coop speedups          | [`table4`] (derived) |
//! | `table6`  | Table 6 κ improvements         | [`table4`] (derived) |
//! | `table7`  | Table 7 per-PE counts          | [`table7`] |
//! | `fig9`    | Fig. 9 coop-vs-indep converg.  | [`fig9`] |
//! | `scaling` | §4.3 F/B vs #cooperating PEs   | [`scaling`] |
//! | `end2end` | §4 end-to-end coop-vs-indep ms/step + bytes/step | [`end2end`] |
//! | `serve`   | online serving matrix: indep/coop × fixed/adaptive batcher | [`serve`] |

pub mod fig3;
pub mod table3;
pub mod fig5;
pub mod table4;
pub mod table7;
pub mod fig9;
pub mod scaling;
pub mod end2end;
pub mod serve;

use crate::coop::engine::ExecMode;
use crate::feature::Codec;
use std::path::PathBuf;

/// Shared harness context. Each harness lowers this into a
/// [`crate::pipeline::PipelineBuilder`] call, so `seed` feeds the
/// dataset generator, the partitioner, and the engine alike.
#[derive(Clone, Debug)]
pub struct Ctx {
    pub out: PathBuf,
    /// reduced sweeps for smoke runs.
    pub quick: bool,
    /// defaults to [`crate::pipeline::DEFAULT_SEED`].
    pub seed: u64,
    /// artifacts directory (for harnesses that train).
    pub artifacts: PathBuf,
    /// engine execution mode (thread-per-PE by default; `--exec serial`
    /// falls back to the bit-identical reference loop).
    pub exec: ExecMode,
    /// at-rest / on-wire row codec for the storage-sensitive harnesses
    /// (`fig5`, `serve`); they additionally sweep the other codecs into
    /// comparison columns/rows.
    pub codec: Codec,
    /// hot-tier budget in MiB (0 = untiered).
    pub hot_mb: usize,
    /// replica-group size (`--replication r`; 1 = flat fabric). The
    /// fabric-sensitive harnesses (`end2end`, `scaling`) additionally
    /// sweep r into comparison rows where the PE count allows.
    pub replication: usize,
    /// intra-group link bandwidth override in GB/s (`--intra-bw`).
    pub intra_bw: Option<f64>,
    /// inter-group link bandwidth override in GB/s (`--inter-bw`).
    pub inter_bw: Option<f64>,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            out: PathBuf::from("results"),
            quick: false,
            seed: crate::pipeline::DEFAULT_SEED,
            artifacts: PathBuf::from("artifacts"),
            exec: ExecMode::Threaded,
            codec: Codec::F32,
            hot_mb: 0,
            replication: 1,
            intra_bw: None,
            inter_bw: None,
        }
    }
}

/// Run one experiment by id; `all` runs everything.
pub fn run(id: &str, ctx: &Ctx) -> crate::Result<()> {
    match id {
        "fig3" => fig3::run(ctx),
        "table3" => table3::run(ctx),
        "fig5a" => fig5::run_fig5a(ctx),
        "fig5b" => fig5::run_fig5b(ctx),
        // both cache-miss panels in one go (the storage-plane smoke
        // target: `repro fig5 --quick --codec int8`)
        "fig5" => fig5::run_fig5a(ctx).and_then(|()| fig5::run_fig5b(ctx)),
        "table4" | "table5" | "table6" => table4::run(ctx),
        "table7" => table7::run(ctx),
        "fig9" => fig9::run(ctx),
        "scaling" => scaling::run(ctx),
        "end2end" => end2end::run(ctx),
        "serve" => serve::run(ctx),
        "all" => {
            let ids = [
                "fig3", "fig5a", "fig5b", "table4", "table7", "scaling", "end2end", "serve",
                "fig9", "table3",
            ];
            for id in ids {
                println!("=== repro {id} ===");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment `{other}`; try fig3 table3 fig5 fig5a fig5b table4 table7 fig9 \
             scaling end2end serve all"
        ),
    }
}
