//! Figure 5: LRU cache miss rates vs batch dependency κ.
//!
//! * 5a — one PE, per-dataset cache sizes from the Table 2 ratios.
//! * 5b — four cooperating PEs, per-PE caches (ownership-disjoint), the
//!   "cooperative feature loading effectively increases the global cache
//!   size" effect.
//!
//! Expected shapes: miss rate falls monotonically with κ; the drop is
//! larger for denser graphs (paper: "improvement is monotonically
//! increasing as a function of |E|/|V|"); coop 4-PE misses sit below
//! 1-PE independent at equal per-PE cache.
//!
//! Since the feature-plane refactor the reported miss rates are
//! **byte-derived** (`EngineReport::derived_miss_rate` = storage bytes /
//! requested bytes over the measured window): the harness reports what
//! actually moved out of the row store, and the tables carry the KiB
//! figures alongside.

use super::Ctx;
use crate::coop::engine::Mode;
use crate::pipeline::PipelineBuilder;
use crate::sampling::Kappa;
use crate::util::csv::Table;

const KAPPAS: &[Kappa] = &[
    Kappa::Finite(1),
    Kappa::Finite(4),
    Kappa::Finite(16),
    Kappa::Finite(64),
    Kappa::Finite(256),
    Kappa::Infinite,
];

pub fn run_fig5a(ctx: &Ctx) -> crate::Result<()> {
    let ds_names: &[&str] = if ctx.quick {
        &["flickr-s"]
    } else {
        &["flickr-s", "yelp-s", "reddit-s", "papers-s", "mag-s"]
    };
    let mut table = Table::new(
        "Figure 5a: 1-PE LRU miss rate vs κ (LABOR-0, b=1024; byte-derived)",
        &["dataset", "kappa", "miss_rate", "requested/batch", "misses/batch", "storage_KiB/batch"],
    );
    for ds_name in ds_names {
        let mut pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(Mode::Independent)
            .exec(ctx.exec)
            .num_pes(1)
            .warmup_batches(if ctx.quick { 3 } else { 8 })
            .measure_batches(if ctx.quick { 6 } else { 16 })
            .seed(ctx.seed)
            .build()?;
        pipe.cfg.batch_per_pe = 1024.min(pipe.ds.train.len().max(64));
        pipe.cfg.cache_per_pe = Some(pipe.ds.cache_size);
        let mut prev = 1.0f64;
        for &kappa in KAPPAS {
            pipe.cfg.kappa = kappa;
            let r = pipe.engine_report();
            table.push_row(&[
                ds_name.to_string(),
                kappa.label(),
                format!("{:.4}", r.derived_miss_rate),
                format!("{:.0}", r.feat_requested),
                format!("{:.0}", r.feat_misses),
                format!("{:.1}", r.feat_storage_bytes / 1024.0),
            ]);
            // shape check (warn, don't fail: small caches are noisy)
            if r.derived_miss_rate > prev * 1.10 {
                eprintln!(
                    "WARN fig5a: miss rate rose at {ds_name} κ={} ({prev:.3} -> {:.3})",
                    kappa.label(),
                    r.derived_miss_rate
                );
            }
            prev = r.derived_miss_rate;
        }
        println!("fig5a: {ds_name} done");
    }
    table.write(&ctx.out, "fig5a")?;
    println!("{}", table.to_markdown());
    Ok(())
}

pub fn run_fig5b(ctx: &Ctx) -> crate::Result<()> {
    let ds_names: &[&str] =
        if ctx.quick { &["flickr-s"] } else { &["papers-s", "mag-s", "reddit-s", "yelp-s"] };
    let mut table = Table::new(
        "Figure 5b: 4 cooperating PEs, per-PE cache, miss rate vs κ (LABOR-0, b=1024/PE; byte-derived)",
        &["dataset", "kappa", "miss_rate", "fabric_rows/batch", "fabric_KiB/batch"],
    );
    for ds_name in ds_names {
        let mut pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(Mode::Cooperative)
            .exec(ctx.exec)
            .num_pes(4)
            .seed(ctx.seed)
            .build()?;
        pipe.cfg.batch_per_pe = 1024.min(pipe.ds.train.len() / 4).max(32);
        // Cache sizing: the paper gives each GPU a 1M-row cache, ~8x its
        // per-PE per-batch request on papers100M. The twins' per-PE vertex
        // universes are far smaller (|V|/4), so a direct ratio either
        // covers the whole universe (flat 0 misses) or under-runs the
        // per-batch request (LRU scan-thrash, flat 1). We probe the
        // per-PE request size and set capacity to 1.15x it — inside the
        // regime where Figure 5b's κ dynamics are observable.
        pipe.cfg.cache_per_pe = Some(pipe.ds.graph.num_vertices()); // effectively infinite
        pipe.cfg.warmup_batches = 0;
        pipe.cfg.measure_batches = 2;
        let probe = pipe.engine_report();
        pipe.cfg.cache_per_pe = Some(((probe.feat_requested * 1.15) as usize).max(64));
        pipe.cfg.warmup_batches = if ctx.quick { 3 } else { 8 };
        pipe.cfg.measure_batches = if ctx.quick { 6 } else { 16 };
        for &kappa in KAPPAS {
            pipe.cfg.kappa = kappa;
            let r = pipe.engine_report();
            table.push_row(&[
                ds_name.to_string(),
                kappa.label(),
                format!("{:.4}", r.derived_miss_rate),
                format!("{:.0}", r.feat_fabric_rows),
                format!("{:.1}", r.feat_fabric_bytes / 1024.0),
            ]);
        }
        // write incrementally: dataset builds are slow, keep partial
        // results durable if the run is interrupted
        table.write(&ctx.out, "fig5b")?;
        println!("fig5b: {ds_name} done");
    }
    println!("{}", table.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5a_quick_shape() {
        let dir = std::env::temp_dir().join("coopgnn_fig5a_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run_fig5a(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig5a.csv")).unwrap();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), KAPPAS.len());
        // κ=1 (first) vs κ=inf (last): misses must drop substantially
        let miss = |row: &str| -> f64 { row.split(',').nth(2).unwrap().parse().unwrap() };
        let first = miss(rows[0]);
        let last = miss(rows[rows.len() - 1]);
        // flickr has the paper's smallest κ benefit (lowest |E|/|V|):
        // require a clear but modest drop here; the full (non-quick) run
        // exhibits the 4x reddit-style drops recorded in EXPERIMENTS.md.
        assert!(last < first * 0.92, "κ=∞ miss {last} must beat κ=1 {first}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The paper's temporal-locality claim on the cached path, asserted
    /// against the *byte-derived* accounting: dependent sampling with a
    /// larger κ strictly lowers the miss rate (= strictly fewer bytes
    /// pulled out of the row store per requested byte).
    #[test]
    fn larger_kappa_strictly_lowers_derived_miss_rate() {
        let report = |kappa: Kappa| {
            let mut pipe = PipelineBuilder::new()
                .dataset("tiny")
                .mode(Mode::Independent)
                .num_pes(1)
                .batch_per_pe(64)
                .cache_per_pe(400)
                .warmup_batches(4)
                .measure_batches(12)
                .seed(2)
                .build()
                .unwrap();
            pipe.cfg.kappa = kappa;
            pipe.engine_report()
        };
        let mut prev = report(Kappa::Finite(1));
        assert!(prev.feat_storage_bytes > 0.0, "bytes must move for the rate to be derived");
        for kappa in [Kappa::Finite(16), Kappa::Finite(256)] {
            let r = report(kappa);
            assert!(
                r.derived_miss_rate < prev.derived_miss_rate,
                "κ={} derived miss {} must be strictly below the previous {}",
                kappa.label(),
                r.derived_miss_rate,
                prev.derived_miss_rate
            );
            // byte- and counter-based views of the same movement agree
            assert!((r.derived_miss_rate - r.cache_miss_rate).abs() < 1e-12);
            prev = r;
        }
    }
}
