//! Figure 5: LRU cache miss rates vs batch dependency κ.
//!
//! * 5a — one PE, per-dataset cache sizes from the Table 2 ratios.
//! * 5b — four cooperating PEs, per-PE caches (ownership-disjoint), the
//!   "cooperative feature loading effectively increases the global cache
//!   size" effect.
//!
//! Expected shapes: miss rate falls monotonically with κ; the drop is
//! larger for denser graphs (paper: "improvement is monotonically
//! increasing as a function of |E|/|V|"); coop 4-PE misses sit below
//! 1-PE independent at equal per-PE cache.
//!
//! Since the feature-plane refactor the reported miss rates are
//! **byte-derived** (`EngineReport::derived_miss_rate` = storage bytes /
//! requested bytes over the measured window): the harness reports what
//! actually moved out of the row store, and the tables carry the KiB
//! figures alongside.

use super::Ctx;
use crate::coop::engine::Mode;
use crate::feature::Codec;
use crate::pipeline::PipelineBuilder;
use crate::sampling::Kappa;
use crate::util::csv::{fmt_kib, Table};

const KAPPAS: &[Kappa] = &[
    Kappa::Finite(1),
    Kappa::Finite(4),
    Kappa::Finite(16),
    Kappa::Finite(64),
    Kappa::Finite(256),
    Kappa::Infinite,
];

pub fn run_fig5a(ctx: &Ctx) -> crate::Result<()> {
    let ds_names: &[&str] = if ctx.quick {
        &["flickr-s"]
    } else {
        &["flickr-s", "yelp-s", "reddit-s", "papers-s", "mag-s"]
    };
    let mut table = Table::new(
        "Figure 5a: 1-PE LRU miss rate vs κ (LABOR-0, b=1024; byte-derived)",
        &[
            "dataset",
            "kappa",
            "miss_rate",
            "requested/batch",
            "misses/batch",
            "storage_KiB/batch",
            "codec",
            "f32_KiB/batch",
            "bytes_vs_f32",
        ],
    );
    for ds_name in ds_names {
        let mut pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(Mode::Independent)
            .exec(ctx.exec)
            .num_pes(1)
            .warmup_batches(if ctx.quick { 3 } else { 8 })
            .measure_batches(if ctx.quick { 6 } else { 16 })
            .seed(ctx.seed)
            .codec(ctx.codec)
            .hot_mb(ctx.hot_mb)
            .build()?;
        pipe.cfg.batch_per_pe = 1024.min(pipe.ds.train.len().max(64));
        pipe.cfg.cache_per_pe = Some(pipe.ds.cache_size);
        let dim = pipe.ds.feat_dim;
        let mut prev = 1.0f64;
        for &kappa in KAPPAS {
            pipe.cfg.kappa = kappa;
            let r = pipe.engine_report();
            // What the same cold fills would have cost at decoded f32 width.
            // Fill *counts* are codec-invariant (the sampler never sees the
            // wire format), so the ratio is a pure wire-compression figure;
            // a hot tier (--hot-mb) additionally drops it by absorbing
            // fills into PE memory.
            let f32_bytes = r.feat_misses * (dim * 4) as f64;
            table.push_row(&[
                ds_name.to_string(),
                kappa.label(),
                format!("{:.4}", r.derived_miss_rate),
                format!("{:.0}", r.feat_requested),
                format!("{:.0}", r.feat_misses),
                fmt_kib(r.feat_storage_bytes),
                ctx.codec.name().to_string(),
                fmt_kib(f32_bytes),
                format!(
                    "{:.4}",
                    if f32_bytes > 0.0 { r.feat_storage_bytes / f32_bytes } else { 1.0 }
                ),
            ]);
            // shape check (warn, don't fail: small caches are noisy)
            if r.derived_miss_rate > prev * 1.10 {
                eprintln!(
                    "WARN fig5a: miss rate rose at {ds_name} κ={} ({prev:.3} -> {:.3})",
                    kappa.label(),
                    r.derived_miss_rate
                );
            }
            prev = r.derived_miss_rate;
        }
        println!("fig5a: {ds_name} done");
    }
    table.write(&ctx.out, "fig5a")?;
    println!("{}", table.to_markdown());
    Ok(())
}

pub fn run_fig5b(ctx: &Ctx) -> crate::Result<()> {
    let ds_names: &[&str] =
        if ctx.quick { &["flickr-s"] } else { &["papers-s", "mag-s", "reddit-s", "yelp-s"] };
    let mut table = Table::new(
        "Figure 5b: 4 cooperating PEs, per-PE cache, miss rate vs κ (LABOR-0, b=1024/PE; byte-derived)",
        &["dataset", "kappa", "miss_rate", "fabric_rows/batch", "fabric_KiB/batch", "codec", "fabric_vs_f32"],
    );
    for ds_name in ds_names {
        let mut pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(Mode::Cooperative)
            .exec(ctx.exec)
            .num_pes(4)
            .seed(ctx.seed)
            .codec(ctx.codec)
            .hot_mb(ctx.hot_mb)
            .build()?;
        pipe.cfg.batch_per_pe = 1024.min(pipe.ds.train.len() / 4).max(32);
        // Cache sizing: the paper gives each GPU a 1M-row cache, ~8x its
        // per-PE per-batch request on papers100M. The twins' per-PE vertex
        // universes are far smaller (|V|/4), so a direct ratio either
        // covers the whole universe (flat 0 misses) or under-runs the
        // per-batch request (LRU scan-thrash, flat 1). We probe the
        // per-PE request size and set capacity to 1.15x it — inside the
        // regime where Figure 5b's κ dynamics are observable.
        pipe.cfg.cache_per_pe = Some(pipe.ds.graph.num_vertices()); // effectively infinite
        pipe.cfg.warmup_batches = 0;
        pipe.cfg.measure_batches = 2;
        let probe = pipe.engine_report();
        pipe.cfg.cache_per_pe = Some(((probe.feat_requested * 1.15) as usize).max(64));
        pipe.cfg.warmup_batches = if ctx.quick { 3 } else { 8 };
        pipe.cfg.measure_batches = if ctx.quick { 6 } else { 16 };
        // Fabric payloads ship the *stored* encoding (decode happens at the
        // consumer), so the on-wire per-row cost vs f32 is exactly the
        // codec's row geometry.
        let fabric_vs_f32 =
            pipe.feature_store().row_bytes() as f64 / (pipe.ds.feat_dim * 4) as f64;
        for &kappa in KAPPAS {
            pipe.cfg.kappa = kappa;
            let r = pipe.engine_report();
            table.push_row(&[
                ds_name.to_string(),
                kappa.label(),
                format!("{:.4}", r.derived_miss_rate),
                format!("{:.0}", r.feat_fabric_rows),
                fmt_kib(r.feat_fabric_bytes),
                ctx.codec.name().to_string(),
                format!("{:.4}", fabric_vs_f32),
            ]);
        }
        // write incrementally: dataset builds are slow, keep partial
        // results durable if the run is interrupted
        table.write(&ctx.out, "fig5b")?;
        println!("fig5b: {ds_name} done");
    }
    println!("{}", table.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5a_quick_shape() {
        let dir = std::env::temp_dir().join("coopgnn_fig5a_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run_fig5a(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig5a.csv")).unwrap();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert_eq!(rows.len(), KAPPAS.len());
        // κ=1 (first) vs κ=inf (last): misses must drop substantially
        let miss = |row: &str| -> f64 { row.split(',').nth(2).unwrap().parse().unwrap() };
        let first = miss(rows[0]);
        let last = miss(rows[rows.len() - 1]);
        // flickr has the paper's smallest κ benefit (lowest |E|/|V|):
        // require a clear but modest drop here; the full (non-quick) run
        // exhibits the 4x reddit-style drops recorded in EXPERIMENTS.md.
        assert!(last < first * 0.92, "κ=∞ miss {last} must beat κ=1 {first}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Acceptance gate for the storage plane: at identical sampled
    /// subgraphs (count columns bit-equal across codecs), int8 rows cut
    /// the measured storage bytes/batch by >= 3x vs f32.
    #[test]
    fn fig5a_codec_columns_report_wire_compression() {
        let dir = std::env::temp_dir().join("coopgnn_fig5a_codec_test");
        let run = |codec: Codec, sub: &str| -> Vec<String> {
            let ctx = Ctx { out: dir.join(sub), quick: true, codec, ..Default::default() };
            run_fig5a(&ctx).unwrap();
            let csv = std::fs::read_to_string(dir.join(sub).join("fig5a.csv")).unwrap();
            csv.lines().skip(1).map(|l| l.to_string()).collect()
        };
        let f32_rows = run(Codec::F32, "f32");
        let int8_rows = run(Codec::Int8, "int8");
        assert_eq!(f32_rows.len(), int8_rows.len());
        for (a, b) in f32_rows.iter().zip(&int8_rows) {
            let a: Vec<&str> = a.split(',').collect();
            let b: Vec<&str> = b.split(',').collect();
            // miss_rate, requested/batch, misses/batch are codec-invariant
            for idx in 2..=4 {
                assert_eq!(a[idx], b[idx], "count column {idx} must not move with the codec");
            }
            let kib = |r: &[&str]| -> f64 { r[5].parse().unwrap() };
            assert!(
                kib(&a) >= 3.0 * kib(&b),
                "int8 must cut storage KiB >= 3x (f32 {} vs int8 {})",
                a[5],
                b[5]
            );
            assert_eq!(a[8], "1.0000", "f32 run must report the identity ratio");
            let ratio: f64 = b[8].parse().unwrap();
            assert!(ratio <= 1.0 / 3.0, "int8 bytes_vs_f32 {ratio} must be <= 1/3");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The paper's temporal-locality claim on the cached path, asserted
    /// against the *byte-derived* accounting: dependent sampling with a
    /// larger κ strictly lowers the miss rate (= strictly fewer bytes
    /// pulled out of the row store per requested byte).
    #[test]
    fn larger_kappa_strictly_lowers_derived_miss_rate() {
        let report = |kappa: Kappa| {
            let mut pipe = PipelineBuilder::new()
                .dataset("tiny")
                .mode(Mode::Independent)
                .num_pes(1)
                .batch_per_pe(64)
                .cache_per_pe(400)
                .warmup_batches(4)
                .measure_batches(12)
                .seed(2)
                .build()
                .unwrap();
            pipe.cfg.kappa = kappa;
            pipe.engine_report()
        };
        let mut prev = report(Kappa::Finite(1));
        assert!(prev.feat_storage_bytes > 0.0, "bytes must move for the rate to be derived");
        for kappa in [Kappa::Finite(16), Kappa::Finite(256)] {
            let r = report(kappa);
            assert!(
                r.derived_miss_rate < prev.derived_miss_rate,
                "κ={} derived miss {} must be strictly below the previous {}",
                kappa.label(),
                r.derived_miss_rate,
                prev.derived_miss_rate
            );
            // byte- and counter-based views of the same movement agree
            assert!((r.derived_miss_rate - r.cache_miss_rate).abs() < 1e-12);
            prev = r;
        }
    }
}
