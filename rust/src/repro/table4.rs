//! Tables 4, 5, 6: per-minibatch stage times for Independent vs
//! Cooperative minibatching on the three systems, and the derived
//! speedup/improvement summaries.
//!
//! The engine measures per-PE counts + cache misses on the synthetic
//! dataset twins; the cost model converts them to estimated stage times
//! with each system's α/β/γ. Global batch sizes follow the paper:
//! b=1024/PE on the A100 systems, b=512/PE on the 16×V100 system.

use super::Ctx;
use crate::coop::engine::{EngineReport, Mode};
use crate::costmodel::{estimate, feature_cache_ms_for, ModelCost, StageTimes, PRESETS};
use crate::pipeline::PipelineBuilder;
use crate::sampling::{Kappa, SamplerKind};
use crate::util::csv::Table;

struct Row {
    system: &'static str,
    dataset: String,
    sampler: &'static str,
    mode: String,
    times: StageTimes,
    cache_kappa_ms: f64,
    wall_sampling_ms: f64,
}

impl Row {
    fn total(&self) -> f64 {
        self.times.sampling_ms
            + self
                .cache_kappa_ms
                .min(self.times.feature_cache_ms)
                .min(self.times.feature_ms)
            + self.times.fb_ms
    }
}

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let ds_specs: Vec<(&str, ModelCost)> = if ctx.quick {
        vec![("tiny", ModelCost::gcn(16, 32))]
    } else {
        vec![
            ("papers-s", ModelCost::gcn(128, 256)),
            ("mag-s", ModelCost::rgcn(768, 1024)),
        ]
    };
    let samplers = [SamplerKind::Labor0, SamplerKind::Neighbor];
    let mut rows: Vec<Row> = Vec::new();

    for preset in PRESETS.iter().filter(|p| !ctx.quick || p.num_pes == 4) {
        let b = if preset.name == "16xV100" { 512 } else { 1024 };
        for (ds_name, model) in &ds_specs {
            let mut pipe = PipelineBuilder::new()
                .dataset(ds_name)
                .exec(ctx.exec)
                .num_pes(preset.num_pes)
                .batch_per_pe(b)
                .seed(ctx.seed)
                .build()?;
            // paper Table 4 cache: 1e6 rows per A100 ≈ 2.2x the per-GPU
            // per-batch request on papers100M (Table 7: |S^3| = 463k).
            // Keep that *pressure* ratio: probe the per-PE request size
            // and scale (see fig5/datasets for why raw row counts do not
            // transfer to the scaled twins).
            pipe.cfg.mode = Mode::Independent;
            pipe.cfg.cache_per_pe = Some(pipe.ds.graph.num_vertices());
            pipe.cfg.warmup_batches = 0;
            pipe.cfg.measure_batches = 2;
            let probe = pipe.engine_report();
            let pressure = if preset.name == "16xV100" { 1.1 } else { 2.2 };
            let cache = ((probe.feat_requested * pressure) as usize).max(64);
            pipe.cfg.cache_per_pe = Some(cache);
            pipe.cfg.warmup_batches = if ctx.quick { 2 } else { 6 };
            pipe.cfg.measure_batches = if ctx.quick { 3 } else { 8 };
            let feat_dim = pipe.ds.feat_dim;
            for &kind in &samplers {
                pipe.cfg.kind = kind;
                for mode in [Mode::Independent, Mode::Cooperative] {
                    pipe.cfg.mode = mode;
                    pipe.cfg.kappa = Kappa::Finite(1);
                    let r1: EngineReport = pipe.engine_report();
                    let times = estimate(&r1, preset, model, feat_dim);
                    // Cache,κ column: LABOR-0 only (as in the paper)
                    let cache_kappa_ms = if kind == SamplerKind::Labor0 {
                        pipe.cfg.kappa = Kappa::Finite(256);
                        let r256 = pipe.engine_report();
                        feature_cache_ms_for(
                            &r256,
                            preset,
                            feat_dim,
                            r256.feat_misses,
                            r256.feat_fabric_rows,
                        )
                    } else {
                        f64::INFINITY
                    };
                    rows.push(Row {
                        system: preset.name,
                        dataset: ds_name.to_string(),
                        sampler: kind.name(),
                        mode: mode.name().to_string(),
                        times,
                        cache_kappa_ms,
                        wall_sampling_ms: r1.wall_sampling_ms,
                    });
                    println!(
                        "table4: {} {} {} {} done",
                        preset.name,
                        ds_name,
                        kind.name(),
                        mode.name()
                    );
                }
            }
        }
    }

    // ---- Table 4 -------------------------------------------------------
    let mut t4 = Table::new(
        "Table 4: estimated per-minibatch stage times (ms) from measured counts",
        &[
            "system", "dataset", "sampler", "mode", "samp_ms", "feat_ms", "cache_ms",
            "cache_k256_ms", "fb_ms", "total_ms", "cpu_wall_samp_ms",
        ],
    );
    for r in &rows {
        t4.push_row(&[
            r.system.to_string(),
            r.dataset.clone(),
            r.sampler.to_string(),
            r.mode.clone(),
            format!("{:.2}", r.times.sampling_ms),
            format!("{:.2}", r.times.feature_ms),
            format!("{:.2}", r.times.feature_cache_ms),
            if r.cache_kappa_ms.is_finite() {
                format!("{:.2}", r.cache_kappa_ms)
            } else {
                "-".into()
            },
            format!("{:.2}", r.times.fb_ms),
            format!("{:.2}", r.total()),
            format!("{:.2}", r.wall_sampling_ms),
        ]);
    }
    t4.write(&ctx.out, "table4")?;
    println!("{}", t4.to_markdown());

    // ---- Table 5: total speedups coop vs indep --------------------------
    let mut t5 = Table::new(
        "Table 5: total-time improvement of Cooperative over Independent (%)",
        &["system", "dataset", "sampler", "improvement_pct"],
    );
    for r in rows.iter().filter(|r| r.mode == "Indep") {
        if let Some(c) = rows.iter().find(|c| {
            c.mode == "Coop"
                && c.system == r.system
                && c.dataset == r.dataset
                && c.sampler == r.sampler
        }) {
            let pct = (r.total() / c.total() - 1.0) * 100.0;
            t5.push_row(&[
                r.system.to_string(),
                r.dataset.clone(),
                r.sampler.to_string(),
                format!("{pct:.0}%"),
            ]);
        }
    }
    t5.write(&ctx.out, "table5")?;
    println!("{}", t5.to_markdown());

    // ---- Table 6: dependent-batch improvement (Cache / Cache,κ) ---------
    let mut t6 = Table::new(
        "Table 6: feature-copy improvement from κ=256 dependent batches (%)",
        &["system", "dataset", "mode", "improvement_pct"],
    );
    for r in rows.iter().filter(|r| r.sampler == "LABOR-0" && r.cache_kappa_ms.is_finite()) {
        let pct = (r.times.feature_cache_ms / r.cache_kappa_ms - 1.0) * 100.0;
        t6.push_row(&[
            r.system.to_string(),
            r.dataset.clone(),
            r.mode.clone(),
            format!("{pct:.0}%"),
        ]);
    }
    t6.write(&ctx.out, "table6")?;
    println!("{}", t6.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table4_coop_wins() {
        let dir = std::env::temp_dir().join("coopgnn_table4_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let t5 = std::fs::read_to_string(dir.join("table5.csv")).unwrap();
        // every sampler row must show a positive improvement on tiny
        for line in t5.lines().skip(1) {
            let pct: f64 = line
                .rsplit(',')
                .next()
                .unwrap()
                .trim_end_matches('%')
                .parse()
                .unwrap();
            assert!(pct > 0.0, "coop must win: {line}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
