//! §4.3 scaling note: per-PE F/B time vs number of cooperating PEs at
//! fixed per-PE batch size (paper: 200/194/187/183 ms on mag240M R-GCN
//! with 1/2/3/4 cooperating GPUs — the decrease is the concave work
//! curve in action, since the *global* batch grows with P).

use super::Ctx;
use crate::coop::engine::Mode;
use crate::costmodel::{estimate, ModelCost, SystemPreset};
use crate::pipeline::PipelineBuilder;
use crate::util::csv::{fmt_kib, Table};

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    let (ds_name, model, b) = if ctx.quick {
        ("tiny", ModelCost::gcn(16, 32), 64usize)
    } else {
        ("mag-s", ModelCost::rgcn(768, 1024), 1024)
    };
    let mut pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .mode(Mode::Cooperative)
        .exec(ctx.exec)
        .num_pes(1)
        .cache_per_pe(1024)
        .warmup_batches(1)
        .measure_batches(if ctx.quick { 2 } else { 6 })
        .seed(ctx.seed)
        .build()?;
    let mut table = Table::new(
        "F/B per-PE time vs #cooperating PEs (fixed b per PE; paper §4.3)",
        &[
            "PEs",
            "r",
            "global_batch",
            "S3_per_pe",
            "cross_KiB_batch",
            "row_inter_KiB",
            "fb_ms_est",
            "fb_vs_1pe",
        ],
    );
    let mut fb1 = None;
    for p in [1usize, 2, 3, 4] {
        let preset = SystemPreset {
            name: "A100-family",
            num_pes: p,
            gamma: 2000.0,
            alpha: 600.0,
            beta: 64.0,
        };
        pipe.set_num_pes(p);
        // the requested replica-group size where the PE count allows it
        let repl = if p % ctx.replication == 0 { ctx.replication } else { 1 };
        pipe.set_replication(repl);
        pipe.cfg.batch_per_pe = b.min(pipe.ds.train.len() / p).max(16);
        let r = pipe.engine_report();
        let t = estimate(&r, &preset, &model, pipe.ds.feat_dim);
        let fb = t.fb_ms;
        if p == 1 {
            fb1 = Some(fb);
        }
        table.push_row(&[
            p.to_string(),
            repl.to_string(),
            (pipe.cfg.batch_per_pe * p).to_string(),
            format!("{:.0}", r.s[3]),
            fmt_kib(r.total_cross_bytes()),
            fmt_kib(r.feat_fabric_inter_bytes),
            format!("{fb:.2}"),
            format!("{:.3}", fb / fb1.unwrap()),
        ]);
        println!("scaling: P={p} done");
    }
    table.write(&ctx.out, "scaling")?;
    println!("{}", table.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fb_per_pe_decreases_with_cooperation() {
        let dir = std::env::temp_dir().join("coopgnn_scaling_test");
        let ctx = Ctx { out: dir.clone(), quick: true, ..Default::default() };
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("scaling.csv")).unwrap();
        let ratios: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.rsplit(',').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(ratios.len(), 4);
        assert!(
            ratios[3] < ratios[0],
            "4-PE coop F/B per PE must be below 1-PE: {ratios:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
