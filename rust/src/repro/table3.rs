//! Table 3 + Figures 4/8: model quality vs batch dependency κ.
//!
//! Trains the GCN through the AOT train-step with the smoothed dependent
//! sampler at κ ∈ {1,4,16,64,256,∞}, tracking validation F1 (early
//! stopping) and reporting test F1 at the best-validation checkpoint.
//! Expected shape (paper): κ ≤ 256 is statistically indistinguishable
//! from κ=1; κ=∞ (frozen neighborhoods) degrades.

use super::Ctx;
use crate::pipeline::PipelineBuilder;
use crate::runtime::{Manifest, Runtime};
use crate::sampling::{Kappa, SamplerKind};
use crate::train::Trainer;
use crate::util::csv::Table;

pub fn run(ctx: &Ctx) -> crate::Result<()> {
    type Table3Cfg =
        (&'static str, &'static str, usize, u64, usize, Vec<Kappa>, (usize, usize, usize));
    let (ds_name, art_name, steps, runs, eval_every, kappas, (batch, layers, hidden)): Table3Cfg =
        if ctx.quick {
            let kappas = vec![Kappa::Finite(1), Kappa::Finite(256), Kappa::Infinite];
            ("tiny", "tiny-b32", 120, 1, 30, kappas, (32, 2, 16))
        } else {
            (
                "conv",
                "conv-b256",
                200,
                1,
                40,
                vec![
                    Kappa::Finite(1),
                    Kappa::Finite(4),
                    Kappa::Finite(16),
                    Kappa::Finite(64),
                    Kappa::Finite(256),
                    Kappa::Infinite,
                ],
                (256, 3, 32),
            )
        };
    // training harness: the PJRT/AOT backend when runtime + artifacts
    // are present, the host layered backend otherwise — the κ sweep
    // always trains for real
    let aot = match (Runtime::cpu(), Manifest::load(&ctx.artifacts)) {
        (Ok(rt), Ok(m)) => Some((rt, m)),
        (Err(e), _) | (_, Err(e)) => {
            println!("table3: PJRT/AOT unavailable ({e}); using the host compute backend");
            None
        }
    };
    let pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .sampler(SamplerKind::Labor0)
        .exec(ctx.exec)
        .seed(ctx.seed)
        .build()?;
    let ds = &pipe.ds;

    let mut t3 = Table::new(
        "Table 3: test F1/accuracy at best-validation checkpoint vs κ",
        &["kappa", "runs", "best_val_f1", "test_f1", "test_acc", "final_loss"],
    );
    let mut fig4 = Table::new(
        "Figure 4/8: validation F1 over training for each κ (run 0)",
        &["kappa", "step", "val_f1", "val_acc", "train_loss"],
    );

    for kappa in kappas {
        let mut best_vals = Vec::new();
        let mut test_f1s = Vec::new();
        let mut test_accs = Vec::new();
        let mut final_losses = Vec::new();
        for run_idx in 0..runs {
            let mut opts = pipe.trainer_options();
            opts.kappa = kappa;
            opts.seed = ctx.seed ^ (run_idx + 1) << 20;
            opts.lr = Some(0.01);
            let mut trainer = match &aot {
                Some((rt, manifest)) => Trainer::new(rt, manifest, art_name, ds, &opts)?,
                None => Trainer::new_host(ds, batch, layers, hidden, &opts)?,
            };
            let mut best_val = 0.0f64;
            let mut test_at_best = (0.0f64, 0.0f64);
            let mut last_loss = 0.0f32;
            for step in 1..=steps {
                let s = trainer.step()?;
                last_loss = s.loss;
                if step % eval_every == 0 || step == steps {
                    let val = trainer.evaluate(&ds.val, 1234)?;
                    if run_idx == 0 {
                        fig4.push_row(&[
                            kappa.label(),
                            step.to_string(),
                            format!("{:.4}", val.macro_f1),
                            format!("{:.4}", val.accuracy),
                            format!("{last_loss:.4}"),
                        ]);
                    }
                    if val.macro_f1 >= best_val {
                        best_val = val.macro_f1;
                        let test = trainer.evaluate(&ds.test, 1234)?;
                        test_at_best = (test.macro_f1, test.accuracy);
                    }
                }
            }
            best_vals.push(best_val);
            test_f1s.push(test_at_best.0);
            test_accs.push(test_at_best.1);
            final_losses.push(last_loss as f64);
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        t3.push_row(&[
            kappa.label(),
            runs.to_string(),
            format!("{:.4}", mean(&best_vals)),
            format!("{:.4}", mean(&test_f1s)),
            format!("{:.4}", mean(&test_accs)),
            format!("{:.4}", mean(&final_losses)),
        ]);
        println!("table3: κ={} done (val F1 {:.4})", kappa.label(), mean(&best_vals));
    }
    t3.write(&ctx.out, "table3")?;
    fig4.write(&ctx.out, "fig4")?;
    println!("{}", t3.to_markdown());
    Ok(())
}
