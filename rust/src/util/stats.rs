//! Timing and summary statistics for the bench harness and the repro
//! drivers (criterion is unavailable offline, so `cargo bench` targets use
//! these helpers with `harness = false`).

use std::time::{Duration, Instant};

/// A simple scoped/manual timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Order statistics summary of a set of samples (times, counts, ...).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            xs[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: q(0.50),
            p95: q(0.95),
            max: xs[n - 1],
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p95={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

/// True when the bench binary was invoked as `cargo bench -- --test`
/// (cargo forwards `--test` to every `harness = false` bench): run a
/// minimal smoke configuration instead of the full measurement.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--test")
}

/// Benchmark runner: warms up, then measures `iters` runs of `f`,
/// reporting a per-iteration Summary in milliseconds. Used by all
/// `rust/benches/*` targets.
pub fn bench_ms<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let s = Summary::of(&samples);
    println!("bench {name:<42} {s}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
