//! Timing and summary statistics for the bench harness and the repro
//! drivers (criterion is unavailable offline, so `cargo bench` targets use
//! these helpers with `harness = false`).

// Allowlisted timing module (coopgnn-lint `wallclock` + clippy
// disallowed-methods): Timer readings only land in wall_* report
// columns, never in a decision path.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// A simple scoped/manual timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Exact percentile of an **ascending-sorted** sample via linear
/// interpolation between closest ranks (the "linear" / type-7 estimator:
/// rank `h = p·(n-1)`, value `x[⌊h⌋] + (h-⌊h⌋)·(x[⌊h⌋+1] - x[⌊h⌋])`).
/// `p` is in `[0, 1]`; out-of-range `p` clamps to the extremes. Panics on
/// an empty slice — callers own the emptiness policy.
///
/// This is the latency-ledger reduction of `serve::report` (p50/p90/p99
/// per-request latencies) and the bench latency columns; unlike the old
/// nearest-rank rounding it is exact on small samples (the p99 of 100
/// points interpolates between the two largest instead of snapping).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let h = p.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = h.floor() as usize;
    let hi = (lo + 1).min(n - 1);
    let frac = h - lo as f64;
    sorted[lo] + frac * (sorted[hi] - sorted[lo])
}

/// Order statistics summary of a set of samples (times, counts, ...).
/// Percentiles use exact sorted-sample interpolation ([`percentile`]).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: percentile(&xs, 0.50),
            p90: percentile(&xs, 0.90),
            p95: percentile(&xs, 0.95),
            p99: percentile(&xs, 0.99),
            max: xs[n - 1],
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p90={:.4} p95={:.4} p99={:.4} \
             max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p95, self.p99,
            self.max
        )
    }
}

/// True when the bench binary was invoked as `cargo bench -- --test`
/// (cargo forwards `--test` to every `harness = false` bench): run a
/// minimal smoke configuration instead of the full measurement.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--test")
}

/// Benchmark runner: warms up, then measures `iters` runs of `f`,
/// reporting a per-iteration Summary in milliseconds. Used by all
/// `rust/benches/*` targets.
pub fn bench_ms<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let s = Summary::of(&samples);
    println!("bench {name:<42} {s}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates_exactly() {
        // even-length sample: the median interpolates between the two
        // middle elements instead of snapping to one of them
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.50), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        // quartile lands a quarter of the way into a gap
        assert!((percentile(&xs, 0.25) - 1.75).abs() < 1e-12);
        // 1..=100: h = 0.99·99 = 98.01 → between 99.0 and 100.0
        let big: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&big, 0.99) - 99.01).abs() < 1e-9);
        assert!((percentile(&big, 0.90) - 90.1).abs() < 1e-9);
        // singleton: every percentile is the sample
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
        // out-of-range p clamps
        assert_eq!(percentile(&xs, -0.5), 1.0);
        assert_eq!(percentile(&xs, 1.5), 4.0);
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn percentile_rejects_empty() {
        percentile(&[], 0.5);
    }

    #[test]
    fn summary_percentile_fields_ordered() {
        let xs: Vec<f64> = (0..200).map(|i| (i * 37 % 200) as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p90, "{s}");
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max, "{s}");
        assert!((s.p99 - 197.01).abs() < 1e-9, "exact p99 of 0..=199: {}", s.p99);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
