//! Scalar math needed by the smoothed dependent sampler (Appendix A.7):
//! the standard-normal CDF Φ (to turn interpolated Gaussians back into
//! uniforms, `r = Φ(n(c))`) and its inverse (to turn hash-uniforms into
//! Gaussians without Box–Muller pairs).

/// Error function, Abramowitz & Stegun 7.1.26 (|ε| ≤ 1.5e-7) with the
/// sign-symmetry extension. Accurate enough for sampling thresholds.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ(x) = P(Z ≤ x).
#[inline]
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Inverse standard normal CDF (Acklam's rational approximation,
/// |relative ε| < 1.15e-9 over (0,1)).
pub fn normal_icdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-6); // A&S 7.1.26 has |ε| ≤ 1.5e-7
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn cdf_symmetry_and_bounds() {
        for i in -40..=40 {
            let x = i as f64 * 0.1;
            let p = normal_cdf(x);
            assert!((0.0..=1.0).contains(&p));
            assert!((p + normal_cdf(-x) - 1.0).abs() < 1e-6);
        }
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-6);
        assert!((normal_cdf(1.96) - 0.975).abs() < 2e-4);
    }

    #[test]
    fn icdf_inverts_cdf() {
        for i in 1..100 {
            let p = i as f64 / 100.0;
            let x = normal_icdf(p);
            assert!((normal_cdf(x) - p).abs() < 2e-4, "p={p} x={x}");
        }
    }

    #[test]
    fn icdf_tails_monotone() {
        let mut prev = f64::NEG_INFINITY;
        for i in 1..10_000 {
            let p = i as f64 / 10_000.0;
            let x = normal_icdf(p);
            assert!(x >= prev, "monotone at p={p}");
            prev = x;
        }
    }
}
