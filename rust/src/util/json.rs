//! Minimal JSON parser (the offline build has no serde). Parses the
//! machine-generated `artifacts/manifest.json` and writes results JSON.
//! Supports the full JSON grammar except `\u` surrogate pairs (the
//! manifest never emits them).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{pad}  ");
                    v.write(out, indent + 1);
                }
                let _ = write!(out, "\n{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\n{pad}  \"{}\": ", escape(k));
                    v.write(out, indent + 1);
                }
                let _ = write!(out, "\n{pad}}}");
            }
        }
    }
}

/// Insert (or replace) top-level `section` in the JSON object file at
/// `path`, creating the file if absent and starting over if the existing
/// content is not a JSON object. This is how the benches accumulate
/// their machine-readable sections into one `BENCH_pipeline.json`
/// artifact across separate processes.
pub fn merge_section(
    path: &std::path::Path,
    section: &str,
    value: Json,
) -> std::io::Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| match j {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(section.to_string(), value);
    std::fs::write(path, Json::Obj(root).to_string_pretty())
}

/// Current schema version of `BENCH_pipeline.json` sections. Bump when a
/// section's field semantics change incompatibly (PR 5 introduced the
/// stamp itself, so it starts at 1).
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// How every stamped section's numbers derive from their builder seed —
/// recorded next to the seed so artifact readers can tell at a glance
/// whether two artifacts are comparable. PR 2 unified all seed defaults
/// behind `pipeline::DEFAULT_SEED` and made the *single* builder seed
/// feed dataset generation, partitioning, and the per-PE RNG streams;
/// that derivation change is exactly what silently broke comparability
/// of pre-PR-2 bench artifacts.
pub const SEED_RECIPE: &str = "pipeline-builder-unified (one seed -> dataset+partition+streams)";

/// Wrap a bench section body with its provenance stamp:
/// `schema_version` ([`BENCH_SCHEMA_VERSION`]), the builder seed the
/// run's numbers derive from, and the [`SEED_RECIPE`] derivation tag.
/// Every `BENCH_pipeline.json` section goes through here (bench_coop,
/// bench_train_step, bench_serve), so artifacts from different commits
/// are self-describing: differing `schema_version` or `seed_recipe`
/// means the absolute numbers are not comparable.
///
/// The seed is stamped as a hex *string*: JSON numbers are f64 here, and
/// a provenance stamp that silently rounds seeds above 2^53 would defeat
/// its own purpose.
pub fn stamped(builder_seed: u64, mut body: BTreeMap<String, Json>) -> Json {
    body.insert("schema_version".to_string(), Json::Num(BENCH_SCHEMA_VERSION as f64));
    body.insert("builder_seed".to_string(), Json::Str(format!("{builder_seed:#x}")));
    body.insert("seed_recipe".to_string(), Json::Str(SEED_RECIPE.to_string()));
    Json::Obj(body)
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            '\r' => "\\r".chars().collect(),
            c => vec![c],
        })
        .collect()
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes.get(self.pos + 1..self.pos + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            s.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
          "format": 1,
          "configs": {
            "tiny-b32": {
              "dataset": "tiny",
              "caps": {"k": 40, "n": [32, 512, 2048, 2048]},
              "lr": 0.01,
              "ok": true,
              "nothing": null
            }
          }
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let cfg = v.get("configs").unwrap().get("tiny-b32").unwrap();
        assert_eq!(cfg.get("dataset").unwrap().as_str(), Some("tiny"));
        let n = cfg.get("caps").unwrap().get("n").unwrap().as_arr().unwrap();
        assert_eq!(n.len(), 4);
        assert_eq!(n[3].as_usize(), Some(2048));
        assert_eq!(cfg.get("lr").unwrap().as_f64(), Some(0.01));
        assert_eq!(cfg.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(cfg.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a": [1, 2.5, "x\ny"], "b": {"c": false}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \"quoted\"""#).unwrap();
        assert_eq!(v.as_str(), Some("café \"quoted\""));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn stamped_sections_carry_schema_and_seed_recipe() {
        let mut body = BTreeMap::new();
        body.insert("wall_ms".to_string(), Json::Num(2.5));
        let s = stamped(7, body);
        assert_eq!(s.get("schema_version").unwrap().as_f64(), Some(BENCH_SCHEMA_VERSION as f64));
        assert_eq!(s.get("builder_seed").unwrap().as_str(), Some("0x7"));
        assert_eq!(s.get("seed_recipe").unwrap().as_str(), Some(SEED_RECIPE));
        assert_eq!(s.get("wall_ms").unwrap().as_f64(), Some(2.5), "body fields survive");
        // round-trips through the writer/parser
        let back = Json::parse(&s.to_string_pretty()).unwrap();
        assert_eq!(back, s);
        // a full-width u64 seed survives exactly (hex string, not f64)
        let big = stamped(0xDEAD_BEEF_DEAD_BEEF, BTreeMap::new());
        assert_eq!(big.get("builder_seed").unwrap().as_str(), Some("0xdeadbeefdeadbeef"));
    }

    #[test]
    fn merge_section_accumulates_across_writes() {
        let path = std::env::temp_dir().join("coopgnn_merge_section_test.json");
        std::fs::remove_file(&path).ok();
        let mut a = BTreeMap::new();
        a.insert("wall_ms".to_string(), Json::Num(1.5));
        merge_section(&path, "bench_coop", Json::Obj(a)).unwrap();
        let mut b = BTreeMap::new();
        b.insert("speedup".to_string(), Json::Num(2.0));
        merge_section(&path, "bench_train_step", Json::Obj(b)).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            root.get("bench_coop").unwrap().get("wall_ms").unwrap().as_f64(),
            Some(1.5)
        );
        assert_eq!(
            root.get("bench_train_step").unwrap().get("speedup").unwrap().as_f64(),
            Some(2.0)
        );
        // replacing a section keeps the others
        merge_section(&path, "bench_coop", Json::Num(7.0)).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("bench_coop").unwrap().as_f64(), Some(7.0));
        assert!(root.get("bench_train_step").is_some());
        std::fs::remove_file(&path).ok();
    }
}
