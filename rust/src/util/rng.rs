//! Deterministic random number generation.
//!
//! Two flavors are needed by the paper's algorithms:
//!
//! * **Stream RNG** ([`Pcg64`]) — an ordinary sequential generator used for
//!   seed permutation, graph generation, weight init, etc.
//! * **Counter-based RNG** ([`counter_hash2`] / [`counter_hash3`]) — a
//!   stateless hash `(seed, key...) -> u64`. LABOR requires that the *same*
//!   random variate `r_t` be produced for a source vertex `t` regardless of
//!   which seed vertex reached it, and the smoothed dependent sampler of
//!   Appendix A.7 requires re-producing `n_ts` for a fixed seed `z` at any
//!   time. A counter-based construction gives both properties for free.

/// PCG-XSH-RR-like 64-bit generator (splitmix64-stepped, xorshift-mixed).
/// Deterministic, seedable, `Clone` — good enough statistical quality for
/// simulation work while staying dependency-free.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams in practice (seeded through splitmix64 twice).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        Pcg64 { state, inc }
    }

    /// Derive a child stream; used to give each PE / each epoch its own
    /// independent generator deterministically.
    pub fn fork(&mut self, tag: u64) -> Self {
        Pcg64::new(self.next_u64() ^ mix(tag))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // LCG step + output mix (PCG style).
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        mix(old)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        u64_to_unit_f64(self.next_u64())
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free is overkill;
    /// modulo bias is negligible for n << 2^64 but we debias anyway).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply method (unbiased enough for all practical n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (single value; second is discarded —
    /// the stream use-cases here are not throughput critical).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u2 = self.next_f64();
        let r = (-2.0 * (1.0 - u1).max(f64::MIN_POSITIVE).ln()).sqrt();
        r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm for
    /// k << n; falls back to shuffle otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<u32> = (0..n as u32).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below(j as u64 + 1) as u32;
            if seen.insert(t) {
                out.push(t);
            } else {
                seen.insert(j as u32);
                out.push(j as u32);
            }
        }
        out
    }
}

/// splitmix64 step — used for seeding only.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Strong 64-bit mixer (xxhash/murmur finalizer family).
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 33)).wrapping_mul(0xFF51AFD7ED558CCD);
    z = (z ^ (z >> 33)).wrapping_mul(0xC4CEB9FE1A85EC53);
    z ^ (z >> 33)
}

/// Counter-based hash of `(seed, a)` — the per-vertex variate generator
/// used by LABOR (`r_t = U(hash(z, t))`).
#[inline]
pub fn counter_hash2(seed: u64, a: u64) -> u64 {
    mix(seed ^ a.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(31))
}

/// Counter-based hash of `(seed, a, b)` — the per-edge variate generator
/// used by NS (`r_ts = U(hash(z, t, s))`).
#[inline]
pub fn counter_hash3(seed: u64, a: u64, b: u64) -> u64 {
    let h = seed
        ^ a.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(31)
        ^ b.wrapping_mul(0xC2B2AE3D27D4EB4F).rotate_left(17);
    mix(h)
}

/// Map a u64 to a uniform f64 in `[0, 1)` using the top 53 bits.
#[inline]
pub fn u64_to_unit_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(9);
        let mut hit = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            hit[v] = true;
        }
        assert!(hit.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn counter_hash_is_stateless_and_keyed() {
        assert_eq!(counter_hash2(1, 2), counter_hash2(1, 2));
        assert_ne!(counter_hash2(1, 2), counter_hash2(1, 3));
        assert_ne!(counter_hash2(1, 2), counter_hash2(2, 2));
        assert_ne!(counter_hash3(1, 2, 3), counter_hash3(1, 3, 2));
    }

    #[test]
    fn counter_hash_uniformity_rough() {
        // Mean of mapped uniforms should be ~0.5.
        let n = 100_000u64;
        let mean: f64 = (0..n)
            .map(|i| u64_to_unit_f64(counter_hash2(123, i)))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_props() {
        let mut r = Pcg64::new(11);
        for &(n, k) in &[(100usize, 5usize), (50, 50), (1000, 100), (10, 9)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "distinct");
            assert!(s.iter().all(|&x| (x as usize) < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
