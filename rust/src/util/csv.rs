//! Tiny CSV/markdown table writers for the repro harnesses. Every paper
//! table/figure harness emits (a) a machine-readable CSV and (b) a
//! human-readable markdown table into `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A rectangular table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row<S: ToString>(&mut self, row: &[S]) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row.iter().map(|s| s.to_string()).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","))
            .unwrap();
        for r in &self.rows {
            writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")).unwrap();
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        writeln!(out, "### {}\n", self.title).unwrap();
        writeln!(out, "| {} |", self.header.join(" | ")).unwrap();
        writeln!(out, "|{}|", vec!["---"; self.header.len()].join("|")).unwrap();
        for r in &self.rows {
            writeln!(out, "| {} |", r.join(" | ")).unwrap();
        }
        out
    }

    /// Write `<dir>/<stem>.csv` and `<dir>/<stem>.md`.
    pub fn write(&self, dir: &Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.md")))?;
        f.write_all(self.to_markdown().as_bytes())?;
        Ok(())
    }
}

/// Format a byte quantity as KiB with fixed 3-decimal precision — the
/// one spelling every repro table uses for byte columns, so the same
/// quantity never drifts between `{:.1}` and `{:.3}` across harnesses.
pub fn fmt_kib(bytes: f64) -> String {
    format!("{:.3}", bytes / 1024.0)
}

/// Format a millisecond quantity with fixed 3-decimal precision — the
/// shared spelling for time columns in the repro tables.
pub fn fmt_ms(ms: f64) -> String {
    format!("{ms:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_formatters_are_stable() {
        assert_eq!(fmt_kib(1024.0), "1.000");
        assert_eq!(fmt_kib(1536.0), "1.500");
        assert_eq!(fmt_kib(0.0), "0.000");
        assert_eq!(fmt_ms(1.23456), "1.235");
        assert_eq!(fmt_ms(0.0), "0.000");
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(&["1", "x,y"]);
        let csv = t.to_csv();
        assert!(csv.contains("a,b"));
        assert!(csv.contains("\"x,y\""));
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push_row(&["1"]);
    }
}
