//! Small zero-dependency utilities shared across the crate: deterministic
//! RNG (stream and counter-based), normal/erf math for the smoothed
//! dependent sampler, timing/statistics helpers for the bench harness, and
//! a tiny property-testing loop used by the test suite (the offline build
//! has no `proptest`).

pub mod rng;
pub mod mathx;
pub mod stats;
pub mod propcheck;
pub mod csv;
pub mod json;

pub use rng::{Pcg64, counter_hash2, counter_hash3, u64_to_unit_f64};
pub use mathx::{erf, normal_cdf, normal_icdf};
pub use stats::{Timer, Summary};
