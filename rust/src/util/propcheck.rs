//! Minimal property-testing loop (offline stand-in for `proptest`).
//!
//! A property is a closure over a seeded [`Pcg64`]; [`check`] runs it for
//! `cases` independent seeds and reports the first failing seed so a
//! failure is reproducible by pinning that seed in a regression test.

use super::rng::Pcg64;

/// Run `prop` for `cases` random cases. Panics with the failing case seed
/// on the first violation. `base_seed` pins the whole run.
pub fn check<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> std::result::Result<(), String>,
{
    let mut meta = Pcg64::new(base_seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Helper: assert-like error constructor for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |rng| {
            count += 1;
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range {x}"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property `bad`")]
    fn failing_property_panics_with_seed() {
        check("bad", 2, 10, |_rng| Err("always fails".into()));
    }
}
