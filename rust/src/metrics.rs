//! Lightweight named counters + wall-clock accumulators used by the coop
//! engine, the trainer, and the repro harnesses.

// Allowlisted timing module (coopgnn-lint `wallclock` + clippy
// disallowed-methods): phase timings feed report columns only.
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::time::Instant;

/// A bag of named u64 counters and f64 accumulators (ms).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, u64>,
    pub times_ms: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    #[inline]
    pub fn get(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn add_time_ms(&mut self, name: &str, ms: f64) {
        *self.times_ms.entry(name.to_string()).or_insert(0.0) += ms;
    }

    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add_time_ms(name, t.elapsed().as_secs_f64() * 1e3);
        out
    }

    /// Merge another metrics bag into this one.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.times_ms {
            *self.times_ms.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, v) in &self.times_ms {
            s.push_str(&format!("{k:<40} {v:.3} ms\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.add("x", 2);
        m.add("x", 3);
        assert_eq!(m.get("x"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn merge_sums() {
        let mut a = Metrics::new();
        a.add("x", 1);
        a.add_time_ms("t", 1.5);
        let mut b = Metrics::new();
        b.add("x", 2);
        b.add("y", 7);
        b.add_time_ms("t", 0.5);
        a.merge(&b);
        assert_eq!(a.get("x"), 3);
        assert_eq!(a.get("y"), 7);
        assert!((a.times_ms["t"] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let mut m = Metrics::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.times_ms["work"] >= 0.0);
    }
}
