//! Deprecated shim — the counter bag moved to the observability plane.
//!
//! The `Metrics` API (named u64 counters + wall-time accumulators) is
//! now [`crate::obs::Registry`], which adds gauges, `LedgerSource`
//! absorption, and a Prometheus-style exposition. This alias keeps old
//! spelling compiling for one deprecation cycle; new code should use
//! `crate::obs::Registry` directly. The wall-clock capture this module
//! used to own lives in [`crate::obs::wall`] (the allowlists moved with
//! it).

/// Deprecated alias for [`crate::obs::Registry`].
#[deprecated(note = "use crate::obs::Registry — the counter bag moved to the obs plane")]
pub type Metrics = crate::obs::Registry;
