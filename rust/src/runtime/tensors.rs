//! Host-buffer <-> PJRT literal helpers and the padded-batch -> input
//! literal assembly implementing the flat AOT calling convention
//! (python/compile/model.py `flat_train_step` / `flat_forward`).

use super::backend::Literal;
use super::manifest::ArtifactConfig;
use crate::sampling::PaddedBatch;
use crate::util::rng::Pcg64;

/// f32 tensor literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> crate::Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32 {dims:?} vs {} elems", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// i32 tensor literal.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> crate::Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32 {dims:?} vs {} elems", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

pub fn lit_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn to_vec_f32(lit: &Literal) -> crate::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

pub fn scalar_f32(lit: &Literal) -> crate::Result<f32> {
    let v = to_vec_f32(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

/// Model parameters + optimizer state held host-side between steps.
pub struct ParamState {
    /// flat f32 buffers in `ArtifactConfig::param_shapes` order.
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub step: f32,
    shapes: Vec<Vec<usize>>,
}

impl ParamState {
    /// Glorot-normal init (matching python model.init_params).
    pub fn init(cfg: &ArtifactConfig, seed: u64) -> ParamState {
        let mut rng = Pcg64::new(seed);
        let mut params = Vec::new();
        let mut shapes = Vec::new();
        for (_name, shape) in cfg.param_shapes() {
            let n: usize = shape.iter().product();
            let buf = if shape.len() == 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f64).sqrt();
                (0..n).map(|_| (rng.next_normal() * scale) as f32).collect()
            } else {
                vec![0f32; n]
            };
            params.push(buf);
            shapes.push(shape);
        }
        let m = params.iter().map(|p| vec![0f32; p.len()]).collect();
        let v = params.iter().map(|p| vec![0f32; p.len()]).collect();
        ParamState { params, m, v, step: 0.0, shapes }
    }

    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    /// Total scalar parameter count.
    pub fn num_scalars(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    pub fn shapes(&self) -> &[Vec<usize>] {
        &self.shapes
    }

    /// Absorb the outputs of a train step: `outs` is the flat output
    /// tuple (params | m | v | step | loss | correct). Returns
    /// (loss, correct).
    pub fn absorb(&mut self, outs: &[Literal]) -> crate::Result<(f32, f32)> {
        let np = self.params.len();
        anyhow::ensure!(outs.len() == 3 * np + 3, "expected {} outs, got {}", 3 * np + 3, outs.len());
        for i in 0..np {
            self.params[i] = to_vec_f32(&outs[i])?;
            self.m[i] = to_vec_f32(&outs[np + i])?;
            self.v[i] = to_vec_f32(&outs[2 * np + i])?;
        }
        self.step = scalar_f32(&outs[3 * np])?;
        let loss = scalar_f32(&outs[3 * np + 1])?;
        let correct = scalar_f32(&outs[3 * np + 2])?;
        Ok((loss, correct))
    }
}

/// Assemble the flat train-step input literals:
/// params | m | v | step | feats | blocks | labels | mask | lr.
pub fn train_inputs(
    cfg: &ArtifactConfig,
    state: &ParamState,
    feats: &[f32],
    batch: &PaddedBatch,
    lr: f32,
) -> crate::Result<Vec<Literal>> {
    let caps = &cfg.caps;
    let l_count = cfg.layers;
    let mut inputs = Vec::with_capacity(cfg.num_train_inputs);
    for (buf, shape) in state.params.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    for (buf, shape) in state.m.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    for (buf, shape) in state.v.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    inputs.push(lit_scalar(state.step));
    inputs.push(lit_f32(feats, &[caps.n[l_count], cfg.d_in])?);
    push_blocks(&mut inputs, caps, batch, l_count)?;
    inputs.push(lit_i32(&batch.labels, &[caps.n[0]])?);
    inputs.push(lit_f32(&batch.label_mask, &[caps.n[0]])?);
    inputs.push(lit_scalar(lr));
    anyhow::ensure!(
        inputs.len() == cfg.num_train_inputs,
        "assembled {} train inputs, manifest says {}",
        inputs.len(),
        cfg.num_train_inputs
    );
    Ok(inputs)
}

/// Assemble the flat forward input literals: params | feats | blocks.
pub fn forward_inputs(
    cfg: &ArtifactConfig,
    state: &ParamState,
    feats: &[f32],
    batch: &PaddedBatch,
) -> crate::Result<Vec<Literal>> {
    let caps = &cfg.caps;
    let l_count = cfg.layers;
    let mut inputs = Vec::with_capacity(cfg.num_forward_inputs);
    for (buf, shape) in state.params.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    inputs.push(lit_f32(feats, &[caps.n[l_count], cfg.d_in])?);
    push_blocks(&mut inputs, caps, batch, l_count)?;
    anyhow::ensure!(
        inputs.len() == cfg.num_forward_inputs,
        "assembled {} forward inputs, manifest says {}",
        inputs.len(),
        cfg.num_forward_inputs
    );
    Ok(inputs)
}

fn push_blocks(
    inputs: &mut Vec<Literal>,
    caps: &crate::sampling::ShapeCaps,
    batch: &PaddedBatch,
    l_count: usize,
) -> crate::Result<()> {
    anyhow::ensure!(batch.caps.n == caps.n && batch.caps.k == caps.k, "batch caps mismatch");
    for l in 0..l_count {
        inputs.push(lit_i32(&batch.nbr_idx[l], &[caps.n[l], caps.k])?);
        inputs.push(lit_f32(&batch.nbr_w[l], &[caps.n[l], caps.k])?);
        inputs.push(lit_i32(&batch.self_idx[l], &[caps.n[l]])?);
        inputs.push(lit_f32(&batch.self_w[l], &[caps.n[l]])?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::ShapeCaps;
    use std::path::PathBuf;

    fn cfg() -> ArtifactConfig {
        ArtifactConfig {
            name: "t".into(),
            dataset: "tiny".into(),
            batch: 32,
            layers: 3,
            d_in: 16,
            hidden: 32,
            classes: 8,
            caps: ShapeCaps { k: 40, n: vec![32, 512, 2048, 2048] },
            lr: 0.01,
            train_hlo: PathBuf::new(),
            forward_hlo: PathBuf::new(),
            num_train_inputs: 35,
            num_forward_inputs: 19,
        }
    }

    #[test]
    fn param_state_init_shapes_and_determinism() {
        let c = cfg();
        let a = ParamState::init(&c, 5);
        let b = ParamState::init(&c, 5);
        assert_eq!(a.num_params(), 6);
        assert_eq!(a.params[0].len(), 16 * 32);
        assert_eq!(a.params[5].len(), 8);
        assert_eq!(a.params[0], b.params[0]);
        assert!(a.params[1].iter().all(|&x| x == 0.0), "biases start at zero");
        assert_eq!(a.num_scalars(), 16 * 32 + 32 + 32 * 32 + 32 + 32 * 8 + 8);
    }

    #[test]
    fn literal_shape_checks() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(lit_i32(&[1, 2, 3], &[3, 1]).is_ok());
    }
}
