//! Host-buffer <-> PJRT literal helpers and the padded-batch -> input
//! literal assembly implementing the flat AOT calling convention
//! (python/compile/model.py `flat_train_step` / `flat_forward`).

use super::backend::Literal;
use super::manifest::ArtifactConfig;
use crate::sampling::PaddedBatch;
use crate::util::rng::Pcg64;

/// f32 tensor literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> crate::Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32 {dims:?} vs {} elems", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// i32 tensor literal.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> crate::Result<Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32 {dims:?} vs {} elems", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

pub fn lit_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn to_vec_f32(lit: &Literal) -> crate::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

pub fn scalar_f32(lit: &Literal) -> crate::Result<f32> {
    let v = to_vec_f32(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

/// Model parameters + optimizer state held host-side between steps.
pub struct ParamState {
    /// flat f32 buffers in `ArtifactConfig::param_shapes` order.
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub step: f32,
    shapes: Vec<Vec<usize>>,
}

impl ParamState {
    /// Glorot-normal init (matching python model.init_params).
    pub fn init(cfg: &ArtifactConfig, seed: u64) -> ParamState {
        ParamState::with_shapes(cfg.param_shapes().into_iter().map(|(_, s)| s).collect(), seed)
    }

    /// Init from bare shapes (same Glorot-normal recipe as [`init`],
    /// without needing an artifact manifest) — the constructor the
    /// multi-PE training plane uses to stand up replicated states: every
    /// replica built from the same `(shapes, seed)` is bit-identical.
    ///
    /// [`init`]: ParamState::init
    pub fn with_shapes(shapes: Vec<Vec<usize>>, seed: u64) -> ParamState {
        let mut rng = Pcg64::new(seed);
        let mut params = Vec::new();
        for shape in &shapes {
            let n: usize = shape.iter().product();
            let buf = if shape.len() == 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f64).sqrt();
                (0..n).map(|_| (rng.next_normal() * scale) as f32).collect()
            } else {
                vec![0f32; n]
            };
            params.push(buf);
        }
        let m = params.iter().map(|p| vec![0f32; p.len()]).collect();
        let v = params.iter().map(|p| vec![0f32; p.len()]).collect();
        ParamState { params, m, v, step: 0.0, shapes }
    }

    /// Host-side Adam update from a flat gradient laid out in parameter
    /// order (concatenation of each parameter's scalars) — the same
    /// update rule as the AOT train step (`python/compile/model.py`:
    /// β1 = 0.9, β2 = 0.999, ε = 1e-8, bias-corrected), so a host
    /// training plane and a PJRT one move parameters identically given
    /// identical gradients. Deterministic in f32: replicas applying the
    /// same flat gradient stay bit-identical.
    pub fn adam_step(&mut self, flat_grads: &[f32], lr: f32) {
        assert_eq!(flat_grads.len(), self.num_scalars(), "flat gradient length");
        const BETA1: f32 = 0.9;
        const BETA2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.step += 1.0;
        let t = self.step;
        let bc1 = 1.0 - BETA1.powf(t);
        let bc2 = 1.0 - BETA2.powf(t);
        let mut off = 0;
        for i in 0..self.params.len() {
            let n = self.params[i].len();
            let g = &flat_grads[off..off + n];
            for j in 0..n {
                let m = BETA1 * self.m[i][j] + (1.0 - BETA1) * g[j];
                let v = BETA2 * self.v[i][j] + (1.0 - BETA2) * g[j] * g[j];
                self.m[i][j] = m;
                self.v[i][j] = v;
                self.params[i][j] -= lr * (m / bc1) / ((v / bc2).sqrt() + EPS);
            }
            off += n;
        }
    }

    /// Bitwise equality of the full optimizer state (params, m, v, step)
    /// — the lockstep invariant the gradient all-reduce maintains across
    /// replicas (f32 `==` would treat `0.0 == -0.0`; replicas must agree
    /// on the exact bits).
    pub fn bits_eq(&self, other: &ParamState) -> bool {
        let eq = |a: &[Vec<f32>], b: &[Vec<f32>]| {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| {
                    x.len() == y.len()
                        && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                })
        };
        self.step.to_bits() == other.step.to_bits()
            && eq(&self.params, &other.params)
            && eq(&self.m, &other.m)
            && eq(&self.v, &other.v)
    }

    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    /// Total scalar parameter count.
    pub fn num_scalars(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    pub fn shapes(&self) -> &[Vec<usize>] {
        &self.shapes
    }

    /// Absorb the outputs of a train step: `outs` is the flat output
    /// tuple (params | m | v | step | loss | correct). Returns
    /// (loss, correct).
    pub fn absorb(&mut self, outs: &[Literal]) -> crate::Result<(f32, f32)> {
        let np = self.params.len();
        let want = 3 * np + 3;
        anyhow::ensure!(outs.len() == want, "expected {} outs, got {}", want, outs.len());
        for i in 0..np {
            self.params[i] = to_vec_f32(&outs[i])?;
            self.m[i] = to_vec_f32(&outs[np + i])?;
            self.v[i] = to_vec_f32(&outs[2 * np + i])?;
        }
        self.step = scalar_f32(&outs[3 * np])?;
        let loss = scalar_f32(&outs[3 * np + 1])?;
        let correct = scalar_f32(&outs[3 * np + 2])?;
        Ok((loss, correct))
    }
}

/// Assemble the flat train-step input literals:
/// params | m | v | step | feats | blocks | labels | mask | lr.
pub fn train_inputs(
    cfg: &ArtifactConfig,
    state: &ParamState,
    feats: &[f32],
    batch: &PaddedBatch,
    lr: f32,
) -> crate::Result<Vec<Literal>> {
    let caps = &cfg.caps;
    let l_count = cfg.layers;
    let mut inputs = Vec::with_capacity(cfg.num_train_inputs);
    for (buf, shape) in state.params.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    for (buf, shape) in state.m.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    for (buf, shape) in state.v.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    inputs.push(lit_scalar(state.step));
    inputs.push(lit_f32(feats, &[caps.n[l_count], cfg.d_in])?);
    push_blocks(&mut inputs, caps, batch, l_count)?;
    inputs.push(lit_i32(&batch.labels, &[caps.n[0]])?);
    inputs.push(lit_f32(&batch.label_mask, &[caps.n[0]])?);
    inputs.push(lit_scalar(lr));
    anyhow::ensure!(
        inputs.len() == cfg.num_train_inputs,
        "assembled {} train inputs, manifest says {}",
        inputs.len(),
        cfg.num_train_inputs
    );
    Ok(inputs)
}

/// Assemble the flat forward input literals: params | feats | blocks.
pub fn forward_inputs(
    cfg: &ArtifactConfig,
    state: &ParamState,
    feats: &[f32],
    batch: &PaddedBatch,
) -> crate::Result<Vec<Literal>> {
    let caps = &cfg.caps;
    let l_count = cfg.layers;
    let mut inputs = Vec::with_capacity(cfg.num_forward_inputs);
    for (buf, shape) in state.params.iter().zip(state.shapes()) {
        inputs.push(lit_f32(buf, shape)?);
    }
    inputs.push(lit_f32(feats, &[caps.n[l_count], cfg.d_in])?);
    push_blocks(&mut inputs, caps, batch, l_count)?;
    anyhow::ensure!(
        inputs.len() == cfg.num_forward_inputs,
        "assembled {} forward inputs, manifest says {}",
        inputs.len(),
        cfg.num_forward_inputs
    );
    Ok(inputs)
}

fn push_blocks(
    inputs: &mut Vec<Literal>,
    caps: &crate::sampling::ShapeCaps,
    batch: &PaddedBatch,
    l_count: usize,
) -> crate::Result<()> {
    anyhow::ensure!(batch.caps.n == caps.n && batch.caps.k == caps.k, "batch caps mismatch");
    for l in 0..l_count {
        inputs.push(lit_i32(&batch.nbr_idx[l], &[caps.n[l], caps.k])?);
        inputs.push(lit_f32(&batch.nbr_w[l], &[caps.n[l], caps.k])?);
        inputs.push(lit_i32(&batch.self_idx[l], &[caps.n[l]])?);
        inputs.push(lit_f32(&batch.self_w[l], &[caps.n[l]])?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::ShapeCaps;
    use std::path::PathBuf;

    fn cfg() -> ArtifactConfig {
        ArtifactConfig {
            name: "t".into(),
            dataset: "tiny".into(),
            batch: 32,
            layers: 3,
            d_in: 16,
            hidden: 32,
            classes: 8,
            caps: ShapeCaps { k: 40, n: vec![32, 512, 2048, 2048] },
            lr: 0.01,
            train_hlo: PathBuf::new(),
            forward_hlo: PathBuf::new(),
            num_train_inputs: 35,
            num_forward_inputs: 19,
        }
    }

    #[test]
    fn param_state_init_shapes_and_determinism() {
        let c = cfg();
        let a = ParamState::init(&c, 5);
        let b = ParamState::init(&c, 5);
        assert_eq!(a.num_params(), 6);
        assert_eq!(a.params[0].len(), 16 * 32);
        assert_eq!(a.params[5].len(), 8);
        assert_eq!(a.params[0], b.params[0]);
        assert!(a.params[1].iter().all(|&x| x == 0.0), "biases start at zero");
        assert_eq!(a.num_scalars(), 16 * 32 + 32 + 32 * 32 + 32 + 32 * 8 + 8);
    }

    #[test]
    fn with_shapes_matches_artifact_init_and_adam_is_deterministic() {
        let c = cfg();
        let from_cfg = ParamState::init(&c, 5);
        let shapes: Vec<Vec<usize>> = from_cfg.shapes().to_vec();
        let bare = ParamState::with_shapes(shapes, 5);
        assert!(from_cfg.bits_eq(&bare), "same shapes + seed ⇒ same state");

        // two replicas applying the same flat gradients stay bitwise
        // lockstep; a diverging gradient breaks it
        let mut a = ParamState::with_shapes(vec![vec![4, 3], vec![3]], 9);
        let mut b = ParamState::with_shapes(vec![vec![4, 3], vec![3]], 9);
        let g: Vec<f32> = (0..a.num_scalars()).map(|i| (i as f32 - 7.0) * 0.01).collect();
        for _ in 0..5 {
            a.adam_step(&g, 0.05);
            b.adam_step(&g, 0.05);
        }
        assert!(a.bits_eq(&b));
        assert!(a.step == 5.0);
        let g2: Vec<f32> = g.iter().map(|x| x + 1e-3).collect();
        b.adam_step(&g2, 0.05);
        a.adam_step(&g, 0.05);
        assert!(!a.bits_eq(&b), "different gradients must diverge");
    }

    #[test]
    fn adam_moves_params_against_gradient() {
        let mut s = ParamState::with_shapes(vec![vec![2, 2]], 3);
        let before = s.params[0].clone();
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        s.adam_step(&g, 0.1);
        for (i, (&b, &a)) in before.iter().zip(&s.params[0]).enumerate() {
            if g[i] > 0.0 {
                assert!(a < b, "positive grad must decrease param {i}");
            } else {
                assert!(a > b, "negative grad must increase param {i}");
            }
        }
    }

    #[test]
    fn literal_shape_checks() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(lit_i32(&[1, 2, 3], &[3, 1]).is_ok());
    }
}
