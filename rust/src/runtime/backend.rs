//! Host-side tensor literals — the data-interchange type between the
//! batch assembly in [`super::tensors`] and the execution backend.
//!
//! The upstream design hands `xla::Literal`s to a PJRT client. The
//! offline toolchain cannot vendor the `xla` crate, so this module keeps
//! the same API surface (`vec1` / `reshape` / `scalar` / `to_vec`) on a
//! plain host buffer. A future `pjrt`-feature backend converts these
//! buffers to device literals at the [`super::client`] boundary; every
//! caller above that boundary is backend-agnostic.

/// Typed flat storage of a literal.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Shape/type mismatch error (Debug-printable, mirroring the xla crate's
/// error usage at call sites).
#[derive(Clone, Debug)]
pub struct LiteralError(pub String);

impl std::fmt::Display for LiteralError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// A host tensor: flat payload + dims (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types storable in a [`Literal`].
pub trait Element: Copy {
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(payload: &Payload) -> Result<Vec<Self>, LiteralError>;
}

impl Element for f32 {
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(payload: &Payload) -> Result<Vec<f32>, LiteralError> {
        match payload {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(LiteralError("literal holds i32, requested f32".into())),
        }
    }
}

impl Element for i32 {
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(payload: &Payload) -> Result<Vec<i32>, LiteralError> {
        match payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(LiteralError("literal holds f32, requested i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: T::wrap(data.to_vec()) }
    }

    /// Rank-0 (scalar) f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { payload: Payload::F32(vec![v]), dims: Vec::new() }
    }

    /// Reinterpret under new dims; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, LiteralError> {
        let n: i64 = dims.iter().product();
        if n as usize != self.payload.len() {
            return Err(LiteralError(format!(
                "reshape {:?} -> {dims:?}: {} elements vs {}",
                self.dims,
                self.payload.len(),
                n
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    /// Copy out as a flat vector of `T`.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, LiteralError> {
        T::unwrap(&self.payload)
    }

    /// Decompose a tuple literal. Host literals are never tuples (tuples
    /// only arise from device executions), so this always errors here.
    pub fn to_tuple(self) -> Result<Vec<Literal>, LiteralError> {
        Err(LiteralError("host literal is not a tuple".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_type_checks() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.element_count(), 1);
        assert!(s.dims().is_empty());
        assert!(s.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
        assert!(i.clone().to_tuple().is_err());
    }
}
