//! Execution runtime: host tensor literals ([`backend`]), the artifact
//! manifest ([`manifest`]), batch → literal assembly ([`tensors`]), and
//! the execution client facade ([`client`]).
//!
//! The upstream design executes AOT-compiled HLO-text artifacts on a PJRT
//! CPU client (`make artifacts` produces the HLO once; Python never runs
//! on the training path). This build ships without an XLA backend — see
//! [`client`] for the stub contract and how to restore execution.

pub mod backend;
pub mod manifest;
pub mod client;
pub mod tensors;

pub use backend::Literal;
pub use client::{Executable, Runtime};
pub use manifest::{ArtifactConfig, Manifest};
