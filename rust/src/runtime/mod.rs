//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path. Python never runs here — `make artifacts`
//! produced the HLO once; this module compiles it on the PJRT CPU client
//! at startup and then executes per minibatch.

pub mod manifest;
pub mod client;
pub mod tensors;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactConfig, Manifest};
