//! Execution client facade.
//!
//! The upstream design compiles AOT'd HLO text on a PJRT CPU client (via
//! the `xla` crate) and executes it per minibatch. That crate cannot be
//! resolved by the offline toolchain, so this build ships a stub client:
//! [`Runtime::cpu`] returns a descriptive error and nothing above this
//! boundary changes — the trainer, repro harnesses, benches, and examples
//! all skip or report cleanly when the runtime is unavailable (they
//! already did so when `artifacts/` was missing). Restoring execution is
//! local to this file: vendor an `xla`/PJRT crate, enable the `pjrt`
//! feature, and convert [`super::backend::Literal`] host buffers at this
//! boundary.

use super::backend::Literal;
use std::path::Path;

/// The process-wide execution client.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// CPU PJRT client. Always errors in this build (no XLA backend).
    pub fn cpu() -> crate::Result<Runtime> {
        anyhow::bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature (the offline \
             toolchain cannot vendor the `xla` crate). Sampling, the cooperative engine, \
             and the count-based repro harnesses run natively; train/eval paths require \
             a PJRT-enabled build."
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> crate::Result<Executable> {
        anyhow::bail!("cannot compile {path:?}: PJRT runtime unavailable in this build")
    }
}

/// One compiled model-variant executable.
pub struct Executable {
    pub name: String,
    pub compile_ms: f64,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    pub fn run(&self, _inputs: &[Literal]) -> crate::Result<Vec<Literal>> {
        anyhow::bail!("cannot execute {}: PJRT runtime unavailable in this build", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().err().expect("stub must error");
        let msg = err.to_string();
        assert!(msg.contains("PJRT"), "got: {msg}");
    }
}
