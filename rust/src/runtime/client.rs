//! PJRT client + executable wrappers over the `xla` crate.
//!
//! HLO **text** is the interchange format (see python/compile/aot.py);
//! `HloModuleProto::from_text_file` reassigns instruction ids so jax≥0.5
//! modules load cleanly on xla_extension 0.5.1.

use std::path::Path;
use std::time::Instant;

/// The process-wide PJRT client. Construction is expensive (plugin
/// init); share one per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client.
    pub fn cpu() -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (once; executions reuse
    /// the compiled module).
    pub fn load_hlo_text(&self, path: &Path) -> crate::Result<Executable> {
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable {
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            compile_ms: t.elapsed().as_secs_f64() * 1e3,
        })
    }
}

/// One compiled model-variant executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_ms: f64,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    /// (aot.py lowers with `return_tuple=True`, so the single output is a
    /// tuple literal which we decompose.)
    pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("decompose tuple {}: {e:?}", self.name))
    }
}
