//! `artifacts/manifest.json` — the single source of truth for the padded
//! tensor shapes negotiated between the Rust block builder and the AOT'd
//! model (see python/compile/aot.py).

use crate::sampling::ShapeCaps;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT'd model configuration.
#[derive(Clone, Debug)]
pub struct ArtifactConfig {
    pub name: String,
    pub dataset: String,
    pub batch: usize,
    pub layers: usize,
    pub d_in: usize,
    pub hidden: usize,
    pub classes: usize,
    pub caps: ShapeCaps,
    pub lr: f32,
    pub train_hlo: PathBuf,
    pub forward_hlo: PathBuf,
    pub num_train_inputs: usize,
    pub num_forward_inputs: usize,
}

impl ArtifactConfig {
    /// Ordered parameter shapes (must mirror python model.param_shapes).
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut shapes = Vec::new();
        let mut d_prev = self.d_in;
        for l in 0..self.layers {
            let d_out = if l == self.layers - 1 { self.classes } else { self.hidden };
            shapes.push((format!("w{l}"), vec![d_prev, d_out]));
            shapes.push((format!("b{l}"), vec![d_out]));
            d_prev = d_out;
        }
        shapes
    }

    pub fn num_params(&self) -> usize {
        2 * self.layers
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: Vec<ArtifactConfig>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(artifacts_dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("manifest.json missing (run `make artifacts`): {e}"))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let configs_obj = root
            .get("configs")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing configs"))?;
        let mut configs = Vec::new();
        for (name, cfg) in configs_obj {
            let req = |key: &str| -> crate::Result<&Json> {
                cfg.get(key).ok_or_else(|| anyhow::anyhow!("config {name} missing {key}"))
            };
            let dims = req("dims")?;
            let caps = req("caps")?;
            let n: Vec<usize> = caps
                .get("n")
                .and_then(|n| n.as_arr())
                .ok_or_else(|| anyhow::anyhow!("caps.n missing"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            configs.push(ArtifactConfig {
                name: name.clone(),
                dataset: req("dataset")?.as_str().unwrap_or_default().to_string(),
                batch: req("batch")?.as_usize().unwrap_or(0),
                layers: dims.get("layers").and_then(|v| v.as_usize()).unwrap_or(3),
                d_in: dims.get("d_in").and_then(|v| v.as_usize()).unwrap_or(0),
                hidden: dims.get("hidden").and_then(|v| v.as_usize()).unwrap_or(0),
                classes: dims.get("classes").and_then(|v| v.as_usize()).unwrap_or(0),
                caps: ShapeCaps {
                    k: caps.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                    n,
                },
                lr: req("lr")?.as_f64().unwrap_or(1e-3) as f32,
                train_hlo: artifacts_dir.join(req("train_hlo")?.as_str().unwrap_or_default()),
                forward_hlo: artifacts_dir
                    .join(req("forward_hlo")?.as_str().unwrap_or_default()),
                num_train_inputs: req("num_train_inputs")?.as_usize().unwrap_or(0),
                num_forward_inputs: req("num_forward_inputs")?.as_usize().unwrap_or(0),
            });
        }
        Ok(Manifest { configs })
    }

    pub fn get(&self, name: &str) -> crate::Result<&ArtifactConfig> {
        self.configs.iter().find(|c| c.name == name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact config `{name}` not in manifest; have: {:?}",
                self.configs.iter().map(|c| &c.name).collect::<Vec<_>>()
            )
        })
    }

    /// Pick the config for (dataset, batch).
    pub fn for_dataset(&self, dataset: &str, batch: usize) -> crate::Result<&ArtifactConfig> {
        self.configs
            .iter()
            .find(|c| c.dataset == dataset && c.batch == batch)
            .ok_or_else(|| anyhow::anyhow!("no artifact for dataset {dataset} batch {batch}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_shapes_mirror_python_convention() {
        let cfg = ArtifactConfig {
            name: "t".into(),
            dataset: "tiny".into(),
            batch: 32,
            layers: 3,
            d_in: 16,
            hidden: 32,
            classes: 8,
            caps: ShapeCaps { k: 40, n: vec![32, 512, 2048, 2048] },
            lr: 0.01,
            train_hlo: PathBuf::new(),
            forward_hlo: PathBuf::new(),
            num_train_inputs: 35,
            num_forward_inputs: 19,
        };
        let shapes = cfg.param_shapes();
        assert_eq!(shapes.len(), 6);
        assert_eq!(shapes[0], ("w0".to_string(), vec![16, 32]));
        assert_eq!(shapes[4], ("w2".to_string(), vec![32, 8]));
        assert_eq!(shapes[5], ("b2".to_string(), vec![8]));
    }
}
