//! The multi-PE training plane: per-PE layered-model replicas over a
//! [`MinibatchStream`], kept in lockstep by a gradient all-reduce on the
//! fabric.
//!
//! This closes the loop the measurement engine leaves open: a
//! [`crate::pipeline::EngineStream`] produces one [`PeWork`] per PE —
//! per-layer counts, the dense pre-gathered input-feature buffer, *and*
//! the layered compute payload ([`crate::model::PeCompute`]: host
//! blocks + activation routes) — and [`ParallelTrainer::step`] turns
//! that into a synchronized optimizer step of the full multi-layer GNN:
//!
//! 1. every PE runs the layered gather→aggregate→matmul forward over
//!    **its own** blocks ([`PeStep`], the host backend's per-PE step
//!    engine). In cooperative mode the hidden activations of each
//!    level are exchanged over the fabric
//!    ([`PeEndpoint::all_to_all_rows`] / [`Exchange::route_rows`]):
//!    each PE computes every owned row exactly once and ships the rows
//!    its peers' aggregations reference — the paper's redundancy-free
//!    work division carried through the model compute, with the
//!    activation bytes accounted like the feature rows;
//! 2. the backward pass retraces the same routes adjointly (gradient
//!    rows return to the level's owners), accumulating real per-layer
//!    weight/bias gradients into one flat buffer that is all-reduced
//!    over the fabric ([`PeEndpoint::all_reduce_f32`], ring or naive —
//!    loss / correct / example counts ride in the same buffer);
//! 3. every PE applies the identical bias-corrected Adam update to its
//!    replicated [`ParamState`], so after any number of steps all
//!    replicas hold **bit-identical** parameters.
//!
//! [`ExecMode::Threaded`] runs steps 1–3 on one scoped OS thread per PE
//! (activation and gradient rounds run on a **trainer-private** fabric —
//! its own endpoints and counters, separate from the stream's sampling
//! fabric); [`ExecMode::Serial`] is the bit-identical reference (rows
//! route through [`Exchange::route_rows`], which accounts the same
//! bytes; every kernel and accumulation runs in the same deterministic
//! order). Both trajectories match exactly — tested below and in
//! `repro::end2end`.
//!
//! Forward-only consumers (holdout evaluation here, the serving plane in
//! [`crate::serve`]) take a [`Predictor`] snapshot via
//! [`ParallelTrainer::predictor`] instead of reaching into the
//! parameters.

use crate::coop::all_to_all::{
    split_send_rows, AllReduceStrategy, Exchange, Fabric, PeEndpoint, Topology,
};
use crate::coop::engine::ExecMode;
use crate::graph::VertexId;
use crate::model::host::PeStep;
use crate::obs::{ms_to_us, Span, StageHists, Trace, TraceSink};
use crate::model::{ModelDims, PeCompute, Predictor};
use crate::pipeline::stream::AbortOnPeerPanic;
use crate::pipeline::{EngineStream, Minibatch, MinibatchStream, PeWork};
use crate::runtime::tensors::ParamState;
use crate::util::stats::Timer;

/// Per-step statistics of one synchronized multi-PE step.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelStepStats {
    /// global mean cross-entropy (identical on every PE by construction).
    pub loss: f32,
    /// global batch accuracy.
    pub acc: f32,
    /// examples (seed vertices) across all PEs this step.
    pub examples: u64,
    /// whole-step wall-clock (all PEs, concurrent in threaded mode).
    // lint:allow(ledger, reason = "run() derives ms_per_step from its own end-to-end timer (stream production included), not from per-step walls")
    pub wall_ms: f64,
    /// local layered forward+backward time, summed across PEs.
    pub compute_ms: f64,
    /// all-reduce time on the critical path (max over PEs in threaded
    /// mode — per-PE elapsed includes barrier waits).
    pub allreduce_ms: f64,
    /// cross-PE gradient bytes this step (fabric-wide).
    pub grad_bytes: u64,
    /// cross-PE hidden-activation bytes this step (forward rows +
    /// backward gradient rows; cooperative mode only).
    pub act_bytes: u64,
    /// the slice of `grad_bytes` that crossed a replica-group boundary
    /// (equals `grad_bytes` on a flat fabric).
    pub grad_inter_bytes: u64,
    /// the slice of `act_bytes` that crossed a replica-group boundary
    /// (first-copy-per-group; equals `act_bytes` on a flat fabric).
    pub act_inter_bytes: u64,
}

/// Aggregates of a [`ParallelTrainer::run`] drive (per-step averages
/// except the losses).
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelRunReport {
    pub steps: usize,
    /// end-to-end ms per step (stream production + train step).
    pub ms_per_step: f64,
    /// stream-reported sampling ms per step (summed over PEs).
    pub sample_ms: f64,
    /// stream-reported feature-loading ms per step (summed over PEs).
    pub feature_ms: f64,
    /// seed vertices consumed per step (all PEs) — ties the byte
    /// ledgers back to work actually done.
    pub examples_per_step: f64,
    pub compute_ms: f64,
    pub allreduce_ms: f64,
    /// f32 bytes read from storage per step (β, all PEs).
    pub storage_bytes_per_step: f64,
    /// feature-row bytes over the fabric per step (α, all PEs).
    pub fabric_bytes_per_step: f64,
    /// gradient bytes over the fabric per step (all PEs).
    pub grad_bytes_per_step: f64,
    /// hidden-activation bytes over the fabric per step (all PEs,
    /// cooperative mode; 0 for independent).
    pub act_bytes_per_step: f64,
    /// inter-group slices of the fabric ledgers (feature rows /
    /// gradients / activations). On a flat fabric (replication 1) each
    /// equals its cross twin; under `--replication r` they shrink while
    /// the trajectory stays bit-identical.
    pub fabric_inter_bytes_per_step: f64,
    pub grad_inter_bytes_per_step: f64,
    pub act_inter_bytes_per_step: f64,
    /// name of the all-reduce algorithm the run used (the
    /// costmodel-picked choice when the caller resolved `auto`).
    pub collective: &'static str,
    pub first_loss: f32,
    pub last_loss: f32,
    pub last_acc: f32,
}

/// Per-block kernel timing accumulated across PEs and steps (block 0 =
/// output layer) — the `layered_train` bench section reads this off the
/// trainer after a run.
#[derive(Clone, Debug, Default)]
pub struct LayerProfile {
    /// gather/aggregate kernel ms per block (forward + backward).
    pub gather_ms: Vec<f64>,
    /// matmul kernel ms per block (forward + backward).
    pub matmul_ms: Vec<f64>,
}

/// `P` model replicas with lockstep parameters: each PE consumes its
/// own [`PeWork`] from a [`MinibatchStream`] batch, executes the
/// layered model over the work's [`PeCompute`] blocks, and the gradient
/// all-reduce keeps every replica's [`ParamState`] bit-identical. See
/// the module docs for the full contract.
pub struct ParallelTrainer {
    num_pes: usize,
    /// replica-group layout of the trainer-private fabric (flat unless
    /// built via [`ParallelTrainer::with_topology`]).
    topo: Topology,
    dims: ModelDims,
    lr: f32,
    exec: ExecMode,
    strategy: AllReduceStrategy,
    replicas: Vec<ParamState>,
    /// live fabric endpoints (threaded mode; `None` per slot in serial).
    endpoints: Vec<Option<PeEndpoint>>,
    /// serial-mode fabric for activation rows and gradients (accounts
    /// the same bytes the threaded endpoints would).
    serial_fabric: Exchange,
    profile: LayerProfile,
    steps: u64,
    /// flight recorder (Off by default — zero overhead; see
    /// [`ParallelTrainer::enable_trace`]).
    trace: Trace,
    /// per-stage step-time histograms accumulated across
    /// [`ParallelTrainer::run`] calls — the p50/p99 columns in
    /// `repro end2end` read these off the trainer after a run.
    hists: StageHists,
}

impl ParallelTrainer {
    /// Stand up `num_pes` bit-identical replicas of the layered model
    /// (`dims`, Glorot init from `seed`) and, in threaded mode, a
    /// connected fabric for activation and gradient rounds.
    pub fn new(
        num_pes: usize,
        dims: ModelDims,
        seed: u64,
        lr: f32,
        exec: ExecMode,
        strategy: AllReduceStrategy,
    ) -> ParallelTrainer {
        ParallelTrainer::with_topology(Topology::flat(num_pes), dims, seed, lr, exec, strategy)
    }

    /// Like [`ParallelTrainer::new`] but over a replica-grouped fabric:
    /// gradient all-reduces run hierarchically (intra-group chain,
    /// leader chain across groups, intra-group fan-out — bit-identical
    /// to the flat canonical sum) and the inter-group ledger slices
    /// shrink accordingly. `topo` fixes the PE count.
    pub fn with_topology(
        topo: Topology,
        dims: ModelDims,
        seed: u64,
        lr: f32,
        exec: ExecMode,
        strategy: AllReduceStrategy,
    ) -> ParallelTrainer {
        let num_pes = topo.num_pes;
        assert!(
            num_pes >= 1 && dims.layers >= 1 && dims.d_in >= 1 && dims.classes >= 2,
            "degenerate trainer shape"
        );
        assert!(dims.layers == 1 || dims.hidden >= 1, "hidden width must be >= 1");
        let replicas = (0..num_pes).map(|_| dims.init_state(seed ^ 0xFACE)).collect();
        let endpoints: Vec<Option<PeEndpoint>> = match exec {
            ExecMode::Threaded => {
                Fabric::endpoints_with(topo).into_iter().map(Some).collect()
            }
            ExecMode::Serial => (0..num_pes).map(|_| None).collect(),
        };
        ParallelTrainer {
            num_pes,
            topo,
            dims,
            lr,
            exec,
            strategy,
            replicas,
            endpoints,
            serial_fabric: Exchange::with_topology(topo),
            profile: LayerProfile {
                gather_ms: vec![0.0; dims.layers],
                matmul_ms: vec![0.0; dims.layers],
            },
            steps: 0,
            trace: Trace::Off,
            hists: StageHists::default(),
        }
    }

    /// Attach a flight recorder: subsequent [`ParallelTrainer::run`]
    /// steps emit per-PE sample/feature spans and coordinator-track
    /// compute / activation-exchange / gradient-all-reduce spans.
    /// Training counters stay bit-identical — spans are derived from
    /// the ledgers after each step, never consulted.
    pub fn enable_trace(&mut self) {
        self.trace = Trace::on("train");
    }

    /// The attached flight recorder ([`Trace::Off`] unless
    /// [`ParallelTrainer::enable_trace`] was called).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Per-stage step-time histograms accumulated across runs.
    pub fn stage_hists(&self) -> &StageHists {
        &self.hists
    }

    pub fn num_pes(&self) -> usize {
        self.num_pes
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The per-PE parameter replicas (bit-identical after every step —
    /// see [`ParallelTrainer::replicas_in_lockstep`]).
    pub fn replicas(&self) -> &[ParamState] {
        &self.replicas
    }

    /// True iff every replica's full optimizer state is bit-identical to
    /// replica 0's — the invariant the all-reduce maintains.
    pub fn replicas_in_lockstep(&self) -> bool {
        self.replicas.iter().all(|r| r.bits_eq(&self.replicas[0]))
    }

    /// Per-block kernel time accumulated so far (all PEs, all steps).
    pub fn layer_profile(&self) -> &LayerProfile {
        &self.profile
    }

    /// Total cross-PE gradient bytes so far (reduce + gather phases;
    /// summed over endpoints in threaded mode, from the serial fabric
    /// otherwise — exactly one of the two is nonzero).
    pub fn grad_bytes_total(&self) -> u64 {
        let threaded: u64 = self
            .endpoints
            .iter()
            .flatten()
            .map(|ep| ep.cross_grad_reduce_bytes + ep.cross_grad_gather_bytes)
            .sum();
        threaded
            + self.serial_fabric.cross_grad_reduce_bytes
            + self.serial_fabric.cross_grad_gather_bytes
    }

    /// The slice of [`ParallelTrainer::grad_bytes_total`] that crossed
    /// a replica-group boundary (equal to it on a flat fabric).
    pub fn grad_inter_bytes_total(&self) -> u64 {
        let threaded: u64 = self
            .endpoints
            .iter()
            .flatten()
            .map(|ep| ep.inter_grad_reduce_bytes + ep.inter_grad_gather_bytes)
            .sum();
        threaded
            + self.serial_fabric.inter_grad_reduce_bytes
            + self.serial_fabric.inter_grad_gather_bytes
    }

    /// Total cross-PE hidden-activation bytes so far (forward rows and
    /// backward gradient rows of the cooperative layered step; the
    /// trainer-private fabric carries no feature rows, so this counter
    /// is purely activation traffic).
    pub fn act_bytes_total(&self) -> u64 {
        let threaded: u64 =
            self.endpoints.iter().flatten().map(|ep| ep.cross_row_bytes).sum();
        threaded + self.serial_fabric.cross_row_bytes
    }

    /// The slice of [`ParallelTrainer::act_bytes_total`] that crossed a
    /// replica-group boundary, counted first-copy-per-remote-group (a
    /// row fanned out to several PEs of one remote group pays the slow
    /// link once; its backward gradient retraces the same route).
    pub fn act_inter_bytes_total(&self) -> u64 {
        let threaded: u64 =
            self.endpoints.iter().flatten().map(|ep| ep.inter_row_bytes).sum();
        threaded + self.serial_fabric.inter_row_bytes
    }

    /// A forward-only parameter snapshot of the lockstep model (replica
    /// 0 is representative of every PE).
    pub fn predictor(&self) -> Predictor {
        Predictor::new(self.dims, self.replicas[0].params.clone())
    }

    /// One synchronized step over a stream batch: layered forward with
    /// activation exchange, layered backward with the adjoint exchange,
    /// one all-reduce, one Adam update per replica. `labels` is the
    /// dataset's full label vector.
    pub fn step(&mut self, mb: &Minibatch, labels: &[u16]) -> ParallelStepStats {
        assert_eq!(
            mb.per_pe.len(),
            self.num_pes,
            "stream PE count must match the trainer (got a {}-PE batch)",
            mb.per_pe.len()
        );
        let coop = batch_is_cooperative(&mb.per_pe);
        let grad_before = self.grad_bytes_total();
        let act_before = self.act_bytes_total();
        let grad_inter_before = self.grad_inter_bytes_total();
        let act_inter_before = self.act_inter_bytes_total();
        let wall = Timer::start();
        let (dims, lr, strategy) = (self.dims, self.lr, self.strategy);
        let gl = dims.num_scalars();
        let (mut compute_ms, mut allreduce_ms) = (0f64, 0f64);
        // every PE ends the all-reduce holding the identical flat buffer
        // ([grads | loss_sum | correct | n]); keep PE 0's for reporting
        let reduced: Vec<f32> = match self.exec {
            ExecMode::Serial => {
                let t = Timer::start();
                let mut bufs =
                    serial_minibatch_grads(dims, coop, &self.replicas, &mut self.serial_fabric, &mb.per_pe, labels, &mut self.profile);
                compute_ms = t.elapsed_ms();
                let t = Timer::start();
                self.serial_fabric.all_reduce_f32(&mut bufs, strategy);
                allreduce_ms = t.elapsed_ms();
                apply_reduced(&mut self.replicas, &bufs[0], gl, lr);
                bufs.swap_remove(0)
            }
            ExecMode::Threaded => {
                if coop {
                    // a cooperative batch has every PE in every fabric
                    // round; a missing payload would deadlock its peers
                    for (p, w) in mb.per_pe.iter().enumerate() {
                        assert!(
                            w.compute.is_some() && w.features.is_some(),
                            "cooperative batch PE {p} lacks compute payload"
                        );
                    }
                }
                type PeResult = (Vec<f32>, f64, f64, Vec<f64>, Vec<f64>);
                let results: Vec<PeResult> = std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .replicas
                        .iter_mut()
                        .zip(self.endpoints.iter_mut())
                        .zip(mb.per_pe.iter())
                        .map(|((state, ep), work)| {
                            scope.spawn(move || {
                                let _abort_guard = AbortOnPeerPanic;
                                let ep = ep.as_mut().expect("threaded trainer has endpoints");
                                let t = Timer::start();
                                let mut buf = vec![0f32; gl + 3];
                                let (gms, mms) =
                                    pe_local_grads(dims, coop, state, Some(ep), work, labels, &mut buf);
                                let compute = t.elapsed_ms();
                                let t = Timer::start();
                                ep.all_reduce_f32(&mut buf, strategy);
                                let reduce = t.elapsed_ms();
                                apply_reduced(std::slice::from_mut(state), &buf, gl, lr);
                                (buf, compute, reduce, gms, mms)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("PE trainer thread panicked"))
                        .collect()
                });
                for (_, c, r, gms, mms) in &results {
                    compute_ms += c;
                    allreduce_ms = allreduce_ms.max(*r);
                    for (acc, v) in self.profile.gather_ms.iter_mut().zip(gms) {
                        *acc += v;
                    }
                    for (acc, v) in self.profile.matmul_ms.iter_mut().zip(mms) {
                        *acc += v;
                    }
                }
                results.into_iter().next().unwrap().0
            }
        };
        self.steps += 1;
        let n = reduced[gl + 2];
        let denom = n.max(1.0);
        ParallelStepStats {
            loss: reduced[gl] / denom,
            acc: reduced[gl + 1] / denom,
            examples: n as u64,
            wall_ms: wall.elapsed_ms(),
            compute_ms,
            allreduce_ms,
            grad_bytes: self.grad_bytes_total() - grad_before,
            act_bytes: self.act_bytes_total() - act_before,
            grad_inter_bytes: self.grad_inter_bytes_total() - grad_inter_before,
            act_inter_bytes: self.act_inter_bytes_total() - act_inter_before,
        }
    }

    /// Drive `steps` synchronized steps off `stream` (any
    /// [`MinibatchStream`] whose PE count matches — including a
    /// prefetch-wrapped one), then [`MinibatchStream::finish`] it so a
    /// background producer stops without computing tail batches.
    pub fn run(
        &mut self,
        stream: &mut dyn MinibatchStream,
        steps: usize,
        labels: &[u16],
    ) -> ParallelRunReport {
        let mut rep = ParallelRunReport {
            steps,
            collective: self.strategy.name(),
            ..Default::default()
        };
        let run = Timer::start();
        let mut cursor = vec![0u64; self.num_pes];
        for step in 0..steps {
            let mb = stream.next_batch();
            let samp: f64 = mb.per_pe.iter().map(|w| w.samp_ms).sum();
            let feat: f64 = mb.per_pe.iter().map(|w| w.feat_ms).sum();
            rep.sample_ms += samp;
            rep.feature_ms += feat;
            rep.storage_bytes_per_step +=
                mb.per_pe.iter().map(|w| w.bytes_from_storage).sum::<u64>() as f64;
            rep.fabric_bytes_per_step +=
                mb.per_pe.iter().map(|w| w.fabric_bytes).sum::<u64>() as f64;
            rep.fabric_inter_bytes_per_step +=
                mb.per_pe.iter().map(|w| w.fabric_inter_bytes).sum::<u64>() as f64;
            self.hists.sample_ms.record(samp);
            self.hists.feature_ms.record(feat);
            if self.trace.enabled() {
                // Per-PE sample + feature-window spans — same derivation
                // the engine uses, from the same PeWork ledgers.
                crate::coop::engine::emit_batch_spans(
                    &mut self.trace,
                    step as u64,
                    &mb.per_pe,
                    &mut cursor,
                );
            }
            let s = self.step(&mb, labels);
            self.hists.compute_ms.record(s.compute_ms);
            self.hists.allreduce_ms.record(s.allreduce_ms);
            if self.trace.enabled() {
                // Coordinator track (tid = num_pes): the synchronized
                // compute / activation-exchange / gradient-all-reduce
                // phases, with fabric bytes attributed.
                let base = cursor.iter().copied().max().unwrap_or(0);
                let coord = self.num_pes as u32;
                let compute_us = ms_to_us(s.compute_ms);
                let ar_us = ms_to_us(s.allreduce_ms);
                let mk = |seq, stage, t0, t1, bytes| Span {
                    batch: step as u64,
                    pe: coord,
                    seq,
                    stage,
                    t_start_us: t0,
                    t_end_us: t1,
                    bytes,
                };
                self.trace
                    .record(mk(0, "compute", base, base + compute_us, 0));
                self.trace.record(mk(
                    1,
                    "act_exchange",
                    base + compute_us,
                    base + compute_us,
                    s.act_bytes,
                ));
                self.trace.record(mk(
                    2,
                    "grad_allreduce",
                    base + compute_us,
                    base + compute_us + ar_us,
                    s.grad_bytes,
                ));
                // Lockstep barrier: every PE's next step starts after
                // the all-reduce completes.
                for c in cursor.iter_mut() {
                    *c = base + compute_us + ar_us;
                }
            }
            rep.examples_per_step += s.examples as f64;
            rep.compute_ms += s.compute_ms;
            rep.allreduce_ms += s.allreduce_ms;
            rep.grad_bytes_per_step += s.grad_bytes as f64;
            rep.act_bytes_per_step += s.act_bytes as f64;
            rep.grad_inter_bytes_per_step += s.grad_inter_bytes as f64;
            rep.act_inter_bytes_per_step += s.act_inter_bytes as f64;
            if step == 0 {
                rep.first_loss = s.loss;
            }
            rep.last_loss = s.loss;
            rep.last_acc = s.acc;
        }
        stream.finish();
        let m = steps.max(1) as f64;
        rep.ms_per_step = run.elapsed_ms() / m;
        rep.sample_ms /= m;
        rep.feature_ms /= m;
        rep.examples_per_step /= m;
        rep.compute_ms /= m;
        rep.allreduce_ms /= m;
        rep.storage_bytes_per_step /= m;
        rep.fabric_bytes_per_step /= m;
        rep.grad_bytes_per_step /= m;
        rep.act_bytes_per_step /= m;
        rep.fabric_inter_bytes_per_step /= m;
        rep.grad_inter_bytes_per_step /= m;
        rep.act_inter_bytes_per_step /= m;
        rep
    }

    /// Holdout accuracy of the (lockstep) layered model over `vs`:
    /// seeds are assigned to PEs by the stream's policy
    /// ([`EngineStream::assign_seeds`]), sampled + gathered through
    /// [`EngineStream::batch_for_seeds`], and predicted through a
    /// [`Predictor`] — the exact compute path the serving plane runs.
    /// Advances the stream's sampler/cache state (evaluation batches
    /// are real batches), so run it after — not between — training
    /// phases, or on a dedicated stream.
    pub fn evaluate(
        &self,
        stream: &mut EngineStream<'_>,
        vs: &[VertexId],
        labels: &[u16],
    ) -> f64 {
        let pred = self.predictor();
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in vs.chunks(1024) {
            let mb = stream.batch_for_seeds(stream.assign_seeds(chunk));
            let pes: Vec<(&PeCompute, &[f32])> = mb
                .per_pe
                .iter()
                .map(|w| {
                    (
                        w.compute.as_ref().expect("engine batches carry compute"),
                        w.features.as_deref().expect("engine batches carry features"),
                    )
                })
                .collect();
            for (pe, preds) in pred.predict_minibatch(&pes).into_iter().enumerate() {
                let seeds = &pes[pe].0.seeds;
                for (&v, &p) in seeds.iter().zip(&preds) {
                    total += 1;
                    if p == labels[v as usize] {
                        correct += 1;
                    }
                }
            }
        }
        correct as f64 / total.max(1) as f64
    }
}

/// A batch is cooperative iff its work records carry activation routes.
/// Mixing cooperative and independent payloads in one batch is a stream
/// bug (the fabric rounds would desynchronize).
fn batch_is_cooperative(per_pe: &[PeWork]) -> bool {
    let coop = per_pe
        .iter()
        .any(|w| w.compute.as_ref().is_some_and(|c| c.routes.is_some()));
    assert!(
        !coop
            || per_pe
                .iter()
                .all(|w| w.compute.as_ref().is_some_and(|c| c.routes.is_some())),
        "mixed cooperative/independent payloads in one batch"
    );
    coop
}

/// One PE's layered forward/backward in threaded mode (straight-line;
/// fabric rounds through the PE's own endpoint when `coop`). Fills
/// `buf = [grads | loss_sum | correct | n]` (zeros when the record has
/// no payload — measurement-only streams) and returns the per-block
/// (gather_ms, matmul_ms) kernel profile.
fn pe_local_grads(
    dims: ModelDims,
    coop: bool,
    state: &ParamState,
    mut ep: Option<&mut PeEndpoint>,
    work: &PeWork,
    labels: &[u16],
    buf: &mut [f32],
) -> (Vec<f64>, Vec<f64>) {
    let gl = dims.num_scalars();
    let (Some(comp), Some(feats)) = (&work.compute, work.features.as_deref()) else {
        debug_assert!(!coop, "cooperative PEs always carry a payload");
        return (vec![0.0; dims.layers], vec![0.0; dims.layers]);
    };
    let mut step = PeStep::new(dims, comp, feats, &state.params);
    step.forward_deepest();
    for l in (0..dims.layers - 1).rev() {
        if coop {
            let buckets = step.send_rows(l);
            let ep = ep.as_mut().expect("cooperative rounds need a fabric endpoint");
            // classify this level's outgoing activation rows: a row
            // fanned out to several PEs of one remote group pays the
            // slow link once, and its backward gradient row retraces
            // the same route — hence the x2
            let routes = comp.routes.as_ref().expect("cooperative routes");
            let per_dst: Vec<&[u32]> =
                routes.send_pos[l].iter().map(|v| v.as_slice()).collect();
            let inter = split_send_rows(&ep.topo, ep.pe, &per_dst);
            ep.note_inter_rows(inter * 2, inter * 2 * dims.hidden as u64 * 4);
            let inbox = ep.all_to_all_rows(buckets, dims.hidden);
            step.forward_level(l, Some(inbox));
        } else {
            step.forward_level(l, None);
        }
    }
    let (loss_sum, correct, n) = step.loss_grad(labels);
    buf[gl] = loss_sum;
    buf[gl + 1] = correct;
    buf[gl + 2] = n;
    for l in 0..dims.layers {
        let out = step.backward_level(l, &mut buf[..gl]);
        if coop && l < dims.layers - 1 {
            let buckets = out.expect("cooperative backward emits gradient buckets");
            let inbox = ep
                .as_mut()
                .expect("cooperative rounds need a fabric endpoint")
                .all_to_all_rows(buckets, dims.hidden);
            step.absorb_grad_inbox(l, inbox);
        }
    }
    (step.gather_ms.clone(), step.matmul_ms.clone())
}

/// Serial reference: all PEs' layered steps inline, with the fabric
/// rounds interleaved level-synchronously through the serial exchange —
/// identical kernel and accumulation order to the threaded path.
fn serial_minibatch_grads(
    dims: ModelDims,
    coop: bool,
    replicas: &[ParamState],
    fabric: &mut Exchange,
    per_pe: &[PeWork],
    labels: &[u16],
    profile: &mut LayerProfile,
) -> Vec<Vec<f32>> {
    let p_count = replicas.len();
    let gl = dims.num_scalars();
    let mut bufs: Vec<Vec<f32>> = vec![vec![0f32; gl + 3]; p_count];
    let mut steps: Vec<Option<PeStep>> = replicas
        .iter()
        .zip(per_pe)
        .map(|(state, work)| match (&work.compute, work.features.as_deref()) {
            (Some(comp), Some(feats)) => Some(PeStep::new(dims, comp, feats, &state.params)),
            _ => {
                assert!(!coop, "cooperative batches always carry a payload");
                None
            }
        })
        .collect();
    for s in steps.iter_mut().flatten() {
        s.forward_deepest();
    }
    for l in (0..dims.layers - 1).rev() {
        if coop {
            // same per-PE inter classification as the threaded path
            // (forward row + backward gradient row per first copy)
            let topo = fabric.topo;
            for (me, work) in per_pe.iter().enumerate() {
                let routes = work
                    .compute
                    .as_ref()
                    .and_then(|c| c.routes.as_ref())
                    .expect("coop payload");
                let per_dst: Vec<&[u32]> =
                    routes.send_pos[l].iter().map(|v| v.as_slice()).collect();
                let inter = split_send_rows(&topo, me, &per_dst);
                fabric.note_inter_rows(inter * 2, inter * 2 * dims.hidden as u64 * 4);
            }
            let buckets: Vec<Vec<Vec<f32>>> = steps
                .iter()
                .map(|s| s.as_ref().expect("coop payload").send_rows(l))
                .collect();
            let inboxes = fabric.route_rows(buckets, dims.hidden);
            for (s, inbox) in steps.iter_mut().zip(inboxes) {
                s.as_mut().expect("coop payload").forward_level(l, Some(inbox));
            }
        } else {
            for s in steps.iter_mut().flatten() {
                s.forward_level(l, None);
            }
        }
    }
    for (s, buf) in steps.iter_mut().zip(bufs.iter_mut()) {
        if let Some(s) = s {
            let (loss_sum, correct, n) = s.loss_grad(labels);
            buf[gl] = loss_sum;
            buf[gl + 1] = correct;
            buf[gl + 2] = n;
        }
    }
    for l in 0..dims.layers {
        let mut round: Vec<Vec<Vec<f32>>> = Vec::new();
        for (s, buf) in steps.iter_mut().zip(bufs.iter_mut()) {
            let out = match s {
                Some(s) => s.backward_level(l, &mut buf[..gl]),
                None => None,
            };
            if coop && l < dims.layers - 1 {
                round.push(out.expect("cooperative backward emits gradient buckets"));
            }
        }
        if coop && l < dims.layers - 1 {
            let inboxes = fabric.route_rows(round, dims.hidden);
            for (s, inbox) in steps.iter_mut().zip(inboxes) {
                s.as_mut().expect("coop payload").absorb_grad_inbox(l, inbox);
            }
        }
    }
    for s in steps.iter().flatten() {
        for (acc, v) in profile.gather_ms.iter_mut().zip(&s.gather_ms) {
            *acc += v;
        }
        for (acc, v) in profile.matmul_ms.iter_mut().zip(&s.matmul_ms) {
            *acc += v;
        }
    }
    bufs
}

/// Scale the reduced gradient by the global example count and apply the
/// Adam update to each given replica — the identical arithmetic on every
/// PE, so lockstep is preserved bit-for-bit. Skips the update when the
/// batch carried no examples.
fn apply_reduced(replicas: &mut [ParamState], reduced: &[f32], gl: usize, lr: f32) {
    let n = reduced[gl + 2];
    if n <= 0.0 {
        return;
    }
    let inv = 1.0 / n;
    let grads: Vec<f32> = reduced[..gl].iter().map(|&g| g * inv).collect();
    for state in replicas {
        state.adam_step(&grads, lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::{EngineConfig, Mode};
    use crate::graph::{datasets, partition};
    use crate::pipeline::EngineStream;

    fn cfg(mode: Mode, exec: ExecMode, pes: usize) -> EngineConfig {
        EngineConfig {
            mode,
            exec,
            num_pes: pes,
            batch_per_pe: 24,
            cache_per_pe: 200,
            warmup_batches: 0,
            measure_batches: 4,
            seed: 11,
            ..Default::default()
        }
    }

    fn dims_for(ds: &datasets::Dataset, layers: usize) -> ModelDims {
        ModelDims { layers, d_in: ds.feat_dim, hidden: 8, classes: ds.num_classes }
    }

    fn trajectory(
        mode: Mode,
        exec: ExecMode,
        pes: usize,
        strategy: AllReduceStrategy,
        steps: usize,
    ) -> ParallelTrainer {
        let ds = datasets::build("tiny", 5).unwrap();
        let part = partition::random(&ds.graph, pes, 3);
        let c = cfg(mode, exec, pes);
        let mut stream = EngineStream::new(&ds, &part, &c);
        let mut pt = ParallelTrainer::new(
            pes,
            dims_for(&ds, c.sampler.layers),
            41,
            0.05,
            exec,
            strategy,
        );
        for _ in 0..steps {
            let mb = stream.next_batch();
            let s = pt.step(&mb, &ds.labels);
            assert!(s.loss.is_finite(), "loss must stay finite");
            assert!(s.examples > 0);
        }
        pt
    }

    /// The tentpole's correctness property: after K steps every PE holds
    /// bit-identical parameters of the full layered model, in both
    /// modes, both exec modes, both all-reduce strategies.
    #[test]
    fn replicas_stay_in_lockstep_after_k_steps() {
        for mode in [Mode::Independent, Mode::Cooperative] {
            for exec in [ExecMode::Serial, ExecMode::Threaded] {
                for strategy in [AllReduceStrategy::Ring, AllReduceStrategy::Naive] {
                    let pt = trajectory(mode, exec, 3, strategy, 4);
                    assert!(
                        pt.replicas_in_lockstep(),
                        "{mode:?}/{exec:?}/{strategy:?}: replicas diverged"
                    );
                    assert_eq!(pt.replicas()[0].step, 4.0);
                }
            }
        }
    }

    /// Serial and threaded trajectories are bit-identical — the
    /// cooperative path exchanges hidden activations both ways, so this
    /// pins the whole layered forward/backward order — and so are ring
    /// vs naive (both reduce in the canonical order).
    #[test]
    fn serial_threaded_and_both_strategies_bit_identical() {
        for mode in [Mode::Independent, Mode::Cooperative] {
            let serial = trajectory(mode, ExecMode::Serial, 2, AllReduceStrategy::Ring, 5);
            let threaded = trajectory(mode, ExecMode::Threaded, 2, AllReduceStrategy::Ring, 5);
            let naive = trajectory(mode, ExecMode::Threaded, 2, AllReduceStrategy::Naive, 5);
            assert!(
                serial.replicas()[0].bits_eq(&threaded.replicas()[0]),
                "{mode:?}: serial vs threaded trajectories diverged"
            );
            assert!(
                threaded.replicas()[0].bits_eq(&naive.replicas()[0]),
                "{mode:?}: ring vs naive trajectories diverged"
            );
        }
    }

    /// Gradient traffic is really accounted: multi-PE steps move bytes,
    /// single-PE steps move none, and serial reports the same totals as
    /// threaded.
    #[test]
    fn grad_byte_accounting_matches_across_exec_modes() {
        let a = trajectory(Mode::Independent, ExecMode::Serial, 3, AllReduceStrategy::Ring, 3);
        let b = trajectory(Mode::Independent, ExecMode::Threaded, 3, AllReduceStrategy::Ring, 3);
        assert!(a.grad_bytes_total() > 0);
        assert_eq!(a.grad_bytes_total(), b.grad_bytes_total());
        let single =
            trajectory(Mode::Independent, ExecMode::Threaded, 1, AllReduceStrategy::Ring, 2);
        assert_eq!(single.grad_bytes_total(), 0, "1 PE has no cross traffic");
    }

    /// The cooperative layered step moves hidden-activation rows over
    /// the fabric (and accounts them identically in both exec modes);
    /// the independent step moves none. The per-block kernel profile
    /// fills in either way.
    #[test]
    fn activation_byte_accounting_is_cooperative_only() {
        let cs = trajectory(Mode::Cooperative, ExecMode::Serial, 3, AllReduceStrategy::Ring, 3);
        let ct = trajectory(Mode::Cooperative, ExecMode::Threaded, 3, AllReduceStrategy::Ring, 3);
        assert!(cs.act_bytes_total() > 0, "coop layered steps must ship activations");
        assert_eq!(cs.act_bytes_total(), ct.act_bytes_total());
        let indep =
            trajectory(Mode::Independent, ExecMode::Threaded, 3, AllReduceStrategy::Ring, 3);
        assert_eq!(indep.act_bytes_total(), 0, "independent mode replicates instead");
        assert_eq!(cs.layer_profile().gather_ms.len(), cs.dims().layers);
        assert_eq!(ct.layer_profile().matmul_ms.len(), ct.dims().layers);
    }

    /// The model actually learns: driving the full run loop on tiny
    /// lowers the loss and beats chance accuracy on the validation
    /// split, evaluated through the same stream + Predictor path the
    /// serving plane uses.
    #[test]
    fn run_reduces_loss_and_beats_chance() {
        let ds = datasets::build("tiny", 5).unwrap();
        let pes = 2;
        let part = partition::random(&ds.graph, pes, 3);
        let mut c = cfg(Mode::Cooperative, ExecMode::Threaded, pes);
        c.measure_batches = 60;
        let mut stream = EngineStream::new(&ds, &part, &c);
        let mut pt = ParallelTrainer::new(
            pes,
            dims_for(&ds, c.sampler.layers),
            41,
            0.05,
            ExecMode::Threaded,
            AllReduceStrategy::Ring,
        );
        let rep = pt.run(&mut stream, 60, &ds.labels);
        assert!(
            rep.last_loss < rep.first_loss,
            "loss must drop: {} -> {}",
            rep.first_loss,
            rep.last_loss
        );
        assert!(rep.act_bytes_per_step > 0.0, "coop run ships activations");
        let acc = pt.evaluate(&mut stream, &ds.val, &ds.labels);
        let chance = 1.0 / ds.num_classes as f64;
        assert!(acc > chance * 1.2, "val acc {acc:.3} vs chance {chance:.3}");
        assert!(rep.ms_per_step > 0.0 && rep.storage_bytes_per_step > 0.0);
    }
}
