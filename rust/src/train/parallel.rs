//! The multi-PE training plane: per-PE trainer replicas over a
//! [`MinibatchStream`], kept in lockstep by a gradient all-reduce on the
//! fabric.
//!
//! This closes the loop the measurement engine leaves open: a
//! [`crate::pipeline::EngineStream`] produces one [`PeWork`] per PE —
//! per-layer counts *and* the dense pre-gathered input-feature buffer —
//! and [`ParallelTrainer::step`] turns that into a synchronized
//! optimizer step:
//!
//! 1. every PE builds its batch tensors from **its own** `PeWork`
//!    (`features` × `feature_vertices`, labels looked up per vertex) and
//!    computes a local gradient;
//! 2. the gradients (plus loss / correct / example counts, carried in
//!    the same flat buffer) are all-reduced over the fabric
//!    ([`PeEndpoint::all_reduce_f32`], ring or naive strategy — bytes
//!    accounted alongside the id/row traffic);
//! 3. every PE applies the identical bias-corrected Adam update to its
//!    replicated [`ParamState`], so after any number of steps all
//!    replicas hold **bit-identical** parameters.
//!
//! [`ExecMode::Threaded`] runs step 1–3 on one scoped OS thread per PE
//! (the gradient rounds run on a **trainer-private** fabric — its own
//! endpoints and counters, separate from the stream's sampling fabric —
//! with the same barrier-per-round discipline, so gradient bytes are
//! read off the trainer, not the stream); [`ExecMode::Serial`] is the
//! bit-identical reference
//! (the all-reduce collapses to [`Exchange::all_reduce_f32`], which
//! accounts the same bytes). Both trajectories match exactly — tested
//! below and in `repro::end2end`.
//!
//! ## The per-PE model while PJRT is stubbed
//!
//! The compute half of each replica is a softmax-regression head over
//! the PE's gathered input rows (`d → C`, bias, mean cross-entropy over
//! the buffer's vertices — every synthetic-dataset vertex is labeled).
//! It is the heaviest data-plane-faithful compute available in this
//! build: the full feature payload is read, the gradient has the real
//! `d·C` shape, and the plane (stream → per-PE tensors → all-reduce →
//! lockstep Adam) is exactly what the AOT train step plugs into once the
//! PJRT client is restored (`runtime::client`) — swap the local-gradient
//! closure for an executable invocation and nothing else moves.

use crate::coop::all_to_all::{AllReduceStrategy, Exchange, Fabric, PeEndpoint};
use crate::coop::engine::ExecMode;
use crate::feature::FeatureStore;
use crate::graph::VertexId;
use crate::pipeline::stream::AbortOnPeerPanic;
use crate::pipeline::{Minibatch, MinibatchStream, PeWork};
use crate::runtime::tensors::ParamState;
use crate::util::stats::Timer;

/// Per-step statistics of one synchronized multi-PE step.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelStepStats {
    /// global mean cross-entropy (identical on every PE by construction).
    pub loss: f32,
    /// global batch accuracy.
    pub acc: f32,
    /// examples (gathered vertices) across all PEs this step.
    pub examples: u64,
    /// whole-step wall-clock (all PEs, concurrent in threaded mode).
    pub wall_ms: f64,
    /// local forward+backward time, summed across PEs.
    pub compute_ms: f64,
    /// all-reduce time on the critical path (max over PEs in threaded
    /// mode — per-PE elapsed includes barrier waits).
    pub allreduce_ms: f64,
    /// cross-PE gradient bytes this step (fabric-wide).
    pub grad_bytes: u64,
}

/// Aggregates of a [`ParallelTrainer::run`] drive (per-step averages
/// except the losses).
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelRunReport {
    pub steps: usize,
    /// end-to-end ms per step (stream production + train step).
    pub ms_per_step: f64,
    /// stream-reported sampling ms per step (summed over PEs).
    pub sample_ms: f64,
    /// stream-reported feature-loading ms per step (summed over PEs).
    pub feature_ms: f64,
    pub compute_ms: f64,
    pub allreduce_ms: f64,
    /// f32 bytes read from storage per step (β, all PEs).
    pub storage_bytes_per_step: f64,
    /// feature-row bytes over the fabric per step (α, all PEs).
    pub fabric_bytes_per_step: f64,
    /// gradient bytes over the fabric per step (all PEs).
    pub grad_bytes_per_step: f64,
    pub first_loss: f32,
    pub last_loss: f32,
    pub last_acc: f32,
}

/// Flat gradient layout: `[dW (d·C) | db (C) | loss_sum | correct | n]`.
/// Carrying the scalar statistics inside the reduced buffer means one
/// all-reduce per step synchronizes gradients *and* reporting.
fn flat_len(dim: usize, classes: usize) -> usize {
    dim * classes + classes + 3
}

/// The model's forward pass for one row: `logits = b + x·W` (W row-major
/// `[dim × classes]`). One implementation shared by training,
/// evaluation, *and* the serving plane's prediction path
/// ([`crate::serve::executor`]) so the three can never drift numerically
/// (f32 summation order included).
pub(crate) fn forward_logits(w: &[f32], b: &[f32], x: &[f32], logits: &mut [f32]) {
    let classes = b.len();
    logits.copy_from_slice(b);
    for (j, &xj) in x.iter().enumerate() {
        let wrow = &w[j * classes..(j + 1) * classes];
        for (c, &wjc) in wrow.iter().enumerate() {
            logits[c] += xj * wjc;
        }
    }
}

/// First-maximum scan — the one tie-break rule (lowest class wins) for
/// training accuracy and evaluation alike. NaN-safe: `>` is false for
/// NaN, so a diverged model degrades to predicting class 0 instead of
/// panicking.
pub(crate) fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (c, &l) in logits.iter().enumerate().skip(1) {
        if l > logits[best] {
            best = c;
        }
    }
    best
}

/// One PE's local forward + backward over its gathered rows: softmax
/// regression `logits = x·W + b`, summed (not averaged) cross-entropy
/// gradient — the global mean is taken after the all-reduce, where the
/// global example count is known. Deterministic f32, shared by both exec
/// modes so trajectories cannot drift.
fn local_grads(
    state: &ParamState,
    work: &PeWork,
    labels: &[u16],
    dim: usize,
    classes: usize,
) -> Vec<f32> {
    let mut flat = vec![0f32; flat_len(dim, classes)];
    let (Some(features), Some(vs)) = (work.features.as_deref(), work.feature_vertices.as_deref())
    else {
        return flat; // measurement-only work record: zero contribution
    };
    debug_assert_eq!(features.len(), vs.len() * dim, "feature buffer shape");
    let w = &state.params[0]; // [dim × classes], row-major
    let b = &state.params[1]; // [classes]
    let (dw, rest) = flat.split_at_mut(dim * classes);
    let (db, stats) = rest.split_at_mut(classes);
    let mut logits = vec![0f32; classes];
    let mut loss_sum = 0f32;
    let mut correct = 0f32;
    for (i, &v) in vs.iter().enumerate() {
        let x = &features[i * dim..(i + 1) * dim];
        forward_logits(w, b, x, &mut logits);
        let y = labels[v as usize] as usize;
        debug_assert!(y < classes, "label {y} out of range for {classes} classes");
        // stable softmax cross-entropy
        let pred = argmax(&logits);
        let max = logits[pred];
        let mut denom = 0f32;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            denom += *l;
        }
        // -ln p_y = ln(Σ exp) - (l_y - max); logits now hold the exps,
        // so l_y - max = ln(exp_y) (clamped against underflow to -inf)
        loss_sum += denom.ln() - logits[y].max(f32::MIN_POSITIVE).ln();
        if pred == y {
            correct += 1.0;
        }
        for (c, &l) in logits.iter().enumerate() {
            let g = l / denom - if c == y { 1.0 } else { 0.0 };
            db[c] += g;
            for (j, &xj) in x.iter().enumerate() {
                dw[j * classes + c] += xj * g;
            }
        }
    }
    stats[0] = loss_sum;
    stats[1] = correct;
    stats[2] = vs.len() as f32;
    flat
}

/// `P` trainer replicas with lockstep parameters: each PE consumes its
/// own [`PeWork`] from a [`MinibatchStream`] batch and the gradient
/// all-reduce keeps every replica's [`ParamState`] bit-identical. See
/// the module docs for the full contract.
pub struct ParallelTrainer {
    num_pes: usize,
    dim: usize,
    classes: usize,
    lr: f32,
    exec: ExecMode,
    strategy: AllReduceStrategy,
    replicas: Vec<ParamState>,
    /// live fabric endpoints (threaded mode; `None` per slot in serial).
    endpoints: Vec<Option<PeEndpoint>>,
    /// serial-mode gradient fabric (accounts the same bytes the threaded
    /// endpoints would).
    serial_fabric: Exchange,
    steps: u64,
}

impl ParallelTrainer {
    /// Stand up `num_pes` bit-identical replicas (`d_in → classes` head,
    /// Glorot init from `seed`) and, in threaded mode, a connected
    /// gradient fabric.
    pub fn new(
        num_pes: usize,
        d_in: usize,
        classes: usize,
        seed: u64,
        lr: f32,
        exec: ExecMode,
        strategy: AllReduceStrategy,
    ) -> ParallelTrainer {
        assert!(num_pes >= 1 && d_in >= 1 && classes >= 2, "degenerate trainer shape");
        let shapes = vec![vec![d_in, classes], vec![classes]];
        let replicas =
            (0..num_pes).map(|_| ParamState::with_shapes(shapes.clone(), seed ^ 0xFACE)).collect();
        let endpoints: Vec<Option<PeEndpoint>> = match exec {
            ExecMode::Threaded => Fabric::endpoints(num_pes).into_iter().map(Some).collect(),
            ExecMode::Serial => (0..num_pes).map(|_| None).collect(),
        };
        ParallelTrainer {
            num_pes,
            dim: d_in,
            classes,
            lr,
            exec,
            strategy,
            replicas,
            endpoints,
            serial_fabric: Exchange::new(num_pes),
            steps: 0,
        }
    }

    pub fn num_pes(&self) -> usize {
        self.num_pes
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The per-PE parameter replicas (bit-identical after every step —
    /// see [`ParallelTrainer::replicas_in_lockstep`]).
    pub fn replicas(&self) -> &[ParamState] {
        &self.replicas
    }

    /// True iff every replica's full optimizer state is bit-identical to
    /// replica 0's — the invariant the all-reduce maintains.
    pub fn replicas_in_lockstep(&self) -> bool {
        self.replicas.iter().all(|r| r.bits_eq(&self.replicas[0]))
    }

    /// Total cross-PE gradient bytes so far (reduce + gather phases;
    /// summed over endpoints in threaded mode, from the serial fabric
    /// otherwise — exactly one of the two is nonzero).
    pub fn grad_bytes_total(&self) -> u64 {
        let threaded: u64 = self
            .endpoints
            .iter()
            .flatten()
            .map(|ep| ep.cross_grad_reduce_bytes + ep.cross_grad_gather_bytes)
            .sum();
        threaded
            + self.serial_fabric.cross_grad_reduce_bytes
            + self.serial_fabric.cross_grad_gather_bytes
    }

    /// One synchronized step over a stream batch: local gradients from
    /// each PE's work record, one all-reduce, one Adam update per
    /// replica. `labels` is the dataset's full label vector.
    pub fn step(&mut self, mb: &Minibatch, labels: &[u16]) -> ParallelStepStats {
        assert_eq!(
            mb.per_pe.len(),
            self.num_pes,
            "stream PE count must match the trainer (got a {}-PE batch)",
            mb.per_pe.len()
        );
        let bytes_before = self.grad_bytes_total();
        let wall = Timer::start();
        let (dim, classes, lr, strategy) = (self.dim, self.classes, self.lr, self.strategy);
        let gl = dim * classes + classes;
        let (mut compute_ms, mut allreduce_ms) = (0f64, 0f64);
        // every PE ends the all-reduce holding the identical flat buffer;
        // keep PE 0's for reporting
        let reduced: Vec<f32> = match self.exec {
            ExecMode::Serial => {
                let t = Timer::start();
                let mut bufs: Vec<Vec<f32>> = self
                    .replicas
                    .iter()
                    .zip(&mb.per_pe)
                    .map(|(state, work)| local_grads(state, work, labels, dim, classes))
                    .collect();
                compute_ms = t.elapsed_ms();
                let t = Timer::start();
                self.serial_fabric.all_reduce_f32(&mut bufs, strategy);
                allreduce_ms = t.elapsed_ms();
                apply_reduced(&mut self.replicas, &bufs[0], gl, lr);
                bufs.swap_remove(0)
            }
            ExecMode::Threaded => {
                let results: Vec<(Vec<f32>, f64, f64)> = std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .replicas
                        .iter_mut()
                        .zip(self.endpoints.iter_mut())
                        .zip(mb.per_pe.iter())
                        .map(|((state, ep), work)| {
                            scope.spawn(move || {
                                let _abort_guard = AbortOnPeerPanic;
                                let ep = ep.as_mut().expect("threaded trainer has endpoints");
                                let t = Timer::start();
                                let mut buf = local_grads(state, work, labels, dim, classes);
                                let compute = t.elapsed_ms();
                                let t = Timer::start();
                                ep.all_reduce_f32(&mut buf, strategy);
                                let reduce = t.elapsed_ms();
                                apply_reduced(std::slice::from_mut(state), &buf, gl, lr);
                                (buf, compute, reduce)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("PE trainer thread panicked"))
                        .collect()
                });
                for (_, c, r) in &results {
                    compute_ms += c;
                    allreduce_ms = allreduce_ms.max(*r);
                }
                results.into_iter().next().unwrap().0
            }
        };
        self.steps += 1;
        let n = reduced[gl + 2];
        let denom = n.max(1.0);
        ParallelStepStats {
            loss: reduced[gl] / denom,
            acc: reduced[gl + 1] / denom,
            examples: n as u64,
            wall_ms: wall.elapsed_ms(),
            compute_ms,
            allreduce_ms,
            grad_bytes: self.grad_bytes_total() - bytes_before,
        }
    }

    /// Drive `steps` synchronized steps off `stream` (any
    /// [`MinibatchStream`] whose PE count matches — including a
    /// prefetch-wrapped one), then [`MinibatchStream::finish`] it so a
    /// background producer stops without computing tail batches.
    pub fn run(
        &mut self,
        stream: &mut dyn MinibatchStream,
        steps: usize,
        labels: &[u16],
    ) -> ParallelRunReport {
        let mut rep = ParallelRunReport { steps, ..Default::default() };
        let run = Timer::start();
        for step in 0..steps {
            let mb = stream.next_batch();
            rep.sample_ms += mb.per_pe.iter().map(|w| w.samp_ms).sum::<f64>();
            rep.feature_ms += mb.per_pe.iter().map(|w| w.feat_ms).sum::<f64>();
            rep.storage_bytes_per_step +=
                mb.per_pe.iter().map(|w| w.bytes_from_storage).sum::<u64>() as f64;
            rep.fabric_bytes_per_step +=
                mb.per_pe.iter().map(|w| w.fabric_bytes).sum::<u64>() as f64;
            let s = self.step(&mb, labels);
            rep.compute_ms += s.compute_ms;
            rep.allreduce_ms += s.allreduce_ms;
            rep.grad_bytes_per_step += s.grad_bytes as f64;
            if step == 0 {
                rep.first_loss = s.loss;
            }
            rep.last_loss = s.loss;
            rep.last_acc = s.acc;
        }
        stream.finish();
        let m = steps.max(1) as f64;
        rep.ms_per_step = run.elapsed_ms() / m;
        rep.sample_ms /= m;
        rep.feature_ms /= m;
        rep.compute_ms /= m;
        rep.allreduce_ms /= m;
        rep.storage_bytes_per_step /= m;
        rep.fabric_bytes_per_step /= m;
        rep.grad_bytes_per_step /= m;
        rep
    }

    /// Replica 0's forward head `(W, b)` (W row-major `[dim × classes]`)
    /// — the model the serving plane runs per request. Lockstep makes
    /// replica 0 representative of every PE.
    pub fn head(&self) -> (&[f32], &[f32]) {
        (&self.replicas[0].params[0], &self.replicas[0].params[1])
    }

    /// Class prediction for one gathered row through replica 0's head —
    /// the exact `forward_logits` + first-max `argmax` pair training and
    /// evaluation use, exposed for per-request serving. `logits` is
    /// caller-provided scratch of length `num_classes`.
    pub fn predict_row(&self, x: &[f32], logits: &mut [f32]) -> u16 {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(logits.len(), self.classes);
        let (w, b) = self.head();
        forward_logits(w, b, x, logits);
        argmax(logits) as u16
    }

    /// Holdout accuracy of the (lockstep) model over `vs`, reading rows
    /// from `store` with replica 0 — the cheap evaluation loop of the
    /// host training plane.
    pub fn evaluate(&self, vs: &[VertexId], labels: &[u16], store: &dyn FeatureStore) -> f64 {
        assert_eq!(store.dim(), self.dim, "store/model shape mismatch");
        let w = &self.replicas[0].params[0];
        let b = &self.replicas[0].params[1];
        let mut row = vec![0f32; self.dim];
        let mut logits = vec![0f32; self.classes];
        let mut correct = 0usize;
        for &v in vs {
            store.copy_row(v, &mut row);
            forward_logits(w, b, &row, &mut logits);
            if argmax(&logits) == labels[v as usize] as usize {
                correct += 1;
            }
        }
        correct as f64 / vs.len().max(1) as f64
    }
}

/// Scale the reduced gradient by the global example count and apply the
/// Adam update to each given replica — the identical arithmetic on every
/// PE, so lockstep is preserved bit-for-bit. Skips the update when the
/// batch carried no examples.
fn apply_reduced(replicas: &mut [ParamState], reduced: &[f32], gl: usize, lr: f32) {
    let n = reduced[gl + 2];
    if n <= 0.0 {
        return;
    }
    let inv = 1.0 / n;
    let grads: Vec<f32> = reduced[..gl].iter().map(|&g| g * inv).collect();
    for state in replicas {
        state.adam_step(&grads, lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::{EngineConfig, Mode};
    use crate::graph::{datasets, partition};
    use crate::pipeline::EngineStream;

    fn cfg(mode: Mode, exec: ExecMode, pes: usize) -> EngineConfig {
        EngineConfig {
            mode,
            exec,
            num_pes: pes,
            batch_per_pe: 24,
            cache_per_pe: 200,
            warmup_batches: 0,
            measure_batches: 4,
            seed: 11,
            ..Default::default()
        }
    }

    fn trajectory(
        mode: Mode,
        exec: ExecMode,
        pes: usize,
        strategy: AllReduceStrategy,
        steps: usize,
    ) -> ParallelTrainer {
        let ds = datasets::build("tiny", 5).unwrap();
        let part = partition::random(&ds.graph, pes, 3);
        let mut stream = EngineStream::new(&ds, &part, &cfg(mode, exec, pes));
        let mut pt = ParallelTrainer::new(
            pes,
            ds.feat_dim,
            ds.num_classes,
            41,
            0.05,
            exec,
            strategy,
        );
        for _ in 0..steps {
            let mb = stream.next_batch();
            let s = pt.step(&mb, &ds.labels);
            assert!(s.loss.is_finite(), "loss must stay finite");
            assert!(s.examples > 0);
        }
        pt
    }

    /// The tentpole's correctness property: after K steps every PE holds
    /// bit-identical parameters, in both modes, both exec modes, both
    /// all-reduce strategies.
    #[test]
    fn replicas_stay_in_lockstep_after_k_steps() {
        for mode in [Mode::Independent, Mode::Cooperative] {
            for exec in [ExecMode::Serial, ExecMode::Threaded] {
                for strategy in [AllReduceStrategy::Ring, AllReduceStrategy::Naive] {
                    let pt = trajectory(mode, exec, 3, strategy, 4);
                    assert!(
                        pt.replicas_in_lockstep(),
                        "{mode:?}/{exec:?}/{strategy:?}: replicas diverged"
                    );
                    assert_eq!(pt.replicas()[0].step, 4.0);
                }
            }
        }
    }

    /// Serial and threaded trajectories are bit-identical — and so are
    /// ring vs naive (both reduce in the canonical order).
    #[test]
    fn serial_threaded_and_both_strategies_bit_identical() {
        for mode in [Mode::Independent, Mode::Cooperative] {
            let serial = trajectory(mode, ExecMode::Serial, 2, AllReduceStrategy::Ring, 5);
            let threaded = trajectory(mode, ExecMode::Threaded, 2, AllReduceStrategy::Ring, 5);
            let naive = trajectory(mode, ExecMode::Threaded, 2, AllReduceStrategy::Naive, 5);
            assert!(
                serial.replicas()[0].bits_eq(&threaded.replicas()[0]),
                "{mode:?}: serial vs threaded trajectories diverged"
            );
            assert!(
                threaded.replicas()[0].bits_eq(&naive.replicas()[0]),
                "{mode:?}: ring vs naive trajectories diverged"
            );
        }
    }

    /// Gradient traffic is really accounted: multi-PE steps move bytes,
    /// single-PE steps move none, and serial reports the same totals as
    /// threaded.
    #[test]
    fn grad_byte_accounting_matches_across_exec_modes() {
        let a = trajectory(Mode::Independent, ExecMode::Serial, 3, AllReduceStrategy::Ring, 3);
        let b = trajectory(Mode::Independent, ExecMode::Threaded, 3, AllReduceStrategy::Ring, 3);
        assert!(a.grad_bytes_total() > 0);
        assert_eq!(a.grad_bytes_total(), b.grad_bytes_total());
        let single =
            trajectory(Mode::Independent, ExecMode::Threaded, 1, AllReduceStrategy::Ring, 2);
        assert_eq!(single.grad_bytes_total(), 0, "1 PE has no cross traffic");
    }

    /// The model actually learns: driving the full run loop on tiny
    /// lowers the loss and beats chance accuracy on the validation split.
    #[test]
    fn run_reduces_loss_and_beats_chance() {
        let ds = datasets::build("tiny", 5).unwrap();
        let pes = 2;
        let part = partition::random(&ds.graph, pes, 3);
        let mut c = cfg(Mode::Cooperative, ExecMode::Threaded, pes);
        c.measure_batches = 30;
        let mut stream = EngineStream::new(&ds, &part, &c);
        let store = stream.feature_store();
        let mut pt = ParallelTrainer::new(
            pes,
            ds.feat_dim,
            ds.num_classes,
            41,
            0.05,
            ExecMode::Threaded,
            AllReduceStrategy::Ring,
        );
        let rep = pt.run(&mut stream, 30, &ds.labels);
        assert!(
            rep.last_loss < rep.first_loss,
            "loss must drop: {} -> {}",
            rep.first_loss,
            rep.last_loss
        );
        let acc = pt.evaluate(&ds.val, &ds.labels, &*store);
        let chance = 1.0 / ds.num_classes as f64;
        assert!(acc > chance * 1.2, "val acc {acc:.3} vs chance {chance:.3}");
        assert!(rep.ms_per_step > 0.0 && rep.storage_bytes_per_step > 0.0);
    }
}
