//! Evaluation metrics: accuracy and macro-F1 from predicted/true labels.
//! (The paper reports F1-scores — micro-F1 equals accuracy for
//! single-label multiclass, so we report accuracy plus macro-F1.)

/// Confusion-derived metrics.
#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub n: usize,
    pub accuracy: f64,
    pub macro_f1: f64,
    pub loss_proxy: f64,
}

/// Compute accuracy + macro-F1.
pub fn score(num_classes: usize, pairs: &[(u16, u16)]) -> EvalStats {
    if pairs.is_empty() {
        return EvalStats::default();
    }
    let mut tp = vec![0usize; num_classes];
    let mut fp = vec![0usize; num_classes];
    let mut fn_ = vec![0usize; num_classes];
    let mut correct = 0usize;
    for &(pred, truth) in pairs {
        if pred == truth {
            correct += 1;
            tp[truth as usize] += 1;
        } else {
            fp[pred as usize] += 1;
            fn_[truth as usize] += 1;
        }
    }
    // macro-F1 over classes that appear (as truth or prediction)
    let mut f1_sum = 0.0;
    let mut f1_n = 0usize;
    for c in 0..num_classes {
        let denom_p = tp[c] + fp[c];
        let denom_r = tp[c] + fn_[c];
        if denom_p + denom_r == 0 {
            continue;
        }
        let p = if denom_p == 0 { 0.0 } else { tp[c] as f64 / denom_p as f64 };
        let r = if denom_r == 0 { 0.0 } else { tp[c] as f64 / denom_r as f64 };
        f1_sum += if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
        f1_n += 1;
    }
    EvalStats {
        n: pairs.len(),
        accuracy: correct as f64 / pairs.len() as f64,
        macro_f1: if f1_n == 0 { 0.0 } else { f1_sum / f1_n as f64 },
        loss_proxy: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let pairs: Vec<(u16, u16)> = (0..10).map(|i| (i % 3, i % 3)).collect();
        let s = score(3, &pairs);
        assert_eq!(s.accuracy, 1.0);
        assert!((s.macro_f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_wrong() {
        let pairs: Vec<(u16, u16)> =
            (0..10).map(|i| ((i % 2) as u16, ((i + 1) % 2) as u16)).collect();
        let s = score(2, &pairs);
        assert_eq!(s.accuracy, 0.0);
        assert_eq!(s.macro_f1, 0.0);
    }

    #[test]
    fn known_confusion() {
        // class 0: tp=2 fp=1 fn=0 -> p=2/3 r=1 f1=0.8
        // class 1: tp=1 fp=0 fn=1 -> p=1 r=0.5 f1=2/3
        let pairs = vec![(0u16, 0u16), (0, 0), (0, 1), (1, 1)];
        let s = score(2, &pairs);
        assert!((s.accuracy - 0.75).abs() < 1e-12);
        assert!((s.macro_f1 - (0.8 + 2.0 / 3.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty() {
        assert_eq!(score(4, &[]).n, 0);
    }
}
