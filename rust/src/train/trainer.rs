//! The Trainer: everything needed to train the paper's GCN end to end
//! from Rust through PJRT.

use super::evalx::{score, EvalStats};
use crate::coop::engine::ExecMode;
use crate::graph::{Csr, Dataset, VertexId};
use crate::runtime::manifest::ArtifactConfig;
use crate::runtime::tensors::{forward_inputs, to_vec_f32, train_inputs, ParamState};
use crate::runtime::{Executable, Manifest, Runtime};
use crate::sampling::{block, Kappa, Mfg, Sampler, SamplerConfig, SamplerKind};
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;

/// Trainer construction options.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub kind: SamplerKind,
    pub kappa: Kappa,
    pub fanout: usize,
    pub seed: u64,
    /// learning-rate override (None = manifest value).
    pub lr: Option<f32>,
    /// execution mode for the multi-PE sampling helpers
    /// ([`Trainer::sample_indep_merged_mfg`] runs one thread per PE when
    /// `Threaded`; `Serial` is the bit-identical debugging fallback).
    pub exec: ExecMode,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            kind: SamplerKind::Labor0,
            kappa: Kappa::Finite(1),
            fanout: 10,
            seed: 0x7EA1,
            lr: None,
            exec: ExecMode::Threaded,
        }
    }
}

/// Per-step statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    /// training accuracy on the batch.
    pub acc: f32,
    pub sample_ms: f64,
    pub pad_ms: f64,
    pub feature_ms: f64,
    pub exec_ms: f64,
    pub truncated_vertices: usize,
    pub truncated_edges: usize,
    /// |S^L| actually sampled (before padding).
    pub input_vertices: usize,
}

/// End-to-end trainer bound to a dataset + artifact config.
pub struct Trainer<'d> {
    pub ds: &'d Dataset,
    pub art: ArtifactConfig,
    train_exe: Executable,
    forward_exe: Executable,
    pub state: ParamState,
    sampler: Sampler<'d>,
    seed_rng: Pcg64,
    lr: f32,
    exec: ExecMode,
    feat_buf: Vec<f32>,
}

impl<'d> Trainer<'d> {
    /// Load artifacts for `config_name` and bind to `ds`.
    pub fn new(
        rt: &Runtime,
        manifest: &Manifest,
        config_name: &str,
        ds: &'d Dataset,
        opts: &TrainerOptions,
    ) -> crate::Result<Trainer<'d>> {
        let art = manifest.get(config_name)?.clone();
        anyhow::ensure!(
            art.d_in == ds.feat_dim && art.classes >= ds.num_classes,
            "artifact {} dims (d_in={}, C={}) incompatible with dataset {} (d={}, C={})",
            art.name, art.d_in, art.classes, ds.name, ds.feat_dim, ds.num_classes
        );
        let train_exe = rt.load_hlo_text(&art.train_hlo)?;
        let forward_exe = rt.load_hlo_text(&art.forward_hlo)?;
        let sampler_cfg = SamplerConfig {
            fanout: opts.fanout,
            layers: art.layers,
            kappa: opts.kappa,
            ..Default::default()
        };
        let sampler = sampler_cfg.build(opts.kind, &ds.graph, opts.seed);
        let state = ParamState::init(&art, opts.seed ^ 0xFACE);
        let lr = opts.lr.unwrap_or(art.lr);
        Ok(Trainer {
            ds,
            art,
            train_exe,
            forward_exe,
            state,
            sampler,
            seed_rng: Pcg64::new(opts.seed ^ 0x5EED),
            lr,
            exec: opts.exec,
            feat_buf: Vec::new(),
        })
    }

    /// Draw the next training seed batch (uniform without replacement).
    pub fn next_seeds(&mut self) -> Vec<VertexId> {
        let b = self.art.batch.min(self.ds.train.len());
        self.seed_rng
            .sample_distinct(self.ds.train.len(), b)
            .into_iter()
            .map(|i| self.ds.train[i as usize])
            .collect()
    }

    /// One training step on freshly drawn seeds.
    pub fn step(&mut self) -> crate::Result<StepStats> {
        let seeds = self.next_seeds();
        self.step_on_seeds(&seeds)
    }

    /// One training step on given seeds (samples an MFG internally and
    /// advances the dependent-batch RNG).
    pub fn step_on_seeds(&mut self, seeds: &[VertexId]) -> crate::Result<StepStats> {
        let t = Timer::start();
        let mfg = self.sampler.sample_mfg(seeds);
        self.sampler.advance_batch();
        let sample_ms = t.elapsed_ms();
        let mut stats = self.step_on_mfg(&mfg)?;
        stats.sample_ms = sample_ms;
        Ok(stats)
    }

    /// One training step on a pre-built MFG (used by the coop/indep
    /// convergence harnesses that construct global or merged batches).
    pub fn step_on_mfg(&mut self, mfg: &Mfg) -> crate::Result<StepStats> {
        let mut stats = StepStats::default();
        let t = Timer::start();
        let labels = &self.ds.labels;
        let batch = mfg.pad(&self.art.caps, |v| labels[v as usize]);
        stats.pad_ms = t.elapsed_ms();
        stats.truncated_vertices = batch.truncated_vertices;
        stats.truncated_edges = batch.truncated_edges;
        stats.input_vertices = mfg.input_vertices().len();

        let t = Timer::start();
        self.gather_padded_features(mfg);
        stats.feature_ms = t.elapsed_ms();

        let t = Timer::start();
        let inputs = train_inputs(&self.art, &self.state, &self.feat_buf, &batch, self.lr)?;
        let outs = self.train_exe.run(&inputs)?;
        let (loss, correct) = self.state.absorb(&outs)?;
        stats.exec_ms = t.elapsed_ms();
        stats.loss = loss;
        let denom = batch.label_mask.iter().sum::<f32>().max(1.0);
        stats.acc = correct / denom;
        Ok(stats)
    }

    fn gather_padded_features(&mut self, mfg: &Mfg) {
        let cap = *self.art.caps.n.last().unwrap();
        let d = self.art.d_in;
        self.feat_buf.clear();
        self.feat_buf.resize(cap * d, 0.0);
        let vs = mfg.clipped_input_vertices(&self.art.caps);
        for (i, &v) in vs.iter().enumerate() {
            self.ds.write_features(v, &mut self.feat_buf[i * d..(i + 1) * d]);
        }
    }

    /// Evaluate accuracy/macro-F1 on `nodes` (validation or test split)
    /// using sampled neighborhoods with an evaluation-only RNG (the
    /// training dependent-RNG state is untouched). `eval_seed` fixes the
    /// sampled neighborhoods across calls for comparability.
    pub fn evaluate(&mut self, nodes: &[VertexId], eval_seed: u64) -> crate::Result<EvalStats> {
        let b = self.art.caps.n[0];
        let sampler_cfg = SamplerConfig {
            fanout: self.sampler.cfg.fanout,
            layers: self.art.layers,
            kappa: Kappa::Finite(1),
            ..Default::default()
        };
        let mut eval_sampler = sampler_cfg.build(self.sampler.kind, &self.ds.graph, eval_seed);
        let mut pairs: Vec<(u16, u16)> = Vec::with_capacity(nodes.len());
        for chunk in nodes.chunks(b) {
            let mfg = eval_sampler.sample_mfg(chunk);
            let batch = {
                let labels = &self.ds.labels;
                mfg.pad(&self.art.caps, |v| labels[v as usize])
            };
            self.gather_padded_features(&mfg);
            let inputs = forward_inputs(&self.art, &self.state, &self.feat_buf, &batch)?;
            let outs = self.forward_exe.run(&inputs)?;
            anyhow::ensure!(outs.len() == 1, "forward returns 1 output");
            let logits = to_vec_f32(&outs[0])?;
            let c = self.art.classes;
            for (i, &v) in chunk.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as u16)
                    .unwrap_or(0);
                pairs.push((pred, self.ds.label(v)));
            }
        }
        Ok(score(self.ds.num_classes, &pairs))
    }

    /// Build one cooperative global MFG: sampling the global batch with
    /// the shared-coin sampler — exactly the union Algorithm 1 produces
    /// (see coop_sampler tests).
    pub fn sample_global_mfg(&mut self, seeds: &[VertexId]) -> Mfg {
        let mfg = self.sampler.sample_mfg(seeds);
        self.sampler.advance_batch();
        mfg
    }

    /// Build a merged block-diagonal MFG of `p` independent sub-batches
    /// (Independent Minibatching semantics: per-PE RNG, duplicates kept).
    ///
    /// With [`ExecMode::Threaded`] (the default) each sub-batch is sampled
    /// by its own PE thread — see [`sample_indep_parts`].
    pub fn sample_indep_merged_mfg(&mut self, seeds: &[VertexId], p: usize, batch_seed: u64) -> Mfg {
        let parts = sample_indep_parts(
            &self.ds.graph,
            self.sampler.cfg,
            self.sampler.kind,
            seeds,
            p,
            batch_seed,
            self.exec,
        );
        block::merge_mfgs(&parts)
    }
}

/// Sample the `p` per-PE sub-batches of one Independent-Minibatching
/// global step — the Runtime-free core of
/// [`Trainer::sample_indep_merged_mfg`], also driven directly by
/// `benches/bench_train_step.rs` so trainer and bench cannot drift.
///
/// PE `i`'s sampler is seeded `batch_seed ^ ((i+1) << 32)` in **both**
/// exec modes, so the result is bit-identical regardless of scheduling;
/// only the wall-clock changes (tested below).
pub fn sample_indep_parts(
    graph: &Csr,
    cfg: SamplerConfig,
    kind: SamplerKind,
    seeds: &[VertexId],
    p: usize,
    batch_seed: u64,
    exec: ExecMode,
) -> Vec<Mfg> {
    let per = seeds.len() / p;
    let pe_sample = |i: usize, chunk: &[VertexId]| -> Mfg {
        let mut s = cfg.build(kind, graph, batch_seed ^ ((i as u64 + 1) << 32));
        s.sample_mfg(chunk)
    };
    match exec {
        ExecMode::Serial => {
            (0..p).map(|i| pe_sample(i, &seeds[i * per..(i + 1) * per])).collect()
        }
        ExecMode::Threaded => std::thread::scope(|scope| {
            let pe_sample = &pe_sample;
            let handles: Vec<_> = (0..p)
                .map(|i| {
                    let chunk = &seeds[i * per..(i + 1) * per];
                    scope.spawn(move || pe_sample(i, chunk))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("PE sampling thread panicked"))
                .collect()
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn indep_parts_serial_and_threaded_bit_identical() {
        let g = generate::chung_lu(2000, 12.0, 2.4, 5);
        let cfg = SamplerConfig::default();
        let seeds: Vec<VertexId> = (0..256).collect();
        for kind in [SamplerKind::Labor0, SamplerKind::Neighbor] {
            let a = sample_indep_parts(&g, cfg, kind, &seeds, 4, 77, ExecMode::Serial);
            let b = sample_indep_parts(&g, cfg, kind, &seeds, 4, 77, ExecMode::Threaded);
            assert_eq!(a.len(), b.len());
            for (pe, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.layer_vertices, y.layer_vertices, "{kind:?} PE{pe} vertices");
                for (l, (ex, ey)) in x.layer_edges.iter().zip(&y.layer_edges).enumerate() {
                    assert_eq!(ex.offsets, ey.offsets, "{kind:?} PE{pe} L{l} offsets");
                    assert_eq!(ex.nbr_local, ey.nbr_local, "{kind:?} PE{pe} L{l} edges");
                }
            }
            let ma = block::merge_mfgs(&a);
            let mb = block::merge_mfgs(&b);
            assert_eq!(ma.layer_vertices, mb.layer_vertices, "{kind:?} merged");
        }
    }
}
