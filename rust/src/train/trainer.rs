//! The Trainer: the compute half of a training step, fed by a pipeline
//! [`TrainStream`] and executed through the unified model API
//! ([`crate::model::GnnModel`]).
//!
//! Since the pipeline redesign the Trainer no longer owns private
//! sampling plumbing: batch drawing and MFG sampling live in
//! [`crate::pipeline::TrainStream`], and the Trainer either pulls from
//! its own stream ([`Trainer::step`], configured by
//! [`TrainerOptions::batching`]) or from any external
//! [`MinibatchStream`] ([`Trainer::step_from`]).
//!
//! Since the feature-plane refactor the Trainer no longer gathers
//! features either: the stream ships each batch's dense `S^L × d` buffer
//! (real rows out of the [`crate::feature::FeatureStore`]); without one
//! the Trainer gathers the dense buffer itself. Pulled through
//! [`crate::pipeline::with_prefetch`], batch t+1's sampling + gathering
//! overlaps batch t's execution (`--prefetch 1` on the train CLI).
//!
//! Since the compute-plane redesign the Trainer no longer touches
//! padding, literal assembly, or executables: it hands the MFG + dense
//! feature buffer to a [`GnnModel`] backend. [`Trainer::new`] binds the
//! PJRT/AOT bridge ([`crate::model::PjrtModel`], where a runtime and
//! artifacts exist); [`Trainer::new_host`] binds the host backend
//! ([`crate::model::HostModel`]) — real layered compute with no
//! artifacts, the default in this build. Trajectories are backend-local
//! but the API, stats, and evaluation path are identical.

use super::evalx::{score, EvalStats};
use crate::coop::engine::ExecMode;
use crate::feature::{Codec, FeatureStore};
use crate::graph::{Dataset, VertexId};
use crate::model::{kernels, GnnModel, HostModel, ModelDims, PjrtModel};
use crate::pipeline::{Batching, MinibatchStream, TrainStream};
use crate::runtime::tensors::ParamState;
use crate::runtime::{Manifest, Runtime};
use crate::sampling::{Kappa, Mfg, SamplerConfig, SamplerKind};
use crate::util::stats::Timer;
use std::sync::Arc;

/// Trainer construction options.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub kind: SamplerKind,
    pub kappa: Kappa,
    pub fanout: usize,
    pub seed: u64,
    /// learning-rate override (None = manifest value).
    pub lr: Option<f32>,
    /// execution mode for multi-PE sampling (`Batching::IndepMerged`
    /// samples one sub-batch per PE thread when `Threaded`; `Serial` is
    /// the bit-identical debugging fallback).
    pub exec: ExecMode,
    /// how the trainer's stream assembles the global batch.
    pub batching: Batching,
    /// at-rest row codec for the stream's feature store (`--codec`);
    /// non-f32 trains on quantized features decoded at gather.
    pub codec: Codec,
    /// hot-tier budget in MiB for the stream's store (`--hot-mb`).
    pub hot_mb: usize,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            kind: SamplerKind::Labor0,
            kappa: Kappa::Finite(1),
            fanout: 10,
            seed: crate::pipeline::DEFAULT_SEED,
            lr: None,
            exec: ExecMode::Threaded,
            batching: Batching::Single,
            codec: Codec::F32,
            hot_mb: 0,
        }
    }
}

/// Per-step statistics.
///
/// Stage-time semantics (every field in ms):
///
/// * `sample_ms` — batch drawing + MFG sampling **in the stream**. It
///   does *not* include the stream's feature gather (that used to be
///   folded in here, which made prefetch-overlap numbers attribute the
///   gather to sampling).
/// * `feature_ms` — all feature-byte movement: the stream's dense
///   gather out of the store **plus** the trainer's prefix copy into
///   the padded tensor.
/// * `pad_ms` — MFG → fixed-shape block padding in the trainer.
/// * `exec_ms` — the train-step execution + optimizer-state absorb.
///
/// Under `--prefetch 1` the stream stages (`sample_ms` + the gather
/// part of `feature_ms`) overlap the previous step's `exec_ms`; the
/// split is what makes that overlap visible in reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    /// training accuracy on the batch.
    pub acc: f32,
    pub sample_ms: f64,
    pub pad_ms: f64,
    pub feature_ms: f64,
    pub exec_ms: f64,
    pub truncated_vertices: usize,
    pub truncated_edges: usize,
    /// |S^L| actually sampled (before padding).
    pub input_vertices: usize,
}

impl StepStats {
    /// Fold a stream-produced minibatch's stage times in: its sampling
    /// portion becomes `sample_ms`, its gather portion joins
    /// `feature_ms` (on top of the trainer-side copy already recorded).
    /// Wall time the stream couldn't attribute to a stage (e.g. merge
    /// overhead) stays with `sample_ms` so the stages still sum to the
    /// stream's wall clock.
    pub(crate) fn absorb_stream_times(&mut self, mb: &crate::pipeline::Minibatch) {
        let samp: f64 = mb.per_pe.iter().map(|w| w.samp_ms).sum();
        let feat: f64 = mb.per_pe.iter().map(|w| w.feat_ms).sum();
        self.sample_ms = (mb.wall_ms - feat).max(samp);
        self.feature_ms += feat;
    }
}

/// End-to-end trainer bound to a dataset + a [`GnnModel`] backend.
pub struct Trainer<'d> {
    pub ds: &'d Dataset,
    model: Box<dyn GnnModel>,
    pub state: ParamState,
    stream: TrainStream<'d>,
    /// shared with the trainer's stream; evaluation and the
    /// no-pre-gathered-buffer fallback read rows from here.
    store: Arc<dyn FeatureStore>,
    lr: f32,
    /// seed batch size (and evaluation chunk size).
    batch: usize,
    feat_buf: Vec<f32>,
}

impl<'d> Trainer<'d> {
    /// Load artifacts for `config_name` and bind the PJRT/AOT backend
    /// to `ds`.
    pub fn new(
        rt: &Runtime,
        manifest: &Manifest,
        config_name: &str,
        ds: &'d Dataset,
        opts: &TrainerOptions,
    ) -> crate::Result<Trainer<'d>> {
        let art = manifest.get(config_name)?.clone();
        anyhow::ensure!(
            art.d_in == ds.feat_dim && art.classes >= ds.num_classes,
            "artifact {} dims (d_in={}, C={}) incompatible with dataset {} (d={}, C={})",
            art.name, art.d_in, art.classes, ds.name, ds.feat_dim, ds.num_classes
        );
        let batch = art.batch;
        let lr = opts.lr.unwrap_or(art.lr);
        let model = PjrtModel::load(rt, art)?;
        Ok(Trainer::with_model(Box::new(model), ds, batch, lr, opts))
    }

    /// Bind the host backend to `ds` — real layered compute with no
    /// artifacts or runtime (depth `layers`, width `hidden`, input and
    /// output widths from the dataset). `opts.lr` defaults to 0.01.
    pub fn new_host(
        ds: &'d Dataset,
        batch: usize,
        layers: usize,
        hidden: usize,
        opts: &TrainerOptions,
    ) -> crate::Result<Trainer<'d>> {
        anyhow::ensure!(batch >= 1, "seed batch size must be >= 1");
        anyhow::ensure!(layers >= 1 && (layers == 1 || hidden >= 1), "degenerate model shape");
        let dims = ModelDims {
            layers,
            d_in: ds.feat_dim,
            hidden,
            classes: ds.num_classes,
        };
        let lr = opts.lr.unwrap_or(0.01);
        Ok(Trainer::with_model(Box::new(HostModel::new(dims)), ds, batch, lr, opts))
    }

    /// Shared backend-agnostic tail: stream, store, and parameter init
    /// (shapes from the model dims, so both backends are interchangeable
    /// on the same state).
    fn with_model(
        model: Box<dyn GnnModel>,
        ds: &'d Dataset,
        batch: usize,
        lr: f32,
        opts: &TrainerOptions,
    ) -> Trainer<'d> {
        let dims = model.dims();
        let sampler_cfg = SamplerConfig {
            fanout: opts.fanout,
            layers: dims.layers,
            kappa: opts.kappa,
            ..Default::default()
        };
        let stream = TrainStream::with_codec(
            ds,
            opts.kind,
            sampler_cfg,
            batch,
            opts.seed,
            opts.exec,
            opts.batching,
            opts.codec,
            opts.hot_mb,
        );
        let store = stream.feature_store();
        let state = dims.init_state(opts.seed ^ 0xFACE);
        Trainer { ds, model, state, stream, store, lr, batch, feat_buf: Vec::new() }
    }

    /// The backend this trainer executes on.
    pub fn model(&self) -> &dyn GnnModel {
        &*self.model
    }

    /// The layered-model shape.
    pub fn dims(&self) -> ModelDims {
        self.model.dims()
    }

    /// Seed batch size (and evaluation chunk size).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Draw the next training seed batch (uniform without replacement).
    pub fn next_seeds(&mut self) -> Vec<VertexId> {
        self.stream.next_seeds()
    }

    /// A fresh external stream with the trainer's exact internal recipe,
    /// sharing its feature store (see [`TrainStream::fresh_clone`]) —
    /// wrap in [`crate::pipeline::with_prefetch`] and feed
    /// [`Trainer::step_from`] for overlapped training with trajectories
    /// bit-identical to [`Trainer::step`] at the same seed.
    pub fn make_stream(&self) -> TrainStream<'d> {
        self.stream.fresh_clone()
    }

    /// One training step pulled from the trainer's own stream — batch
    /// drawing, sampling, *and feature gathering* all happen in the
    /// stream; the trainer pads and executes.
    pub fn step(&mut self) -> crate::Result<StepStats> {
        let mb = self.stream.next_batch();
        self.step_on_batch(mb)
    }

    /// One training step pulled from an external stream (e.g. the
    /// Figure 9 convergence arms, or a prefetched wrapper of the same
    /// recipe). The stream must materialize a merged MFG; engine
    /// measurement streams yield counts only.
    pub fn step_from(&mut self, stream: &mut dyn MinibatchStream) -> crate::Result<StepStats> {
        let mb = stream.next_batch();
        self.step_on_batch(mb)
    }

    /// Shared consumer half: pad + execute a stream-produced minibatch,
    /// using its pre-gathered feature buffer when it ships one. Stream
    /// stage times are split per the [`StepStats`] field semantics
    /// (sampling vs feature gather), not lumped into `sample_ms`.
    fn step_on_batch(&mut self, mb: crate::pipeline::Minibatch) -> crate::Result<StepStats> {
        let mfg = mb
            .merged
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("stream yields no merged MFG (measurement stream?)"))?;
        let pre = mb.per_pe.first().and_then(|w| w.features.as_deref());
        let mut stats = self.step_on_mfg_with(mfg, pre)?;
        stats.absorb_stream_times(&mb);
        Ok(stats)
    }

    /// One training step on given seeds (samples via the trainer's
    /// stream, advancing its dependent-batch RNG).
    pub fn step_on_seeds(&mut self, seeds: &[VertexId]) -> crate::Result<StepStats> {
        let t = Timer::start();
        let mfg = self.stream.sample_on(seeds);
        let sample_ms = t.elapsed_ms();
        let mut stats = self.step_on_mfg(&mfg)?;
        stats.sample_ms = sample_ms;
        Ok(stats)
    }

    /// One training step on a pre-built MFG (used by harnesses that
    /// construct batches through external streams); features come from
    /// the trainer's store.
    pub fn step_on_mfg(&mut self, mfg: &Mfg) -> crate::Result<StepStats> {
        self.step_on_mfg_with(mfg, None)
    }

    fn step_on_mfg_with(&mut self, mfg: &Mfg, pre: Option<&[f32]>) -> crate::Result<StepStats> {
        let mut stats = StepStats::default();
        let t = Timer::start();
        if pre.is_none() {
            self.fill_features(mfg);
        }
        stats.feature_ms = t.elapsed_ms();
        let feats = pre.unwrap_or(&self.feat_buf);
        let m = self.model.train_on_mfg(&mut self.state, mfg, feats, &self.ds.labels, self.lr)?;
        stats.pad_ms = m.pad_ms;
        stats.exec_ms = m.exec_ms;
        stats.loss = m.loss;
        stats.acc = m.accuracy();
        stats.truncated_vertices = m.truncated_vertices;
        stats.truncated_edges = m.truncated_edges;
        stats.input_vertices = mfg.input_vertices().len();
        Ok(stats)
    }

    /// Gather the dense `S^L × d` input buffer from the store (the
    /// no-stream-buffer fallback; with a stream-shipped buffer the
    /// expensive gather already happened in the stream, possibly
    /// overlapped with the previous step's execution). Padding — if the
    /// backend needs any — is the backend's business.
    fn fill_features(&mut self, mfg: &Mfg) {
        let d = self.model.dims().d_in;
        let vs = mfg.input_vertices();
        self.feat_buf.clear();
        self.feat_buf.resize(vs.len() * d, 0.0);
        self.store.gather_into(vs, &mut self.feat_buf);
    }

    /// Evaluate accuracy/macro-F1 on `nodes` (validation or test split)
    /// using sampled neighborhoods with an evaluation-only RNG (the
    /// training dependent-RNG state is untouched). `eval_seed` fixes the
    /// sampled neighborhoods across calls for comparability. Logits come
    /// from the backend's forward path ([`GnnModel::forward_on_mfg`]).
    pub fn evaluate(&mut self, nodes: &[VertexId], eval_seed: u64) -> crate::Result<EvalStats> {
        let dims = self.model.dims();
        let sampler_cfg = SamplerConfig {
            fanout: self.stream.config().fanout,
            layers: dims.layers,
            kappa: Kappa::Finite(1),
            ..Default::default()
        };
        let mut eval_sampler = sampler_cfg.build(self.stream.kind(), &self.ds.graph, eval_seed);
        let mut pairs: Vec<(u16, u16)> = Vec::with_capacity(nodes.len());
        for chunk in nodes.chunks(self.batch) {
            let mfg = eval_sampler.sample_mfg(chunk);
            self.fill_features(&mfg);
            let logits = self.model.forward_on_mfg(&self.state, &mfg, &self.feat_buf)?;
            let c = dims.classes;
            for (i, &v) in chunk.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                pairs.push((kernels::argmax(row) as u16, self.ds.label(v)));
            }
        }
        Ok(score(self.ds.num_classes, &pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::pipeline::{Minibatch, PeWork};

    /// The single-PE trainer is actually runnable in this build: the
    /// host backend trains the layered model end-to-end — loss drops,
    /// trajectories are seed-deterministic, and evaluation flows
    /// through the same backend's forward path.
    #[test]
    fn host_backend_trains_and_evaluates() {
        let ds = datasets::build("tiny", 5).unwrap();
        let opts = TrainerOptions { seed: 77, lr: Some(0.05), ..Default::default() };
        let mut a = Trainer::new_host(&ds, 48, 2, 8, &opts).unwrap();
        let mut b = Trainer::new_host(&ds, 48, 2, 8, &opts).unwrap();
        assert_eq!(a.model().backend(), "host");
        assert_eq!(a.dims().layers, 2);
        let (mut first, mut last) = (0f32, 0f32);
        for step in 0..40 {
            let sa = a.step().unwrap();
            let sb = b.step().unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "same-seed trainers diverged");
            assert_eq!(sa.truncated_vertices, 0, "host backend never truncates");
            if step == 0 {
                first = sa.loss;
            }
            last = sa.loss;
        }
        assert!(a.state.bits_eq(&b.state), "parameter trajectories diverged");
        assert!(last < first * 0.9, "loss must drop: {first} -> {last}");
        let val = a.evaluate(&ds.val, 1234).unwrap();
        let chance = 1.0 / ds.num_classes as f64;
        assert!(val.accuracy > chance * 1.2, "val acc {:.3} vs chance {chance:.3}", val.accuracy);
        // fixed eval seed => reproducible evaluation
        let again = a.evaluate(&ds.val, 1234).unwrap();
        assert_eq!(val.accuracy, again.accuracy);
    }

    /// `step_from` an external fresh-clone stream is bit-identical to
    /// the trainer's own stream at the same seed (the prefetch oracle's
    /// foundation, now through the model API).
    #[test]
    fn external_stream_matches_internal_trajectory() {
        let ds = datasets::build("tiny", 9).unwrap();
        let opts = TrainerOptions { seed: 31, lr: Some(0.05), ..Default::default() };
        let mut own = Trainer::new_host(&ds, 32, 2, 8, &opts).unwrap();
        let mut ext = Trainer::new_host(&ds, 32, 2, 8, &opts).unwrap();
        let mut stream = ext.make_stream();
        for _ in 0..5 {
            let sa = own.step().unwrap();
            let sb = ext.step_from(&mut stream).unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
        }
        assert!(own.state.bits_eq(&ext.state));
    }

    /// The timing-misattribution regression: the stream's gather time
    /// must land in `feature_ms` (on top of the trainer-side copy), not
    /// be folded into `sample_ms`; unattributed stream wall stays with
    /// sampling so the stages still cover the wall clock.
    #[test]
    fn stream_times_split_sampling_from_gather() {
        let work = PeWork { samp_ms: 6.0, feat_ms: 3.0, ..Default::default() };
        let mb = Minibatch { index: 0, per_pe: vec![work], merged: None, wall_ms: 10.0 };
        let mut stats = StepStats { feature_ms: 0.5, ..Default::default() }; // trainer-side copy
        stats.absorb_stream_times(&mb);
        assert!((stats.feature_ms - 3.5).abs() < 1e-12, "gather + copy: {}", stats.feature_ms);
        assert!((stats.sample_ms - 7.0).abs() < 1e-12, "wall minus gather: {}", stats.sample_ms);

        // stage sum can exceed a threaded stream's wall (per-PE elapsed
        // overlaps); sample_ms then falls back to the reported sampling
        let work = PeWork { samp_ms: 6.0, feat_ms: 8.0, ..Default::default() };
        let mb = Minibatch { index: 0, per_pe: vec![work], merged: None, wall_ms: 9.0 };
        let mut stats = StepStats::default();
        stats.absorb_stream_times(&mb);
        assert!((stats.sample_ms - 6.0).abs() < 1e-12);
        assert!((stats.feature_ms - 8.0).abs() < 1e-12);
    }
}
