//! The Trainer: the compute half of a training step (padding, feature
//! padding, PJRT execution, optimizer state), fed by a pipeline
//! [`TrainStream`].
//!
//! Since the pipeline redesign the Trainer no longer owns private
//! sampling plumbing: batch drawing and MFG sampling live in
//! [`crate::pipeline::TrainStream`], and the Trainer either pulls from
//! its own stream ([`Trainer::step`], configured by
//! [`TrainerOptions::batching`]) or from any external
//! [`MinibatchStream`] ([`Trainer::step_from`]).
//!
//! Since the feature-plane refactor the Trainer no longer gathers
//! features either: the stream ships each batch's dense `S^L × d` buffer
//! (real rows out of the [`crate::feature::FeatureStore`]), and the
//! trainer's feature stage is reduced to a prefix memcpy into the padded
//! `[cap × d]` tensor. Pulled through
//! [`crate::pipeline::with_prefetch`], batch t+1's sampling + gathering
//! overlaps batch t's execution (`--prefetch 1` on the train CLI).

use super::evalx::{score, EvalStats};
use crate::coop::engine::ExecMode;
use crate::feature::{FeatureStore, PartitionedFeatureStore};
use crate::graph::{Dataset, VertexId};
use crate::pipeline::{Batching, MinibatchStream, TrainStream};
use crate::runtime::manifest::ArtifactConfig;
use crate::runtime::tensors::{forward_inputs, to_vec_f32, train_inputs, ParamState};
use crate::runtime::{Executable, Manifest, Runtime};
use crate::sampling::{Kappa, Mfg, SamplerConfig, SamplerKind};
use crate::util::stats::Timer;
use std::sync::Arc;

/// Trainer construction options.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub kind: SamplerKind,
    pub kappa: Kappa,
    pub fanout: usize,
    pub seed: u64,
    /// learning-rate override (None = manifest value).
    pub lr: Option<f32>,
    /// execution mode for multi-PE sampling (`Batching::IndepMerged`
    /// samples one sub-batch per PE thread when `Threaded`; `Serial` is
    /// the bit-identical debugging fallback).
    pub exec: ExecMode,
    /// how the trainer's stream assembles the global batch.
    pub batching: Batching,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            kind: SamplerKind::Labor0,
            kappa: Kappa::Finite(1),
            fanout: 10,
            seed: crate::pipeline::DEFAULT_SEED,
            lr: None,
            exec: ExecMode::Threaded,
            batching: Batching::Single,
        }
    }
}

/// Per-step statistics.
///
/// Stage-time semantics (every field in ms):
///
/// * `sample_ms` — batch drawing + MFG sampling **in the stream**. It
///   does *not* include the stream's feature gather (that used to be
///   folded in here, which made prefetch-overlap numbers attribute the
///   gather to sampling).
/// * `feature_ms` — all feature-byte movement: the stream's dense
///   gather out of the store **plus** the trainer's prefix copy into
///   the padded tensor.
/// * `pad_ms` — MFG → fixed-shape block padding in the trainer.
/// * `exec_ms` — the train-step execution + optimizer-state absorb.
///
/// Under `--prefetch 1` the stream stages (`sample_ms` + the gather
/// part of `feature_ms`) overlap the previous step's `exec_ms`; the
/// split is what makes that overlap visible in reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    /// training accuracy on the batch.
    pub acc: f32,
    pub sample_ms: f64,
    pub pad_ms: f64,
    pub feature_ms: f64,
    pub exec_ms: f64,
    pub truncated_vertices: usize,
    pub truncated_edges: usize,
    /// |S^L| actually sampled (before padding).
    pub input_vertices: usize,
}

impl StepStats {
    /// Fold a stream-produced minibatch's stage times in: its sampling
    /// portion becomes `sample_ms`, its gather portion joins
    /// `feature_ms` (on top of the trainer-side copy already recorded).
    /// Wall time the stream couldn't attribute to a stage (e.g. merge
    /// overhead) stays with `sample_ms` so the stages still sum to the
    /// stream's wall clock.
    pub(crate) fn absorb_stream_times(&mut self, mb: &crate::pipeline::Minibatch) {
        let samp: f64 = mb.per_pe.iter().map(|w| w.samp_ms).sum();
        let feat: f64 = mb.per_pe.iter().map(|w| w.feat_ms).sum();
        self.sample_ms = (mb.wall_ms - feat).max(samp);
        self.feature_ms += feat;
    }
}

/// End-to-end trainer bound to a dataset + artifact config.
pub struct Trainer<'d> {
    pub ds: &'d Dataset,
    pub art: ArtifactConfig,
    train_exe: Executable,
    forward_exe: Executable,
    pub state: ParamState,
    stream: TrainStream<'d>,
    /// shared with the trainer's stream; evaluation and the
    /// no-pre-gathered-buffer fallback read rows from here.
    store: Arc<PartitionedFeatureStore>,
    lr: f32,
    feat_buf: Vec<f32>,
}

impl<'d> Trainer<'d> {
    /// Load artifacts for `config_name` and bind to `ds`.
    pub fn new(
        rt: &Runtime,
        manifest: &Manifest,
        config_name: &str,
        ds: &'d Dataset,
        opts: &TrainerOptions,
    ) -> crate::Result<Trainer<'d>> {
        let art = manifest.get(config_name)?.clone();
        anyhow::ensure!(
            art.d_in == ds.feat_dim && art.classes >= ds.num_classes,
            "artifact {} dims (d_in={}, C={}) incompatible with dataset {} (d={}, C={})",
            art.name, art.d_in, art.classes, ds.name, ds.feat_dim, ds.num_classes
        );
        let train_exe = rt.load_hlo_text(&art.train_hlo)?;
        let forward_exe = rt.load_hlo_text(&art.forward_hlo)?;
        let sampler_cfg = SamplerConfig {
            fanout: opts.fanout,
            layers: art.layers,
            kappa: opts.kappa,
            ..Default::default()
        };
        let stream = TrainStream::new(
            ds,
            opts.kind,
            sampler_cfg,
            art.batch,
            opts.seed,
            opts.exec,
            opts.batching,
        );
        let store = stream.feature_store();
        let state = ParamState::init(&art, opts.seed ^ 0xFACE);
        let lr = opts.lr.unwrap_or(art.lr);
        Ok(Trainer {
            ds,
            art,
            train_exe,
            forward_exe,
            state,
            stream,
            store,
            lr,
            feat_buf: Vec::new(),
        })
    }

    /// Draw the next training seed batch (uniform without replacement).
    pub fn next_seeds(&mut self) -> Vec<VertexId> {
        self.stream.next_seeds()
    }

    /// A fresh external stream with the trainer's exact internal recipe,
    /// sharing its feature store (see [`TrainStream::fresh_clone`]) —
    /// wrap in [`crate::pipeline::with_prefetch`] and feed
    /// [`Trainer::step_from`] for overlapped training with trajectories
    /// bit-identical to [`Trainer::step`] at the same seed.
    pub fn make_stream(&self) -> TrainStream<'d> {
        self.stream.fresh_clone()
    }

    /// One training step pulled from the trainer's own stream — batch
    /// drawing, sampling, *and feature gathering* all happen in the
    /// stream; the trainer pads and executes.
    pub fn step(&mut self) -> crate::Result<StepStats> {
        let mb = self.stream.next_batch();
        self.step_on_batch(mb)
    }

    /// One training step pulled from an external stream (e.g. the
    /// Figure 9 convergence arms, or a prefetched wrapper of the same
    /// recipe). The stream must materialize a merged MFG; engine
    /// measurement streams yield counts only.
    pub fn step_from(&mut self, stream: &mut dyn MinibatchStream) -> crate::Result<StepStats> {
        let mb = stream.next_batch();
        self.step_on_batch(mb)
    }

    /// Shared consumer half: pad + execute a stream-produced minibatch,
    /// using its pre-gathered feature buffer when it ships one. Stream
    /// stage times are split per the [`StepStats`] field semantics
    /// (sampling vs feature gather), not lumped into `sample_ms`.
    fn step_on_batch(&mut self, mb: crate::pipeline::Minibatch) -> crate::Result<StepStats> {
        let mfg = mb
            .merged
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("stream yields no merged MFG (measurement stream?)"))?;
        let pre = mb.per_pe.first().and_then(|w| w.features.as_deref());
        let mut stats = self.step_on_mfg_with(mfg, pre)?;
        stats.absorb_stream_times(&mb);
        Ok(stats)
    }

    /// One training step on given seeds (samples via the trainer's
    /// stream, advancing its dependent-batch RNG).
    pub fn step_on_seeds(&mut self, seeds: &[VertexId]) -> crate::Result<StepStats> {
        let t = Timer::start();
        let mfg = self.stream.sample_on(seeds);
        let sample_ms = t.elapsed_ms();
        let mut stats = self.step_on_mfg(&mfg)?;
        stats.sample_ms = sample_ms;
        Ok(stats)
    }

    /// One training step on a pre-built MFG (used by harnesses that
    /// construct batches through external streams); features come from
    /// the trainer's store.
    pub fn step_on_mfg(&mut self, mfg: &Mfg) -> crate::Result<StepStats> {
        self.step_on_mfg_with(mfg, None)
    }

    fn step_on_mfg_with(&mut self, mfg: &Mfg, pre: Option<&[f32]>) -> crate::Result<StepStats> {
        let mut stats = StepStats::default();
        let t = Timer::start();
        let labels = &self.ds.labels;
        let batch = mfg.pad(&self.art.caps, |v| labels[v as usize]);
        stats.pad_ms = t.elapsed_ms();
        stats.truncated_vertices = batch.truncated_vertices;
        stats.truncated_edges = batch.truncated_edges;
        stats.input_vertices = mfg.input_vertices().len();

        let t = Timer::start();
        self.fill_padded_features(mfg, pre);
        stats.feature_ms = t.elapsed_ms();

        let t = Timer::start();
        let inputs = train_inputs(&self.art, &self.state, &self.feat_buf, &batch, self.lr)?;
        let outs = self.train_exe.run(&inputs)?;
        let (loss, correct) = self.state.absorb(&outs)?;
        stats.exec_ms = t.elapsed_ms();
        stats.loss = loss;
        let denom = batch.label_mask.iter().sum::<f32>().max(1.0);
        stats.acc = correct / denom;
        Ok(stats)
    }

    /// Fill the padded `[cap × d]` input tensor. With a stream-shipped
    /// buffer (`pre`, dense rows over the full `S^L` in order) this is a
    /// prefix memcpy — the expensive gather already happened in the
    /// stream, possibly overlapped with the previous step's execution.
    /// Without one, the clipped input rows are read from the store.
    fn fill_padded_features(&mut self, mfg: &Mfg, pre: Option<&[f32]>) {
        let cap = *self.art.caps.n.last().unwrap();
        let d = self.art.d_in;
        self.feat_buf.clear();
        self.feat_buf.resize(cap * d, 0.0);
        let vs = mfg.clipped_input_vertices(&self.art.caps);
        match pre {
            Some(rows) => {
                debug_assert_eq!(rows.len(), mfg.input_vertices().len() * d);
                // the clipped list is a prefix of S^L, so its rows are a
                // prefix of the shipped buffer
                self.feat_buf[..vs.len() * d].copy_from_slice(&rows[..vs.len() * d]);
            }
            None => self.store.gather_into(vs, &mut self.feat_buf[..vs.len() * d]),
        }
    }

    /// Evaluate accuracy/macro-F1 on `nodes` (validation or test split)
    /// using sampled neighborhoods with an evaluation-only RNG (the
    /// training dependent-RNG state is untouched). `eval_seed` fixes the
    /// sampled neighborhoods across calls for comparability.
    pub fn evaluate(&mut self, nodes: &[VertexId], eval_seed: u64) -> crate::Result<EvalStats> {
        let b = self.art.caps.n[0];
        let sampler_cfg = SamplerConfig {
            fanout: self.stream.config().fanout,
            layers: self.art.layers,
            kappa: Kappa::Finite(1),
            ..Default::default()
        };
        let mut eval_sampler = sampler_cfg.build(self.stream.kind(), &self.ds.graph, eval_seed);
        let mut pairs: Vec<(u16, u16)> = Vec::with_capacity(nodes.len());
        for chunk in nodes.chunks(b) {
            let mfg = eval_sampler.sample_mfg(chunk);
            let batch = {
                let labels = &self.ds.labels;
                mfg.pad(&self.art.caps, |v| labels[v as usize])
            };
            self.fill_padded_features(&mfg, None);
            let inputs = forward_inputs(&self.art, &self.state, &self.feat_buf, &batch)?;
            let outs = self.forward_exe.run(&inputs)?;
            anyhow::ensure!(outs.len() == 1, "forward returns 1 output");
            let logits = to_vec_f32(&outs[0])?;
            let c = self.art.classes;
            for (i, &v) in chunk.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as u16)
                    .unwrap_or(0);
                pairs.push((pred, self.ds.label(v)));
            }
        }
        Ok(score(self.ds.num_classes, &pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{Minibatch, PeWork};

    /// The timing-misattribution regression: the stream's gather time
    /// must land in `feature_ms` (on top of the trainer-side copy), not
    /// be folded into `sample_ms`; unattributed stream wall stays with
    /// sampling so the stages still cover the wall clock.
    #[test]
    fn stream_times_split_sampling_from_gather() {
        let work = PeWork { samp_ms: 6.0, feat_ms: 3.0, ..Default::default() };
        let mb = Minibatch { index: 0, per_pe: vec![work], merged: None, wall_ms: 10.0 };
        let mut stats = StepStats { feature_ms: 0.5, ..Default::default() }; // trainer-side copy
        stats.absorb_stream_times(&mb);
        assert!((stats.feature_ms - 3.5).abs() < 1e-12, "gather + copy: {}", stats.feature_ms);
        assert!((stats.sample_ms - 7.0).abs() < 1e-12, "wall minus gather: {}", stats.sample_ms);

        // stage sum can exceed a threaded stream's wall (per-PE elapsed
        // overlaps); sample_ms then falls back to the reported sampling
        let work = PeWork { samp_ms: 6.0, feat_ms: 8.0, ..Default::default() };
        let mb = Minibatch { index: 0, per_pe: vec![work], merged: None, wall_ms: 9.0 };
        let mut stats = StepStats::default();
        stats.absorb_stream_times(&mb);
        assert!((stats.sample_ms - 6.0).abs() < 1e-12);
        assert!((stats.feature_ms - 8.0).abs() < 1e-12);
    }
}
