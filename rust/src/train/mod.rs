//! The training loop: pipeline stream → layered GNN compute → metrics,
//! all through the [`crate::model::GnnModel`] backend seam.
//!
//! [`trainer::Trainer`] owns a single-PE model backend (the host
//! layered compute plane by default, the PJRT/AOT bridge when a runtime
//! and artifacts are present) and the host-side parameter/optimizer
//! state; batch drawing and MFG sampling come from a
//! [`crate::pipeline::TrainStream`] (the trainer's own, configured by
//! [`TrainerOptions`], or any external
//! [`crate::pipeline::MinibatchStream`] via [`Trainer::step_from`]).
//! One [`Trainer::step`] = one backend `train_on_mfg`; Python is never
//! involved. [`evalx`] adds accuracy / macro-F1 evaluation over the
//! validation/test splits through the backend forward pass.
//!
//! [`parallel::ParallelTrainer`] is the **multi-PE training plane**: one
//! layered-model replica per PE over a
//! [`crate::pipeline::EngineStream`], per-layer hidden-activation
//! exchange between PEs in cooperative mode, and replicated
//! [`crate::runtime::tensors::ParamState`]s kept bit-identical by a
//! gradient all-reduce on the fabric
//! ([`crate::coop::all_to_all::PeEndpoint::all_reduce_f32`]) — the
//! independent-vs-cooperative end-to-end comparison (`repro end2end`,
//! CLI `train --train-pes N`) runs through it. [`parallel::LayerProfile`]
//! carries its per-layer gather/matmul compute decomposition.

pub mod trainer;
pub mod evalx;
pub mod parallel;

pub use trainer::{StepStats, Trainer, TrainerOptions};
pub use evalx::EvalStats;
pub use parallel::{LayerProfile, ParallelRunReport, ParallelStepStats, ParallelTrainer};

// retained re-export: the indep-merged sampling core moved to the
// pipeline with the rest of the batch-assembly logic
pub use crate::pipeline::sample_indep_parts;
