//! The training loop: pipeline stream → padded blocks → AOT train-step
//! → metrics.
//!
//! [`trainer::Trainer`] owns the compiled train/forward executables and
//! the host-side parameter/optimizer state; batch drawing and MFG
//! sampling come from a [`crate::pipeline::TrainStream`] (the trainer's
//! own, configured by [`TrainerOptions`], or any external
//! [`crate::pipeline::MinibatchStream`] via [`Trainer::step_from`]).
//! One [`Trainer::step`] = one PJRT execution; Python is never involved.
//! [`evalx`] adds accuracy / macro-F1 evaluation over the
//! validation/test splits through the forward executable.

pub mod trainer;
pub mod evalx;

pub use trainer::{StepStats, Trainer, TrainerOptions};
pub use evalx::EvalStats;

// retained re-export: the indep-merged sampling core moved to the
// pipeline with the rest of the batch-assembly logic
pub use crate::pipeline::sample_indep_parts;
