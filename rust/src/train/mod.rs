//! The training loop: samplers → padded blocks → AOT train-step → metrics.
//!
//! [`trainer::Trainer`] owns the compiled train/forward executables, the
//! host-side parameter/optimizer state, the (dependent) sampler, and the
//! batch drawing. One [`Trainer::step`] = one PJRT execution; Python is
//! never involved. [`evalx`] adds accuracy / macro-F1 evaluation over the
//! validation/test splits through the forward executable.

pub mod trainer;
pub mod evalx;

pub use trainer::{sample_indep_parts, StepStats, Trainer, TrainerOptions};
pub use evalx::EvalStats;
