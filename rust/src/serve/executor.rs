//! Batch execution for the serving plane: admitted requests become one
//! cooperative (or independent) engine batch, and the measured counts
//! become a modeled service time.
//!
//! The executor is a thin owner of the pipeline's
//! [`EngineStream`] driven through
//! [`EngineStream::batch_for_seeds`] — the engine's explicit-seed entry
//! point. Per-PE samplers, the row-carrying fabric, and the LRU row
//! caches all live *in the stream, across batches*: consecutive request
//! batches hit warm caches exactly like κ-dependent minibatching, which
//! is what converts the workload's hot-set skew into latency wins.
//!
//! **Service time is modeled, not measured.** The engine's counts
//! (sampled edges, storage bytes at β, fabric bytes at α, gathered rows)
//! are deterministic for a fixed seed — identical across
//! `--exec serial|threaded` and `--prefetch 0|1` — so pushing them
//! through the [`crate::costmodel`] bandwidth constants yields a
//! bit-reproducible virtual service time ([`modeled_service_us`]), while
//! real CPU wall time is recorded for the benches but never consulted by
//! any decision. A fixed [`BATCH_OVERHEAD_US`] dispatch cost is what
//! makes batching worth waiting for at all.
//!
//! Predictions run the full layered model through a
//! [`crate::model::Predictor`] snapshot (the same compute path training
//! and evaluation use) over each PE's [`crate::model::PeCompute`] blocks
//! and gathered feature buffer. With `--prefetch 1` the prediction pass
//! of batch `t` runs on a background thread while the event loop admits
//! and samples batch `t+1` — real overlap, and *provably*
//! ledger-neutral, because predictions only feed the output checksum,
//! never an admission.

use crate::coop::engine::Mode;
use crate::costmodel::{ModelCost, SystemPreset};
use crate::graph::{Partition, VertexId};
use crate::model::{PeCompute, Predictor};
use crate::pipeline::{EngineStream, PeWork};
use crate::util::stats::Timer;
use std::collections::HashMap;

use super::workload::Request;

/// Fixed per-dispatch overhead (µs): admission, tensor assembly, kernel
/// launch — the cost that amortizes away as the batch grows, creating
/// the queueing-delay vs per-item-work tradeoff the adaptive batcher
/// navigates.
pub const BATCH_OVERHEAD_US: f64 = 150.0;

/// Modeled µs for one PE's stage counts at `preset` bandwidths:
/// sampling (adjacency reads at β, id redistribution at α), feature
/// loading (storage bytes at β, row fabric at α), and a memory-bound
/// *inference* forward (no backward) at γ. Mirrors
/// [`crate::costmodel::estimate`]'s constants, reduced to one PE and
/// forward-only.
///
/// `s` is `|S^l|` for `l in 0..=L` (`s[L]` = gathered input rows), `e`
/// is `|E^l|` for `l in 0..L`, `cross_ids` the total ids this PE pushed
/// cross-PE over all rounds (each travels out and back, 4 B per id per
/// direction).
#[allow(clippy::too_many_arguments)]
pub fn stage_us(
    s: &[f64],
    e: &[f64],
    cross_ids: f64,
    storage_bytes: f64,
    fabric_bytes: f64,
    d_in: usize,
    preset: &SystemPreset,
    model: &ModelCost,
) -> f64 {
    // GB/s → bytes/µs is ×1e3
    let us = |bytes: f64, gbps: f64| bytes / (gbps * 1e3);
    let layers = e.len();
    debug_assert_eq!(s.len(), layers + 1, "s carries L+1 per-layer counts");
    // sampling: 8 B per candidate edge examined ×4 (costmodel's adjacency
    // constant) + 16 B bookkeeping per processed vertex, at β; ids out
    // and back at α
    let samp_beta: f64 = e.iter().map(|&x| x * 32.0).sum::<f64>()
        + s[..layers].iter().map(|&x| x * 16.0).sum::<f64>();
    let samp_alpha = cross_ids * 8.0;
    // inference forward: stream edge messages + read source rows + write
    // hidden activations, once (no backward in serving)
    let requested = s[layers];
    let fwd_gamma = model.m_factor
        * 4.0
        * (e.iter().sum::<f64>() * model.hidden as f64
            + requested * d_in as f64
            + s[0] * model.hidden as f64);
    us(samp_beta + storage_bytes, preset.beta)
        + us(samp_alpha + fabric_bytes, preset.alpha)
        + us(fwd_gamma, preset.gamma)
}

/// One PE's modeled stage time from its measured work record.
fn pe_us(w: &PeWork, preset: &SystemPreset, model: &ModelCost) -> f64 {
    let s: Vec<f64> = w.counts_s.iter().map(|&c| c as f64).collect();
    let e: Vec<f64> = w.counts_e.iter().map(|&c| c as f64).collect();
    let cross: f64 = w.counts_cross.iter().map(|&c| c as f64).sum();
    // model width is the decoded dimensionality — with a compressed
    // codec row_bytes/4 would understate it (the old derivation)
    let d_in = (w.dim as usize).max(1);
    stage_us(
        &s,
        &e,
        cross,
        w.bytes_from_storage as f64,
        w.fabric_bytes as f64,
        d_in,
        preset,
        model,
    )
}

/// Virtual service time of one executed batch: dispatch overhead plus
/// the slowest PE's modeled stage time (the batch is synchronous — all
/// PEs barrier on the fabric). Integer µs, deterministically rounded,
/// never zero.
pub fn modeled_service_us(per_pe: &[PeWork], preset: &SystemPreset, model: &ModelCost) -> u64 {
    let max_pe = per_pe.iter().map(|w| pe_us(w, preset, model)).fold(0.0, f64::max);
    (BATCH_OVERHEAD_US + max_pe).round().max(1.0) as u64
}

/// Everything the server needs to know about one executed batch (the
/// per-request predictions arrive separately, possibly from the
/// prefetch thread — see [`Executor::finish`]).
#[derive(Clone, Copy, Debug)]
pub struct BatchExecution {
    /// 0-based dispatch index.
    pub batch: u32,
    /// admitted requests.
    pub size: usize,
    /// modeled virtual service time (µs).
    pub service_us: u64,
    /// wire bytes (encoded rows of the active codec) read from storage
    /// across PEs (β).
    pub storage_bytes: u64,
    /// feature-row wire bytes over the fabric across PEs (α).
    pub fabric_bytes: u64,
    /// the slice of `fabric_bytes` that crossed a replica-group
    /// boundary (equals `fabric_bytes` on a flat fabric).
    pub fabric_inter_bytes: u64,
    /// cache fills served decoded out of the hot tier across PEs
    /// (0 without a tiered store).
    pub hot_rows: u64,
    /// decoded f32 bytes those hot fills moved (γ).
    pub hot_bytes: u64,
    /// rows requested through the caches across PEs.
    // lint:allow(ledger, reason = "determinism-assert counter only: compared across exec modes in tests, deliberately absent from the serve ledger")
    pub requested_rows: u64,
    /// sampled edges across PEs and layers.
    // lint:allow(ledger, reason = "determinism-assert counter only: compared across exec modes in tests, deliberately absent from the serve ledger")
    pub sampled_edges: u64,
    /// real CPU wall of assignment + sampling + gathering (measured for
    /// the benches; **never** consulted by a serving decision).
    pub wall_ms: f64,
}

/// The serving plane's execution engine: request→PE assignment, one
/// explicit-seed engine batch per dispatch, modeled service time,
/// layered-model predictions (optionally prediction-prefetched).
pub struct Executor<'p> {
    stream: EngineStream<'p>,
    part: &'p Partition,
    mode: Mode,
    num_pes: usize,
    preset: &'static SystemPreset,
    model: ModelCost,
    pred: Predictor,
    /// overlap batch t's prediction pass with batch t+1's admission.
    prefetch: bool,
    pending: Option<std::thread::JoinHandle<Vec<(u64, u16)>>>,
    done: Vec<(u64, u16)>,
    /// independent-mode round-robin assignment cursor (persists across
    /// batches so PE load stays balanced over time).
    rr_cursor: usize,
    batches: u32,
}

impl<'p> Executor<'p> {
    /// Stand up an executor over a pipeline's stream and a parameter
    /// snapshot ([`crate::train::ParallelTrainer::predictor`] /
    /// [`crate::model::GnnModel::predictor`]); predictions run the full
    /// layered model over each dispatched batch's per-PE compute.
    pub fn new(
        stream: EngineStream<'p>,
        part: &'p Partition,
        mode: Mode,
        preset: &'static SystemPreset,
        model: ModelCost,
        pred: Predictor,
        prefetch: bool,
    ) -> Executor<'p> {
        let num_pes = part.num_parts;
        Executor {
            stream,
            part,
            mode,
            num_pes,
            preset,
            model,
            pred,
            prefetch,
            pending: None,
            done: Vec::new(),
            rr_cursor: 0,
            batches: 0,
        }
    }

    pub fn batches(&self) -> u32 {
        self.batches
    }

    /// Execute one admitted batch: assign each request to a PE (owner
    /// of its vertex in cooperative mode — the Algorithm 1 discipline —
    /// round-robin in independent mode), run the engine on the
    /// deduplicated per-PE seed lists, model the service time from the
    /// measured counts, and start the prediction pass.
    pub fn execute(&mut self, reqs: &[Request]) -> BatchExecution {
        assert!(!reqs.is_empty(), "dispatched an empty batch");
        let wall = Timer::start();
        let mut per_pe_seeds: Vec<Vec<VertexId>> = vec![Vec::new(); self.num_pes];
        let mut assignment: Vec<(u64, VertexId, usize)> = Vec::with_capacity(reqs.len());
        for r in reqs {
            let pe = match self.mode {
                Mode::Cooperative => self.part.part_of(r.vertex),
                Mode::Independent => {
                    let pe = self.rr_cursor % self.num_pes;
                    self.rr_cursor += 1;
                    pe
                }
            };
            assignment.push((r.id, r.vertex, pe));
            per_pe_seeds[pe].push(r.vertex);
        }
        // two requests for the same vertex on one PE share one seed
        // (first-occurrence order kept — deterministic)
        for seeds in per_pe_seeds.iter_mut() {
            let mut seen = std::collections::HashSet::with_capacity(seeds.len());
            seeds.retain(|v| seen.insert(*v));
        }

        let mb = self.stream.batch_for_seeds(per_pe_seeds);
        let service_us = modeled_service_us(&mb.per_pe, self.preset, &self.model);
        let exec = BatchExecution {
            batch: self.batches,
            size: reqs.len(),
            service_us,
            storage_bytes: mb.per_pe.iter().map(|w| w.bytes_from_storage).sum(),
            fabric_bytes: mb.per_pe.iter().map(|w| w.fabric_bytes).sum(),
            fabric_inter_bytes: mb.per_pe.iter().map(|w| w.fabric_inter_bytes).sum(),
            hot_rows: mb.per_pe.iter().map(|w| w.hot_rows).sum(),
            hot_bytes: mb.per_pe.iter().map(|w| w.hot_bytes).sum(),
            requested_rows: mb.per_pe.iter().map(|w| w.requested).sum(),
            sampled_edges: mb
                .per_pe
                .iter()
                .map(|w| w.counts_e.iter().sum::<u64>())
                .sum(),
            wall_ms: wall.elapsed_ms(),
        };
        self.batches += 1;

        // prediction pass: each PE's compute payload covers its seeds
        // (blocks over S^L independently; over S̃^L + activation routes
        // cooperatively), with the gathered buffer as the input rows
        let pes: Vec<(PeCompute, Vec<f32>)> = mb
            .per_pe
            .into_iter()
            .map(|w| {
                (
                    w.compute.expect("engine batches carry layered compute"),
                    w.features.expect("engine batches carry feature buffers"),
                )
            })
            .collect();
        if self.prefetch {
            // join batch t-1's pass (it has had a full admission cycle
            // to run), then launch batch t's in the background
            if let Some(h) = self.pending.take() {
                self.done.extend(h.join().expect("prediction thread panicked"));
            }
            let pred = self.pred.clone();
            self.pending = Some(std::thread::spawn(move || {
                predict_batch(&pred, &pes, &assignment)
            }));
        } else {
            self.done.extend(predict_batch(&self.pred, &pes, &assignment));
        }
        exec
    }

    /// Join any in-flight prediction pass and hand back every
    /// `(request id, predicted class)` produced since the last call.
    pub fn finish(&mut self) -> Vec<(u64, u16)> {
        if let Some(h) = self.pending.take() {
            self.done.extend(h.join().expect("prediction thread panicked"));
        }
        std::mem::take(&mut self.done)
    }
}

/// The forward pass over one executed batch: run the layered model over
/// every PE's compute payload at once (cooperative batches exchange
/// hidden activations between the PE contexts, exactly like training),
/// then route each request's predicted class back by its seed vertex.
/// Pure function of its inputs — safe to run on the prefetch thread.
fn predict_batch(
    pred: &Predictor,
    pes: &[(PeCompute, Vec<f32>)],
    assignment: &[(u64, VertexId, usize)],
) -> Vec<(u64, u16)> {
    let refs: Vec<(&PeCompute, &[f32])> =
        pes.iter().map(|(c, f)| (c, f.as_slice())).collect();
    let classes = pred.predict_minibatch(&refs);
    let maps: Vec<HashMap<VertexId, u16>> = pes
        .iter()
        .zip(&classes)
        .map(|((c, _), cls)| c.seeds.iter().copied().zip(cls.iter().copied()).collect())
        .collect();
    assignment
        .iter()
        .map(|&(id, v, pe)| {
            let class = *maps[pe]
                .get(&v)
                .expect("request vertex must be a seed on its assigned PE");
            (id, class)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::all_to_all::AllReduceStrategy;
    use crate::coop::engine::ExecMode;
    use crate::costmodel;
    use crate::pipeline::PipelineBuilder;

    fn requests(vs: &[VertexId]) -> Vec<Request> {
        vs.iter()
            .enumerate()
            .map(|(i, &v)| Request {
                id: i as u64,
                requester: (i % 3) as u32,
                vertex: v,
                arrival_us: i as u64,
            })
            .collect()
    }

    fn run_batches(
        mode: Mode,
        exec: ExecMode,
        prefetch: bool,
    ) -> (Vec<BatchExecution>, Vec<(u64, u16)>) {
        let pipe = PipelineBuilder::new()
            .dataset("tiny")
            .mode(mode)
            .exec(exec)
            .num_pes(3)
            .cache_per_pe(300)
            .seed(17)
            .build()
            .unwrap();
        let trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let stream = pipe.stream();
        let mut ex = Executor::new(
            stream,
            &pipe.part,
            mode,
            costmodel::preset("4xA100").unwrap(),
            ModelCost::gcn(pipe.ds.feat_dim, 128),
            trainer.predictor(),
            prefetch,
        );
        let mut execs = Vec::new();
        for round in 0..3 {
            let vs: Vec<VertexId> = (0..40).map(|i| (i * 7 + round) % 2000).collect();
            execs.push(ex.execute(&requests(&vs)));
        }
        let mut preds = ex.finish();
        preds.sort_unstable();
        (execs, preds)
    }

    #[test]
    fn serial_threaded_and_prefetch_are_bit_identical() {
        for mode in [Mode::Independent, Mode::Cooperative] {
            let (base, preds0) = run_batches(mode, ExecMode::Serial, false);
            for (exec, prefetch) in
                [(ExecMode::Threaded, false), (ExecMode::Serial, true), (ExecMode::Threaded, true)]
            {
                let (other, preds1) = run_batches(mode, exec, prefetch);
                for (a, b) in base.iter().zip(&other) {
                    assert_eq!(a.service_us, b.service_us, "{mode:?}/{exec:?}/{prefetch}");
                    assert_eq!(a.storage_bytes, b.storage_bytes, "{mode:?}/{exec:?}/{prefetch}");
                    assert_eq!(a.fabric_bytes, b.fabric_bytes, "{mode:?}/{exec:?}/{prefetch}");
                    assert_eq!(a.requested_rows, b.requested_rows);
                    assert_eq!(a.sampled_edges, b.sampled_edges);
                }
                assert_eq!(preds0, preds1, "{mode:?}/{exec:?}/{prefetch}: predictions");
            }
        }
    }

    #[test]
    fn predictions_match_a_duplicate_pipeline_predictor() {
        let build = || {
            PipelineBuilder::new()
                .dataset("tiny")
                .mode(Mode::Cooperative)
                .num_pes(2)
                .seed(23)
                .build()
                .unwrap()
        };
        let pipe = build();
        let trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let mut ex = Executor::new(
            pipe.stream(),
            &pipe.part,
            Mode::Cooperative,
            costmodel::preset("4xA100").unwrap(),
            ModelCost::gcn(pipe.ds.feat_dim, 128),
            trainer.predictor(),
            false,
        );
        let vs: Vec<VertexId> = vec![5, 9, 9, 100, 731]; // duplicate on purpose
        let reqs = requests(&vs);
        ex.execute(&reqs);
        let mut preds = ex.finish();
        preds.sort_unstable();
        assert_eq!(preds.len(), reqs.len(), "every request predicted, duplicates included");

        // oracle: an identically-seeded pipeline, the same owner
        // assignment + per-PE dedup, predicted straight through the
        // Predictor minibatch path — validates the executor's
        // request→PE→seed routing, duplicates included
        let dup = build();
        let oracle = dup.parallel_trainer(0.05, AllReduceStrategy::Ring).predictor();
        let mut per_pe: Vec<Vec<VertexId>> = vec![Vec::new(); 2];
        for &v in &vs {
            let pe = dup.part.part_of(v);
            if !per_pe[pe].contains(&v) {
                per_pe[pe].push(v);
            }
        }
        let mut stream = dup.stream();
        let mb = stream.batch_for_seeds(per_pe);
        let pes: Vec<(PeCompute, Vec<f32>)> = mb
            .per_pe
            .into_iter()
            .map(|w| (w.compute.unwrap(), w.features.unwrap()))
            .collect();
        let refs: Vec<(&PeCompute, &[f32])> =
            pes.iter().map(|(c, f)| (c, f.as_slice())).collect();
        let classes = oracle.predict_minibatch(&refs);
        let mut want: HashMap<VertexId, u16> = HashMap::new();
        for ((c, _), cls) in pes.iter().zip(&classes) {
            for (&v, &cl) in c.seeds.iter().zip(cls) {
                want.insert(v, cl);
            }
        }
        for (id, class) in preds {
            let v = reqs[id as usize].vertex;
            assert_eq!(class, want[&v], "request {id} (vertex {v})");
        }
    }

    #[test]
    fn warm_caches_cut_storage_bytes_across_request_batches() {
        // the κ-style temporal story: re-serving the same hot vertices
        // must hit the caches the previous batch filled
        let pipe = PipelineBuilder::new()
            .dataset("tiny")
            .mode(Mode::Cooperative)
            .num_pes(2)
            .cache_per_pe(1000)
            .seed(31)
            .build()
            .unwrap();
        let trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let mut ex = Executor::new(
            pipe.stream(),
            &pipe.part,
            Mode::Cooperative,
            costmodel::preset("4xA100").unwrap(),
            ModelCost::gcn(pipe.ds.feat_dim, 128),
            trainer.predictor(),
            false,
        );
        let vs: Vec<VertexId> = (0..60).map(|i| i * 3 % 2000).collect();
        let cold = ex.execute(&requests(&vs));
        let warm = ex.execute(&requests(&vs));
        assert!(cold.storage_bytes > 0);
        assert!(
            warm.storage_bytes < cold.storage_bytes,
            "second pass must hit warm caches: {} vs {}",
            warm.storage_bytes,
            cold.storage_bytes
        );
        // (the byte saving flows into the modeled service time too, but
        // on tiny's 64-byte rows it can round away at µs resolution —
        // the repro table on flickr-s is where it shows)
        ex.finish();
    }

    #[test]
    fn modeled_service_is_concave_in_batch_size() {
        let pipe = PipelineBuilder::new()
            .dataset("tiny")
            .mode(Mode::Cooperative)
            .num_pes(2)
            .cache_per_pe(0) // pass-through caches: pure per-batch work
            .seed(41)
            .build()
            .unwrap();
        let trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let mut service = |n: usize| {
            let mut ex = Executor::new(
                pipe.stream(),
                &pipe.part,
                Mode::Cooperative,
                costmodel::preset("4xA100").unwrap(),
                ModelCost::gcn(pipe.ds.feat_dim, 128),
                trainer.predictor(),
                false,
            );
            let vs: Vec<VertexId> = (0..n as u32).map(|i| (i * 13) % 2000).collect();
            let e = ex.execute(&requests(&vs));
            ex.finish();
            e.service_us as f64
        };
        let (s32, s128) = (service(32), service(128));
        assert!(s128 > s32, "more requests, more modeled work");
        assert!(s128 < 4.0 * s32, "concavity: 4x the requests, < 4x the time ({s32} vs {s128})");
        // the work term itself (overhead subtracted) must also be
        // concave — the paper's |S^L(n)| sublinearity, not just
        // overhead amortization
        let (w32, w128) = (s32 - BATCH_OVERHEAD_US, s128 - BATCH_OVERHEAD_US);
        assert!(w128 < 4.0 * w32, "sublinear sampled work: {w32} vs {w128}");
    }
}
