//! The per-request latency ledger and its reduction to serving metrics.
//!
//! Every admitted request gets one [`RequestRecord`] — arrival,
//! dispatch, completion (all virtual µs), the batch that carried it, and
//! the predicted class — and every dispatch one [`BatchRecord`] with its
//! modeled service time and measured byte movement. The reduction
//! ([`Ledger::summarize`]) produces the numbers the repro table and the
//! CLI print: exact p50/p90/p99 latency ([`crate::util::stats::percentile`]),
//! virtual throughput, mean batch size, bytes per request, and the SLO
//! violation rate.
//!
//! [`Ledger::checksum`] folds every record — timestamps *and*
//! predictions — into one FNV-1a hash: the single number the
//! determinism tests compare across `--exec serial|threaded` and
//! `--prefetch 0|1`.

use crate::graph::VertexId;
use crate::util::stats::percentile;
use std::collections::HashMap;

/// One served request's life in virtual time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestRecord {
    pub id: u64,
    pub requester: u32,
    pub vertex: VertexId,
    pub arrival_us: u64,
    pub dispatch_us: u64,
    pub completion_us: u64,
    /// 0-based index of the batch that served it.
    pub batch: u32,
    /// predicted class (the trainer head's argmax).
    pub predicted: u16,
}

impl RequestRecord {
    pub fn latency_us(&self) -> u64 {
        self.completion_us - self.arrival_us
    }
}

/// One dispatched batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchRecord {
    pub index: u32,
    pub size: u32,
    pub dispatch_us: u64,
    // lint:allow(ledger, reason = "completion_us = dispatch_us + service_us is folded per request by the caller of record_batch; kept for per-batch introspection")
    pub service_us: u64,
    pub storage_bytes: u64,
    pub fabric_bytes: u64,
    /// slice of `fabric_bytes` that crossed the slower inter-group
    /// fabric tier (0 on a flat topology).
    pub fabric_inter_bytes: u64,
    /// cache fills served decoded out of the hot tier (0 untiered).
    pub hot_rows: u64,
    /// decoded f32 bytes those hot fills moved (γ).
    pub hot_bytes: u64,
}

/// The full run transcript: requests, batches, and drop accounting.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub requests: Vec<RequestRecord>,
    pub batches: Vec<BatchRecord>,
    /// arrivals after the final dispatch that were never admitted.
    pub dropped: u64,
    by_id: HashMap<u64, usize>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Record one dispatched batch and all requests it carries
    /// (predictions are merged later via [`Ledger::set_prediction`]).
    pub fn record_batch(
        &mut self,
        batch: BatchRecord,
        reqs: &[super::workload::Request],
        completion_us: u64,
    ) {
        for r in reqs {
            debug_assert!(r.arrival_us <= batch.dispatch_us, "dispatched before arrival");
            self.by_id.insert(r.id, self.requests.len());
            self.requests.push(RequestRecord {
                id: r.id,
                requester: r.requester,
                vertex: r.vertex,
                arrival_us: r.arrival_us,
                dispatch_us: batch.dispatch_us,
                completion_us,
                batch: batch.index,
                predicted: 0,
            });
        }
        self.batches.push(batch);
    }

    /// Attach a prediction to its request (panics on unknown ids — the
    /// executor only predicts what the server admitted).
    pub fn set_prediction(&mut self, id: u64, class: u16) {
        let idx = *self.by_id.get(&id).expect("prediction for an unadmitted request");
        self.requests[idx].predicted = class;
    }

    /// FNV-1a over every record in id order: timestamps, batch
    /// assignment, and predictions. Two runs with equal checksums made
    /// the same admissions at the same virtual times and predicted the
    /// same classes.
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut ids: Vec<u64> = self.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let mut h = OFFSET;
        let mut fold = |x: u64| {
            for byte in x.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        for id in ids {
            let r = &self.requests[self.by_id[&id]];
            fold(r.id);
            fold(r.requester as u64);
            fold(r.vertex as u64);
            fold(r.arrival_us);
            fold(r.dispatch_us);
            fold(r.completion_us);
            fold(r.batch as u64);
            fold(r.predicted as u64);
        }
        h
    }

    /// Derive the flight-recorder trace from the transcript: track 0
    /// carries each dispatched batch's virtual service window split
    /// across `serve_storage` / `serve_fabric` / `serve_hot`
    /// proportionally to the byte ledgers (largest-remainder, so the
    /// sub-spans tile the window and their summed bytes equal the
    /// ledger totals exactly), track 1 carries every request's
    /// `queue` (arrival→dispatch) and `service` (dispatch→completion)
    /// phases. All timestamps are the virtual-µs clock, so the trace —
    /// like the ledger it is a pure function of — is **bit-identical
    /// across serial/threaded exec and prefetch 0/1** at a fixed seed
    /// (pinned in `tests/integration_obs.rs`).
    pub fn trace(&self) -> crate::obs::TraceBuffer {
        use crate::obs::{split_dur, Span, TraceBuffer, TraceSink};
        let mut buf = TraceBuffer::new("serve");
        for b in &self.batches {
            let parts = split_dur(
                b.service_us,
                &[b.storage_bytes, b.fabric_bytes, b.hot_bytes],
            );
            let mut t = b.dispatch_us;
            for (seq, (stage, (dur, bytes))) in
                ["serve_storage", "serve_fabric", "serve_hot"]
                    .into_iter()
                    .zip(parts.iter().zip([
                        b.storage_bytes,
                        b.fabric_bytes,
                        b.hot_bytes,
                    ]))
                    .enumerate()
            {
                buf.record(Span {
                    batch: b.index as u64,
                    pe: 0,
                    seq: seq as u32,
                    stage,
                    t_start_us: t,
                    t_end_us: t + dur,
                    bytes,
                });
                t += dur;
            }
        }
        // Requests ride track 1; seq restarts per batch (two spans per
        // request, admission order), so (batch, pe, seq) stays a total
        // order.
        let mut seq_in_batch: std::collections::BTreeMap<u32, u32> =
            std::collections::BTreeMap::new();
        for r in &self.requests {
            let seq = seq_in_batch.entry(r.batch).or_insert(0);
            buf.record(Span {
                batch: r.batch as u64,
                pe: 1,
                seq: *seq,
                stage: "queue",
                t_start_us: r.arrival_us,
                t_end_us: r.dispatch_us,
                bytes: 0,
            });
            buf.record(Span {
                batch: r.batch as u64,
                pe: 1,
                seq: *seq + 1,
                stage: "service",
                t_start_us: r.dispatch_us,
                t_end_us: r.completion_us,
                bytes: 0,
            });
            *seq += 2;
        }
        buf
    }

    /// Reduce the ledger to the serving metrics, judging latencies
    /// against `slo_us`.
    pub fn summarize(&self, slo_us: u64) -> ServeReport {
        let n = self.requests.len();
        if n == 0 {
            return ServeReport { slo_ms: slo_us as f64 / 1e3, ..Default::default() };
        }
        let mut lat_ms: Vec<f64> =
            self.requests.iter().map(|r| r.latency_us() as f64 / 1e3).collect();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Exact per-request phase waterfall: queue (arrival→dispatch)
        // and service (dispatch→completion) percentiles from the full
        // per-request populations — not histogram approximations.
        let mut queue_ms: Vec<f64> = self
            .requests
            .iter()
            .map(|r| (r.dispatch_us - r.arrival_us) as f64 / 1e3)
            .collect();
        queue_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut service_ms: Vec<f64> = self
            .requests
            .iter()
            .map(|r| (r.completion_us - r.dispatch_us) as f64 / 1e3)
            .collect();
        service_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let violations = self.requests.iter().filter(|r| r.latency_us() > slo_us).count();
        let first_arrival = self.requests.iter().map(|r| r.arrival_us).min().unwrap();
        let last_completion = self.requests.iter().map(|r| r.completion_us).max().unwrap();
        let span_s = (last_completion - first_arrival).max(1) as f64 / 1e6;
        let storage: u64 = self.batches.iter().map(|b| b.storage_bytes).sum();
        let fabric: u64 = self.batches.iter().map(|b| b.fabric_bytes).sum();
        let inter: u64 = self.batches.iter().map(|b| b.fabric_inter_bytes).sum();
        let hot_rows: u64 = self.batches.iter().map(|b| b.hot_rows).sum();
        let hot_bytes: u64 = self.batches.iter().map(|b| b.hot_bytes).sum();
        // Σ batch.size == served requests: every admitted request rides
        // exactly one batch, so this equals `n` (debug-asserted below)
        // while keeping the batch ledger itself load-bearing.
        let sized: u64 = self.batches.iter().map(|b| b.size as u64).sum();
        debug_assert_eq!(sized, n as u64, "batch sizes must cover every request");
        ServeReport {
            served: n as u64,
            batches: self.batches.len() as u64,
            dropped: self.dropped,
            mean_batch: sized as f64 / self.batches.len().max(1) as f64,
            p50_ms: percentile(&lat_ms, 0.50),
            p90_ms: percentile(&lat_ms, 0.90),
            p99_ms: percentile(&lat_ms, 0.99),
            max_ms: lat_ms[n - 1],
            queue_p50_ms: percentile(&queue_ms, 0.50),
            queue_p99_ms: percentile(&queue_ms, 0.99),
            service_p50_ms: percentile(&service_ms, 0.50),
            service_p99_ms: percentile(&service_ms, 0.99),
            requests_per_s: n as f64 / span_s,
            storage_bytes_per_req: storage as f64 / n as f64,
            fabric_bytes_per_req: fabric as f64 / n as f64,
            fabric_inter_bytes_per_req: inter as f64 / n as f64,
            hot_rows_per_req: hot_rows as f64 / n as f64,
            hot_bytes_per_req: hot_bytes as f64 / n as f64,
            slo_ms: slo_us as f64 / 1e3,
            slo_violations: violations as u64,
            slo_violation_rate: violations as f64 / n as f64,
            checksum: self.checksum(),
        }
    }
}

/// The serving-plane scorecard (latencies in virtual milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeReport {
    pub served: u64,
    pub batches: u64,
    pub dropped: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// queue-phase (arrival→dispatch) latency percentiles — the exact
    /// per-request waterfall, computed from the full population in
    /// [`Ledger::summarize`], not a histogram estimate.
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    /// service-phase (dispatch→completion) latency percentiles.
    pub service_p50_ms: f64,
    pub service_p99_ms: f64,
    /// virtual throughput: served / (last completion − first arrival).
    pub requests_per_s: f64,
    /// storage (β) bytes per served request.
    pub storage_bytes_per_req: f64,
    /// fabric (α) feature-row bytes per served request.
    pub fabric_bytes_per_req: f64,
    /// slice of the fabric bytes that crossed the inter-group tier
    /// (≤ `fabric_bytes_per_req`; 0 on a flat topology).
    pub fabric_inter_bytes_per_req: f64,
    /// hot-tier fills per served request (0 without tiering).
    pub hot_rows_per_req: f64,
    /// decoded hot-tier (γ) bytes per served request — deliberately
    /// *not* part of [`ServeReport::bytes_per_req`]: the headline column
    /// counts β+α wire movement, which the hot tier avoids.
    pub hot_bytes_per_req: f64,
    pub slo_ms: f64,
    pub slo_violations: u64,
    pub slo_violation_rate: f64,
    /// ledger checksum (admissions + timestamps + predictions).
    pub checksum: u64,
}

impl ServeReport {
    /// Total data-plane bytes per request (β + α) — the cooperative
    /// batching headline column.
    pub fn bytes_per_req(&self) -> f64 {
        self.storage_bytes_per_req + self.fabric_bytes_per_req
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} requests in {} batches (mean batch {:.1}, dropped {})",
            self.served, self.batches, self.mean_batch, self.dropped
        )?;
        writeln!(
            f,
            "latency ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  max {:.2}  (SLO {:.1} ms, \
             violations {} = {:.2}%)",
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.max_ms,
            self.slo_ms,
            self.slo_violations,
            self.slo_violation_rate * 100.0
        )?;
        writeln!(
            f,
            "phase waterfall ms: queue p50 {:.3} / p99 {:.3}  →  service p50 {:.3} / p99 {:.3}",
            self.queue_p50_ms, self.queue_p99_ms, self.service_p50_ms, self.service_p99_ms
        )?;
        write!(
            f,
            "throughput {:.0} req/s (virtual); bytes/request: {:.0} storage (β) + {:.0} \
             fabric (α, {:.0} inter) = {:.0} wire, {:.0} hot-tier (γ); ledger checksum {:#018x}",
            self.requests_per_s,
            self.storage_bytes_per_req,
            self.fabric_bytes_per_req,
            self.fabric_inter_bytes_per_req,
            self.bytes_per_req(),
            self.hot_bytes_per_req,
            self.checksum
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::workload::Request;

    fn req(id: u64, requester: u32, vertex: VertexId, arrival_us: u64) -> Request {
        Request { id, requester, vertex, arrival_us }
    }

    fn two_batch_ledger() -> Ledger {
        let mut l = Ledger::new();
        l.record_batch(
            BatchRecord {
                index: 0,
                size: 2,
                dispatch_us: 100,
                service_us: 400,
                storage_bytes: 1000,
                fabric_bytes: 200,
                fabric_inter_bytes: 150,
                hot_rows: 3,
                hot_bytes: 192,
            },
            &[req(0, 0, 5, 10), req(1, 1, 9, 60)],
            500,
        );
        l.record_batch(
            BatchRecord {
                index: 1,
                size: 1,
                dispatch_us: 700,
                service_us: 300,
                storage_bytes: 500,
                fabric_bytes: 0,
                fabric_inter_bytes: 0,
                hot_rows: 0,
                hot_bytes: 0,
            },
            &[req(2, 0, 7, 600)],
            1000,
        );
        l.set_prediction(0, 3);
        l.set_prediction(1, 1);
        l.set_prediction(2, 3);
        l
    }

    #[test]
    fn summarize_reduces_latency_and_bytes() {
        let l = two_batch_ledger();
        let r = l.summarize(450);
        assert_eq!(r.served, 3);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 1.5).abs() < 1e-12);
        // latencies: 490, 440, 400 µs → sorted [0.40, 0.44, 0.49] ms
        assert!((r.p50_ms - 0.44).abs() < 1e-9);
        assert!((r.max_ms - 0.49).abs() < 1e-9);
        assert_eq!(r.slo_violations, 1, "490µs breaches a 450µs SLO");
        assert!((r.storage_bytes_per_req - 500.0).abs() < 1e-9);
        assert!((r.fabric_bytes_per_req - 200.0 / 3.0).abs() < 1e-9);
        // the inter slice survives the reduction and never exceeds the
        // fabric total (the counter-conservation property the lint pins)
        assert!((r.fabric_inter_bytes_per_req - 150.0 / 3.0).abs() < 1e-9);
        assert!(r.fabric_inter_bytes_per_req <= r.fabric_bytes_per_req);
        assert!((r.bytes_per_req() - (1500.0 + 200.0) / 3.0).abs() < 1e-9);
        // hot-tier traffic is tracked per request but kept out of the
        // wire-bytes headline
        assert!((r.hot_rows_per_req - 1.0).abs() < 1e-9);
        assert!((r.hot_bytes_per_req - 64.0).abs() < 1e-9);
        // span = 1000 − 10 µs → ~3030 req/s virtual
        assert!((r.requests_per_s - 3.0 / (990.0 / 1e6)).abs() < 1.0);
    }

    #[test]
    fn waterfall_percentiles_are_exact() {
        let r = two_batch_ledger().summarize(450);
        // queue µs: 90, 40, 100 → sorted ms [0.04, 0.09, 0.10]
        assert!((r.queue_p50_ms - 0.09).abs() < 1e-9);
        assert!((r.queue_p99_ms - 0.0998).abs() < 1e-9);
        // service µs: 400, 400, 300 → sorted ms [0.30, 0.40, 0.40]
        assert!((r.service_p50_ms - 0.40).abs() < 1e-9);
        assert!((r.service_p99_ms - 0.40).abs() < 1e-9);
    }

    #[test]
    fn trace_spans_tile_batches_and_reconcile_bytes() {
        let l = two_batch_ledger();
        let t = l.trace();
        // 3 byte-stage spans per batch + 2 phase spans per request.
        assert_eq!(t.span_count(), 2 * 3 + 3 * 2);
        assert_eq!(t.stage_bytes("serve_storage"), 1500);
        assert_eq!(t.stage_bytes("serve_fabric"), 200);
        assert_eq!(t.stage_bytes("serve_hot"), 192);
        // (batch, pe, seq) is strictly increasing over the merge.
        let m = t.merged();
        for w in m.windows(2) {
            assert!((w[0].batch, w[0].pe, w[0].seq) < (w[1].batch, w[1].pe, w[1].seq));
        }
        // Batch sub-spans tile the service window exactly.
        let batch0: Vec<_> = m.iter().filter(|s| s.batch == 0 && s.pe == 0).collect();
        assert_eq!(batch0.first().unwrap().t_start_us, 100);
        assert_eq!(batch0.last().unwrap().t_end_us, 500);
        // Pure function of the ledger: identical ledgers → identical JSON.
        assert_eq!(t.to_chrome_json(), two_batch_ledger().trace().to_chrome_json());
    }

    #[test]
    fn checksum_is_stable_and_sensitive() {
        let a = two_batch_ledger();
        let b = two_batch_ledger();
        assert_eq!(a.checksum(), b.checksum(), "identical ledgers, identical checksums");
        let mut c = two_batch_ledger();
        c.set_prediction(1, 2);
        assert_ne!(a.checksum(), c.checksum(), "predictions are part of the contract");
        let mut d = two_batch_ledger();
        d.requests[0].completion_us += 1;
        assert_ne!(a.checksum(), d.checksum(), "timestamps are part of the contract");
    }

    #[test]
    fn empty_ledger_summarizes_to_zeros() {
        let r = Ledger::new().summarize(1000);
        assert_eq!(r.served, 0);
        assert_eq!(r.batches, 0);
        assert_eq!(r.p99_ms, 0.0);
    }

    #[test]
    #[should_panic(expected = "unadmitted")]
    fn prediction_for_unknown_request_is_a_bug() {
        let mut l = Ledger::new();
        l.set_prediction(42, 0);
    }
}
