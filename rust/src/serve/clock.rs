//! Virtual time for the serving plane: an integer-microsecond clock and
//! a deterministic event queue.
//!
//! **No wall-clock in the decision path.** Every serving decision —
//! arrival, batcher admission, batch completion — happens at a
//! [`VirtualClock`] timestamp, and event ordering ties are broken by a
//! monotone insertion sequence number, so a run is a pure function of
//! its seed: the same workload, the same admissions, the same latency
//! ledger, bit for bit, whether the engine underneath runs
//! `--exec serial` or `threaded`, `--prefetch 0` or `1`. Real CPU time
//! is still *measured* (the executor records its sampling/gather wall
//! for the benches) but never *consulted*.

use super::workload::Request;

/// Monotone virtual time in integer microseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now_us: u64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now_us: 0 }
    }

    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Jump to an event timestamp. Time never flows backwards — the
    /// event queue pops in nondecreasing order and this asserts it.
    pub fn advance_to(&mut self, t_us: u64) {
        assert!(t_us >= self.now_us, "virtual time ran backwards: {} -> {t_us}", self.now_us);
        self.now_us = t_us;
    }
}

/// What can happen at a point in virtual time.
#[derive(Clone, Debug)]
pub enum Event {
    /// A request enters the system.
    Arrival(Request),
    /// The in-flight batch finishes (modeled service time elapsed); the
    /// executor becomes free.
    BatchDone { batch: u32 },
    /// A batcher-requested wakeup (its `WaitUntil` deadline) with no
    /// guarantee an arrival lands first.
    Poll,
}

/// One scheduled entry; ordering key is `(at_us, seq)` — `seq` is the
/// insertion sequence number, so simultaneous events pop in the order
/// they were scheduled (deterministic, insertion-stable).
#[derive(Clone, Debug)]
struct Scheduled {
    at_us: u64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        (self.at_us, self.seq) == (other.at_us, other.seq)
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at_us, self.seq).cmp(&(other.at_us, other.seq))
    }
}

/// Min-heap of scheduled events (std's `BinaryHeap` is a max-heap, so
/// entries are wrapped in `Reverse`).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<Scheduled>>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, at_us: u64, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(std::cmp::Reverse(Scheduled { at_us, seq, event }));
    }

    /// Pop the earliest event (ties in insertion order).
    pub fn pop(&mut self) -> Option<(u64, Event)> {
        self.heap.pop().map(|std::cmp::Reverse(s)| (s.at_us, s.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poll_at(q: &mut EventQueue, t: u64) {
        q.push(t, Event::Poll);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance_to(10);
        c.advance_to(10); // same instant is fine
        c.advance_to(25);
        assert_eq!(c.now_us(), 25);
    }

    #[test]
    #[should_panic(expected = "ran backwards")]
    fn clock_rejects_backwards_time() {
        let mut c = VirtualClock::new();
        c.advance_to(10);
        c.advance_to(9);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        poll_at(&mut q, 30);
        poll_at(&mut q, 10);
        poll_at(&mut q, 20);
        let times: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn simultaneous_events_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, Event::BatchDone { batch: 0 });
        q.push(5, Event::Poll);
        q.push(5, Event::BatchDone { batch: 1 });
        let mut order = Vec::new();
        while let Some((t, ev)) = q.pop() {
            assert_eq!(t, 5);
            order.push(match ev {
                Event::BatchDone { batch } => format!("done{batch}"),
                Event::Poll => "poll".to_string(),
                Event::Arrival(_) => "arrival".to_string(),
            });
        }
        assert_eq!(order, vec!["done0", "poll", "done1"], "insertion-stable tie-break");
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        poll_at(&mut q, 8);
        poll_at(&mut q, 3);
        assert_eq!(q.pop().unwrap().0, 3);
        poll_at(&mut q, 5);
        poll_at(&mut q, 4);
        assert_eq!(q.pop().unwrap().0, 4);
        assert_eq!(q.pop().unwrap().0, 5);
        assert_eq!(q.pop().unwrap().0, 8);
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
    }
}
