//! The online inference serving plane: SLO-aware dynamic cooperative
//! batching over a virtual-time request stream.
//!
//! The paper proves sampled-subgraph size is *concave* in batch size, so
//! PEs sharing one large batch do strictly less work per item. Training
//! exploits that offline; this module exploits it **online**: requests
//! arrive one by one, a dynamic batcher holds them back exactly as long
//! as a p99 latency SLO allows, and each admitted batch runs through the
//! same cooperative multi-PE engine as training — per-PE sampling,
//! row-carrying fabric exchange, LRU caches persisting *across* request
//! batches (κ-style temporal locality, fed by the workload's hot-set
//! skew).
//!
//! ```text
//!            virtual µs                 admitted FIFO prefix
//!  ┌──────────┐  arrivals  ┌─────────┐  Dispatch(n)  ┌──────────────┐
//!  │ workload │───────────▶│ batcher │──────────────▶│   executor   │
//!  │ (Poisson │  [clock +  │ (fixed/ │               │ batch_for_   │
//!  │ /closed) │   events]  │adaptive)│◀──observe ŝ───│ seeds → cost │
//!  └──────────┘            └─────────┘               │ model → head │
//!        ▲                     │ WaitUntil(t)        └──────┬───────┘
//!        └── completions ──────┴──── BatchDone ◀────────────┘
//!                                          │
//!                                   ┌──────▼──────┐
//!                                   │    report   │ p50/p90/p99,
//!                                   │   (ledger)  │ req/s, bytes/req
//!                                   └─────────────┘
//! ```
//!
//! Everything decision-relevant runs on the [`clock::VirtualClock`]
//! (integer µs, no wall-clock in the decision path) and the service time
//! of a batch is *modeled* from the engine's deterministic counts
//! ([`executor::modeled_service_us`]), so a run is bit-reproducible at a
//! fixed seed: identical request ledgers and prediction checksums across
//! `--exec serial|threaded` and `--prefetch 0|1` (enforced by
//! `tests/integration_serve.rs`).
//!
//! Entry points: [`crate::pipeline::Pipeline::server`] (builder hook),
//! the `coopgnn serve` CLI subcommand, `repro serve` (the scenario
//! matrix indep/coop × fixed/adaptive), `benches/bench_serve.rs`, and
//! `examples/serve_demo.rs`.

pub mod batcher;
pub mod clock;
pub mod executor;
pub mod report;
pub mod workload;

pub use batcher::{Batcher, BatcherKind, CostCurve, Decision};
pub use clock::{Event, EventQueue, VirtualClock};
pub use executor::{modeled_service_us, BatchExecution, Executor, BATCH_OVERHEAD_US};
pub use report::{BatchRecord, Ledger, RequestRecord, ServeReport};
pub use workload::{Request, Workload, WorkloadKind};

use crate::coop::all_to_all::AllReduceStrategy;
use crate::costmodel::{self, ModelCost, SystemPreset};
use crate::pipeline::Pipeline;
use batcher::ADAPTIVE_CAP_FACTOR;
use std::collections::VecDeque;

/// Serving-plane knobs (the engine-side knobs — mode, PEs, exec, κ,
/// cache, prefetch — come from the [`crate::pipeline::PipelineConfig`]
/// the server is built over).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// aggregate offered load (requests/s of virtual time).
    pub rate_per_s: f64,
    /// p99 latency objective (virtual µs).
    pub slo_us: u64,
    pub batcher: BatcherKind,
    /// stop after this many dispatched batches.
    pub duration_batches: usize,
    /// the fixed baseline's per-PE batch size; the adaptive policy may
    /// grow to [`ADAPTIVE_CAP_FACTOR`]× its global size.
    pub fixed_batch_per_pe: usize,
    pub workload: WorkloadKind,
    /// logical clients (requester ids; the closed loop's population).
    pub clients: usize,
    /// probability a request targets the hot set.
    pub hot_prob: f64,
    /// hot-set size as a fraction of |V|.
    pub hot_frac: f64,
    /// cost-model hardware the virtual service times are computed for.
    pub preset: &'static SystemPreset,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            rate_per_s: 2000.0,
            slo_us: 50_000,
            batcher: BatcherKind::Adaptive,
            duration_batches: 32,
            fixed_batch_per_pe: 32,
            workload: WorkloadKind::OpenPoisson,
            clients: 64,
            hot_prob: 0.8,
            hot_frac: 0.05,
            preset: costmodel::preset("4xA100").unwrap(),
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.rate_per_s > 0.0, "--rate must be positive");
        anyhow::ensure!(self.slo_us >= 1, "--slo-ms must be positive");
        anyhow::ensure!(self.duration_batches >= 1, "--duration-batches must be >= 1");
        anyhow::ensure!(self.fixed_batch_per_pe >= 1, "--batch must be >= 1");
        anyhow::ensure!(self.clients >= 1, "--clients must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.hot_prob), "--hot must be in [0,1]");
        anyhow::ensure!(
            self.hot_frac > 0.0 && self.hot_frac <= 1.0,
            "hot-set fraction must be in (0,1]"
        );
        Ok(())
    }
}

/// What a finished run hands back: the scorecard, the full transcript
/// (for tests and CSV emission), and the real CPU time the executor
/// spent (benches only — virtual time never sees it).
pub struct ServeOutcome {
    pub report: ServeReport,
    pub ledger: Ledger,
    /// summed executor wall (assignment + sampling + gathering), ms.
    pub exec_wall_ms: f64,
}

impl Pipeline {
    /// Stand up an online-inference server over this pipeline: the
    /// engine stream (with its persistent per-PE caches and fabric), a
    /// layered-model [`crate::model::Predictor`] snapshot initialized
    /// from the pipeline seed, a calibrated cost curve, and a seeded
    /// workload. Consume it with [`Server::run`].
    pub fn server(&self, scfg: ServeConfig) -> crate::Result<Server<'_>> {
        scfg.validate()?;
        let model = ModelCost::gcn(self.ds.feat_dim, 128);
        let trainer = self.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let executor = Executor::new(
            self.stream(),
            &self.part,
            self.cfg.mode,
            scfg.preset,
            model,
            trainer.predictor(),
            self.cfg.prefetch,
        );
        let fixed_global = scfg.fixed_batch_per_pe * self.cfg.num_pes;
        let curve = CostCurve::calibrate(
            &self.ds.graph,
            self.cfg.kind,
            &self.cfg.sampler_config(),
            self.ds.feat_dim,
            self.feature_store().row_bytes(),
            self.cfg.num_pes,
            scfg.preset,
            &model,
            fixed_global * ADAPTIVE_CAP_FACTOR,
            self.cfg.seed,
        );
        let batcher = Batcher::new(scfg.batcher, fixed_global, scfg.slo_us, curve);
        let workload = Workload::new(
            self.ds.graph.num_vertices(),
            scfg.workload,
            scfg.rate_per_s,
            scfg.clients as u32,
            scfg.hot_prob,
            scfg.hot_frac,
            self.cfg.seed,
        );
        Ok(Server {
            scfg,
            clock: VirtualClock::new(),
            events: EventQueue::new(),
            queue: VecDeque::new(),
            workload,
            batcher,
            executor,
            ledger: Ledger::new(),
            busy_until: None,
            pending_poll: None,
            dispatched: 0,
        })
    }
}

/// The event loop: arrivals in, batches out, everything in virtual
/// time. One instance serves one run.
pub struct Server<'p> {
    scfg: ServeConfig,
    clock: VirtualClock,
    events: EventQueue,
    queue: VecDeque<Request>,
    workload: Workload,
    batcher: Batcher,
    executor: Executor<'p>,
    ledger: Ledger,
    /// completion timestamp of the in-flight batch (executor serves one
    /// batch at a time — dispatches wait for it).
    busy_until: Option<u64>,
    /// earliest scheduled batcher wakeup (dedupes `WaitUntil` polls).
    pending_poll: Option<u64>,
    dispatched: usize,
}

impl Server<'_> {
    /// Drive the simulation to completion: `duration_batches`
    /// dispatches plus the final batch's completion.
    pub fn run(mut self) -> ServeOutcome {
        for r in self.workload.initial_arrivals() {
            self.events.push(r.arrival_us, Event::Arrival(r));
        }
        let duration = self.scfg.duration_batches;
        let mut exec_wall_ms = 0.0;
        while let Some((t, ev)) = self.events.pop() {
            self.clock.advance_to(t);
            match ev {
                Event::Arrival(r) => {
                    if self.dispatched < duration {
                        if self.workload.kind() == WorkloadKind::OpenPoisson {
                            // keep exactly one pending arrival chained
                            let next = self.workload.next_open(r.arrival_us);
                            self.events.push(next.arrival_us, Event::Arrival(next));
                        }
                        self.queue.push_back(r);
                    } else {
                        // past the measurement horizon: never admitted
                        self.ledger.dropped += 1;
                    }
                }
                Event::BatchDone { .. } => self.busy_until = None,
                Event::Poll => self.pending_poll = None,
            }
            self.try_dispatch(&mut exec_wall_ms);
            if self.dispatched >= duration && self.busy_until.is_none() {
                break;
            }
        }
        // whatever is still queued was never served
        self.ledger.dropped += self.queue.len() as u64;
        for (id, class) in self.executor.finish() {
            self.ledger.set_prediction(id, class);
        }
        let report = self.ledger.summarize(self.scfg.slo_us);
        ServeOutcome { report, ledger: self.ledger, exec_wall_ms }
    }

    /// Consult the batcher if the executor is free and work is queued;
    /// dispatch or schedule the requested wakeup.
    fn try_dispatch(&mut self, exec_wall_ms: &mut f64) {
        if self.busy_until.is_some()
            || self.dispatched >= self.scfg.duration_batches
            || self.queue.is_empty()
        {
            return;
        }
        let now = self.clock.now_us();
        let oldest = self.queue.front().unwrap().arrival_us;
        match self.batcher.decide(now, self.queue.len(), oldest) {
            Decision::Dispatch(k) => {
                let k = k.min(self.queue.len());
                let reqs: Vec<Request> = self.queue.drain(..k).collect();
                let exec = self.executor.execute(&reqs);
                *exec_wall_ms += exec.wall_ms;
                self.batcher.observe(exec.size, exec.service_us);
                let completion = now + exec.service_us;
                self.busy_until = Some(completion);
                self.events.push(completion, Event::BatchDone { batch: exec.batch });
                self.ledger.record_batch(
                    BatchRecord {
                        index: exec.batch,
                        size: exec.size as u32,
                        dispatch_us: now,
                        service_us: exec.service_us,
                        storage_bytes: exec.storage_bytes,
                        fabric_bytes: exec.fabric_bytes,
                        fabric_inter_bytes: exec.fabric_inter_bytes,
                        hot_rows: exec.hot_rows,
                        hot_bytes: exec.hot_bytes,
                    },
                    &reqs,
                    completion,
                );
                self.dispatched += 1;
                if self.workload.kind() == WorkloadKind::ClosedLoop
                    && self.dispatched < self.scfg.duration_batches
                {
                    // each served client thinks, then re-issues; the
                    // arrival is scheduled now (deterministically) but
                    // timestamped after the completion it reacts to
                    for r in &reqs {
                        let next = self.workload.next_after_completion(r.requester, completion);
                        self.events.push(next.arrival_us, Event::Arrival(next));
                    }
                }
            }
            Decision::WaitUntil(t) => {
                debug_assert!(t > now, "batcher wakeups must be in the future");
                let earlier = match self.pending_poll {
                    Some(p) => t < p,
                    None => true,
                };
                if earlier {
                    self.events.push(t, Event::Poll);
                    self.pending_poll = Some(t);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::engine::Mode;
    use crate::pipeline::PipelineBuilder;

    fn pipe(mode: Mode, pes: usize) -> Pipeline {
        PipelineBuilder::new()
            .dataset("tiny")
            .mode(mode)
            .num_pes(pes)
            .seed(19)
            .build()
            .unwrap()
    }

    fn scfg(batcher: BatcherKind) -> ServeConfig {
        ServeConfig {
            rate_per_s: 20_000.0,
            slo_us: 30_000,
            batcher,
            duration_batches: 10,
            fixed_batch_per_pe: 16,
            clients: 8,
            ..Default::default()
        }
    }

    #[test]
    fn serves_the_requested_number_of_batches() {
        let p = pipe(Mode::Cooperative, 2);
        let out = p.server(scfg(BatcherKind::Adaptive)).unwrap().run();
        assert_eq!(out.report.batches, 10);
        assert!(out.report.served > 0);
        assert!(out.report.p50_ms > 0.0 && out.report.p99_ms >= out.report.p50_ms);
        assert!(out.report.storage_bytes_per_req > 0.0);
        assert!(out.report.requests_per_s > 0.0);
        // every admitted request completed inside the run
        for r in &out.ledger.requests {
            assert!(r.completion_us > r.arrival_us);
            assert!(r.dispatch_us >= r.arrival_us);
        }
    }

    #[test]
    fn adaptive_builds_bigger_batches_than_fixed_under_load() {
        // 20k req/s against a 30ms SLO: the adaptive batcher has ~28ms
        // of budget to accumulate ~500 requests (capped at 4×32=128);
        // the fixed batcher dispatches every 32
        let p = pipe(Mode::Cooperative, 2);
        let fixed = p.server(scfg(BatcherKind::Fixed)).unwrap().run();
        let adaptive = p.server(scfg(BatcherKind::Adaptive)).unwrap().run();
        assert!(
            adaptive.report.mean_batch > 1.5 * fixed.report.mean_batch,
            "adaptive {} vs fixed {}",
            adaptive.report.mean_batch,
            fixed.report.mean_batch
        );
        // concavity + warm caches: bigger batches pay fewer bytes per
        // request
        assert!(
            adaptive.report.bytes_per_req() < fixed.report.bytes_per_req(),
            "adaptive {} vs fixed {}",
            adaptive.report.bytes_per_req(),
            fixed.report.bytes_per_req()
        );
    }

    #[test]
    fn same_seed_same_ledger_checksum() {
        let p = pipe(Mode::Independent, 2);
        let a = p.server(scfg(BatcherKind::Adaptive)).unwrap().run();
        let b = p.server(scfg(BatcherKind::Adaptive)).unwrap().run();
        assert_eq!(a.report.checksum, b.report.checksum);
        assert_eq!(a.report.served, b.report.served);
        let mut p2 = pipe(Mode::Independent, 2);
        p2.cfg.seed = 77;
        let c = p2.server(scfg(BatcherKind::Adaptive)).unwrap().run();
        assert_ne!(a.report.checksum, c.report.checksum, "seed must matter");
    }

    #[test]
    fn closed_loop_serves_and_respects_client_population() {
        let p = pipe(Mode::Cooperative, 2);
        let cfg = ServeConfig {
            workload: WorkloadKind::ClosedLoop,
            clients: 6,
            rate_per_s: 5_000.0,
            duration_batches: 8,
            fixed_batch_per_pe: 4,
            batcher: BatcherKind::Fixed,
            ..Default::default()
        };
        let out = p.server(cfg).unwrap().run();
        assert_eq!(out.report.batches, 8);
        assert!(out.report.served > 0);
        let requesters: std::collections::HashSet<u32> =
            out.ledger.requests.iter().map(|r| r.requester).collect();
        assert!(requesters.len() <= 6, "only the client population issues requests");
        // closed loop: a client never has two requests in flight
        let mut last_completion: std::collections::HashMap<u32, u64> = Default::default();
        let mut by_arrival = out.ledger.requests.clone();
        by_arrival.sort_by_key(|r| r.arrival_us);
        for r in &by_arrival {
            if let Some(&c) = last_completion.get(&r.requester) {
                assert!(r.arrival_us > c, "client {} re-issued before completion", r.requester);
            }
            last_completion.insert(r.requester, r.completion_us);
        }
    }

    #[test]
    fn rejects_invalid_serve_configs() {
        let p = pipe(Mode::Cooperative, 2);
        for bad in [
            ServeConfig { rate_per_s: 0.0, ..Default::default() },
            ServeConfig { slo_us: 0, ..Default::default() },
            ServeConfig { duration_batches: 0, ..Default::default() },
            ServeConfig { hot_prob: 1.5, ..Default::default() },
        ] {
            assert!(p.server(bad).is_err());
        }
    }
}
