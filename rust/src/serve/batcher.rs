//! Dynamic batch admission under a p99 latency SLO.
//!
//! The paper's Theorem 3.1/3.2 concavity — `E[|S^L(n)|]` grows strictly
//! sublinearly in the batch size `n` — means the *marginal* sampling +
//! feature-loading + forward cost of one more queued request falls as
//! the batch grows. An online server can therefore spend latency
//! headroom to buy work efficiency: hold requests back, let the batch
//! grow, and dispatch at the last moment the SLO allows.
//!
//! Two admission policies share one interface:
//!
//! * [`BatcherKind::Fixed`] — the classic baseline: dispatch as soon as
//!   `B` requests are queued, or flush a partial batch once the oldest
//!   request has waited half the SLO (so low load cannot starve it).
//! * [`BatcherKind::Adaptive`] — SLO-deadline batching with cost-model
//!   look-ahead: given `q` queued requests, consult the calibrated
//!   [`CostCurve`] (counts from a probe sweep pushed through the
//!   [`crate::costmodel`] bandwidths, continuously corrected by observed
//!   service times) for the modeled service time `ŝ(q)`, and dispatch
//!   only when `now ≥ oldest_arrival + SLO − ŝ(q) − margin` — i.e. wait
//!   exactly as long as the p99 budget permits, no longer. Every new
//!   arrival re-evaluates the deadline with a larger `q` (and a larger
//!   `ŝ`), so the wait shrinks as the batch grows; a hard cap
//!   ([`ADAPTIVE_CAP_FACTOR`]`·B·P`) bounds the executor's working set.
//!
//! Decisions are pure functions of virtual time + queue state — no
//! wall-clock, no hidden state beyond the deterministic EWMA correction
//! — so admission sequences are bit-reproducible.

use super::executor::{stage_us, BATCH_OVERHEAD_US};
use crate::costmodel::{ModelCost, SystemPreset};
use crate::graph::Csr;
use crate::sampling::{SamplerConfig, SamplerKind};
use crate::util::rng::Pcg64;

/// Admission policy selector (CLI `--batcher fixed|adaptive`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatcherKind {
    Fixed,
    Adaptive,
}

impl BatcherKind {
    pub fn name(&self) -> &'static str {
        match self {
            BatcherKind::Fixed => "fixed",
            BatcherKind::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<BatcherKind> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" => Some(BatcherKind::Fixed),
            "adaptive" | "slo" => Some(BatcherKind::Adaptive),
            _ => None,
        }
    }
}

/// The adaptive batcher may grow a batch to this multiple of the fixed
/// baseline's global size before dispatching unconditionally.
pub const ADAPTIVE_CAP_FACTOR: usize = 4;

/// What the batcher wants done right now. The server consults the
/// batcher whenever the executor is free and the queue is non-empty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Admit the first `n` queued requests (FIFO prefix — per-requester
    /// order is preserved by construction).
    Dispatch(usize),
    /// Hold; re-consult at this virtual timestamp unless an arrival
    /// triggers an earlier re-evaluation. Always strictly in the future.
    WaitUntil(u64),
}

/// Modeled service time as a function of global batch size — the
/// concave cost curve the adaptive policy consults.
///
/// Calibrated offline (at server construction) by sampling one probe
/// MFG per grid size with a throwaway sampler, splitting the global
/// counts evenly across PEs, assuming cold caches (every requested row
/// is a storage read), and pushing the per-PE counts through the
/// [`crate::costmodel`] bandwidth constants. That is an upper bound on
/// the live regime — warm κ-style caches and cooperative deduplication
/// only shave it — so [`Batcher::observe`]'s EWMA correction factor
/// (observed/predicted) adapts the curve to what the executor actually
/// measures.
#[derive(Clone, Debug)]
pub struct CostCurve {
    /// global batch sizes of the probe grid, ascending.
    sizes: Vec<f64>,
    /// modeled service µs at each grid size (includes dispatch
    /// overhead).
    us: Vec<f64>,
}

impl CostCurve {
    /// Probe a geometric grid of global batch sizes up to `cap_global`.
    #[allow(clippy::too_many_arguments)]
    pub fn calibrate(
        graph: &Csr,
        kind: SamplerKind,
        scfg: &SamplerConfig,
        feat_dim: usize,
        row_bytes: usize,
        num_pes: usize,
        preset: &SystemPreset,
        model: &ModelCost,
        cap_global: usize,
        seed: u64,
    ) -> CostCurve {
        let nv = graph.num_vertices();
        let mut grid: Vec<usize> = Vec::new();
        let mut n = num_pes.max(1);
        while n < cap_global {
            grid.push(n);
            n *= 2;
        }
        grid.push(cap_global.max(num_pes.max(1)));
        grid.dedup();
        let mut probe_rng = Pcg64::new(seed ^ 0xCA11B);
        let p = num_pes.max(1) as f64;
        // wire bytes per encoded row — the store's codec, not dim*4
        let row_bytes = row_bytes as f64;
        let (sizes, us): (Vec<f64>, Vec<f64>) = grid
            .iter()
            .map(|&n| {
                let mut sampler = scfg.build(kind, graph, seed ^ 0x90BE);
                let seeds: Vec<u32> = probe_rng.sample_distinct(nv, n.min(nv));
                let mfg = sampler.sample_mfg(&seeds);
                let s: Vec<f64> =
                    mfg.vertex_counts().iter().map(|&c| c as f64 / p).collect();
                let e: Vec<f64> = mfg.edge_counts().iter().map(|&c| c as f64 / p).collect();
                let requested = s[s.len() - 1];
                let t = BATCH_OVERHEAD_US
                    + stage_us(&s, &e, 0.0, requested * row_bytes, 0.0, feat_dim, preset, model);
                (n as f64, t)
            })
            .unzip();
        CostCurve { sizes, us }
    }

    /// A hand-built curve (tests / synthetic policies).
    pub fn from_points(sizes: Vec<f64>, us: Vec<f64>) -> CostCurve {
        assert_eq!(sizes.len(), us.len());
        assert!(!sizes.is_empty(), "curve needs at least one point");
        assert!(sizes.windows(2).all(|w| w[0] < w[1]), "sizes must ascend");
        CostCurve { sizes, us }
    }

    /// Modeled service µs at global batch size `n`: piecewise-linear
    /// interpolation on the grid, last-segment extrapolation above it,
    /// clamped to the first point below it.
    pub fn service_us(&self, n: usize) -> f64 {
        let x = n as f64;
        let k = self.sizes.len();
        if k == 1 || x <= self.sizes[0] {
            return self.us[0];
        }
        // segment whose right end is the first grid size >= x (the last
        // segment extrapolates beyond the grid)
        let hi = self.sizes.iter().position(|&s| s >= x).unwrap_or(k - 1).max(1);
        let (x0, x1) = (self.sizes[hi - 1], self.sizes[hi]);
        let (y0, y1) = (self.us[hi - 1], self.us[hi]);
        y0 + (x - x0) / (x1 - x0) * (y1 - y0)
    }
}

/// The admission policy object: one per server run.
pub struct Batcher {
    kind: BatcherKind,
    /// the fixed baseline's global dispatch size `B·P`.
    fixed_global: usize,
    /// adaptive hard cap ([`ADAPTIVE_CAP_FACTOR`]`·fixed_global`).
    cap_global: usize,
    slo_us: u64,
    curve: CostCurve,
    /// EWMA of observed/modeled service time (starts at 1.0).
    correction: f64,
}

impl Batcher {
    pub fn new(kind: BatcherKind, fixed_global: usize, slo_us: u64, curve: CostCurve) -> Batcher {
        assert!(fixed_global >= 1, "fixed batch size must be >= 1");
        assert!(slo_us >= 1, "SLO must be positive");
        Batcher {
            kind,
            fixed_global,
            cap_global: fixed_global * ADAPTIVE_CAP_FACTOR,
            slo_us,
            curve,
            correction: 1.0,
        }
    }

    pub fn kind(&self) -> BatcherKind {
        self.kind
    }

    /// Largest batch this policy will ever dispatch.
    pub fn cap_global(&self) -> usize {
        match self.kind {
            BatcherKind::Fixed => self.fixed_global,
            BatcherKind::Adaptive => self.cap_global,
        }
    }

    /// Current corrected service-time estimate for a global batch of
    /// `n` (µs).
    pub fn estimate_us(&self, n: usize) -> f64 {
        self.curve.service_us(n) * self.correction
    }

    /// Admission decision. The server calls this only when the executor
    /// is free and at least one request is queued (`queue_len >= 1`,
    /// `oldest_arrival_us <= now_us`).
    pub fn decide(&self, now_us: u64, queue_len: usize, oldest_arrival_us: u64) -> Decision {
        debug_assert!(queue_len >= 1);
        debug_assert!(oldest_arrival_us <= now_us);
        match self.kind {
            BatcherKind::Fixed => {
                if queue_len >= self.fixed_global {
                    return Decision::Dispatch(self.fixed_global);
                }
                // flush a partial batch after half the SLO so low
                // offered load cannot starve the oldest request
                let deadline = oldest_arrival_us + self.slo_us / 2;
                if now_us >= deadline {
                    Decision::Dispatch(queue_len)
                } else {
                    Decision::WaitUntil(deadline)
                }
            }
            BatcherKind::Adaptive => {
                let q = queue_len.min(self.cap_global);
                if q >= self.cap_global {
                    return Decision::Dispatch(self.cap_global);
                }
                // last safe dispatch moment for the oldest request:
                // its wait + modeled service + margin must fit the SLO.
                // Each arrival re-evaluates with a larger q (and larger
                // ŝ), so the deadline only moves earlier as load grows.
                let margin = self.slo_us / 8;
                let s_hat = self.estimate_us(q).round() as u64;
                let budget = self.slo_us.saturating_sub(s_hat + margin);
                let deadline = oldest_arrival_us + budget;
                if now_us >= deadline {
                    Decision::Dispatch(q)
                } else {
                    Decision::WaitUntil(deadline)
                }
            }
        }
    }

    /// Feed back a dispatched batch's modeled-from-measurement service
    /// time so the curve tracks the live regime (warm caches,
    /// cooperative dedup, real arrival mix). Deterministic EWMA.
    pub fn observe(&mut self, batch_size: usize, actual_service_us: u64) {
        let predicted = self.curve.service_us(batch_size);
        if predicted > 0.0 {
            let r = actual_service_us as f64 / predicted;
            self.correction = 0.7 * self.correction + 0.3 * r;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel;
    use crate::graph::generate;

    fn toy_curve() -> CostCurve {
        // overhead 100µs + concave-ish work term
        let sizes = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        let us: Vec<f64> = sizes.iter().map(|n| 100.0 + 30.0 * n.powf(0.8)).collect();
        CostCurve::from_points(sizes.to_vec(), us)
    }

    #[test]
    fn calibrated_curve_is_increasing_and_concave() {
        let g = generate::chung_lu(4000, 10.0, 2.5, 3);
        let scfg = SamplerConfig::default();
        let preset = costmodel::preset("4xA100").unwrap();
        let model = ModelCost::gcn(64, 128);
        let curve = CostCurve::calibrate(
            &g,
            SamplerKind::Labor0,
            &scfg,
            64,
            64 * 4,
            4,
            preset,
            &model,
            512,
            11,
        );
        let (a, b, c) = (curve.service_us(32), curve.service_us(64), curve.service_us(128));
        assert!(a < b && b < c, "more requests, more modeled work: {a} {b} {c}");
        // concavity (the paper's Theorem 3.1 shape): doubling the batch
        // must cost strictly less than doubling the time
        assert!(b < 2.0 * a, "concave step 32→64: {b} vs {a}");
        assert!(c < 2.0 * b, "concave step 64→128: {c} vs {b}");
        // per-request cost falls with batch size
        assert!(c / 128.0 < a / 32.0, "amortization must improve");
    }

    #[test]
    fn narrower_wire_rows_cheapen_the_calibrated_curve() {
        // int8 rows (d+5 wire bytes) shrink the storage term of the
        // modeled service time at every probe size
        let g = generate::chung_lu(4000, 10.0, 2.5, 3);
        let scfg = SamplerConfig::default();
        let preset = costmodel::preset("4xA100").unwrap();
        let model = ModelCost::gcn(64, 128);
        let mk = |row_bytes| {
            CostCurve::calibrate(
                &g,
                SamplerKind::Labor0,
                &scfg,
                64,
                row_bytes,
                4,
                preset,
                &model,
                512,
                11,
            )
        };
        let (f32c, int8c) = (mk(64 * 4), mk(64 + 5));
        for n in [8, 64, 512] {
            assert!(
                int8c.service_us(n) < f32c.service_us(n),
                "n={n}: int8 {} must undercut f32 {}",
                int8c.service_us(n),
                f32c.service_us(n)
            );
        }
    }

    #[test]
    fn curve_interpolates_and_extrapolates() {
        let c = CostCurve::from_points(vec![2.0, 4.0], vec![10.0, 14.0]);
        assert_eq!(c.service_us(2), 10.0);
        assert_eq!(c.service_us(3), 12.0);
        assert_eq!(c.service_us(4), 14.0);
        assert_eq!(c.service_us(1), 10.0, "clamped below the grid");
        assert_eq!(c.service_us(6), 18.0, "last-segment extrapolation");
    }

    #[test]
    fn fixed_dispatches_at_size_or_flush_deadline() {
        let b = Batcher::new(BatcherKind::Fixed, 8, 10_000, toy_curve());
        assert_eq!(b.decide(100, 8, 50), Decision::Dispatch(8));
        assert_eq!(b.decide(100, 20, 50), Decision::Dispatch(8), "never more than B");
        // partial queue: wait until oldest + slo/2 …
        assert_eq!(b.decide(100, 3, 50), Decision::WaitUntil(5_050));
        // … then flush whatever is there
        assert_eq!(b.decide(5_050, 3, 50), Decision::Dispatch(3));
        assert_eq!(b.cap_global(), 8);
    }

    #[test]
    fn adaptive_waits_while_budget_allows_then_dispatches() {
        let slo = 50_000u64; // 50ms
        let b = Batcher::new(BatcherKind::Adaptive, 8, slo, toy_curve());
        // young queue of 4: ŝ(4) ≈ 191µs, margin 6250 → deadline ≈
        // oldest + 43.5ms — far in the future, so hold
        let d = b.decide(1_000, 4, 500);
        let Decision::WaitUntil(t) = d else { panic!("expected wait, got {d:?}") };
        assert!(t > 40_000 && t < 500 + slo, "deadline inside the SLO budget: {t}");
        // at the deadline the same queue dispatches
        assert_eq!(b.decide(t, 4, 500), Decision::Dispatch(4));
        // cap: a flooded queue dispatches the cap immediately
        assert_eq!(b.decide(1_000, 10_000, 999), Decision::Dispatch(32));
        assert_eq!(b.cap_global(), 32);
    }

    #[test]
    fn adaptive_deadline_moves_earlier_as_queue_grows() {
        let b = Batcher::new(BatcherKind::Adaptive, 64, 20_000, toy_curve());
        let t_small = match b.decide(0, 2, 0) {
            Decision::WaitUntil(t) => t,
            d => panic!("{d:?}"),
        };
        let t_big = match b.decide(0, 100, 0) {
            Decision::WaitUntil(t) => t,
            d => panic!("{d:?}"),
        };
        assert!(t_big < t_small, "bigger batch, bigger ŝ, earlier deadline");
    }

    #[test]
    fn observe_corrects_the_estimate_deterministically() {
        let mut b = Batcher::new(BatcherKind::Adaptive, 8, 10_000, toy_curve());
        let before = b.estimate_us(16);
        // the executor keeps reporting twice the modeled time
        for _ in 0..10 {
            let actual = (b.curve.service_us(16) * 2.0) as u64;
            b.observe(16, actual);
        }
        let after = b.estimate_us(16);
        assert!(after > 1.8 * before, "correction converges upward: {before} -> {after}");
        let mut b2 = Batcher::new(BatcherKind::Adaptive, 8, 10_000, toy_curve());
        for _ in 0..10 {
            let actual = (b2.curve.service_us(16) * 2.0) as u64;
            b2.observe(16, actual);
        }
        assert_eq!(b.estimate_us(16), b2.estimate_us(16), "EWMA is deterministic");
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BatcherKind::Fixed, BatcherKind::Adaptive] {
            assert_eq!(BatcherKind::parse(k.name()), Some(k));
        }
        assert_eq!(BatcherKind::parse("nope"), None);
    }
}
