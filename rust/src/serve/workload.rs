//! Deterministic request generators over a dataset's vertex population.
//!
//! Two arrival disciplines, both driven by one seeded [`Pcg64`] stream
//! so a run is reproducible down to the microsecond:
//!
//! * **Open-loop Poisson** ([`WorkloadKind::OpenPoisson`]) — arrivals at
//!   exponential interarrival gaps with mean `1/rate`, independent of
//!   completions (the offered load does not back off when the server
//!   falls behind — the discipline that exposes SLO violations honestly;
//!   see "Open Versus Closed: A Cautionary Tale", Schroeder et al.).
//! * **Closed-loop** ([`WorkloadKind::ClosedLoop`]) — `clients` logical
//!   users, each with at most one request outstanding; after a
//!   completion the client thinks for an exponential time with mean
//!   `clients/rate` and issues its next request, so the aggregate
//!   offered load matches `rate` while the server keeps up.
//!
//! Each request targets one vertex of the dataset's population, drawn
//! from a **hot-set mix**: with probability `hot_prob` the vertex comes
//! from a fixed random subset of `hot_frac·|V|` vertices, else uniformly
//! from the whole population. The skew is what makes the per-PE LRU row
//! caches (persisting across batches, κ-style) earn their keep in the
//! latency numbers.
//!
//! Requests within one requester are issued in increasing arrival time
//! and increasing id — the FIFO baseline the batcher admission property
//! test checks against.

use crate::graph::VertexId;
use crate::util::rng::Pcg64;

/// One inference request: "what class is vertex `vertex`?"
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    /// globally unique, assigned in creation (= arrival-scheduling)
    /// order.
    pub id: u64,
    /// logical client issuing the request.
    pub requester: u32,
    /// the queried vertex.
    pub vertex: VertexId,
    /// virtual arrival timestamp (µs).
    pub arrival_us: u64,
}

/// Arrival discipline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    OpenPoisson,
    ClosedLoop,
}

impl WorkloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::OpenPoisson => "open",
            WorkloadKind::ClosedLoop => "closed",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "open" | "poisson" => Some(WorkloadKind::OpenPoisson),
            "closed" | "closed-loop" => Some(WorkloadKind::ClosedLoop),
            _ => None,
        }
    }
}

/// The request generator. All randomness flows from the construction
/// seed; the generator never reads the wall clock.
pub struct Workload {
    kind: WorkloadKind,
    rng: Pcg64,
    /// open loop: mean interarrival (µs). closed loop: mean think (µs).
    mean_gap_us: f64,
    clients: u32,
    /// round-robin requester assignment for open-loop arrivals.
    next_client: u32,
    hot: Vec<VertexId>,
    hot_prob: f64,
    population: usize,
    next_id: u64,
}

impl Workload {
    /// Build a generator over a population of `num_vertices`.
    /// `rate_per_s` is the aggregate offered load; for the closed loop
    /// it is converted to a per-client mean think time of
    /// `clients/rate` so both disciplines offer comparable load.
    pub fn new(
        num_vertices: usize,
        kind: WorkloadKind,
        rate_per_s: f64,
        clients: u32,
        hot_prob: f64,
        hot_frac: f64,
        seed: u64,
    ) -> Workload {
        assert!(num_vertices > 0, "empty vertex population");
        assert!(rate_per_s > 0.0, "rate must be positive");
        assert!(clients >= 1, "need at least one client");
        assert!((0.0..=1.0).contains(&hot_prob), "hot_prob in [0,1]");
        assert!(hot_frac > 0.0 && hot_frac <= 1.0, "hot_frac in (0,1]");
        let mut rng = Pcg64::new(seed ^ 0x5E4E);
        let hot_n = ((num_vertices as f64 * hot_frac) as usize).clamp(1, num_vertices);
        let hot: Vec<VertexId> = rng.sample_distinct(num_vertices, hot_n);
        let mean_gap_us = match kind {
            WorkloadKind::OpenPoisson => 1e6 / rate_per_s,
            WorkloadKind::ClosedLoop => clients as f64 * 1e6 / rate_per_s,
        };
        Workload {
            kind,
            rng,
            mean_gap_us,
            clients,
            next_client: 0,
            hot,
            hot_prob,
            population: num_vertices,
            next_id: 0,
        }
    }

    pub fn kind(&self) -> WorkloadKind {
        self.kind
    }

    /// Expected interarrival gap of the *aggregate* stream (µs) — the
    /// adaptive batcher's look-ahead horizon.
    pub fn expected_gap_us(&self) -> f64 {
        match self.kind {
            WorkloadKind::OpenPoisson => self.mean_gap_us,
            WorkloadKind::ClosedLoop => self.mean_gap_us / self.clients as f64,
        }
    }

    /// Exponential variate with the given mean, floored at 1 µs so
    /// virtual time always advances between arrivals of one stream.
    fn exp_us(&mut self, mean: f64) -> u64 {
        let u = self.rng.next_f64();
        ((-mean * (1.0 - u).ln()).round() as u64).max(1)
    }

    fn draw_vertex(&mut self) -> VertexId {
        if self.rng.next_f64() < self.hot_prob {
            self.hot[self.rng.next_below(self.hot.len() as u64) as usize]
        } else {
            self.rng.next_below(self.population as u64) as VertexId
        }
    }

    fn make_request(&mut self, requester: u32, arrival_us: u64) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        Request { id, requester, vertex: self.draw_vertex(), arrival_us }
    }

    /// The arrivals to seed the event queue with at time 0: one pending
    /// arrival for the open loop, one per client for the closed loop
    /// (each staggered by an independent think draw).
    pub fn initial_arrivals(&mut self) -> Vec<Request> {
        match self.kind {
            WorkloadKind::OpenPoisson => {
                let t = self.exp_us(self.mean_gap_us);
                let c = self.next_client % self.clients;
                self.next_client += 1;
                vec![self.make_request(c, t)]
            }
            WorkloadKind::ClosedLoop => (0..self.clients)
                .map(|c| {
                    let t = self.exp_us(self.mean_gap_us);
                    self.make_request(c, t)
                })
                .collect(),
        }
    }

    /// Open loop only: the arrival after `prev` (schedule when `prev`'s
    /// arrival event fires, keeping exactly one pending arrival).
    pub fn next_open(&mut self, prev_arrival_us: u64) -> Request {
        assert_eq!(self.kind, WorkloadKind::OpenPoisson, "open-loop chaining only");
        let t = prev_arrival_us + self.exp_us(self.mean_gap_us);
        let c = self.next_client % self.clients;
        self.next_client += 1;
        self.make_request(c, t)
    }

    /// Closed loop only: `requester`'s next request after its previous
    /// one completed at `completion_us` (think time, then re-issue).
    pub fn next_after_completion(&mut self, requester: u32, completion_us: u64) -> Request {
        assert_eq!(self.kind, WorkloadKind::ClosedLoop, "completion chaining is closed-loop");
        let t = completion_us + self.exp_us(self.mean_gap_us);
        self.make_request(requester, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(kind: WorkloadKind, seed: u64) -> Workload {
        Workload::new(2000, kind, 5000.0, 4, 0.8, 0.05, seed)
    }

    #[test]
    fn same_seed_same_stream() {
        let mut a = wl(WorkloadKind::OpenPoisson, 9);
        let mut b = wl(WorkloadKind::OpenPoisson, 9);
        let mut ra = a.initial_arrivals().remove(0);
        let mut rb = b.initial_arrivals().remove(0);
        for _ in 0..200 {
            assert_eq!(ra, rb);
            ra = a.next_open(ra.arrival_us);
            rb = b.next_open(rb.arrival_us);
        }
        let mut c = wl(WorkloadKind::OpenPoisson, 10);
        let rc = c.initial_arrivals().remove(0);
        assert_ne!((rc.arrival_us, rc.vertex), (rb.arrival_us, rb.vertex), "seed matters");
    }

    #[test]
    fn open_loop_rate_and_monotonicity() {
        let mut w = wl(WorkloadKind::OpenPoisson, 3);
        let mut r = w.initial_arrivals().remove(0);
        let (mut n, mut last) = (0u64, 0u64);
        for _ in 0..4000 {
            assert!(r.arrival_us > last, "arrivals strictly ordered");
            assert!(r.id == n, "ids count creation order");
            last = r.arrival_us;
            n += 1;
            r = w.next_open(r.arrival_us);
        }
        // 5000 req/s → mean gap 200µs; 4000 arrivals ≈ 0.8 virtual s
        let mean_gap = last as f64 / n as f64;
        assert!((mean_gap - 200.0).abs() < 20.0, "mean gap {mean_gap} vs 200µs");
    }

    #[test]
    fn hot_set_skews_vertex_draws() {
        let mut w = Workload::new(2000, WorkloadKind::OpenPoisson, 1000.0, 2, 0.9, 0.05, 7);
        let hot: std::collections::HashSet<VertexId> = w.hot.iter().copied().collect();
        let mut r = w.initial_arrivals().remove(0);
        let mut hits = 0usize;
        let total = 2000;
        for _ in 0..total {
            if hot.contains(&r.vertex) {
                hits += 1;
            }
            r = w.next_open(r.arrival_us);
        }
        // 90% targeted at 5% of vertices (+ ~5%·0.1 uniform spillover)
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.8, "hot fraction {frac} — skew must bite");
        assert!(w.hot.len() == 100, "5% of 2000");
    }

    #[test]
    fn requester_streams_are_fifo_by_construction() {
        let mut w = wl(WorkloadKind::OpenPoisson, 21);
        let mut r = w.initial_arrivals().remove(0);
        let mut last_per: std::collections::HashMap<u32, (u64, u64)> = Default::default();
        for _ in 0..500 {
            if let Some(&(id, at)) = last_per.get(&r.requester) {
                assert!(r.id > id && r.arrival_us > at, "per-requester order");
            }
            last_per.insert(r.requester, (r.id, r.arrival_us));
            r = w.next_open(r.arrival_us);
        }
        assert_eq!(last_per.len(), 4, "round-robin covers all clients");
    }

    #[test]
    fn closed_loop_one_outstanding_per_client() {
        let mut w = wl(WorkloadKind::ClosedLoop, 5);
        let first = w.initial_arrivals();
        assert_eq!(first.len(), 4, "one initial request per client");
        let requesters: std::collections::HashSet<u32> =
            first.iter().map(|r| r.requester).collect();
        assert_eq!(requesters.len(), 4);
        // chaining: next request of client 2 comes strictly after its
        // completion
        let next = w.next_after_completion(2, 10_000);
        assert_eq!(next.requester, 2);
        assert!(next.arrival_us > 10_000);
        // aggregate offered load ≈ rate: mean think = clients/rate
        assert!((w.expected_gap_us() - 200.0).abs() < 1e-9);
    }
}
