//! Multi-layer bipartite blocks (MFGs) and their fixed-fanout padded
//! tensor form — the L3 ↔ L2 contract.
//!
//! [`build_mfg`] applies the paper's expansion rule (Eq. 2)
//! `S^{l+1} = S^l ∪ N_sampled(S^l)` layer by layer. The vertex array of
//! layer `l+1` lists the layer-`l` vertices **first** (prefix-nesting), so
//! position `i` refers to the same vertex in every deeper layer — the AOT
//! model exploits this to chain aggregations without re-gather.
//!
//! [`Mfg::pad`] converts an MFG into [`PaddedBatch`]: dense
//! `[cap_l × k]` neighbor-index/weight tensors (fanout ≤ k always holds
//! for NS/RW; LABOR can exceed k for a few seeds — overflow edges are
//! dropped with weight renormalization and counted). TPU rationale: this
//! turns scatter-style SpMM into regular gather + masked mean, see
//! DESIGN.md §Hardware-Adaptation.

use super::{Neighborhoods, Sampler};
use crate::graph::VertexId;
use std::collections::HashMap;

/// Per-layer edges of an MFG: for dst `i` (position in layer l's vertex
/// array), `nbr_local[offsets[i]..offsets[i+1]]` are positions in layer
/// (l+1)'s vertex array.
#[derive(Clone, Debug, Default)]
pub struct LayerEdges {
    pub offsets: Vec<u32>,
    pub nbr_local: Vec<u32>,
}

impl LayerEdges {
    pub fn num_edges(&self) -> usize {
        self.nbr_local.len()
    }
    pub fn of(&self, i: usize) -> &[u32] {
        &self.nbr_local[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// A sampled L-layer message-flow graph.
#[derive(Clone, Debug, Default)]
pub struct Mfg {
    /// `layer_vertices[l]` = global ids of S^l; `layer_vertices[l]` is a
    /// prefix of `layer_vertices[l+1]` (unless `self_pos` overrides).
    pub layer_vertices: Vec<Vec<VertexId>>,
    /// `layer_edges[l]` connects layer l (dst) to layer l+1 (src).
    pub layer_edges: Vec<LayerEdges>,
    /// Position of dst `i` of layer l inside layer l+1's vertex array.
    /// `None` ⇒ prefix nesting (position = i). Merged MFGs (block-
    /// diagonal unions of independent per-PE batches) set this
    /// explicitly because concatenation breaks prefix nesting.
    pub self_pos: Option<Vec<Vec<u32>>>,
}

impl Mfg {
    pub fn num_layers(&self) -> usize {
        self.layer_edges.len()
    }
    pub fn seeds(&self) -> &[VertexId] {
        &self.layer_vertices[0]
    }
    /// The input-feature vertex set S^L (deepest layer).
    pub fn input_vertices(&self) -> &[VertexId] {
        self.layer_vertices.last().unwrap()
    }
    /// |S^l| per layer.
    pub fn vertex_counts(&self) -> Vec<usize> {
        self.layer_vertices.iter().map(|v| v.len()).collect()
    }
    /// |E^l| per layer.
    pub fn edge_counts(&self) -> Vec<usize> {
        self.layer_edges.iter().map(|e| e.num_edges()).collect()
    }
    /// Total work proxy Σ_l |S^l| (paper Eq. 3 numerator).
    pub fn total_vertices(&self) -> usize {
        self.layer_vertices.iter().skip(1).map(|v| v.len()).sum()
    }
}

/// Build an MFG by recursive sampling (paper Eq. 2).
pub fn build_mfg(sampler: &mut Sampler<'_>, seeds: &[VertexId]) -> Mfg {
    let layers = sampler.cfg.layers;
    let mut mfg = Mfg::default();
    mfg.layer_vertices.push(seeds.to_vec());
    let mut nbh = Neighborhoods::default();
    for l in 0..layers {
        let dst = mfg.layer_vertices[l].clone();
        sampler.sample_layer(&dst, l, &mut nbh);
        // next layer's vertex array: dst first, then newly-seen sources
        let mut next: Vec<VertexId> = dst.clone();
        let mut local: HashMap<VertexId, u32> = HashMap::with_capacity(next.len() * 2);
        for (i, &v) in next.iter().enumerate() {
            local.insert(v, i as u32);
        }
        let mut edges = LayerEdges::default();
        edges.offsets.push(0);
        for i in 0..dst.len() {
            for &t in nbh.of(i) {
                let idx = *local.entry(t).or_insert_with(|| {
                    next.push(t);
                    (next.len() - 1) as u32
                });
                edges.nbr_local.push(idx);
            }
            edges.offsets.push(edges.nbr_local.len() as u32);
        }
        mfg.layer_vertices.push(next);
        mfg.layer_edges.push(edges);
    }
    mfg
}

/// Fixed tensor shape caps negotiated with the AOT artifacts
/// (`artifacts/manifest.json` mirrors these).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeCaps {
    /// fanout k (second dim of the neighbor tensors).
    pub k: usize,
    /// vertex-array cap per layer, `n[0]` = seed cap … `n[L]` = input cap.
    pub n: Vec<usize>,
}

impl ShapeCaps {
    pub fn layers(&self) -> usize {
        self.n.len() - 1
    }
}

/// An MFG padded/truncated to fixed shapes, plus batch labels. All
/// vectors are row-major and sized exactly to the cap so they can be
/// wrapped into PJRT literals without copies.
#[derive(Clone, Debug)]
pub struct PaddedBatch {
    pub caps: ShapeCaps,
    /// actual |S^l| before padding (≤ cap after truncation accounting).
    pub actual: Vec<usize>,
    /// per layer l: `[cap_l * k]` indices into layer l+1 rows.
    pub nbr_idx: Vec<Vec<i32>>,
    /// per layer l: `[cap_l * k]` weights (1/(deg+1) or 0 for padding).
    pub nbr_w: Vec<Vec<f32>>,
    /// per layer l: `[cap_l]` self index into layer l+1 rows.
    pub self_idx: Vec<Vec<i32>>,
    /// per layer l: `[cap_l]` self weight.
    pub self_w: Vec<Vec<f32>>,
    /// `[cap_0]` class labels (0 where masked).
    pub labels: Vec<i32>,
    /// `[cap_0]` 1.0 for real seeds, 0.0 for padding.
    pub label_mask: Vec<f32>,
    /// diagnostics: vertices/edges dropped by cap truncation.
    pub truncated_vertices: usize,
    pub truncated_edges: usize,
}

impl Mfg {
    /// Pad to `caps`, reading labels from `labels_of` (global-id ->
    /// class). Vertices beyond a layer cap are dropped; edges pointing at
    /// dropped vertices (or beyond the per-dst fanout cap k) are dropped
    /// and the mean renormalized over survivors.
    pub fn pad(&self, caps: &ShapeCaps, labels_of: impl Fn(VertexId) -> u16) -> PaddedBatch {
        assert_eq!(caps.layers(), self.num_layers(), "cap layer mismatch");
        let layers = self.num_layers();
        let k = caps.k;
        let mut out = PaddedBatch {
            caps: caps.clone(),
            actual: self.vertex_counts(),
            nbr_idx: Vec::with_capacity(layers),
            nbr_w: Vec::with_capacity(layers),
            self_idx: Vec::with_capacity(layers),
            self_w: Vec::with_capacity(layers),
            labels: vec![0; caps.n[0]],
            label_mask: vec![0.0; caps.n[0]],
            truncated_vertices: 0,
            truncated_edges: 0,
        };
        for l in 0..layers {
            let cap_dst = caps.n[l];
            let cap_src = caps.n[l + 1];
            let n_dst = self.layer_vertices[l].len().min(cap_dst);
            out.truncated_vertices += self.layer_vertices[l].len().saturating_sub(cap_dst);
            let mut nbr_idx = vec![0i32; cap_dst * k];
            let mut nbr_w = vec![0f32; cap_dst * k];
            let mut self_idx = vec![0i32; cap_dst];
            let mut self_w = vec![0f32; cap_dst];
            let edges = &self.layer_edges[l];
            for i in 0..n_dst {
                // survivors: sampled neighbors within both caps
                let nbrs = edges.of(i);
                let mut kept = 0usize;
                for &j in nbrs {
                    if (j as usize) < cap_src && kept < k {
                        nbr_idx[i * k + kept] = j as i32;
                        kept += 1;
                    } else {
                        out.truncated_edges += 1;
                    }
                }
                // dst i's own row in layer l+1: position i under prefix
                // nesting, or the explicit merged position
                let pos = match &self.self_pos {
                    Some(sp) => sp[l][i] as usize,
                    None => i,
                };
                if pos >= cap_src {
                    // self row truncated away: zero the whole row
                    out.truncated_edges += 1;
                    self_idx[i] = 0;
                    self_w[i] = 0.0;
                    for slot in 0..k {
                        nbr_w[i * k + slot] = 0.0;
                    }
                    continue;
                }
                self_idx[i] = pos as i32;
                let inv = 1.0 / (kept as f32 + 1.0); // +1 for self
                for slot in 0..kept {
                    nbr_w[i * k + slot] = inv;
                }
                self_w[i] = inv;
            }
            out.nbr_idx.push(nbr_idx);
            out.nbr_w.push(nbr_w);
            out.self_idx.push(self_idx);
            out.self_w.push(self_w);
        }
        let n0 = self.layer_vertices[0].len().min(caps.n[0]);
        for i in 0..n0 {
            out.labels[i] = labels_of(self.layer_vertices[0][i]) as i32;
            out.label_mask[i] = 1.0;
        }
        // the last-layer vertex count drives feature gathering; count its
        // truncation too
        out.truncated_vertices +=
            self.input_vertices().len().saturating_sub(*caps.n.last().unwrap());
        out
    }

    /// The input vertices clipped to the feature cap — what the feature
    /// loader must gather, in row order.
    pub fn clipped_input_vertices(&self, caps: &ShapeCaps) -> &[VertexId] {
        let cap = *caps.n.last().unwrap();
        let vs = self.input_vertices();
        &vs[..vs.len().min(cap)]
    }
}

/// Block-diagonal merge of independently-sampled MFGs — the exact
/// semantics of Independent Minibatching with gradient averaging: P PEs
/// compute on their private MFGs and all-reduce; numerically this equals
/// one step on the concatenated batch (shared vertices appear once *per
/// PE*, each with its PE's own sampled neighborhood — the duplication the
/// paper quantifies). Prefix nesting breaks under concatenation, so the
/// merged MFG carries explicit `self_pos`.
pub fn merge_mfgs(parts: &[Mfg]) -> Mfg {
    assert!(!parts.is_empty());
    let layers = parts[0].num_layers();
    assert!(parts.iter().all(|m| m.num_layers() == layers));
    let mut out = Mfg {
        layer_vertices: vec![Vec::new(); layers + 1],
        layer_edges: (0..layers)
            .map(|_| LayerEdges { offsets: vec![0], nbr_local: vec![] })
            .collect(),
        self_pos: Some(vec![Vec::new(); layers]),
    };
    for l in 0..=layers {
        for m in parts {
            out.layer_vertices[l].extend_from_slice(&m.layer_vertices[l]);
        }
    }
    for l in 0..layers {
        // offset of part i inside the merged layer-(l+1) array
        let mut src_offset = 0u32;
        for m in parts {
            let e = &m.layer_edges[l];
            let n_dst = m.layer_vertices[l].len();
            for i in 0..n_dst {
                for &j in e.of(i) {
                    out.layer_edges[l].nbr_local.push(src_offset + j);
                }
                let end = out.layer_edges[l].nbr_local.len() as u32;
                out.layer_edges[l].offsets.push(end);
                let pos = match &m.self_pos {
                    Some(sp) => sp[l][i],
                    None => i as u32,
                };
                out.self_pos.as_mut().unwrap()[l].push(src_offset + pos);
            }
            src_offset += m.layer_vertices[l + 1].len() as u32;
        }
    }
    out
}

/// Estimate safe caps for a (dataset, sampler, batch-size) combo by
/// sampling `trials` probe batches and taking the max per-layer count
/// with `margin` headroom. Used by config tooling and tests; the shipped
/// artifact configs freeze the result in `artifacts/manifest.json`.
pub fn estimate_caps(
    sampler_cfg: &super::SamplerConfig,
    kind: super::SamplerKind,
    graph: &crate::graph::Csr,
    train: &[VertexId],
    batch_size: usize,
    trials: usize,
    margin: f64,
    seed: u64,
) -> ShapeCaps {
    use crate::util::rng::Pcg64;
    let mut rng = Pcg64::new(seed);
    let mut maxima = vec![0usize; sampler_cfg.layers + 1];
    // LABOR samples *expected* fanout k; individual seeds can exceed it,
    // so the padded-tensor k must be the observed max (with margin).
    let mut max_fanout = sampler_cfg.max_fanout();
    for t in 0..trials {
        let mut s = sampler_cfg.build(kind, graph, seed ^ (t as u64) << 16);
        let idx = rng.sample_distinct(train.len(), batch_size.min(train.len()));
        let seeds: Vec<VertexId> = idx.iter().map(|&i| train[i as usize]).collect();
        let mfg = s.sample_mfg(&seeds);
        for (l, c) in mfg.vertex_counts().iter().enumerate() {
            maxima[l] = maxima[l].max(*c);
        }
        for e in &mfg.layer_edges {
            for i in 0..e.offsets.len() - 1 {
                max_fanout = max_fanout.max(e.of(i).len());
            }
        }
    }
    ShapeCaps {
        k: ((max_fanout as f64) * margin).ceil() as usize,
        n: maxima
            .iter()
            .enumerate()
            .map(|(l, &m)| {
                if l == 0 {
                    batch_size
                } else {
                    ((m as f64) * margin).ceil() as usize
                }
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::{Kappa, SamplerConfig, SamplerKind};

    fn mfg_fixture(seed: u64) -> (crate::graph::Csr, Mfg) {
        let g = generate::chung_lu(1500, 14.0, 2.4, seed);
        let cfg =
            SamplerConfig { layers: 3, fanout: 10, kappa: Kappa::Finite(1), ..Default::default() };
        let mut s = cfg.build(SamplerKind::Labor0, &g, seed);
        let seeds: Vec<u32> = (0..64).collect();
        let mfg = s.sample_mfg(&seeds);
        (g, mfg)
    }

    #[test]
    fn prefix_nesting_invariant() {
        let (_, mfg) = mfg_fixture(1);
        for l in 0..mfg.num_layers() {
            let a = &mfg.layer_vertices[l];
            let b = &mfg.layer_vertices[l + 1];
            assert!(b.len() >= a.len());
            assert_eq!(&b[..a.len()], &a[..], "layer {l} prefix nesting");
        }
    }

    #[test]
    fn monotone_expansion_eq2() {
        let (_, mfg) = mfg_fixture(2);
        let counts = mfg.vertex_counts();
        for l in 0..counts.len() - 1 {
            assert!(counts[l + 1] >= counts[l], "S^l grows: {counts:?}");
        }
    }

    #[test]
    fn edges_reference_valid_sources() {
        let (g, mfg) = mfg_fixture(3);
        for l in 0..mfg.num_layers() {
            let dst = &mfg.layer_vertices[l];
            let src = &mfg.layer_vertices[l + 1];
            let e = &mfg.layer_edges[l];
            for i in 0..dst.len() {
                for &j in e.of(i) {
                    let t = src[j as usize];
                    assert!(g.neighbors(dst[i]).contains(&t), "edge maps to a real neighbor");
                }
            }
        }
    }

    #[test]
    fn no_duplicate_vertices_within_layer() {
        let (_, mfg) = mfg_fixture(4);
        for vs in &mfg.layer_vertices {
            let set: std::collections::HashSet<_> = vs.iter().collect();
            assert_eq!(set.len(), vs.len());
        }
    }

    #[test]
    fn pad_roundtrip_no_truncation() {
        let (_, mfg) = mfg_fixture(5);
        let counts = mfg.vertex_counts();
        // k cap must exceed LABOR's max realized fanout (expected k=10,
        // but individual seeds overshoot)
        let k = 32;
        let caps = ShapeCaps { k, n: counts.iter().map(|c| c + 8).collect() };
        let pb = mfg.pad(&caps, |_| 3);
        assert_eq!(pb.truncated_vertices, 0);
        assert_eq!(pb.truncated_edges, 0);
        // weights of each real dst row sum to ~1 (mean over deg+1)
        for l in 0..mfg.num_layers() {
            for i in 0..counts[l] {
                let wsum: f32 = pb.nbr_w[l][i * k..(i + 1) * k].iter().sum::<f32>()
                    + pb.self_w[l][i];
                assert!((wsum - 1.0).abs() < 1e-5, "layer {l} row {i} wsum {wsum}");
            }
            // padding rows are fully zeroed
            for i in counts[l]..caps.n[l] {
                assert_eq!(pb.self_w[l][i], 0.0);
                assert!(pb.nbr_w[l][i * k..(i + 1) * k].iter().all(|&w| w == 0.0));
            }
        }
        // labels
        assert_eq!(pb.label_mask.iter().filter(|&&m| m == 1.0).count(), counts[0]);
        assert!(pb.labels[..counts[0]].iter().all(|&l| l == 3));
    }

    #[test]
    fn pad_truncation_renormalizes() {
        let (_, mfg) = mfg_fixture(6);
        let counts = mfg.vertex_counts();
        // squeeze the deepest layer hard
        let mut n = counts.clone();
        let full = n[3];
        n[3] = (full * 2) / 3;
        let k = 32;
        let caps = ShapeCaps { k, n };
        let pb = mfg.pad(&caps, |_| 0);
        assert!(pb.truncated_vertices > 0 || pb.truncated_edges > 0);
        // every surviving row still has weights summing to 1 or 0
        for i in 0..counts[2].min(pb.caps.n[2]) {
            let wsum: f32 =
                pb.nbr_w[2][i * k..(i + 1) * k].iter().sum::<f32>() + pb.self_w[2][i];
            assert!(
                (wsum - 1.0).abs() < 1e-5 || wsum == 0.0,
                "renormalized wsum {wsum} at row {i}"
            );
        }
    }

    #[test]
    fn merged_mfg_preserves_per_part_semantics() {
        let g = generate::chung_lu(1500, 14.0, 2.4, 8);
        let cfg = SamplerConfig { layers: 2, fanout: 6, ..Default::default() };
        // two *independent* RNGs (different batch seeds) like indep PEs
        let mut s1 = cfg.build(SamplerKind::Labor0, &g, 1);
        let mut s2 = cfg.build(SamplerKind::Labor0, &g, 2);
        let m1 = s1.sample_mfg(&(0..32).collect::<Vec<u32>>());
        let m2 = s2.sample_mfg(&(32..64).collect::<Vec<u32>>());
        let merged = merge_mfgs(&[m1.clone(), m2.clone()]);
        // layer sizes are sums
        for l in 0..=2 {
            assert_eq!(
                merged.layer_vertices[l].len(),
                m1.layer_vertices[l].len() + m2.layer_vertices[l].len()
            );
        }
        // every merged edge maps to the same global vertex pair as the
        // part it came from
        let sp = merged.self_pos.as_ref().unwrap();
        for l in 0..2 {
            let dst = &merged.layer_vertices[l];
            let src = &merged.layer_vertices[l + 1];
            let e = &merged.layer_edges[l];
            for i in 0..dst.len() {
                // self position points at the same vertex id
                assert_eq!(src[sp[l][i] as usize], dst[i], "self pos layer {l} dst {i}");
                for &j in e.of(i) {
                    assert!(g.neighbors(dst[i]).contains(&src[j as usize]));
                }
            }
        }
        // padding the merged MFG keeps weight normalization
        let caps = ShapeCaps {
            k: 32,
            n: merged.vertex_counts().iter().map(|c| c + 4).collect(),
        };
        let pb = merged.pad(&caps, |_| 1);
        assert_eq!(pb.truncated_vertices, 0);
        for i in 0..merged.layer_vertices[0].len() {
            let wsum: f32 =
                pb.nbr_w[0][i * 32..(i + 1) * 32].iter().sum::<f32>() + pb.self_w[0][i];
            assert!((wsum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn estimate_caps_covers_observations() {
        let g = generate::chung_lu(1500, 14.0, 2.4, 9);
        let cfg = SamplerConfig::default();
        let train: Vec<u32> = (0..800).collect();
        let caps = estimate_caps(&cfg, SamplerKind::Labor0, &g, &train, 64, 5, 1.2, 7);
        assert_eq!(caps.n[0], 64);
        // fresh batches should fit with margin almost surely
        let mut s = cfg.build(SamplerKind::Labor0, &g, 1234);
        let seeds: Vec<u32> = (100..164).collect();
        let mfg = s.sample_mfg(&seeds);
        let pb = mfg.pad(&caps, |_| 0);
        assert_eq!(pb.truncated_vertices, 0, "caps {:?} counts {:?}", caps.n, mfg.vertex_counts());
    }
}
