//! Smoothed dependent minibatching (paper §3.2 and Appendix A.7).
//!
//! Every sampler consumes uniform random variates keyed by vertex
//! (`r_t`, LABOR) or edge (`r_ts`, NS/RW). Ordinarily each minibatch uses
//! a fresh PRNG seed, so the variates — and hence the sampled
//! neighborhoods — are independent across batches. The smoothed dependent
//! scheme instead interpolates between two seeds `z₁, z₂` over a window of
//! κ batches: for batch `i` in the window, with `c = i/κ`,
//!
//! ```text
//!   n(c)  = cos(cπ/2)·n₁ + sin(cπ/2)·n₂ ,  n₁ = Φ⁻¹(U(hash(z₁,·))),
//!   r(c)  = Φ(n(c))                        n₂ = Φ⁻¹(U(hash(z₂,·)))
//! ```
//!
//! `n(c)` is standard normal for every `c` (the cos/sin coefficients keep
//! unit variance), so **each individual batch is sampled from exactly the
//! same distribution as the independent scheme** — only the *correlation*
//! between consecutive batches changes. After κ batches, `z₁ ← z₂` and a
//! fresh `z₂` is drawn, so neighborhoods decorrelate fully every κ steps.
//! κ=1 degenerates to independent batches; κ=∞ freezes neighborhoods.

use crate::util::mathx::{normal_cdf, normal_icdf};
use crate::util::rng::{counter_hash2, counter_hash3, u64_to_unit_f64, Pcg64};

/// The batch-dependency parameter κ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kappa {
    /// Decorrelate fully every `k` batches (k=1 ⇒ independent batches).
    Finite(u32),
    /// Neighborhoods never change (paper's κ=∞ ablation).
    Infinite,
}

impl Kappa {
    pub fn parse(s: &str) -> Option<Kappa> {
        if s == "inf" || s == "∞" {
            Some(Kappa::Infinite)
        } else {
            s.parse::<u32>().ok().filter(|&k| k >= 1).map(Kappa::Finite)
        }
    }
    pub fn label(&self) -> String {
        match self {
            Kappa::Finite(k) => k.to_string(),
            Kappa::Infinite => "inf".to_string(),
        }
    }
}

/// Stateless-per-query, stateful-per-batch variate generator.
#[derive(Clone, Debug)]
pub struct DependentRng {
    z1: u64,
    z2: u64,
    kappa: Kappa,
    /// batch index within the current κ window.
    i: u32,
    /// stream for drawing fresh seeds at window boundaries.
    seeder: Pcg64,
    /// cached cos/sin of the current interpolation coefficient.
    cos_c: f64,
    sin_c: f64,
}

impl DependentRng {
    pub fn new(seed: u64, kappa: Kappa) -> Self {
        let mut seeder = Pcg64::new(seed);
        let z1 = seeder.next_u64();
        let z2 = seeder.next_u64();
        let mut rng = DependentRng { z1, z2, kappa, i: 0, seeder, cos_c: 1.0, sin_c: 0.0 };
        rng.refresh_coeffs();
        rng
    }

    pub fn kappa(&self) -> Kappa {
        self.kappa
    }

    fn refresh_coeffs(&mut self) {
        let c = match self.kappa {
            Kappa::Infinite => 0.0,
            Kappa::Finite(k) => self.i as f64 / k as f64,
        };
        let a = c * std::f64::consts::FRAC_PI_2;
        self.cos_c = a.cos();
        self.sin_c = a.sin();
    }

    /// Advance to the next minibatch: step `i`, rotate seeds at window
    /// boundaries. No-op for κ=∞.
    pub fn advance(&mut self) {
        if let Kappa::Finite(k) = self.kappa {
            self.i += 1;
            if self.i >= k {
                self.i = 0;
                self.z1 = self.z2;
                self.z2 = self.seeder.next_u64();
            }
            self.refresh_coeffs();
        }
    }

    /// Interpolate two hash-uniforms into the current window's uniform.
    #[inline]
    fn smooth(&self, h1: u64, h2: u64) -> f64 {
        if self.sin_c == 0.0 {
            // fast path: pure z1 (κ=∞ always, and i=0 of every window)
            return u64_to_unit_f64(h1);
        }
        let n1 = normal_icdf(clamp_open(u64_to_unit_f64(h1)));
        let n2 = normal_icdf(clamp_open(u64_to_unit_f64(h2)));
        normal_cdf(self.cos_c * n1 + self.sin_c * n2)
    }

    /// Per-vertex variate `r_t` (LABOR family). `domain` separates GNN
    /// layers so each layer rolls independent coins.
    #[inline]
    pub fn vertex_variate(&self, domain: u64, t: u64) -> f64 {
        let key = domain.wrapping_mul(0x9E37_79B9).wrapping_add(t);
        self.smooth(counter_hash2(self.z1, key), counter_hash2(self.z2, key))
    }

    /// Per-edge variate `r_ts` (NS).
    #[inline]
    pub fn edge_variate(&self, domain: u64, t: u64, s: u64) -> f64 {
        self.smooth(
            counter_hash3(self.z1 ^ domain, t, s),
            counter_hash3(self.z2 ^ domain, t, s),
        )
    }

    /// A sequential stream seeded from the current window state — used by
    /// the random-walk sampler, which needs many variates per (seed, walk)
    /// rather than one per edge. Walks stay frozen under κ=∞ and rotate
    /// smoothly otherwise (the stream seed interpolates discretely: it
    /// reuses z1 for a `1-i/κ` fraction of walks and z2 for the rest).
    #[inline]
    pub fn walk_stream(&self, domain: u64, s: u64, walk: u64) -> Pcg64 {
        let frac = match self.kappa {
            Kappa::Infinite => 0.0,
            Kappa::Finite(k) => self.i as f64 / k as f64,
        };
        // walk-index-hash decides which seed this walk currently uses
        let gate = u64_to_unit_f64(counter_hash3(0xA11CE, s, walk));
        let z = if gate < frac { self.z2 } else { self.z1 };
        Pcg64::new(counter_hash3(z ^ domain, s, walk))
    }
}

#[inline]
fn clamp_open(u: f64) -> f64 {
    u.clamp(1e-12, 1.0 - 1e-12)
}

/// Per-layer memo for `vertex_variate`: the LABOR samplers query `r_t`
/// once per *edge examined*, but the value only depends on the vertex —
/// with average degree `d̄`, memoization removes `(d̄-1)/d̄` of the hash +
/// Φ/Φ⁻¹ work (the dominant cost of the κ>1 smoothing path; see
/// EXPERIMENTS.md §Perf). Generation-stamped so `begin_layer` is O(1).
#[derive(Clone, Debug, Default)]
pub struct VariateCache {
    gen: Vec<u32>,
    val: Vec<f64>,
    cur: u32,
}

impl VariateCache {
    /// Start a new memo window (new layer or new batch).
    pub fn begin(&mut self, num_vertices: usize) {
        if self.gen.len() < num_vertices {
            self.gen.resize(num_vertices, 0);
            self.val.resize(num_vertices, 0.0);
        }
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            // stamp wrap: invalidate everything explicitly
            self.gen.iter_mut().for_each(|g| *g = u32::MAX);
            self.cur = 1;
        }
    }

    /// Memoized `rng.vertex_variate(domain, t)`.
    ///
    /// Perf note: memoization only pays when the variate is expensive —
    /// the κ>1 smoothing path costs two hashes + 2Φ⁻¹ + Φ, while the
    /// κ=1 / window-start fast path is a single hash (cheaper than the
    /// memo's two random-access table touches; measured −2.4× when
    /// memoizing unconditionally, EXPERIMENTS.md §Perf). So the memo is
    /// bypassed on the fast path.
    #[inline]
    pub fn get(&mut self, rng: &DependentRng, domain: u64, t: u64) -> f64 {
        if rng.sin_c == 0.0 {
            // fast path: one hash, cheaper than the memo itself
            return rng.vertex_variate(domain, t);
        }
        let i = t as usize;
        debug_assert!(i < self.gen.len());
        if self.gen[i] == self.cur {
            self.val[i]
        } else {
            let v = rng.vertex_variate(domain, t);
            self.gen[i] = self.cur;
            self.val[i] = v;
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kappa_parse() {
        assert_eq!(Kappa::parse("1"), Some(Kappa::Finite(1)));
        assert_eq!(Kappa::parse("256"), Some(Kappa::Finite(256)));
        assert_eq!(Kappa::parse("inf"), Some(Kappa::Infinite));
        assert_eq!(Kappa::parse("0"), None);
    }

    #[test]
    fn infinite_kappa_is_frozen() {
        let mut r = DependentRng::new(5, Kappa::Infinite);
        let before: Vec<f64> = (0..50).map(|t| r.vertex_variate(0, t)).collect();
        for _ in 0..100 {
            r.advance();
        }
        let after: Vec<f64> = (0..50).map(|t| r.vertex_variate(0, t)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn kappa_one_decorrelates_every_batch() {
        let mut r = DependentRng::new(6, Kappa::Finite(1));
        let a: Vec<f64> = (0..100).map(|t| r.vertex_variate(0, t)).collect();
        r.advance();
        let b: Vec<f64> = (0..100).map(|t| r.vertex_variate(0, t)).collect();
        let same = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        assert!(same < 3, "κ=1 batches must be independent, {same} identical");
    }

    #[test]
    fn variates_uniform_at_every_phase() {
        // The smoothing must preserve marginal uniformity for any c.
        for phase in 0..4 {
            let mut r = DependentRng::new(7, Kappa::Finite(4));
            for _ in 0..phase {
                r.advance();
            }
            let n = 20_000u64;
            let mean: f64 = (0..n).map(|t| r.vertex_variate(1, t)).sum::<f64>() / n as f64;
            assert!((mean - 0.5).abs() < 0.02, "phase {phase} mean {mean}");
            // second moment of U(0,1) is 1/3
            let m2: f64 =
                (0..n).map(|t| r.vertex_variate(1, t).powi(2)).sum::<f64>() / n as f64;
            assert!((m2 - 1.0 / 3.0).abs() < 0.02, "phase {phase} m2 {m2}");
        }
    }

    #[test]
    fn correlation_decays_with_phase_distance() {
        // Within a window, variates at phase i and i+1 must be *more*
        // correlated for larger κ (slower change).
        let corr = |kappa: u32| -> f64 {
            let mut r = DependentRng::new(8, Kappa::Finite(kappa));
            let a: Vec<f64> = (0..5000).map(|t| r.vertex_variate(0, t)).collect();
            r.advance();
            let b: Vec<f64> = (0..5000).map(|t| r.vertex_variate(0, t)).collect();
            pearson(&a, &b)
        };
        let c2 = corr(2);
        let c16 = corr(16);
        let c256 = corr(256);
        assert!(c16 > c2, "κ=16 corr {c16} should exceed κ=2 corr {c2}");
        assert!(c256 > c16, "κ=256 corr {c256} should exceed κ=16 corr {c16}");
        assert!(c256 > 0.99, "κ=256 adjacent batches nearly identical, got {c256}");
    }

    #[test]
    fn window_rotation_reaches_fresh_seed() {
        // After exactly κ advances the old z2 becomes z1: variates at the
        // window start must equal the previous window's c→1 limit trend —
        // and, critically, after 2κ advances nothing of the original z1
        // remains (full decorrelation).
        let mut r = DependentRng::new(9, Kappa::Finite(8));
        let a: Vec<f64> = (0..2000).map(|t| r.vertex_variate(0, t)).collect();
        for _ in 0..16 {
            r.advance();
        }
        let b: Vec<f64> = (0..2000).map(|t| r.vertex_variate(0, t)).collect();
        let c = pearson(&a, &b);
        assert!(c.abs() < 0.1, "2κ-separated batches must decorrelate, corr {c}");
    }

    #[test]
    fn edge_variate_distinct_per_edge() {
        let r = DependentRng::new(10, Kappa::Finite(1));
        let v1 = r.edge_variate(0, 1, 2);
        let v2 = r.edge_variate(0, 2, 1);
        let v3 = r.edge_variate(1, 1, 2);
        assert_ne!(v1, v2);
        assert_ne!(v1, v3);
        assert_eq!(v1, r.edge_variate(0, 1, 2), "stateless repeatability");
    }

    #[test]
    fn walk_stream_frozen_under_infinite_kappa() {
        let mut r = DependentRng::new(11, Kappa::Infinite);
        let mut s1 = r.walk_stream(0, 5, 3);
        r.advance();
        let mut s2 = r.walk_stream(0, 5, 3);
        for _ in 0..10 {
            assert_eq!(s1.next_u64(), s2.next_u64());
        }
    }

    fn pearson(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum::<f64>() / n;
        let va: f64 = a.iter().map(|x| (x - ma).powi(2)).sum::<f64>() / n;
        let vb: f64 = b.iter().map(|y| (y - mb).powi(2)).sum::<f64>() / n;
        cov / (va * vb).sqrt()
    }
}
