//! Random-walk sampling (Ying et al. 2018, PinSAGE; paper Appendix A.1.3).
//!
//! For each seed `s`: run `a` walks. A walk starts by stepping to a random
//! neighbor `s'` of `s`; each of the remaining `o-1` steps continues from
//! the current vertex with probability `1-p` or restarts from `s` with
//! probability `p`. Visit counts are accumulated over all walks, and the
//! top-k most-visited vertices become the sampled "neighborhood" of `s`
//! (this samples from Ã = Σ_i A^i without materializing it).
//!
//! Note the sampled vertices are *not* necessarily direct neighbors — the
//! MFG builder treats them as layer-(l+1) sources all the same.

use super::dependent::DependentRng;
use super::{Neighborhoods, RwParams};
use crate::graph::{Csr, VertexId};
use std::collections::HashMap;

pub fn sample(
    g: &Csr,
    seeds: &[VertexId],
    fanout: usize,
    params: RwParams,
    rng: &DependentRng,
    layer: usize,
    out: &mut Neighborhoods,
) {
    let domain = 0x52_57 ^ (layer as u64) << 8; // "RW" tag + layer
    let mut visits: HashMap<VertexId, u32> = HashMap::with_capacity(128);
    for &s in seeds {
        visits.clear();
        if g.degree(s) > 0 {
            for w in 0..params.num_walks {
                let mut stream = rng.walk_stream(domain, s as u64, w as u64);
                // first hop always from s
                let nbrs = g.neighbors(s);
                let mut cur = nbrs[stream.next_below(nbrs.len() as u64) as usize];
                *visits.entry(cur).or_insert(0) += 1;
                for _ in 1..params.walk_length {
                    let from = if stream.next_f64() < params.restart_prob { s } else { cur };
                    let nbrs = g.neighbors(from);
                    if nbrs.is_empty() {
                        break;
                    }
                    cur = nbrs[stream.next_below(nbrs.len() as u64) as usize];
                    if cur != s {
                        *visits.entry(cur).or_insert(0) += 1;
                    }
                }
            }
        }
        // top-k by visit count (deterministic tie-break on vertex id)
        let mut ranked: Vec<(u32, VertexId)> =
            visits.iter().map(|(&v, &c)| (c, v)).collect();
        ranked.sort_unstable_by(|a, b| b.cmp(a));
        for &(_, v) in ranked.iter().take(fanout) {
            out.nbrs.push(v);
        }
        out.offsets.push(out.nbrs.len() as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::Kappa;

    fn params() -> RwParams {
        RwParams { walk_length: 3, restart_prob: 0.5, num_walks: 40 }
    }

    fn run(g: &Csr, seeds: &[u32], fanout: usize, seed: u64) -> Neighborhoods {
        let rng = DependentRng::new(seed, Kappa::Finite(1));
        let mut out = Neighborhoods::default();
        out.offsets.push(0);
        sample(g, seeds, fanout, params(), &rng, 0, &mut out);
        out
    }

    #[test]
    fn respects_fanout_and_no_self() {
        let g = generate::chung_lu(1000, 15.0, 2.4, 1);
        let seeds: Vec<u32> = (0..50).collect();
        let out = run(&g, &seeds, 10, 2);
        for (i, &s) in seeds.iter().enumerate() {
            assert!(out.of(i).len() <= 10);
            assert!(!out.of(i).contains(&s), "seed {s} visited itself");
        }
    }

    #[test]
    fn reaches_multi_hop_vertices() {
        // A path graph 0->1->2 (edges stored as in-neighbors of the
        // *destination*; walk follows in-neighbors which is fine for the
        // count experiments): build 2 <- 1 <- 0 chain and walk from 2.
        let mut b = crate::graph::CsrBuilder::new(3);
        b.add_edge(1, 2); // N(2) = {1}
        b.add_edge(0, 1); // N(1) = {0}
        let g = b.finish();
        let out = run(&g, &[2], 10, 3);
        assert!(out.of(0).contains(&1));
        assert!(out.of(0).contains(&0), "2-hop vertex reachable via walk");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = generate::chung_lu(500, 12.0, 2.4, 4);
        let a = run(&g, &[1, 2, 3], 10, 9);
        let b = run(&g, &[1, 2, 3], 10, 9);
        assert_eq!(a.nbrs, b.nbrs);
    }

    #[test]
    fn isolated_vertex_empty() {
        let mut b = crate::graph::CsrBuilder::new(4);
        b.add_edge(0, 1);
        let g = b.finish();
        let out = run(&g, &[3], 10, 1);
        assert!(out.of(0).is_empty());
    }

    #[test]
    fn visit_bias_toward_close_vertices() {
        // With restart 0.5, direct neighbors must dominate the top-k.
        let g = generate::chung_lu(2000, 20.0, 2.3, 5);
        let v = (0..2000u32).find(|&v| g.degree(v) >= 15).unwrap();
        let out = run(&g, &[v], 10, 6);
        let direct: usize =
            out.of(0).iter().filter(|t| g.neighbors(v).contains(t)).count();
        assert!(direct * 2 >= out.of(0).len(), "direct {direct} of {}", out.of(0).len());
    }
}
