//! LABOR sampling (Balin & Çatalyürek 2023; paper Appendix A.1.2).
//!
//! **LABOR-0**: every vertex `t` rolls one uniform variate `r_t` per
//! batch/layer; the edge `(t→s)` is kept iff `r_t ≤ k / deg(s)`. Because
//! all seeds consult the *same* `r_t` for a shared source `t`, the number
//! of unique sampled vertices is smaller than NS's in expectation — the
//! property the paper's concavity arguments amplify.
//!
//! **LABOR-***: the importance-sampling variant. Edge `(t→s)` is kept iff
//! `r_t ≤ min(1, c_s · π_t)` where per-seed normalizers `c_s` solve
//! `Σ_{t∈N(s)} min(1, c_s π_t) = k` (expected per-seed fanout = k, the
//! LABOR paper's first-moment constraint) and the importance weights π are
//! iterated toward the fixed point that concentrates probability on
//! vertices shared by many seeds: `π_t ∝ sqrt(Σ_{s : t∈N(s)} c_s²)`.
//! A few rounds suffice. This follows the LABOR paper's construction with
//! the variance constraint replaced by the first-moment constraint; the
//! orderings the paper relies on (|LABOR-*| ≤ |LABOR-0| ≤ |NS| unique
//! vertices) are preserved, which is what Figures 3/6 consume.
//!
//! Perf note (EXPERIMENTS.md §Perf): per-vertex variates are memoized per
//! layer through [`VariateCache`], and LABOR-*'s π/accumulator tables are
//! dense generation-stamped arrays owned by [`LaborScratch`] — the
//! original HashMap implementation ran at 4.6 M examined-edges/s; the
//! dense version removes all per-edge hashing.

use super::dependent::{DependentRng, VariateCache};
use super::Neighborhoods;
use crate::graph::{Csr, VertexId};

/// Reusable scratch owned by the sampler (no allocation per batch after
/// warmup).
#[derive(Clone, Debug, Default)]
pub struct LaborScratch {
    pub variates: VariateCache,
    /// dense π table, generation stamped.
    pi_gen: Vec<u32>,
    pi_val: Vec<f64>,
    cur: u32,
    /// vertices touched by the current batch (for O(batch) iteration).
    touched: Vec<VertexId>,
    /// per-seed sorted-π buffer for the c_s solver.
    pis: Vec<f64>,
    /// suffix-sum buffer for the c_s solver.
    suffix: Vec<f64>,
    /// c_s per seed.
    c_of_seed: Vec<f64>,
    /// sqrt-accumulator values (reuses pi stamps: valid iff acc_gen==cur).
    acc_gen: Vec<u32>,
    acc_val: Vec<f64>,
}

impl LaborScratch {
    fn begin(&mut self, n: usize) {
        if self.pi_gen.len() < n {
            self.pi_gen.resize(n, 0);
            self.pi_val.resize(n, 0.0);
            self.acc_gen.resize(n, 0);
            self.acc_val.resize(n, 0.0);
        }
        self.cur = self.cur.wrapping_add(1);
        if self.cur == 0 {
            self.pi_gen.iter_mut().for_each(|g| *g = u32::MAX);
            self.acc_gen.iter_mut().for_each(|g| *g = u32::MAX);
            self.cur = 1;
        }
        self.touched.clear();
    }

    #[inline]
    fn pi(&self, t: VertexId) -> f64 {
        debug_assert_eq!(self.pi_gen[t as usize], self.cur);
        self.pi_val[t as usize]
    }
}

/// LABOR-0: keep `(t→s)` iff `r_t ≤ k/deg(s)`.
pub fn sample_labor0(
    g: &Csr,
    seeds: &[VertexId],
    fanout: usize,
    rng: &DependentRng,
    layer: usize,
    scratch: &mut LaborScratch,
    out: &mut Neighborhoods,
) {
    let domain = layer as u64;
    scratch.variates.begin(g.num_vertices());
    for &s in seeds {
        let nbrs = g.neighbors(s);
        if nbrs.len() <= fanout {
            out.nbrs.extend_from_slice(nbrs);
        } else {
            let thresh = fanout as f64 / nbrs.len() as f64;
            for &t in nbrs {
                if scratch.variates.get(rng, domain, t as u64) <= thresh {
                    out.nbrs.push(t);
                }
            }
        }
        out.offsets.push(out.nbrs.len() as u32);
    }
}

/// LABOR-*: importance-weighted per-vertex thresholds, iterated `rounds`
/// times over the batch before the final sampling pass.
pub fn sample_labor_star(
    g: &Csr,
    seeds: &[VertexId],
    fanout: usize,
    rounds: usize,
    rng: &DependentRng,
    layer: usize,
    scratch: &mut LaborScratch,
    out: &mut Neighborhoods,
) {
    let domain = layer as u64;
    scratch.begin(g.num_vertices());
    scratch.variates.begin(g.num_vertices());

    // Initialize π = 1 over the batch's source universe.
    for &s in seeds {
        for &t in g.neighbors(s) {
            let i = t as usize;
            if scratch.pi_gen[i] != scratch.cur {
                scratch.pi_gen[i] = scratch.cur;
                scratch.pi_val[i] = 1.0;
                scratch.touched.push(t);
            }
        }
    }

    // c_s solver: given the seed's neighbor π values (sorted descending
    // in `pis`), find c with Σ min(1, c·π_i) = k. deg ≤ k ⇒ take all.
    fn solve_c(pis: &mut [f64], suffix: &mut Vec<f64>, k: usize) -> f64 {
        if pis.len() <= k {
            return f64::INFINITY;
        }
        pis.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
        let n = pis.len();
        suffix.clear();
        suffix.resize(n + 1, 0.0);
        for i in (0..n).rev() {
            suffix[i] = suffix[i + 1] + pis[i];
        }
        for m in 0..n {
            let c = (k as f64 - m as f64) / suffix[m].max(1e-300);
            let upper_ok = m == 0 || c * pis[m - 1] >= 1.0 - 1e-12;
            let lower_ok = c * pis[m] <= 1.0 + 1e-12;
            if c > 0.0 && upper_ok && lower_ok {
                return c;
            }
        }
        1.0 / pis[n - 1].max(1e-300)
    }

    scratch.c_of_seed.clear();
    scratch.c_of_seed.resize(seeds.len(), 0.0);
    for _round in 0..rounds.max(1) {
        // 1) solve all c_s under current π
        for (i, &s) in seeds.iter().enumerate() {
            let mut pis = std::mem::take(&mut scratch.pis);
            let mut suffix = std::mem::take(&mut scratch.suffix);
            pis.clear();
            for &t in g.neighbors(s) {
                pis.push(scratch.pi(t));
            }
            scratch.c_of_seed[i] = solve_c(&mut pis, &mut suffix, fanout);
            scratch.pis = pis;
            scratch.suffix = suffix;
        }
        // 2) π_t ← sqrt(Σ_s c_s²) over finite-c seeds touching t
        let cur = scratch.cur;
        let mut any = false;
        for (i, &s) in seeds.iter().enumerate() {
            let c = scratch.c_of_seed[i];
            if !c.is_finite() {
                continue;
            }
            any = true;
            for &t in g.neighbors(s) {
                let j = t as usize;
                if scratch.acc_gen[j] != cur {
                    scratch.acc_gen[j] = cur;
                    scratch.acc_val[j] = 0.0;
                }
                scratch.acc_val[j] += c * c;
            }
        }
        if !any {
            break; // every seed takes its full neighborhood
        }
        let mut max_pi = 0.0f64;
        for &t in &scratch.touched {
            let j = t as usize;
            let v = if scratch.acc_gen[j] == cur { scratch.acc_val[j].sqrt() } else { 0.0 };
            scratch.pi_val[j] = v;
            max_pi = max_pi.max(v);
            // reset acc stamp for the next round
            scratch.acc_gen[j] = cur.wrapping_sub(1);
        }
        if max_pi > 0.0 {
            for &t in &scratch.touched {
                scratch.pi_val[t as usize] /= max_pi;
            }
        } else {
            for &t in &scratch.touched {
                scratch.pi_val[t as usize] = 1.0;
            }
        }
    }

    // Final sampling pass (memoized variates).
    for (i, &s) in seeds.iter().enumerate() {
        let nbrs = g.neighbors(s);
        let c = scratch.c_of_seed[i];
        if !c.is_finite() || nbrs.len() <= fanout {
            out.nbrs.extend_from_slice(nbrs);
        } else {
            for &t in nbrs {
                let p = (c * scratch.pi(t)).min(1.0);
                if scratch.variates.get(rng, domain, t as u64) <= p {
                    out.nbrs.push(t);
                }
            }
        }
        out.offsets.push(out.nbrs.len() as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::Kappa;
    use std::collections::BTreeMap;

    fn run0(g: &Csr, seeds: &[u32], fanout: usize, seed: u64) -> Neighborhoods {
        let rng = DependentRng::new(seed, Kappa::Finite(1));
        let mut scratch = LaborScratch::default();
        let mut out = Neighborhoods::default();
        out.offsets.push(0);
        sample_labor0(g, seeds, fanout, &rng, 0, &mut scratch, &mut out);
        out
    }

    fn run_star(g: &Csr, seeds: &[u32], fanout: usize, seed: u64) -> Neighborhoods {
        let rng = DependentRng::new(seed, Kappa::Finite(1));
        let mut scratch = LaborScratch::default();
        let mut out = Neighborhoods::default();
        out.offsets.push(0);
        sample_labor_star(g, seeds, fanout, 3, &rng, 0, &mut scratch, &mut out);
        out
    }

    #[test]
    fn labor0_expected_fanout_k() {
        // E[#sampled per seed] = deg * k/deg = k for deg > k.
        let g = generate::chung_lu(2000, 30.0, 2.2, 2);
        let seeds: Vec<u32> = (0..2000u32).filter(|&v| g.degree(v) > 10).take(100).collect();
        let mut total = 0usize;
        let trials = 50;
        for t in 0..trials as u64 {
            let out = run0(&g, &seeds, 10, 500 + t);
            total += out.nbrs.len();
        }
        let avg_per_seed = total as f64 / trials as f64 / seeds.len() as f64;
        assert!((avg_per_seed - 10.0).abs() < 0.8, "avg fanout {avg_per_seed}, want ≈10");
    }

    #[test]
    fn labor0_small_degree_takes_all() {
        let g = generate::chung_lu(1000, 6.0, 2.5, 3);
        let v = (0..1000u32).find(|&v| (1..=5).contains(&g.degree(v))).unwrap();
        let out = run0(&g, &[v], 10, 1);
        assert_eq!(out.of(0).len(), g.degree(v));
    }

    #[test]
    fn labor0_same_variate_shared_across_seeds() {
        // If two seeds share a neighbor t with identical thresholds, then
        // t is sampled by both or neither.
        let g = generate::chung_lu(500, 30.0, 2.2, 4);
        let mut found = None;
        'outer: for a in 0..500u32 {
            if g.degree(a) <= 10 {
                continue;
            }
            for b in (a + 1)..500u32 {
                if g.degree(b) == g.degree(a) {
                    let na: std::collections::HashSet<u32> =
                        g.neighbors(a).iter().copied().collect();
                    if let Some(&t) = g.neighbors(b).iter().find(|t| na.contains(t)) {
                        found = Some((a, b, t));
                        break 'outer;
                    }
                }
            }
        }
        if let Some((a, b, t)) = found {
            for s in 0..100u64 {
                let out = run0(&g, &[a, b], 10, s);
                let in_a = out.of(0).contains(&t);
                let in_b = out.of(1).contains(&t);
                assert_eq!(in_a, in_b, "shared coin violated for t={t} seed={s}");
            }
        }
    }

    #[test]
    fn labor_star_keeps_expected_fanout() {
        // The first-moment constraint should hold: E[#edges per seed] ≈ k.
        let g = generate::chung_lu(2000, 30.0, 2.2, 5);
        let seeds: Vec<u32> = (0..300).collect();
        let trials = 30;
        let mut total = 0usize;
        let mut nseeds_big = 0usize;
        for t in 0..trials as u64 {
            let out = run_star(&g, &seeds, 10, 700 + t);
            for (i, &s) in seeds.iter().enumerate() {
                if g.degree(s) > 10 {
                    total += out.of(i).len();
                    nseeds_big += 1;
                }
            }
        }
        let avg = total as f64 / nseeds_big as f64;
        assert!((avg - 10.0).abs() < 1.5, "LABOR-* avg fanout {avg}, want ≈10");
    }

    #[test]
    fn labor_star_subsets_real_neighbors() {
        let g = generate::chung_lu(800, 20.0, 2.3, 6);
        let seeds: Vec<u32> = (0..100).collect();
        let out = run_star(&g, &seeds, 10, 11);
        for (i, &s) in seeds.iter().enumerate() {
            for &t in out.of(i) {
                assert!(g.neighbors(s).contains(&t));
            }
        }
    }

    #[test]
    fn scratch_reuse_is_equivalent_to_fresh() {
        // running twice through the same scratch must equal fresh runs
        let g = generate::chung_lu(600, 25.0, 2.3, 8);
        let seeds_a: Vec<u32> = (0..150).collect();
        let seeds_b: Vec<u32> = (150..300).collect();
        let rng = DependentRng::new(77, Kappa::Finite(1));
        let mut scratch = LaborScratch::default();
        let mut out1 = Neighborhoods::default();
        out1.offsets.push(0);
        sample_labor_star(&g, &seeds_a, 10, 3, &rng, 0, &mut scratch, &mut out1);
        let mut out2 = Neighborhoods::default();
        out2.offsets.push(0);
        sample_labor_star(&g, &seeds_b, 10, 3, &rng, 0, &mut scratch, &mut out2);
        // fresh
        let mut fresh = LaborScratch::default();
        let mut out2f = Neighborhoods::default();
        out2f.offsets.push(0);
        sample_labor_star(&g, &seeds_b, 10, 3, &rng, 0, &mut fresh, &mut out2f);
        assert_eq!(out2.nbrs, out2f.nbrs, "scratch reuse changed results");
    }

    #[test]
    fn c_solver_monotone_effect() {
        // Hub vertices (shared by many seeds) are sampled at rates no
        // lower than under LABOR-0.
        let g = generate::chung_lu(600, 35.0, 2.15, 7);
        let seeds: Vec<u32> = (0..300).collect();
        // BTreeMap: max_by_key breaks frequency ties on key order
        // instead of hash order, so `hub` is stable across runs
        let mut freq: BTreeMap<u32, usize> = BTreeMap::new();
        for &s in &seeds {
            for &t in g.neighbors(s) {
                *freq.entry(t).or_insert(0) += 1;
            }
        }
        let hub = *freq.iter().max_by_key(|(_, &c)| c).unwrap().0;
        let trials = 60u64;
        let mut star_hits = 0usize;
        let mut l0_hits = 0usize;
        for t in 0..trials {
            if run_star(&g, &seeds, 10, 900 + t).nbrs.contains(&hub) {
                star_hits += 1;
            }
            if run0(&g, &seeds, 10, 900 + t).nbrs.contains(&hub) {
                l0_hits += 1;
            }
        }
        assert!(
            star_hits >= l0_hits,
            "hub should be at least as likely under LABOR-*: {star_hits} vs {l0_hits}"
        );
    }
}
