//! Neighbor Sampling (Hamilton et al. 2017; paper Appendix A.1.1).
//!
//! For each seed `s`: if `deg(s) ≤ k` take the full neighborhood,
//! otherwise sample `k` random neighbors without replacement.
//!
//! Implementation: **bottom-k by per-edge variate**. Each edge `(t→s)` is
//! scored with `r_ts` from the [`DependentRng`]; the k lowest-scored
//! neighbors are kept. For a fresh seed this is exactly uniform k-without-
//! replacement, and it makes NS compatible with dependent minibatching
//! (Appendix A.7: "a single random variate r_ts will be used for each
//! edge"): consecutive batches with slowly-rotating variates keep mostly
//! the same bottom-k set.

use super::dependent::DependentRng;
use super::Neighborhoods;
use crate::graph::{Csr, VertexId};

pub fn sample(
    g: &Csr,
    seeds: &[VertexId],
    fanout: usize,
    rng: &DependentRng,
    layer: usize,
    out: &mut Neighborhoods,
) {
    let domain = layer as u64;
    // scratch: (score, neighbor) for the current seed
    let mut scored: Vec<(f64, VertexId)> = Vec::with_capacity(64);
    for &s in seeds {
        let nbrs = g.neighbors(s);
        if nbrs.len() <= fanout {
            out.nbrs.extend_from_slice(nbrs);
        } else {
            scored.clear();
            for &t in nbrs {
                scored.push((rng.edge_variate(domain, t as u64, s as u64), t));
            }
            // partial selection of the k smallest
            scored.select_nth_unstable_by(fanout - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(_, t) in &scored[..fanout] {
                out.nbrs.push(t);
            }
        }
        out.offsets.push(out.nbrs.len() as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::Kappa;

    fn setup() -> Csr {
        generate::chung_lu(1000, 25.0, 2.3, 1)
    }

    fn run(g: &Csr, seeds: &[u32], fanout: usize, seed: u64) -> Neighborhoods {
        let rng = DependentRng::new(seed, Kappa::Finite(1));
        let mut out = Neighborhoods::default();
        out.offsets.push(0);
        sample(g, seeds, fanout, &rng, 0, &mut out);
        out
    }

    #[test]
    fn full_neighborhood_when_small() {
        let g = setup();
        let v = (0..1000u32).find(|&v| g.degree(v) > 0 && g.degree(v) <= 4).unwrap();
        let out = run(&g, &[v], 10, 3);
        assert_eq!(out.of(0).len(), g.degree(v));
        let mut got = out.of(0).to_vec();
        got.sort_unstable();
        let mut want = g.neighbors(v).to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn exactly_k_when_large() {
        let g = setup();
        let v = (0..1000u32).max_by_key(|&v| g.degree(v)).unwrap();
        assert!(g.degree(v) > 10);
        let out = run(&g, &[v], 10, 4);
        assert_eq!(out.of(0).len(), 10);
        // distinct
        let set: std::collections::HashSet<_> = out.of(0).iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn deterministic_given_seed_uniform_over_neighbors() {
        let g = setup();
        let v = (0..1000u32).max_by_key(|&v| g.degree(v)).unwrap();
        let a = run(&g, &[v], 5, 7);
        let b = run(&g, &[v], 5, 7);
        assert_eq!(a.nbrs, b.nbrs);
        // across seeds, (nearly) every neighbor should eventually appear;
        // with k=5, d≈250, 600 trials the expected miss count is ≈ 0
        let mut seen = std::collections::HashSet::new();
        for s in 0..600u64 {
            seen.extend(run(&g, &[v], 5, s).nbrs.iter().copied());
        }
        assert!(
            seen.len() as f64 >= 0.99 * g.degree(v) as f64,
            "uniformity coverage {} of {}",
            seen.len(),
            g.degree(v)
        );
    }

    #[test]
    fn selection_unbiased_roughly() {
        // bottom-k selection must be uniform: each neighbor of a degree-d
        // vertex appears with prob k/d.
        let g = setup();
        let v = (0..1000u32).find(|&v| g.degree(v) >= 20).unwrap();
        let d = g.degree(v);
        let k = 5;
        let trials = 3000;
        // BTreeMap: the failure message order is deterministic across runs
        let mut counts = std::collections::BTreeMap::new();
        for s in 0..trials as u64 {
            for &t in run(&g, &[v], k, 90_000 + s).of(0) {
                *counts.entry(t).or_insert(0usize) += 1;
            }
        }
        let expected = trials as f64 * k as f64 / d as f64;
        for (&t, &c) in &counts {
            let ratio = c as f64 / expected;
            assert!((0.6..1.4).contains(&ratio), "nbr {t}: count {c} vs expected {expected}");
        }
    }
}
