//! Graph sampling for GNN minibatch training (paper §2.2 / Appendix A.1).
//!
//! Four samplers, all "batch-size aware" in the paper's sense (the
//! expected number of sampled vertices is a function of the batch size):
//!
//! * [`neighbor`] — Neighbor Sampling (GraphSAGE): per-**edge** random
//!   variates, bottom-k selection.
//! * [`labor`] — LABOR-0 and LABOR-* : per-**vertex** random variates, so
//!   seeds sharing a source vertex reuse one coin — fewer unique vertices.
//! * [`random_walk`] — PinSAGE-style random walks with restart; top-k
//!   visited vertices become the sampled neighborhood.
//! * [`dependent`] — the smoothed dependent-minibatch variate generator of
//!   Appendix A.7, shared by all samplers: consecutive minibatches reuse
//!   slowly-rotating random variates (`r = Φ(cos(cπ/2)·n₁ + sin(cπ/2)·n₂)`),
//!   raising temporal locality of vertex accesses without biasing any
//!   single batch.
//!
//! [`block`] assembles per-layer samples into a multi-layer bipartite
//! message-flow graph ([`block::Mfg`]) following the paper's expansion
//! rule `S^{l+1} = S^l ∪ N(S^l)` (Eq. 2), and converts MFGs into the
//! fixed-fanout padded tensors consumed by the AOT-compiled model.

pub mod dependent;
pub mod neighbor;
pub mod labor;
pub mod random_walk;
pub mod block;
pub mod edge_pred;

use crate::graph::{Csr, VertexId};
pub use block::{Mfg, PaddedBatch, ShapeCaps};
pub use dependent::{DependentRng, Kappa};

/// Which sampling algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    /// Neighbor Sampling (Hamilton et al. 2017).
    Neighbor,
    /// LABOR-0 (Balin & Çatalyürek 2023), per-vertex variates.
    Labor0,
    /// LABOR-* importance-sampling variant.
    LaborStar,
    /// Random walks (Ying et al. 2018).
    RandomWalk,
}

impl SamplerKind {
    pub const ALL: [SamplerKind; 4] = [
        SamplerKind::Neighbor,
        SamplerKind::Labor0,
        SamplerKind::LaborStar,
        SamplerKind::RandomWalk,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Neighbor => "NS",
            SamplerKind::Labor0 => "LABOR-0",
            SamplerKind::LaborStar => "LABOR-*",
            SamplerKind::RandomWalk => "RW",
        }
    }

    pub fn parse(s: &str) -> Option<SamplerKind> {
        match s.to_ascii_lowercase().as_str() {
            "ns" | "neighbor" => Some(SamplerKind::Neighbor),
            "labor0" | "labor-0" => Some(SamplerKind::Labor0),
            "labor*" | "labor-*" | "laborstar" => Some(SamplerKind::LaborStar),
            "rw" | "randomwalk" => Some(SamplerKind::RandomWalk),
            _ => None,
        }
    }
}

/// Random-walk hyperparameters (paper Appendix A.5: o=3, p=0.5, a=100).
#[derive(Clone, Copy, Debug)]
pub struct RwParams {
    pub walk_length: usize,
    pub restart_prob: f64,
    pub num_walks: usize,
}

impl Default for RwParams {
    fn default() -> Self {
        RwParams { walk_length: 3, restart_prob: 0.5, num_walks: 100 }
    }
}

/// The deepest layer index a per-layer fanout override can address
/// (fixed so [`SamplerConfig`] stays `Copy`).
pub const MAX_FANOUT_LAYERS: usize = 8;

/// Sampler configuration shared by all algorithms.
#[derive(Clone, Copy, Debug)]
pub struct SamplerConfig {
    /// Uniform fanout k (paper uses 10); per-layer overrides in
    /// `fanouts` take precedence where set.
    pub fanout: usize,
    /// Per-layer fanout overrides, indexed by the `layer` argument of
    /// [`Sampler::sample_layer`] (0 = the seeds' first hop). A `0` slot
    /// means "no override — use the uniform `fanout`"; all-zero (the
    /// default) is the classic uniform configuration.
    pub fanouts: [usize; MAX_FANOUT_LAYERS],
    /// Number of GNN layers L (paper uses 3).
    pub layers: usize,
    pub rw: RwParams,
    /// Batch-dependency parameter κ of §3.2 (1 = independent batches).
    pub kappa: Kappa,
    /// LABOR-* fixed-point rounds.
    pub labor_star_rounds: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            fanout: 10,
            fanouts: [0; MAX_FANOUT_LAYERS],
            layers: 3,
            rw: RwParams::default(),
            kappa: Kappa::Finite(1),
            labor_star_rounds: 3,
        }
    }
}

impl SamplerConfig {
    /// Build a sampler over `graph` with deterministic seed.
    pub fn build<'g>(&self, kind: SamplerKind, graph: &'g Csr, seed: u64) -> Sampler<'g> {
        Sampler {
            kind,
            cfg: *self,
            graph,
            rng: DependentRng::new(seed, self.kappa),
            scratch: labor::LaborScratch::default(),
        }
    }

    /// The effective fanout of GNN layer `layer` (per-layer override
    /// when set, the uniform `fanout` otherwise).
    pub fn fanout_at(&self, layer: usize) -> usize {
        match self.fanouts.get(layer) {
            Some(&k) if k > 0 => k,
            _ => self.fanout,
        }
    }

    /// The largest effective fanout across the configured layers (caps
    /// padded-tensor shapes).
    pub fn max_fanout(&self) -> usize {
        (0..self.layers).map(|l| self.fanout_at(l)).max().unwrap_or(self.fanout)
    }
}

/// One layer's raw sample: per-seed neighbor lists, flattened.
#[derive(Clone, Debug, Default)]
pub struct Neighborhoods {
    /// offsets[i]..offsets[i+1] spans `nbrs` for seed i.
    pub offsets: Vec<u32>,
    pub nbrs: Vec<VertexId>,
}

impl Neighborhoods {
    pub fn clear(&mut self) {
        self.offsets.clear();
        self.nbrs.clear();
    }
    pub fn num_seeds(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }
    pub fn num_edges(&self) -> usize {
        self.nbrs.len()
    }
    pub fn of(&self, i: usize) -> &[VertexId] {
        &self.nbrs[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// A configured sampler bound to a graph. Holds the dependent-RNG state;
/// call [`Sampler::advance_batch`] between minibatches (the trainer and
/// the coop engine do this).
pub struct Sampler<'g> {
    pub kind: SamplerKind,
    pub cfg: SamplerConfig,
    pub graph: &'g Csr,
    pub rng: DependentRng,
    /// reusable per-batch scratch (variate memo + LABOR-* π tables);
    /// sized to |V| on first use, zero allocation afterwards.
    scratch: labor::LaborScratch,
}

impl<'g> Sampler<'g> {
    /// Sample the in-neighborhoods of `seeds` for GNN layer `layer`
    /// (layers use distinct variate domains so a vertex appearing in two
    /// layers of one batch gets independent neighborhoods, as in DGL).
    pub fn sample_layer(&mut self, seeds: &[VertexId], layer: usize, out: &mut Neighborhoods) {
        out.clear();
        out.offsets.push(0);
        let fanout = self.cfg.fanout_at(layer);
        match self.kind {
            SamplerKind::Neighbor => {
                neighbor::sample(self.graph, seeds, fanout, &self.rng, layer, out)
            }
            SamplerKind::Labor0 => labor::sample_labor0(
                self.graph,
                seeds,
                fanout,
                &self.rng,
                layer,
                &mut self.scratch,
                out,
            ),
            SamplerKind::LaborStar => labor::sample_labor_star(
                self.graph,
                seeds,
                fanout,
                self.cfg.labor_star_rounds,
                &self.rng,
                layer,
                &mut self.scratch,
                out,
            ),
            SamplerKind::RandomWalk => {
                random_walk::sample(
                    self.graph,
                    seeds,
                    fanout,
                    self.cfg.rw,
                    &self.rng,
                    layer,
                    out,
                )
            }
        }
        debug_assert_eq!(out.num_seeds(), seeds.len());
    }

    /// Sample a full L-layer MFG starting from `seeds` (paper Eq. 2
    /// expansion `S^{l+1} = S^l ∪ N_sampled(S^l)`).
    pub fn sample_mfg(&mut self, seeds: &[VertexId]) -> Mfg {
        block::build_mfg(self, seeds)
    }

    /// Advance the dependent-batch counter (call once per minibatch).
    pub fn advance_batch(&mut self) {
        self.rng.advance();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn kind_parse_roundtrip() {
        for k in SamplerKind::ALL {
            assert_eq!(SamplerKind::parse(k.name()), Some(k));
        }
        assert_eq!(SamplerKind::parse("nope"), None);
    }

    #[test]
    fn all_samplers_respect_seed_count_and_membership() {
        let g = generate::chung_lu(2000, 12.0, 2.5, 3);
        let seeds: Vec<u32> = (0..64).collect();
        for kind in SamplerKind::ALL {
            let cfg = SamplerConfig {
                rw: RwParams { num_walks: 10, ..Default::default() },
                ..Default::default()
            };
            let mut s = cfg.build(kind, &g, 99);
            let mut out = Neighborhoods::default();
            s.sample_layer(&seeds, 0, &mut out);
            assert_eq!(out.num_seeds(), seeds.len(), "{kind:?}");
            if kind != SamplerKind::RandomWalk {
                // sampled neighbors must be real in-neighbors
                for (i, &seed) in seeds.iter().enumerate() {
                    for &t in out.of(i) {
                        assert!(g.neighbors(seed).contains(&t), "{kind:?}: {t} not nbr of {seed}");
                    }
                }
            }
        }
    }

    #[test]
    fn ns_rw_fanout_bound() {
        let g = generate::chung_lu(2000, 30.0, 2.3, 5);
        let seeds: Vec<u32> = (100..200).collect();
        for kind in [SamplerKind::Neighbor, SamplerKind::RandomWalk] {
            let cfg = SamplerConfig {
                fanout: 5,
                rw: RwParams { num_walks: 20, ..Default::default() },
                ..Default::default()
            };
            let mut s = cfg.build(kind, &g, 42);
            let mut out = Neighborhoods::default();
            s.sample_layer(&seeds, 1, &mut out);
            for i in 0..seeds.len() {
                assert!(out.of(i).len() <= 5, "{kind:?} exceeded fanout: {}", out.of(i).len());
            }
        }
    }

    #[test]
    fn per_layer_fanout_overrides_apply_by_layer() {
        let mut cfg = SamplerConfig { fanout: 7, ..Default::default() };
        cfg.fanouts[1] = 3;
        assert_eq!(cfg.fanout_at(0), 7, "unset slot falls back to the uniform fanout");
        assert_eq!(cfg.fanout_at(1), 3);
        assert_eq!(cfg.fanout_at(MAX_FANOUT_LAYERS + 5), 7, "beyond the array is uniform");
        assert_eq!(cfg.max_fanout(), 7);
        cfg.fanouts[2] = 20;
        assert_eq!(cfg.max_fanout(), 20);

        // and the sampler really respects the per-layer bound
        let g = generate::chung_lu(2000, 30.0, 2.3, 5);
        let seeds: Vec<u32> = (100..200).collect();
        let mut s = cfg.build(SamplerKind::Neighbor, &g, 42);
        let mut out = Neighborhoods::default();
        s.sample_layer(&seeds, 1, &mut out);
        for i in 0..seeds.len() {
            assert!(out.of(i).len() <= 3, "layer-1 override violated: {}", out.of(i).len());
        }
    }

    #[test]
    fn labor_shares_vertex_coins_across_seeds() {
        // LABOR-0 must sample fewer (or equal) unique vertices than NS in
        // expectation — check on a graph with heavy seed overlap.
        let g = generate::chung_lu(500, 40.0, 2.2, 6);
        let seeds: Vec<u32> = (0..200).collect();
        let cfg = SamplerConfig::default();
        let uniq = |kind: SamplerKind| -> f64 {
            let mut total = 0usize;
            for trial in 0..10u64 {
                let mut s = cfg.build(kind, &g, 1000 + trial);
                let mut out = Neighborhoods::default();
                s.sample_layer(&seeds, 0, &mut out);
                let set: std::collections::HashSet<_> = out.nbrs.iter().collect();
                total += set.len();
            }
            total as f64 / 10.0
        };
        let ns = uniq(SamplerKind::Neighbor);
        let l0 = uniq(SamplerKind::Labor0);
        assert!(l0 <= ns * 1.02, "LABOR-0 uniques {l0} should be <= NS {ns}");
    }

    #[test]
    fn labor_star_samples_fewer_uniques_than_labor0() {
        let g = generate::chung_lu(800, 30.0, 2.2, 8);
        let seeds: Vec<u32> = (0..300).collect();
        let cfg = SamplerConfig::default();
        let uniq = |kind: SamplerKind| -> f64 {
            let mut total = 0usize;
            for trial in 0..20u64 {
                let mut s = cfg.build(kind, &g, 2000 + trial);
                let mut out = Neighborhoods::default();
                s.sample_layer(&seeds, 0, &mut out);
                let set: std::collections::HashSet<_> = out.nbrs.iter().collect();
                total += set.len();
            }
            total as f64 / 20.0
        };
        let l0 = uniq(SamplerKind::Labor0);
        let ls = uniq(SamplerKind::LaborStar);
        assert!(ls <= l0 * 1.02, "LABOR-* uniques {ls} should be <= LABOR-0 {l0}");
    }
}
