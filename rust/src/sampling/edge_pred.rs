//! Edge-prediction workload generation (paper §4.1, bottom rows of
//! Figures 3/6).
//!
//! "We add reverse edges to the graph making it undirected and sample a
//! batch of edges. For each of these edges a random negative edge (an
//! edge that is not part of E) with one endpoint coinciding with the
//! positive edge is sampled. Then, all of the endpoints of these positive
//! and negative edges are used as seed vertices."

use crate::graph::{Csr, VertexId};
use crate::util::rng::Pcg64;

/// One positive edge + its coupled negative edge.
#[derive(Clone, Copy, Debug)]
pub struct EdgeSample {
    pub pos: (VertexId, VertexId),
    pub neg: (VertexId, VertexId),
}

/// Sample `batch_size` positive edges with coupled negatives from an
/// (assumed undirected) graph.
pub fn sample_edges(g: &Csr, batch_size: usize, rng: &mut Pcg64) -> Vec<EdgeSample> {
    let n = g.num_vertices() as u64;
    let mut out = Vec::with_capacity(batch_size);
    for _ in 0..batch_size {
        let pos = g.random_edge(rng);
        // keep one endpoint, resample the other until the pair is a
        // non-edge (graphs here are sparse, so this terminates fast)
        let keep_src = rng.next_f64() < 0.5;
        let anchor = if keep_src { pos.0 } else { pos.1 };
        let mut neg = pos;
        for _ in 0..64 {
            let other = rng.next_below(n) as VertexId;
            if other == anchor {
                continue;
            }
            let cand = if keep_src { (anchor, other) } else { (other, anchor) };
            if !g.has_edge(cand.0, cand.1) {
                neg = cand;
                break;
            }
        }
        out.push(EdgeSample { pos, neg });
    }
    out
}

/// Collect the distinct endpoints of a batch of edge samples — the seed
/// set handed to the node samplers.
pub fn seeds_of(samples: &[EdgeSample]) -> Vec<VertexId> {
    let mut set = std::collections::HashSet::with_capacity(samples.len() * 4);
    let mut seeds = Vec::with_capacity(samples.len() * 4);
    for e in samples {
        for v in [e.pos.0, e.pos.1, e.neg.0, e.neg.1] {
            if set.insert(v) {
                seeds.push(v);
            }
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn positives_exist_negatives_do_not() {
        let g = generate::chung_lu(2000, 10.0, 2.4, 3).to_undirected();
        let mut rng = Pcg64::new(5);
        let batch = sample_edges(&g, 128, &mut rng);
        assert_eq!(batch.len(), 128);
        let mut neg_ok = 0;
        for e in &batch {
            assert!(g.has_edge(e.pos.0, e.pos.1));
            if !g.has_edge(e.neg.0, e.neg.1) {
                neg_ok += 1;
            }
            // negative shares an endpoint with the positive
            assert!(
                e.neg.0 == e.pos.0
                    || e.neg.0 == e.pos.1
                    || e.neg.1 == e.pos.0
                    || e.neg.1 == e.pos.1
            );
        }
        assert!(neg_ok >= 126, "negatives must (almost) always be non-edges: {neg_ok}");
    }

    #[test]
    fn seeds_are_distinct_and_cover_endpoints() {
        let g = generate::chung_lu(1000, 8.0, 2.4, 4).to_undirected();
        let mut rng = Pcg64::new(6);
        let batch = sample_edges(&g, 64, &mut rng);
        let seeds = seeds_of(&batch);
        let set: std::collections::HashSet<_> = seeds.iter().collect();
        assert_eq!(set.len(), seeds.len());
        for e in &batch {
            assert!(set.contains(&e.pos.0) && set.contains(&e.pos.1));
            assert!(set.contains(&e.neg.0) && set.contains(&e.neg.1));
        }
        // ~4 endpoints per sample minus collisions
        assert!(seeds.len() <= 64 * 4);
        assert!(seeds.len() > 64, "should have many distinct endpoints");
    }
}
