//! Log-bucketed mergeable histograms for per-stage latency columns.
//!
//! The repro tables historically printed per-window *averages* only;
//! the observability plane replaces those with p50/p99 columns backed
//! by [`LogHist`]: a base-2^(1/8) logarithmic histogram (8 sub-buckets
//! per octave, ≈ 9% relative bucket width) plus an exact zero bucket
//! and tracked min/max clamp bounds.
//!
//! Contract (property-tested in `tests/proptests.rs` against the exact
//! type-7 [`crate::util::stats::percentile`]): for any quantile `p`,
//! [`LogHist::quantile_bounds`] returns `(lo, hi)` with
//! `lo <= exact_percentile(pooled, p) <= hi`, and the bound survives
//! [`LogHist::merge`] — merging per-shard histograms brackets the
//! percentile of the *pooled* samples. The bracket follows from the
//! recording invariant `bucket_lower(i) <= v < bucket_upper(i)`, which
//! is enforced with an explicit boundary-nudge loop after the float
//! `log2` (float rounding near bucket edges can land one bucket off;
//! the nudge makes the invariant exact rather than approximate).
//!
//! Histograms never feed a ledger or a decision — they are display-only
//! derivatives, so float `log2`/`exp2` here do not touch the
//! determinism contract (same-machine runs bucket identically; ledgers
//! stay integer).

use std::collections::BTreeMap;

/// Sub-buckets per octave: bucket `i` covers `[2^(i/8), 2^((i+1)/8))`.
const SUB: i32 = 8;

fn bucket_lower(idx: i32) -> f64 {
    (idx as f64 / SUB as f64).exp2()
}

fn bucket_upper(idx: i32) -> f64 {
    ((idx + 1) as f64 / SUB as f64).exp2()
}

/// Bucket index for a strictly positive value, with the boundary-nudge
/// loop making `bucket_lower(i) <= v < bucket_upper(i)` exact.
fn bucket_of(v: f64) -> i32 {
    debug_assert!(v > 0.0 && v.is_finite());
    let mut idx = (v.log2() * SUB as f64).floor() as i32;
    while bucket_lower(idx) > v {
        idx -= 1;
    }
    while bucket_upper(idx) <= v {
        idx += 1;
    }
    idx
}

/// Mergeable log-bucketed histogram over non-negative samples.
#[derive(Clone, Debug, Default)]
pub struct LogHist {
    /// exact count of samples equal to zero (log buckets can't hold 0).
    zero: u64,
    /// sparse bucket counts, keyed by log-bucket index (ordered map so
    /// every scan/export is deterministic).
    buckets: BTreeMap<i32, u64>,
    n: u64,
    sum: f64,
    /// exact extrema of recorded samples — used to clamp quantile
    /// bounds so the bracket never widens past observed data.
    min: f64,
    max: f64,
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist::default()
    }

    /// Record one sample. Negative / non-finite inputs are clamped into
    /// the zero bucket (stage times are non-negative by construction;
    /// the clamp keeps a rogue NaN from poisoning the whole histogram).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
        if v == 0.0 {
            self.zero += 1;
        } else {
            *self.buckets.entry(bucket_of(v)).or_insert(0) += 1;
        }
    }

    /// Fold `other` into `self` (bucket-wise addition; extrema widen).
    pub fn merge(&mut self, other: &LogHist) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.zero += other.zero;
        self.n += other.n;
        self.sum += other.sum;
        for (&idx, &c) in &other.buckets {
            *self.buckets.entry(idx).or_insert(0) += c;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// `(lower, upper)` bounds of the bucket holding the 0-based rank-`k`
    /// sample (ranks follow ascending value order: zero bucket first,
    /// then log buckets by index).
    fn rank_bounds(&self, k: u64) -> (f64, f64) {
        debug_assert!(k < self.n);
        if k < self.zero {
            return (0.0, 0.0);
        }
        let mut seen = self.zero;
        for (&idx, &c) in &self.buckets {
            seen += c;
            if k < seen {
                return (bucket_lower(idx), bucket_upper(idx));
            }
        }
        // Unreachable when counts are consistent; fall back to extrema.
        (self.min, self.max)
    }

    /// Bracket of the exact type-7 percentile: returns `(lo, hi)` such
    /// that `lo <= percentile(sorted_samples, p) <= hi`. The type-7
    /// estimate interpolates between the samples at ranks `floor(h)`
    /// and `ceil(h)` (`h = p·(n−1)`), so bracketing those two samples'
    /// buckets — clamped to the exact recorded extrema — brackets the
    /// interpolation.
    pub fn quantile_bounds(&self, p: f64) -> (f64, f64) {
        if self.n == 0 {
            return (0.0, 0.0);
        }
        let p = p.clamp(0.0, 1.0);
        let h = p * (self.n - 1) as f64;
        let k_lo = h.floor() as u64;
        let k_hi = h.ceil() as u64;
        let lo = self.rank_bounds(k_lo).0.max(self.min);
        let hi = self.rank_bounds(k_hi).1.min(self.max);
        (lo, hi)
    }

    /// Point estimate for table columns: midpoint of the clamped
    /// bracket. Within one bucket width (≈ 9%) of the exact percentile.
    pub fn quantile_mid(&self, p: f64) -> f64 {
        let (lo, hi) = self.quantile_bounds(p);
        (lo + hi) / 2.0
    }
}

/// Per-stage histograms the multi-PE trainer fills per step (ms units),
/// surfaced as p50/p99 columns in `repro end2end`.
#[derive(Clone, Debug, Default)]
pub struct StageHists {
    /// summed-across-PEs sampling time per step.
    pub sample_ms: LogHist,
    /// summed-across-PEs feature-loading time per step.
    pub feature_ms: LogHist,
    /// forward+backward compute time per step.
    pub compute_ms: LogHist,
    /// gradient all-reduce time per step.
    pub allreduce_ms: LogHist,
}

impl StageHists {
    pub fn merge(&mut self, other: &StageHists) {
        self.sample_ms.merge(&other.sample_ms);
        self.feature_ms.merge(&other.feature_ms);
        self.compute_ms.merge(&other.compute_ms);
        self.allreduce_ms.merge(&other.allreduce_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_invariant_holds_at_boundaries() {
        for &v in &[1.0, 2.0, 0.5, 1024.0, 1e-9, 3.7, 8.999999999] {
            let i = bucket_of(v);
            assert!(bucket_lower(i) <= v, "lower({i}) > {v}");
            assert!(v < bucket_upper(i), "{v} >= upper({i})");
        }
    }

    #[test]
    fn quantiles_bracket_exact_percentile() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 * 0.37).collect();
        let mut h = LogHist::new();
        for &s in &samples {
            h.record(s);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &p in &[0.0, 0.5, 0.9, 0.99, 1.0] {
            let exact = crate::util::stats::percentile(&sorted, p);
            let (lo, hi) = h.quantile_bounds(p);
            assert!(lo <= exact && exact <= hi, "p={p}: ({lo},{hi}) vs {exact}");
        }
    }

    #[test]
    fn merge_matches_pooled_recording() {
        let mut a = LogHist::new();
        let mut b = LogHist::new();
        let mut pooled = LogHist::new();
        for i in 0..50 {
            let v = (i as f64 * 1.91) % 17.0;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            pooled.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), pooled.count());
        assert_eq!(a.quantile_bounds(0.5), pooled.quantile_bounds(0.5));
        assert_eq!(a.quantile_bounds(0.99), pooled.quantile_bounds(0.99));
    }

    #[test]
    fn zero_and_empty_are_exact() {
        let h = LogHist::new();
        assert_eq!(h.quantile_bounds(0.5), (0.0, 0.0));
        let mut z = LogHist::new();
        z.record(0.0);
        z.record(0.0);
        assert_eq!(z.quantile_bounds(0.99), (0.0, 0.0));
        assert_eq!(z.count(), 2);
    }
}
