//! Observability plane: flight-recorder span tracing, the unified
//! ledger registry, and log-bucketed stage histograms.
//!
//! Three pieces, all **derived from the ledgers the pipeline already
//! keeps** (nothing here is consulted by a sampling/batching/serving
//! decision, and tracing off is zero-overhead):
//!
//! * [`span`] — `(batch, pe, stage, t_start, t_end, bytes)` spans in
//!   per-track append-only buffers, merged by `(batch, pe, seq)` and
//!   exported as Chrome/Perfetto trace-event JSON (`--trace out.json`
//!   on `engine` / `train` / `serve`).
//! * [`registry`] — the [`LEDGER_STRUCTS`] declaration table (the
//!   single source `coopgnn-lint`'s `ledger` rule is generated from)
//!   plus the runtime [`Registry`] counter bag with a Prometheus-style
//!   text exposition (`--metrics-out metrics.prom`).
//! * [`hist`] — mergeable log-bucketed [`LogHist`]s whose quantile
//!   bounds provably bracket the exact interpolated percentile,
//!   backing the p50/p99 columns in `repro end2end` / `repro serve`.
//!
//! [`wall`] is the plane's single wall-clock capture shim — the only
//! obs file on the lint `wallclock` allowlist.

pub mod hist;
pub mod registry;
pub mod span;
pub mod wall;

pub use hist::{LogHist, StageHists};
pub use registry::{LedgerDecl, LedgerSource, Registry, LEDGER_STRUCTS};
pub use span::{ms_to_us, split_dur, Span, Trace, TraceBuffer, TraceSink};
pub use wall::WallClock;
