//! Flight-recorder span tracing: per-track append-only buffers of
//! `(batch, pe, stage, t_start, t_end, bytes)` records, merged
//! deterministically by `(batch, pe, seq)` and exported as Chrome /
//! Perfetto trace-event JSON (load the file at `chrome://tracing` or
//! <https://ui.perfetto.dev>).
//!
//! Spans are **derived post-hoc from the ledgers** the pipeline already
//! keeps (per-batch [`crate::pipeline::PeWork`], the serve
//! [`crate::serve::report::Ledger`]): nothing on the hot path records
//! wall time for tracing, so
//!
//! * tracing **off is zero-overhead** — [`Trace::Off`] holds no
//!   allocation and `record` is a discriminant check;
//! * counters are **bit-identical with tracing on vs off** (the trace
//!   only reads what was already counted);
//! * serve traces are **bit-identical across serial/threaded exec and
//!   prefetch 0/1** — timestamps are the virtual-µs clock, inherited
//!   from the ledger's existing bit-identity contract;
//! * per-stage summed `bytes` reconcile exactly with the corresponding
//!   report ledger fields (pinned in `tests/integration_obs.rs`).
//!
//! Wall-clock-derived spans (engine/train stage times) carry ms
//! measurements converted to integer µs; they are honest measurements,
//! not virtual time, and are only captured through the existing
//! allowlisted timing sites (see [`crate::obs::wall`]).

/// One traced interval on one track.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// batch / step / dispatch index the span belongs to.
    pub batch: u64,
    /// track id: PE index for engine/train tracks (trainer coordinator
    /// = `num_pes`), 0 = batches / 1 = requests for serve.
    pub pe: u32,
    /// per-`(batch, pe)` sequence number — assigned in emission order,
    /// making `(batch, pe, seq)` a total order over all spans.
    pub seq: u32,
    /// pipeline stage name (static, lower_snake — see module docs of
    /// the emitting plane for the stage vocabulary).
    pub stage: &'static str,
    pub t_start_us: u64,
    pub t_end_us: u64,
    /// bytes attributed to this span (0 for pure-time stages).
    pub bytes: u64,
}

impl Span {
    pub fn dur_us(&self) -> u64 {
        self.t_end_us.saturating_sub(self.t_start_us)
    }
}

/// Anything that can accept spans. The pipeline planes emit through
/// this trait so a future sink (streaming writer, ring buffer) can slot
/// in without touching emission sites.
pub trait TraceSink {
    fn record(&mut self, span: Span);
    /// `false` lets emitters skip span *derivation* work entirely.
    fn enabled(&self) -> bool {
        true
    }
}

/// Per-track append-only span buffers plus the category stamped into
/// the Chrome export (`"engine"`, `"train"`, `"serve"`).
#[derive(Clone, Debug)]
pub struct TraceBuffer {
    cat: &'static str,
    per_track: Vec<Vec<Span>>,
}

impl TraceBuffer {
    pub fn new(cat: &'static str) -> TraceBuffer {
        TraceBuffer { cat, per_track: Vec::new() }
    }

    pub fn cat(&self) -> &'static str {
        self.cat
    }

    pub fn span_count(&self) -> usize {
        self.per_track.iter().map(|t| t.len()).sum()
    }

    /// Distinct batch indices seen across all tracks.
    pub fn batch_count(&self) -> usize {
        let mut batches: Vec<u64> =
            self.per_track.iter().flatten().map(|s| s.batch).collect();
        batches.sort_unstable();
        batches.dedup();
        batches.len()
    }

    /// Sum of `bytes` across every span with the given stage name —
    /// the reconciliation hook against report ledger fields.
    pub fn stage_bytes(&self, stage: &str) -> u64 {
        self.per_track
            .iter()
            .flatten()
            .filter(|s| s.stage == stage)
            .map(|s| s.bytes)
            .sum()
    }

    /// All spans merged into one list, sorted by `(batch, pe, seq)`.
    /// Emission guarantees the key is unique per span, so this order is
    /// total and independent of track interleaving.
    pub fn merged(&self) -> Vec<Span> {
        let mut all: Vec<Span> =
            self.per_track.iter().flatten().cloned().collect();
        all.sort_by_key(|s| (s.batch, s.pe, s.seq));
        all
    }

    /// Chrome trace-event JSON: an array of `"ph":"X"` complete events,
    /// integer µs timestamps, one `tid` per track, sorted by
    /// `(tid, ts, batch, seq)` so timestamps are monotone per track
    /// (checked by `python/tests/test_trace_schema.py`). Output is a
    /// pure function of the span set — byte-identical whenever the
    /// spans are.
    pub fn to_chrome_json(&self) -> String {
        let mut events: Vec<&Span> = self.per_track.iter().flatten().collect();
        events.sort_by_key(|s| (s.pe, s.t_start_us, s.batch, s.seq));
        let mut out = String::from("[\n");
        for (i, s) in events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":0,\"tid\":{},\"args\":{{\"batch\":{},\"seq\":{},\"bytes\":{}}}}}",
                s.stage,
                self.cat,
                s.t_start_us,
                s.dur_us(),
                s.pe,
                s.batch,
                s.seq,
                s.bytes
            ));
        }
        out.push_str("\n]\n");
        out
    }
}

impl TraceSink for TraceBuffer {
    fn record(&mut self, span: Span) {
        let track = span.pe as usize;
        if self.per_track.len() <= track {
            self.per_track.resize_with(track + 1, Vec::new);
        }
        self.per_track[track].push(span);
    }
}

/// The switch the CLI layers hand into the pipeline: `Off` is the
/// default everywhere and costs one discriminant check per (skipped)
/// emission site — no allocation, no derivation work.
#[derive(Clone, Debug, Default)]
pub enum Trace {
    #[default]
    Off,
    On(TraceBuffer),
}

impl Trace {
    pub fn on(cat: &'static str) -> Trace {
        Trace::On(TraceBuffer::new(cat))
    }

    pub fn buffer(&self) -> Option<&TraceBuffer> {
        match self {
            Trace::Off => None,
            Trace::On(b) => Some(b),
        }
    }
}

impl TraceSink for Trace {
    fn record(&mut self, span: Span) {
        if let Trace::On(b) = self {
            b.record(span);
        }
    }

    fn enabled(&self) -> bool {
        matches!(self, Trace::On(_))
    }
}

/// Convert a measured ms duration to integer virtual-export µs.
pub fn ms_to_us(ms: f64) -> u64 {
    if ms.is_finite() && ms > 0.0 {
        (ms * 1000.0).round() as u64
    } else {
        0
    }
}

/// Split `total_us` across `weights` proportionally with the
/// largest-remainder method: shares sum to exactly `total_us`, ties go
/// to the lowest index, and a zero weight vector gives the whole total
/// to the first slot — fully deterministic integer arithmetic.
pub fn split_dur(total_us: u64, weights: &[u64]) -> Vec<u64> {
    if weights.is_empty() {
        return Vec::new();
    }
    let w_sum: u128 = weights.iter().map(|&w| w as u128).sum();
    if w_sum == 0 {
        let mut out = vec![0u64; weights.len()];
        out[0] = total_us;
        return out;
    }
    let mut shares = vec![0u64; weights.len()];
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(weights.len());
    let mut given: u64 = 0;
    for (i, &w) in weights.iter().enumerate() {
        let num = total_us as u128 * w as u128;
        shares[i] = (num / w_sum) as u64;
        given += shares[i];
        rems.push((num % w_sum, i));
    }
    // Hand the leftover µs to the largest remainders, lowest index on
    // ties (sort by (-rem, idx)).
    rems.sort_by_key(|&(r, i)| (std::cmp::Reverse(r), i));
    let mut leftover = total_us - given;
    for &(_, i) in &rems {
        if leftover == 0 {
            break;
        }
        shares[i] += 1;
        leftover -= 1;
    }
    shares
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(batch: u64, pe: u32, seq: u32) -> Span {
        Span {
            batch,
            pe,
            seq,
            stage: "sample",
            t_start_us: batch * 10,
            t_end_us: batch * 10 + 5,
            bytes: 7,
        }
    }

    #[test]
    fn merged_is_total_order_by_batch_pe_seq() {
        let mut b = TraceBuffer::new("engine");
        for &(batch, pe, seq) in
            &[(1, 0, 0), (0, 1, 1), (0, 0, 0), (0, 1, 0), (1, 0, 1)]
        {
            b.record(span(batch, pe, seq));
        }
        let m = b.merged();
        for w in m.windows(2) {
            assert!(
                (w[0].batch, w[0].pe, w[0].seq) < (w[1].batch, w[1].pe, w[1].seq)
            );
        }
        assert_eq!(m.len(), 5);
    }

    #[test]
    fn trace_off_records_nothing_and_reports_disabled() {
        let mut t = Trace::Off;
        assert!(!t.enabled());
        t.record(span(0, 0, 0));
        assert!(t.buffer().is_none());
    }

    #[test]
    fn stage_bytes_sums_only_matching_stage() {
        let mut b = TraceBuffer::new("engine");
        b.record(span(0, 0, 0));
        let mut other = span(0, 0, 1);
        other.stage = "cache_fill";
        other.bytes = 100;
        b.record(other);
        assert_eq!(b.stage_bytes("sample"), 7);
        assert_eq!(b.stage_bytes("cache_fill"), 100);
        assert_eq!(b.stage_bytes("absent"), 0);
    }

    #[test]
    fn split_dur_conserves_total_and_is_proportional() {
        assert_eq!(split_dur(10, &[1, 1, 1]).iter().sum::<u64>(), 10);
        assert_eq!(split_dur(100, &[3, 1]), vec![75, 25]);
        assert_eq!(split_dur(7, &[0, 0]), vec![7, 0]);
        assert_eq!(split_dur(0, &[5, 5]), vec![0, 0]);
        let s = split_dur(1_000_003, &[123, 456, 789, 1]);
        assert_eq!(s.iter().sum::<u64>(), 1_000_003);
    }

    #[test]
    fn chrome_json_is_monotone_per_track() {
        let mut b = TraceBuffer::new("train");
        b.record(span(2, 0, 0));
        b.record(span(0, 0, 0));
        b.record(span(1, 1, 0));
        let json = b.to_chrome_json();
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        // ts values per tid appear in sorted order in the output.
        let ts0: Vec<&str> = json
            .lines()
            .filter(|l| l.contains("\"tid\":0"))
            .collect();
        assert_eq!(ts0.len(), 2);
        assert!(ts0[0].contains("\"ts\":0") && ts0[1].contains("\"ts\":20"));
    }
}
