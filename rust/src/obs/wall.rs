//! The observability plane's **single wall-clock capture shim**.
//!
//! The determinism contract bans ambient wall-clock reads outside a
//! short allowlist (`coopgnn-lint`'s `wallclock` rule +
//! `clippy.toml` disallowed-methods). Every wall measurement the obs
//! plane takes goes through [`WallClock`] here, so the allowlist gains
//! exactly one obs entry and a grep for `Instant::now` in `obs/` hits
//! one file. Wall readings captured through this shim are *report-only*
//! — they may be printed or exported, but must never steer a sampling,
//! batching, or serving decision (those run on the virtual clock).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

/// A started wall-clock measurement (monotonic).
#[derive(Clone, Copy, Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    /// Begin a measurement.
    pub fn start() -> WallClock {
        WallClock { start: Instant::now() }
    }

    /// Elapsed milliseconds since [`WallClock::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotone_nonnegative() {
        let w = WallClock::start();
        let a = w.elapsed_ms();
        let b = w.elapsed_ms();
        assert!(a >= 0.0 && b >= a);
    }
}
