//! Unified ledger registry + runtime counter bag.
//!
//! Two layers live here:
//!
//! 1. **The declaration table** [`LEDGER_STRUCTS`]: the single list of
//!    every lint-tracked counter struct in the tree, with its declaring
//!    file and the merge functions that must reference all of its
//!    numeric fields. `coopgnn-lint`'s `ledger` rule **parses this
//!    table out of this file** (see `rust/tools/lint/src/config.rs`)
//!    instead of carrying a hand-maintained copy — registering a new
//!    counter struct here is the only way to add one, so forgetting the
//!    lint wiring is impossible. Keep every entry a plain string
//!    literal: the lint parser reads quoted strings positionally
//!    (struct, declaring file, then `(file, fn)` pairs) and fails loud
//!    on anything else.
//! 2. **The runtime [`Registry`]**: the tree's one counter API (the
//!    old `metrics::Metrics` bag folded in — `metrics.rs` is now a
//!    deprecated re-export), able to absorb any [`LedgerSource`] and
//!    emit a Prometheus-style text exposition for `--metrics-out`.

use std::collections::BTreeMap;

use crate::coop::engine::EngineReport;
use crate::coop::feature_loader::{LoadStats, PeLoad};
use crate::obs::wall::WallClock;
use crate::pipeline::PeWork;
use crate::serve::executor::BatchExecution;
use crate::serve::report::{BatchRecord, ServeReport};
use crate::train::{ParallelRunReport, ParallelStepStats};

/// One registered counter struct: its name, the file that declares it,
/// and the `(file, fn)` merge sites whose bodies must reference every
/// numeric field (the ledger-conservation contract).
#[derive(Clone, Copy, Debug)]
pub struct LedgerDecl {
    pub strukt: &'static str,
    pub decl_file: &'static str,
    pub merge_fns: &'static [(&'static str, &'static str)],
}

/// The eight lint-tracked counter structs. **Parsed by `coopgnn-lint`**
/// — string literals only, and keep the `];` terminator on its own
/// line.
pub const LEDGER_STRUCTS: &[LedgerDecl] = &[
    LedgerDecl {
        strukt: "PeWork",
        decl_file: "rust/src/pipeline/stream.rs",
        merge_fns: &[
            ("rust/src/coop/engine.rs", "reduce"),
            ("rust/src/train/parallel.rs", "run"),
            ("rust/src/serve/executor.rs", "pe_us"),
        ],
    },
    LedgerDecl {
        strukt: "EngineReport",
        decl_file: "rust/src/coop/engine.rs",
        merge_fns: &[("rust/src/coop/engine.rs", "finalize")],
    },
    LedgerDecl {
        strukt: "LoadStats",
        decl_file: "rust/src/coop/feature_loader.rs",
        merge_fns: &[("rust/src/coop/feature_loader.rs", "from_loads")],
    },
    LedgerDecl {
        strukt: "PeLoad",
        decl_file: "rust/src/coop/feature_loader.rs",
        merge_fns: &[("rust/src/coop/feature_loader.rs", "from_loads")],
    },
    LedgerDecl {
        strukt: "ParallelStepStats",
        decl_file: "rust/src/train/parallel.rs",
        merge_fns: &[("rust/src/train/parallel.rs", "run")],
    },
    LedgerDecl {
        strukt: "ParallelRunReport",
        decl_file: "rust/src/train/parallel.rs",
        merge_fns: &[("rust/src/train/parallel.rs", "run")],
    },
    LedgerDecl {
        strukt: "BatchExecution",
        decl_file: "rust/src/serve/executor.rs",
        merge_fns: &[("rust/src/serve/mod.rs", "try_dispatch")],
    },
    LedgerDecl {
        strukt: "BatchRecord",
        decl_file: "rust/src/serve/report.rs",
        merge_fns: &[
            ("rust/src/serve/report.rs", "record_batch"),
            ("rust/src/serve/report.rs", "summarize"),
        ],
    },
];

/// A counter struct that can export its numeric fields into the
/// registry as gauges (`coopgnn_<prefix>_<field>`).
pub trait LedgerSource {
    /// Struct name as it appears in [`LEDGER_STRUCTS`] (or a report
    /// type exported for `--metrics-out` only).
    fn ledger_name(&self) -> &'static str;
    /// Prometheus metric prefix (lower_snake struct name).
    fn metric_prefix(&self) -> &'static str;
    /// `(field, value)` pairs, declaration order.
    fn fields(&self) -> Vec<(&'static str, f64)>;
}

/// The tree's one counter API: named u64 counters, f64 gauges, and
/// wall-time accumulators (ms; captured only through the
/// [`crate::obs::wall`] shim). Ordered maps keep every export
/// deterministic.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub times_ms: BTreeMap<String, f64>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    #[inline]
    pub fn get(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn add_time_ms(&mut self, name: &str, ms: f64) {
        *self.times_ms.entry(name.to_string()).or_insert(0.0) += ms;
    }

    /// Time a closure on the wall clock (report-only; goes through the
    /// single obs capture shim).
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let w = WallClock::start();
        let out = f();
        self.add_time_ms(name, w.elapsed_ms());
        out
    }

    /// Merge another registry into this one (counters/times add,
    /// gauges overwrite — a gauge is a last-value observation).
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.times_ms {
            *self.times_ms.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// Absorb a counter struct's numeric fields as gauges.
    pub fn observe(&mut self, src: &dyn LedgerSource) {
        let prefix = src.metric_prefix();
        for (field, v) in src.fields() {
            self.gauges.insert(format!("coopgnn_{prefix}_{field}"), v);
        }
    }

    /// Human-readable dump (the old `Metrics::report` shape).
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, v) in &self.times_ms {
            s.push_str(&format!("{k:<40} {v:.3} ms\n"));
        }
        s
    }

    /// Prometheus text exposition (the `--metrics-out` payload):
    /// counters as `counter`, gauges and accumulated times as `gauge`,
    /// keys in sorted order — byte-identical for identical contents.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, v) in &self.times_ms {
            s.push_str(&format!("# TYPE {k}_ms gauge\n{k}_ms {v}\n"));
        }
        s
    }
}

impl LedgerSource for PeWork {
    fn ledger_name(&self) -> &'static str {
        "PeWork"
    }
    fn metric_prefix(&self) -> &'static str {
        "pe_work"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("requested", self.requested as f64),
            ("misses", self.misses as f64),
            ("fabric", self.fabric as f64),
            ("row_bytes", self.row_bytes as f64),
            ("dim", self.dim as f64),
            ("bytes_from_storage", self.bytes_from_storage as f64),
            ("fabric_bytes", self.fabric_bytes as f64),
            ("fabric_inter_bytes", self.fabric_inter_bytes as f64),
            ("hot_rows", self.hot_rows as f64),
            ("hot_bytes", self.hot_bytes as f64),
            ("prefetch_rows", self.prefetch_rows as f64),
            ("prefetch_bytes", self.prefetch_bytes as f64),
            ("samp_ms", self.samp_ms),
            ("feat_ms", self.feat_ms),
        ]
    }
}

impl LedgerSource for EngineReport {
    fn ledger_name(&self) -> &'static str {
        "EngineReport"
    }
    fn metric_prefix(&self) -> &'static str {
        "engine_report"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("num_pes", self.num_pes as f64),
            ("feat_requested", self.feat_requested),
            ("feat_misses", self.feat_misses),
            ("feat_fabric_rows", self.feat_fabric_rows),
            ("cache_miss_rate", self.cache_miss_rate),
            ("feat_storage_bytes", self.feat_storage_bytes),
            ("feat_fabric_bytes", self.feat_fabric_bytes),
            ("feat_fabric_inter_bytes", self.feat_fabric_inter_bytes),
            ("derived_miss_rate", self.derived_miss_rate),
            ("feat_hot_rows", self.feat_hot_rows),
            ("feat_hot_bytes", self.feat_hot_bytes),
            ("hot_hit_rate", self.hot_hit_rate),
            ("prefetch_rows", self.prefetch_rows),
            ("prefetch_bytes", self.prefetch_bytes),
            ("dup_factor", self.dup_factor),
            ("wall_sampling_ms", self.wall_sampling_ms),
            ("wall_feature_ms", self.wall_feature_ms),
            ("wall_batch_ms", self.wall_batch_ms),
        ]
    }
}

impl LedgerSource for LoadStats {
    fn ledger_name(&self) -> &'static str {
        "LoadStats"
    }
    fn metric_prefix(&self) -> &'static str {
        "load_stats"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("requested", self.requested as f64),
            ("misses", self.misses as f64),
            ("bytes_from_storage", self.bytes_from_storage as f64),
            ("hot_rows", self.hot_rows as f64),
            ("hot_bytes", self.hot_bytes as f64),
        ]
    }
}

impl LedgerSource for PeLoad {
    fn ledger_name(&self) -> &'static str {
        "PeLoad"
    }
    fn metric_prefix(&self) -> &'static str {
        "pe_load"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("requested", self.requested as f64),
            ("misses", self.misses as f64),
            ("bytes_from_storage", self.bytes_from_storage as f64),
            ("hot_rows", self.hot_rows as f64),
            ("hot_bytes", self.hot_bytes as f64),
            ("fabric_rows", self.fabric_rows as f64),
            ("fabric_bytes", self.fabric_bytes as f64),
            ("fabric_inter_bytes", self.fabric_inter_bytes as f64),
        ]
    }
}

impl LedgerSource for ParallelStepStats {
    fn ledger_name(&self) -> &'static str {
        "ParallelStepStats"
    }
    fn metric_prefix(&self) -> &'static str {
        "parallel_step"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("loss", self.loss as f64),
            ("acc", self.acc as f64),
            ("examples", self.examples as f64),
            ("wall_ms", self.wall_ms),
            ("compute_ms", self.compute_ms),
            ("allreduce_ms", self.allreduce_ms),
            ("grad_bytes", self.grad_bytes as f64),
            ("act_bytes", self.act_bytes as f64),
            ("grad_inter_bytes", self.grad_inter_bytes as f64),
            ("act_inter_bytes", self.act_inter_bytes as f64),
        ]
    }
}

impl LedgerSource for ParallelRunReport {
    fn ledger_name(&self) -> &'static str {
        "ParallelRunReport"
    }
    fn metric_prefix(&self) -> &'static str {
        "parallel_run"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("steps", self.steps as f64),
            ("ms_per_step", self.ms_per_step),
            ("sample_ms", self.sample_ms),
            ("feature_ms", self.feature_ms),
            ("examples_per_step", self.examples_per_step),
            ("compute_ms", self.compute_ms),
            ("allreduce_ms", self.allreduce_ms),
            ("storage_bytes_per_step", self.storage_bytes_per_step),
            ("fabric_bytes_per_step", self.fabric_bytes_per_step),
            ("grad_bytes_per_step", self.grad_bytes_per_step),
            ("act_bytes_per_step", self.act_bytes_per_step),
            ("fabric_inter_bytes_per_step", self.fabric_inter_bytes_per_step),
            ("grad_inter_bytes_per_step", self.grad_inter_bytes_per_step),
            ("act_inter_bytes_per_step", self.act_inter_bytes_per_step),
            ("first_loss", self.first_loss as f64),
            ("last_loss", self.last_loss as f64),
            ("last_acc", self.last_acc as f64),
        ]
    }
}

impl LedgerSource for BatchExecution {
    fn ledger_name(&self) -> &'static str {
        "BatchExecution"
    }
    fn metric_prefix(&self) -> &'static str {
        "batch_execution"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("batch", self.batch as f64),
            ("size", self.size as f64),
            ("service_us", self.service_us as f64),
            ("storage_bytes", self.storage_bytes as f64),
            ("fabric_bytes", self.fabric_bytes as f64),
            ("fabric_inter_bytes", self.fabric_inter_bytes as f64),
            ("hot_rows", self.hot_rows as f64),
            ("hot_bytes", self.hot_bytes as f64),
        ]
    }
}

impl LedgerSource for BatchRecord {
    fn ledger_name(&self) -> &'static str {
        "BatchRecord"
    }
    fn metric_prefix(&self) -> &'static str {
        "batch_record"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("index", self.index as f64),
            ("size", self.size as f64),
            ("dispatch_us", self.dispatch_us as f64),
            ("service_us", self.service_us as f64),
            ("storage_bytes", self.storage_bytes as f64),
            ("fabric_bytes", self.fabric_bytes as f64),
            ("fabric_inter_bytes", self.fabric_inter_bytes as f64),
            ("hot_rows", self.hot_rows as f64),
            ("hot_bytes", self.hot_bytes as f64),
        ]
    }
}

// Not a lint-tracked counter struct (it is a derived summary), but the
// natural `--metrics-out` payload for the serve command.
impl LedgerSource for ServeReport {
    fn ledger_name(&self) -> &'static str {
        "ServeReport"
    }
    fn metric_prefix(&self) -> &'static str {
        "serve_report"
    }
    fn fields(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("served", self.served as f64),
            ("batches", self.batches as f64),
            ("dropped", self.dropped as f64),
            ("mean_batch", self.mean_batch),
            ("p50_ms", self.p50_ms),
            ("p90_ms", self.p90_ms),
            ("p99_ms", self.p99_ms),
            ("max_ms", self.max_ms),
            ("requests_per_s", self.requests_per_s),
            ("storage_bytes_per_req", self.storage_bytes_per_req),
            ("fabric_bytes_per_req", self.fabric_bytes_per_req),
            ("fabric_inter_bytes_per_req", self.fabric_inter_bytes_per_req),
            ("hot_rows_per_req", self.hot_rows_per_req),
            ("hot_bytes_per_req", self.hot_bytes_per_req),
            ("slo_violations", self.slo_violations as f64),
            ("slo_violation_rate", self.slo_violation_rate),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Registry::new();
        m.add("x", 2);
        m.add("x", 3);
        assert_eq!(m.get("x"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn merge_sums() {
        let mut a = Registry::new();
        a.add("x", 1);
        a.add_time_ms("t", 1.5);
        let mut b = Registry::new();
        b.add("x", 2);
        b.add("y", 7);
        b.add_time_ms("t", 0.5);
        a.merge(&b);
        assert_eq!(a.get("x"), 3);
        assert_eq!(a.get("y"), 7);
        assert!((a.times_ms["t"] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn time_records() {
        let mut m = Registry::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.times_ms["work"] >= 0.0);
    }

    fn batch_record() -> BatchRecord {
        BatchRecord {
            index: 0,
            size: 0,
            dispatch_us: 0,
            service_us: 0,
            storage_bytes: 0,
            fabric_bytes: 0,
            fabric_inter_bytes: 0,
            hot_rows: 0,
            hot_bytes: 0,
        }
    }

    #[test]
    fn registry_covers_all_eight_ledger_structs() {
        // Every LEDGER_STRUCTS entry has a LedgerSource impl whose
        // ledger_name matches — the registration contract the lint
        // rule is generated from.
        let exec = BatchExecution {
            batch: 0,
            size: 0,
            service_us: 0,
            storage_bytes: 0,
            fabric_bytes: 0,
            fabric_inter_bytes: 0,
            hot_rows: 0,
            hot_bytes: 0,
            requested_rows: 0,
            sampled_edges: 0,
            wall_ms: 0.0,
        };
        let sources: Vec<Box<dyn LedgerSource>> = vec![
            Box::new(PeWork::default()),
            Box::new(EngineReport::default()),
            Box::new(LoadStats::default()),
            Box::new(PeLoad::default()),
            Box::new(ParallelStepStats::default()),
            Box::new(ParallelRunReport::default()),
            Box::new(exec),
            Box::new(batch_record()),
        ];
        let mut names: Vec<&str> =
            sources.iter().map(|s| s.ledger_name()).collect();
        let mut declared: Vec<&str> =
            LEDGER_STRUCTS.iter().map(|d| d.strukt).collect();
        names.sort_unstable();
        declared.sort_unstable();
        assert_eq!(names, declared);
        assert_eq!(LEDGER_STRUCTS.len(), 8);
    }

    #[test]
    fn observe_exports_prefixed_gauges_and_prometheus_text() {
        let mut reg = Registry::new();
        let rec = BatchRecord { storage_bytes: 4096, ..batch_record() };
        reg.observe(&rec);
        assert_eq!(reg.gauges["coopgnn_batch_record_storage_bytes"], 4096.0);
        let prom = reg.to_prometheus();
        assert!(prom.contains("# TYPE coopgnn_batch_record_storage_bytes gauge"));
        assert!(prom.contains("coopgnn_batch_record_storage_bytes 4096\n"));
    }

    #[test]
    fn ledger_decl_table_is_well_formed() {
        for d in LEDGER_STRUCTS {
            assert!(!d.strukt.is_empty());
            assert!(d.decl_file.starts_with("rust/src/"));
            assert!(!d.merge_fns.is_empty(), "{} has no merge fns", d.strukt);
            for (f, fun) in d.merge_fns {
                assert!(f.starts_with("rust/src/"), "{fun} in bad file {f}");
            }
        }
    }
}
