//! Feature-loading stage: vertex-embedding traffic accounting
//! (paper Table 1 "Feature loading" row, Figures 5a/5b).
//!
//! * **Independent**: PE `p` pulls every vertex of its own `S^L` through
//!   its private LRU cache; misses cost storage (β) bandwidth. The same
//!   vertex cached on two PEs occupies two cache slots — duplication
//!   shrinks the *effective* global cache.
//! * **Cooperative**: PE `p` pulls only its **owned** `S_p^L` through its
//!   cache (misses → β), then the fabric redistributes rows to the PEs
//!   whose sampled edges reference them (`c·|S̃_p^L|` rows → α). Per-PE
//!   caches hold disjoint vertex sets, so the global effective cache is P
//!   times larger — the effect Figure 5b measures.

use super::cache::LruCache;
use crate::graph::VertexId;

/// Traffic produced by loading features for one minibatch.
#[derive(Clone, Copy, Debug, Default)]
pub struct FeatureTraffic {
    /// vertex rows requested (max over PEs).
    pub max_requested: u64,
    /// cache misses = rows actually read from storage (max over PEs).
    pub max_misses: u64,
    /// totals across PEs.
    pub total_requested: u64,
    pub total_misses: u64,
    /// rows crossing the fabric (coop only; max over PEs / total).
    pub max_fabric_rows: u64,
    pub total_fabric_rows: u64,
}

impl FeatureTraffic {
    pub fn miss_rate(&self) -> f64 {
        if self.total_requested == 0 {
            0.0
        } else {
            self.total_misses as f64 / self.total_requested as f64
        }
    }
}

/// Pull one PE's requested rows through that PE's private cache —
/// the per-thread unit of the feature-loading stage. Returns
/// `(requested, misses)`. The cache lives behind the PE's thread
/// boundary in the threaded engine; this function is the only thing that
/// touches it during loading.
pub fn load_pe(vs: &[VertexId], cache: &mut LruCache) -> (u64, u64) {
    let mut misses = 0u64;
    for &v in vs {
        if !cache.access(v) {
            misses += 1;
        }
    }
    (vs.len() as u64, misses)
}

/// Independent loading: `inputs[p]` = S^L of PE p's private MFG.
///
/// Note: the engine itself aggregates feature traffic per PE thread via
/// [`load_pe`] + its batch reduction; `load_independent` /
/// [`load_cooperative`] are the standalone whole-fabric equivalents
/// (public API + reference for the accounting semantics). Both route
/// through [`load_pe`], so the cache behavior cannot diverge.
pub fn load_independent(inputs: &[Vec<VertexId>], caches: &mut [LruCache]) -> FeatureTraffic {
    assert_eq!(inputs.len(), caches.len());
    let mut t = FeatureTraffic::default();
    for (vs, cache) in inputs.iter().zip(caches.iter_mut()) {
        let (requested, misses) = load_pe(vs, cache);
        t.max_requested = t.max_requested.max(requested);
        t.max_misses = t.max_misses.max(misses);
        t.total_requested += requested;
        t.total_misses += misses;
    }
    t
}

/// Cooperative loading: `owned[p]` = S_p^L (disjoint by ownership),
/// `fabric_rows[p]` = how many of PE p's requested rows (`S̃_p^L`) live on
/// other PEs (the `cross` recorded during sampling — those rows move over
/// the fabric after the storage reads complete).
pub fn load_cooperative(
    owned: &[Vec<VertexId>],
    fabric_rows: &[u64],
    caches: &mut [LruCache],
) -> FeatureTraffic {
    assert_eq!(owned.len(), caches.len());
    let mut t = FeatureTraffic::default();
    for ((vs, cache), &fab) in owned.iter().zip(caches.iter_mut()).zip(fabric_rows.iter()) {
        let (requested, misses) = load_pe(vs, cache);
        t.max_requested = t.max_requested.max(requested);
        t.max_misses = t.max_misses.max(misses);
        t.total_requested += requested;
        t.total_misses += misses;
        t.max_fabric_rows = t.max_fabric_rows.max(fab);
        t.total_fabric_rows += fab;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indep_counts_misses_per_pe() {
        let mut caches = vec![LruCache::new(4), LruCache::new(4)];
        let inputs = vec![vec![1, 2, 3], vec![1, 2]];
        let t = load_independent(&inputs, &mut caches);
        assert_eq!(t.total_requested, 5);
        assert_eq!(t.total_misses, 5, "cold caches miss everything");
        assert_eq!(t.max_requested, 3);
        // re-run: all warm now
        let t2 = load_independent(&inputs, &mut caches);
        assert_eq!(t2.total_misses, 0);
        assert_eq!(t2.miss_rate(), 0.0);
    }

    #[test]
    fn indep_duplicates_occupy_both_caches() {
        // same vertex requested by both PEs → cached twice (the waste
        // cooperative loading removes)
        let mut caches = vec![LruCache::new(4), LruCache::new(4)];
        load_independent(&[vec![9], vec![9]], &mut caches);
        assert!(caches[0].contains(9));
        assert!(caches[1].contains(9));
    }

    #[test]
    fn coop_accounts_fabric_rows() {
        let mut caches = vec![LruCache::new(4), LruCache::new(4)];
        let owned = vec![vec![1, 2], vec![3]];
        let t = load_cooperative(&owned, &[5, 2], &mut caches);
        assert_eq!(t.total_fabric_rows, 7);
        assert_eq!(t.max_fabric_rows, 5);
        assert_eq!(t.total_misses, 3);
        // ownership disjointness means no duplicate caching
        assert!(caches[0].contains(1) && !caches[1].contains(1));
    }
}
