//! Feature-loading stage: vertex-embedding movement + traffic accounting
//! (paper Table 1 "Feature loading" row, Figures 5a/5b).
//!
//! Since the feature-plane refactor this stage moves **real bytes**: rows
//! live in a [`FeatureStore`] (one shard per PE), caches carry row
//! payloads, and cooperative redistribution ships f32 rows over the
//! fabric. Every count in the reports is derived from that movement.
//!
//! * **Independent** ([`load_independent`]): PE `p` pulls every vertex of
//!   its own `S^L` through its private LRU row cache; misses copy the row
//!   out of storage (β bandwidth). The same vertex cached on two PEs
//!   occupies two cache slots — duplication shrinks the *effective*
//!   global cache. Output: each PE's dense input-feature buffer in `S^L`
//!   order.
//! * **Cooperative** ([`load_cooperative`] /
//!   [`load_pe_cooperative`]): PE `p` pulls only its **owned** `S_p^L`
//!   through its cache (misses → β), then a feature-row all-to-all ships
//!   each requested row to the PEs whose sampled edges reference it
//!   (`c·|S̃_p^L|` rows → α). Per-PE caches hold disjoint vertex sets, so
//!   the global effective cache is P times larger — the effect Figure 5b
//!   measures. Output: each PE's dense buffer over its sorted `S̃_p^L`.
//!
//! ## Replica groups (mirror serving)
//!
//! On a fabric whose [`Topology`] has `replication > 1`, every PE holds
//! a replica of its group-mates' shards (r× shard memory), so a
//! requester resolves rows owned by a **same-group** PE from its local
//! mirror: the owner ships an *empty* bucket (the all-to-all protocol
//! stays intact) and the requester fills that inbox slot from the store
//! before assembly — bit-identical because decode is a pure function of
//! the stored wire bytes. Rows still shipped into *remote* groups are
//! classified by [`split_send_rows`]: the first copy of each distinct
//! row into a group crosses the slow link (charged to the `inter_*`
//! ledgers via `note_inter_rows`), further copies are intra-group
//! relays. Owner-side cache pulls are untouched, so storage/miss counts
//! are identical across replication factors.
//!
//! Migration note (feature-plane PR): `load_pe` gained
//! `(store, out)` parameters and returns [`LoadStats`];
//! `load_independent` takes the store and returns per-PE [`PeLoad`]s
//! (buffers + bytes) instead of a bare [`FeatureTraffic`];
//! `load_cooperative(owned, fabric_rows, caches)` — which took
//! pre-counted fabric rows and moved nothing — is replaced by
//! `load_cooperative(tildes, final_requests, final_owned, part, caches,
//! store, exchange)` which performs the actual row exchange along the
//! sampler-retained request lists. Use
//! [`FeatureTraffic::from_loads`] to recover the old summary shape.

use super::all_to_all::{split_send_rows, Exchange, PeEndpoint};
use super::cache::LruCache;
use crate::feature::{Codec, FeatureStore, Tier};
use crate::graph::{Partition, VertexId};

/// Storage-side result of pulling one PE's rows through its cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    /// vertex rows requested through the cache.
    pub requested: u64,
    /// cache misses (each one filled a slot from a store tier).
    pub misses: u64,
    /// *wire* bytes copied out of cold storage (β traffic), counted at
    /// the fill site — `(misses - hot_rows) * store.row_bytes()` must
    /// equal this by the fill-once-per-miss contract (property-tested).
    pub bytes_from_storage: u64,
    /// cache misses served by the store's hot tier (decoded rows already
    /// resident in PE memory — γ, not β).
    pub hot_rows: u64,
    /// decoded f32 bytes those hot fills moved (`hot_rows * dim * 4`).
    pub hot_bytes: u64,
}

/// One PE's feature-loading result for one minibatch: accounting plus
/// the dense input-feature buffer its model consumes.
#[derive(Clone, Debug, Default)]
pub struct PeLoad {
    /// rows requested through this PE's cache (owner-side in coop mode).
    pub requested: u64,
    /// cache misses = rows read from a store tier.
    pub misses: u64,
    /// wire bytes copied from cold storage (β bandwidth).
    pub bytes_from_storage: u64,
    /// misses served by the store's hot tier (γ, decoded rows).
    pub hot_rows: u64,
    /// decoded bytes those hot fills moved.
    pub hot_bytes: u64,
    /// feature rows that arrived over the fabric (coop only; α).
    pub fabric_rows: u64,
    /// wire bytes that arrived over the fabric, measured at the inbox
    /// (encoded size when the codec is not f32).
    pub fabric_bytes: u64,
    /// wire bytes this PE's *sends* pushed across a replica-group
    /// boundary (owner-side, first-copy-per-group; see
    /// [`split_send_rows`]). Fabric-wide totals are the contract — a
    /// single PE's sent-inter and received-fabric columns need not
    /// match. Equals `fabric_bytes` summed fabric-wide at r = 1.
    pub fabric_inter_bytes: u64,
    /// dense row-major input features: `S^L` order (independent) or
    /// sorted `S̃^L` order (cooperative).
    pub features: Vec<f32>,
}

/// Traffic summary across PEs (the shape the engine reduction and the
/// cost model consume).
#[derive(Clone, Copy, Debug, Default)]
pub struct FeatureTraffic {
    /// vertex rows requested (max over PEs).
    pub max_requested: u64,
    /// cache misses = rows actually read from storage (max over PEs).
    pub max_misses: u64,
    /// totals across PEs.
    pub total_requested: u64,
    pub total_misses: u64,
    /// rows crossing the fabric (coop only; max over PEs / total).
    pub max_fabric_rows: u64,
    pub total_fabric_rows: u64,
    /// wire bytes copied from cold storage across PEs (β).
    pub total_storage_bytes: u64,
    /// wire bytes received over the fabric across PEs (α).
    pub total_fabric_bytes: u64,
    /// wire bytes that crossed a replica-group boundary across PEs.
    pub total_fabric_inter_bytes: u64,
    /// misses served by hot tiers across PEs (γ).
    pub total_hot_rows: u64,
    pub total_hot_bytes: u64,
}

impl FeatureTraffic {
    pub fn miss_rate(&self) -> f64 {
        if self.total_requested == 0 {
            0.0
        } else {
            self.total_misses as f64 / self.total_requested as f64
        }
    }

    /// Reduce per-PE loads into the cross-PE summary.
    pub fn from_loads(loads: &[PeLoad]) -> FeatureTraffic {
        let mut t = FeatureTraffic::default();
        for l in loads {
            t.max_requested = t.max_requested.max(l.requested);
            t.max_misses = t.max_misses.max(l.misses);
            t.total_requested += l.requested;
            t.total_misses += l.misses;
            t.max_fabric_rows = t.max_fabric_rows.max(l.fabric_rows);
            t.total_fabric_rows += l.fabric_rows;
            t.total_storage_bytes += l.bytes_from_storage;
            t.total_fabric_bytes += l.fabric_bytes;
            t.total_fabric_inter_bytes += l.fabric_inter_bytes;
            t.total_hot_rows += l.hot_rows;
            t.total_hot_bytes += l.hot_bytes;
        }
        t
    }
}

/// Pull one PE's requested rows through that PE's private row cache into
/// a dense buffer — the per-thread unit of the feature-loading stage.
/// Hits copy bytes from the cache arena; misses fill the slot from
/// `store` (β-bandwidth read) first. The cache lives behind the PE's
/// thread boundary in the threaded engine; this function is the only
/// thing that touches it during loading.
pub fn load_pe<S: FeatureStore + ?Sized>(
    vs: &[VertexId],
    cache: &mut LruCache,
    store: &S,
    out: &mut Vec<f32>,
) -> LoadStats {
    let dim = store.dim();
    assert_eq!(cache.dim(), dim, "cache/store row shape mismatch");
    out.clear();
    out.resize(vs.len() * dim, 0.0);
    let codec = store.codec();
    let row_bytes = store.row_bytes() as u64;
    let mut misses = 0u64;
    let mut storage_bytes = 0u64;
    let mut hot_rows = 0u64;
    let mut hot_bytes = 0u64;
    for (i, &v) in vs.iter().enumerate() {
        let row = &mut out[i * dim..(i + 1) * dim];
        // a miss fills from whichever tier holds `v`: hot moves decoded
        // bytes at γ, cold moves wire bytes at β
        let mut tier = Tier::Cold;
        let hit = if codec == Codec::F32 {
            cache.access_row(v, row, |slot| {
                tier = store.tier_of(v);
                store.copy_row(v, slot);
            })
        } else {
            cache.access_row_encoded(v, row, |slot| {
                tier = store.tier_of(v);
                store.copy_encoded_row(v, slot);
            })
        };
        if !hit {
            misses += 1;
            match tier {
                Tier::Hot => {
                    hot_rows += 1;
                    hot_bytes += dim as u64 * 4;
                }
                Tier::Cold => storage_bytes += row_bytes,
            }
        }
    }
    LoadStats {
        requested: vs.len() as u64,
        misses,
        bytes_from_storage: storage_bytes,
        hot_rows,
        hot_bytes,
    }
}

/// Independent loading: `inputs[p]` = S^L of PE p's private MFG. Every
/// PE reads any vertex straight from storage on a miss (no ownership
/// restriction — that is precisely the duplication the paper counts).
///
/// Note: the engine itself loads per PE thread via [`load_pe`] + its
/// batch reduction; `load_independent` / [`load_cooperative`] are the
/// standalone whole-fabric equivalents (public API + reference for the
/// accounting semantics). All paths route through [`load_pe`], so the
/// cache behavior cannot diverge.
pub fn load_independent<S: FeatureStore + ?Sized>(
    inputs: &[Vec<VertexId>],
    caches: &mut [LruCache],
    store: &S,
) -> Vec<PeLoad> {
    assert_eq!(inputs.len(), caches.len());
    inputs
        .iter()
        .zip(caches.iter_mut())
        .map(|(vs, cache)| {
            let mut features = Vec::new();
            let stats = load_pe(vs, cache, store, &mut features);
            PeLoad {
                requested: stats.requested,
                misses: stats.misses,
                bytes_from_storage: stats.bytes_from_storage,
                hot_rows: stats.hot_rows,
                hot_bytes: stats.hot_bytes,
                fabric_rows: 0,
                fabric_bytes: 0,
                fabric_inter_bytes: 0,
                features,
            }
        })
        .collect()
}

/// Gather the *encoded* rows of `ids` straight off the store's shard
/// bytes — the compressed fabric payload. No storage-byte charge here:
/// like the f32 path's buffer copy out of `owned_rows`, this re-reads
/// rows the owner already pulled (and paid for) through its cache.
fn encoded_rows_for<S: FeatureStore + ?Sized>(ids: &[VertexId], store: &S) -> Vec<u8> {
    let mut out = Vec::with_capacity(ids.len() * store.row_bytes());
    let mut scratch = Vec::new();
    for &t in ids {
        store.copy_encoded_row(t, &mut scratch);
        out.extend_from_slice(&scratch);
    }
    out
}

/// Decode a per-src inbox of encoded rows into the flat f32 shape
/// [`assemble_rows`] consumes. Decode is a pure function of the wire
/// bytes, so requester-side rows are bit-identical to the owner's own
/// decodes.
fn decode_inbox(inbox: &[Vec<u8>], codec: Codec, dim: usize, row_bytes: usize) -> Vec<Vec<f32>> {
    inbox
        .iter()
        .map(|bytes| {
            debug_assert_eq!(bytes.len() % row_bytes, 0, "ragged encoded inbox");
            let n = bytes.len() / row_bytes;
            let mut rows = vec![0f32; n * dim];
            for i in 0..n {
                codec.decode_row(
                    &bytes[i * row_bytes..(i + 1) * row_bytes],
                    &mut rows[i * dim..(i + 1) * dim],
                );
            }
            rows
        })
        .collect()
}

/// Gather the rows of `ids` out of an owner's dense `owned_rows` buffer
/// (`final_owned` sorted ascending, rows parallel to it).
fn rows_for(
    ids: &[VertexId],
    final_owned: &[VertexId],
    owned_rows: &[f32],
    dim: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(ids.len() * dim);
    for &t in ids {
        let r = final_owned
            .binary_search(&t)
            .expect("requested row must be resident on its owner (routed during sampling)");
        out.extend_from_slice(&owned_rows[r * dim..(r + 1) * dim]);
    }
    out
}

/// Reassemble a PE's dense input buffer in `tilde` order from per-owner
/// row inboxes (`inbox[owner]` = rows from that owner, in this PE's
/// request order — which is `tilde` order restricted to that owner).
fn assemble_rows(
    tilde: &[VertexId],
    part: &Partition,
    inbox: &[Vec<f32>],
    dim: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(tilde.len() * dim);
    let mut cursors = vec![0usize; inbox.len()];
    for &t in tilde {
        let o = part.part_of(t);
        let c = cursors[o];
        out.extend_from_slice(&inbox[o][c..c + dim]);
        cursors[o] = c + dim;
    }
    debug_assert!(
        cursors.iter().zip(inbox).all(|(&c, b)| c == b.len()),
        "row inbox not fully consumed"
    );
}

/// Cooperative loading, whole-fabric serial reference: `tildes[p]` =
/// sorted `S̃_p^L` (what PE p's deepest layer references),
/// `final_requests[q][owner]` = `S̃_q^L ∩ V_owner` in q's tilde order (the
/// last id round's buckets, retained by
/// [`crate::coop::coop_sampler::CoopSample::final_requests`]), and
/// `final_owned[p]` = sorted `S_p^L` (the deduplicated union of rows
/// requested from owner p — every request list is a subset). Owners pull
/// their rows through their caches (misses → storage), then the row
/// all-to-all on `exchange` ships each requester its rows;
/// `PeLoad::features` is PE p's dense buffer in `tildes[p]` order.
pub fn load_cooperative<S: FeatureStore + ?Sized>(
    tildes: &[Vec<VertexId>],
    final_requests: &[Vec<Vec<VertexId>>],
    final_owned: &[Vec<VertexId>],
    part: &Partition,
    caches: &mut [LruCache],
    store: &S,
    exchange: &mut Exchange,
) -> Vec<PeLoad> {
    let p_count = caches.len();
    assert_eq!(tildes.len(), p_count);
    assert_eq!(final_requests.len(), p_count);
    assert_eq!(final_owned.len(), p_count);
    assert_eq!(part.num_parts, p_count);
    let dim = store.dim();

    // 1. owner-side storage pull (sorted S_p^L through each PE's cache —
    //    the exact access order the membership-era engine used)
    let mut owned_rows: Vec<Vec<f32>> = vec![Vec::new(); p_count];
    let mut loads: Vec<PeLoad> = final_owned
        .iter()
        .zip(caches.iter_mut())
        .zip(owned_rows.iter_mut())
        .map(|((vs, cache), rows)| {
            let stats = load_pe(vs, cache, store, rows);
            PeLoad {
                requested: stats.requested,
                misses: stats.misses,
                bytes_from_storage: stats.bytes_from_storage,
                hot_rows: stats.hot_rows,
                hot_bytes: stats.hot_bytes,
                ..Default::default()
            }
        })
        .collect();

    let codec = store.codec();
    let row_bytes = store.row_bytes();
    let topo = exchange.topo;

    // owner-side replica classification: the first copy of each row into
    // a remote group crosses the slow link (see module docs); charged
    // here because only the owner sees its per-destination lists
    for owner in 0..p_count {
        let per_dst: Vec<&[VertexId]> =
            (0..p_count).map(|q| final_requests[q][owner].as_slice()).collect();
        let inter = split_send_rows(&topo, owner, &per_dst);
        loads[owner].fabric_inter_bytes = inter * row_bytes as u64;
        exchange.note_inter_rows(inter, inter * row_bytes as u64);
    }
    // with replication, same-group requesters are mirror-served: the
    // owner ships an empty bucket and the requester reads its local
    // replica of the owner's shard
    let mirrored = |owner: usize, q: usize| owner != q && topo.same_group(owner, q);

    if codec == Codec::F32 {
        // 2. per-(owner, requester) row buckets, along the retained
        //    request lists (requester tilde order by construction)
        let buckets: Vec<Vec<Vec<f32>>> = (0..p_count)
            .map(|owner| {
                (0..p_count)
                    .map(|q| {
                        if mirrored(owner, q) {
                            Vec::new()
                        } else {
                            rows_for(
                                &final_requests[q][owner],
                                &final_owned[owner],
                                &owned_rows[owner],
                                dim,
                            )
                        }
                    })
                    .collect()
            })
            .collect();

        // 3. the α-bandwidth round + 4. requester-side assembly/accounting
        let mut inboxes = exchange.route_rows(buckets, dim);
        for (q, (load, inbox)) in loads.iter_mut().zip(inboxes.iter_mut()).enumerate() {
            let fabric_bytes: u64 = inbox
                .iter()
                .enumerate()
                .filter(|(src, _)| *src != q)
                .map(|(_, rows)| rows.len() as u64 * 4)
                .sum();
            load.fabric_bytes = fabric_bytes;
            load.fabric_rows = fabric_bytes / (dim as u64 * 4);
            for o in 0..p_count {
                if mirrored(o, q) {
                    debug_assert!(inbox[o].is_empty(), "mirrored owner must ship empty");
                    store.gather(&final_requests[q][o], &mut inbox[o]);
                }
            }
            assemble_rows(&tildes[q], part, inbox, dim, &mut load.features);
        }
    } else {
        // compressed fabric: ship the stored wire bytes, decode at the
        // requester — cross-PE traffic shrinks by the codec ratio
        let buckets: Vec<Vec<Vec<u8>>> = (0..p_count)
            .map(|owner| {
                (0..p_count)
                    .map(|q| {
                        if mirrored(owner, q) {
                            Vec::new()
                        } else {
                            encoded_rows_for(&final_requests[q][owner], store)
                        }
                    })
                    .collect()
            })
            .collect();
        let mut inboxes = exchange.route_encoded_rows(buckets, row_bytes);
        for (q, (load, inbox)) in loads.iter_mut().zip(inboxes.iter_mut()).enumerate() {
            let fabric_bytes: u64 = inbox
                .iter()
                .enumerate()
                .filter(|(src, _)| *src != q)
                .map(|(_, bytes)| bytes.len() as u64)
                .sum();
            load.fabric_bytes = fabric_bytes;
            load.fabric_rows = fabric_bytes / row_bytes as u64;
            for o in 0..p_count {
                if mirrored(o, q) {
                    debug_assert!(inbox[o].is_empty(), "mirrored owner must ship empty");
                    inbox[o] = encoded_rows_for(&final_requests[q][o], store);
                }
            }
            let decoded = decode_inbox(inbox, codec, dim, row_bytes);
            assemble_rows(&tildes[q], part, &decoded, dim, &mut load.features);
        }
    }
    loads
}

/// Cooperative loading for **one PE thread** over a live fabric endpoint
/// — bit-identical to this PE's slice of [`load_cooperative`] (tested in
/// the module tests and the byte-accounting property test).
///
/// `final_requests[q]` is the id bucket PE q sent this PE in the last
/// sampling round (its `S̃_q^L ∩ V_p`, in q's tilde order); every PE of
/// the fabric must call this concurrently.
pub fn load_pe_cooperative<S: FeatureStore + ?Sized>(
    ep: &mut PeEndpoint,
    part: &Partition,
    tilde: &[VertexId],
    final_owned: &[VertexId],
    final_requests: &[Vec<VertexId>],
    cache: &mut LruCache,
    store: &S,
) -> PeLoad {
    let dim = store.dim();
    let codec = store.codec();
    let row_bytes = store.row_bytes();
    let topo = ep.topo;
    let me = ep.pe;
    let mut owned_rows = Vec::new();
    let stats = load_pe(final_owned, cache, store, &mut owned_rows);

    // owner-side replica classification (see [`load_cooperative`])
    let per_dst: Vec<&[VertexId]> = final_requests.iter().map(|v| v.as_slice()).collect();
    let inter_rows = split_send_rows(&topo, me, &per_dst);
    let fabric_inter_bytes = inter_rows * row_bytes as u64;
    ep.note_inter_rows(inter_rows, fabric_inter_bytes);

    // same-group requesters are mirror-served (empty bucket over the
    // fabric, local replica read at the requester)
    let mirrored = |owner: usize, q: usize| owner != q && topo.same_group(owner, q);
    // this PE's own request list to a same-group owner `o` is its tilde
    // restricted to `o`'s vertices — exactly the bucket it sent `o` in
    // the last sampling round
    let my_requests_to = |o: usize| -> Vec<VertexId> {
        tilde.iter().copied().filter(|&t| part.part_of(t) == o).collect()
    };

    let (fabric_bytes, features) = if codec == Codec::F32 {
        let buckets: Vec<Vec<f32>> = final_requests
            .iter()
            .enumerate()
            .map(|(q, ids)| {
                if mirrored(me, q) {
                    Vec::new()
                } else {
                    rows_for(ids, final_owned, &owned_rows, dim)
                }
            })
            .collect();
        let mut inbox = ep.all_to_all_rows(buckets, dim);
        let fabric_bytes: u64 = inbox
            .iter()
            .enumerate()
            .filter(|(src, _)| *src != me)
            .map(|(_, rows)| rows.len() as u64 * 4)
            .sum();
        for o in 0..inbox.len() {
            if mirrored(o, me) {
                debug_assert!(inbox[o].is_empty(), "mirrored owner must ship empty");
                store.gather(&my_requests_to(o), &mut inbox[o]);
            }
        }
        let mut features = Vec::new();
        assemble_rows(tilde, part, &inbox, dim, &mut features);
        (fabric_bytes, features)
    } else {
        let buckets: Vec<Vec<u8>> = final_requests
            .iter()
            .enumerate()
            .map(|(q, ids)| {
                if mirrored(me, q) {
                    Vec::new()
                } else {
                    encoded_rows_for(ids, store)
                }
            })
            .collect();
        let mut inbox = ep.all_to_all_encoded_rows(buckets, row_bytes);
        let fabric_bytes: u64 = inbox
            .iter()
            .enumerate()
            .filter(|(src, _)| *src != me)
            .map(|(_, bytes)| bytes.len() as u64)
            .sum();
        for o in 0..inbox.len() {
            if mirrored(o, me) {
                debug_assert!(inbox[o].is_empty(), "mirrored owner must ship empty");
                inbox[o] = encoded_rows_for(&my_requests_to(o), store);
            }
        }
        let decoded = decode_inbox(&inbox, codec, dim, row_bytes);
        let mut features = Vec::new();
        assemble_rows(tilde, part, &decoded, dim, &mut features);
        (fabric_bytes, features)
    };
    PeLoad {
        requested: stats.requested,
        misses: stats.misses,
        bytes_from_storage: stats.bytes_from_storage,
        hot_rows: stats.hot_rows,
        hot_bytes: stats.hot_bytes,
        fabric_rows: fabric_bytes / row_bytes as u64,
        fabric_bytes,
        fabric_inter_bytes,
        features,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coop::all_to_all::Fabric;
    use crate::coop::coop_sampler::{partition_seeds, sample_cooperative};
    use crate::feature::PartitionedFeatureStore;
    use crate::graph::{datasets, partition};
    use crate::sampling::{SamplerConfig, SamplerKind};

    fn fixture() -> (crate::graph::Dataset, Partition, PartitionedFeatureStore) {
        let ds = datasets::build("tiny", 6).unwrap();
        let part = partition::random(&ds.graph, 3, 4);
        let store = PartitionedFeatureStore::build(&ds, &part);
        (ds, part, store)
    }

    #[test]
    fn indep_counts_misses_and_moves_bytes() {
        let (ds, _part, store) = fixture();
        let d = store.dim();
        let mut caches = vec![LruCache::with_rows(4, d), LruCache::with_rows(4, d)];
        let inputs = vec![vec![1, 2, 3], vec![1, 2]];
        let loads = load_independent(&inputs, &mut caches, &store);
        let t = FeatureTraffic::from_loads(&loads);
        assert_eq!(t.total_requested, 5);
        assert_eq!(t.total_misses, 5, "cold caches miss everything");
        assert_eq!(t.max_requested, 3);
        assert_eq!(t.total_storage_bytes, 5 * store.row_bytes() as u64);
        // buffers carry the true rows, in S^L order
        let mut want = vec![0f32; d];
        ds.write_features(3, &mut want);
        assert_eq!(&loads[0].features[2 * d..3 * d], &want[..]);
        // re-run: all warm now — zero storage bytes, same rows served
        let loads2 = load_independent(&inputs, &mut caches, &store);
        let t2 = FeatureTraffic::from_loads(&loads2);
        assert_eq!(t2.total_misses, 0);
        assert_eq!(t2.total_storage_bytes, 0);
        assert_eq!(t2.miss_rate(), 0.0);
        assert_eq!(loads2[0].features, loads[0].features, "hits serve identical bytes");
    }

    #[test]
    fn indep_duplicates_occupy_both_caches() {
        // same vertex requested by both PEs → cached twice (the waste
        // cooperative loading removes)
        let (_ds, _part, store) = fixture();
        let d = store.dim();
        let mut caches = vec![LruCache::with_rows(4, d), LruCache::with_rows(4, d)];
        load_independent(&[vec![9], vec![9]], &mut caches, &store);
        assert!(caches[0].contains(9));
        assert!(caches[1].contains(9));
        assert_eq!(caches[0].peek_row(9).unwrap(), store.row(9));
    }

    /// (per-PE tilde lists, per-PE final_owned, per-owner-per-requester
    /// request lists).
    /// (per-PE tilde lists, per-PE final_owned, the sampler-retained
    /// `final_requests[q][owner]` lists).
    type CoopFixture = (Vec<Vec<VertexId>>, Vec<Vec<VertexId>>, Vec<Vec<Vec<VertexId>>>);

    /// Run Algorithm 1's sampling to get consistent (tilde, final_owned,
    /// final_requests) fixtures for the cooperative loaders.
    fn coop_fixture(ds: &crate::graph::Dataset, part: &Partition) -> CoopFixture {
        let cfg = SamplerConfig::default();
        let p_count = part.num_parts;
        let mut samplers: Vec<_> =
            (0..p_count).map(|_| cfg.build(SamplerKind::Labor0, &ds.graph, 11)).collect();
        let seeds: Vec<VertexId> = (0..200).collect();
        let per_pe = partition_seeds(&seeds, part);
        let coop = sample_cooperative(&ds.graph, part, &mut samplers, &per_pe, cfg.layers);
        let tildes: Vec<Vec<VertexId>> =
            coop.layers[cfg.layers - 1].iter().map(|pl| pl.tilde.clone()).collect();
        (tildes, coop.final_owned, coop.final_requests)
    }

    #[test]
    fn coop_moves_the_rows_the_requesters_need() {
        let (ds, part, store) = fixture();
        let d = store.dim();
        let (tildes, final_owned, reqs) = coop_fixture(&ds, &part);
        let mut caches: Vec<LruCache> =
            (0..3).map(|_| LruCache::with_rows(500, d)).collect();
        let mut ex = Exchange::new(3);
        let loads =
            load_cooperative(&tildes, &reqs, &final_owned, &part, &mut caches, &store, &mut ex);
        for (q, load) in loads.iter().enumerate() {
            // the assembled buffer must equal a direct store gather over
            // the tilde list — bytes through cache + fabric == hash truth
            let mut want = Vec::new();
            store.gather(&tildes[q], &mut want);
            assert_eq!(load.features, want, "PE {q} buffer");
            // fabric accounting equals the non-owned share of tilde
            let cross =
                tildes[q].iter().filter(|&&t| part.part_of(t) != q).count() as u64;
            assert_eq!(load.fabric_rows, cross, "PE {q} fabric rows");
            assert_eq!(load.fabric_bytes, cross * store.row_bytes() as u64);
            // cold caches: every owned row came from storage once
            assert_eq!(load.misses, final_owned[q].len() as u64);
            assert_eq!(load.bytes_from_storage, load.misses * store.row_bytes() as u64);
            // ownership disjointness: only owned rows are cached
            for &v in &final_owned[q] {
                assert!(caches[q].contains(v));
            }
        }
        assert_eq!(ex.cross_rows, loads.iter().map(|l| l.fabric_rows).sum::<u64>());
    }

    #[test]
    fn hot_tier_fills_split_bytes_without_changing_counts() {
        use crate::feature::TieredStore;
        let (ds, part, _store) = fixture();
        let d = ds.feat_dim;
        let flat = TieredStore::build(&ds, &part, Codec::F32, 0);
        let tiered = TieredStore::build(&ds, &part, Codec::F32, 64 * 1024);
        let inputs = vec![(0u32..300).collect::<Vec<_>>()];
        let mut c1 = vec![LruCache::with_rows(64, d)];
        let mut c2 = vec![LruCache::with_rows(64, d)];
        let a = &load_independent(&inputs, &mut c1, &flat)[0];
        let b = &load_independent(&inputs, &mut c2, &tiered)[0];
        // tiering never changes the hit/miss stream or the payload …
        assert_eq!(a.requested, b.requested);
        assert_eq!(a.misses, b.misses);
        assert_eq!(a.features, b.features, "hot rows must serve identical bytes");
        // … only which ledger the fill bytes land in
        assert!(b.hot_rows > 0, "hot tier must serve some of the top-degree fills");
        assert_eq!(b.hot_bytes, b.hot_rows * (d as u64 * 4));
        assert_eq!(
            b.bytes_from_storage,
            (b.misses - b.hot_rows) * flat.row_bytes() as u64
        );
        assert_eq!(a.bytes_from_storage, a.misses * flat.row_bytes() as u64);
        assert_eq!(a.hot_rows, 0);
    }

    #[test]
    fn coop_encoded_fabric_ships_wire_bytes_and_decodes_at_requester() {
        use crate::feature::TieredStore;
        let (ds, part, f32_store) = fixture();
        let d = ds.feat_dim;
        let (tildes, final_owned, reqs) = coop_fixture(&ds, &part);
        for codec in [Codec::Fp16, Codec::Int8] {
            let store = TieredStore::build(&ds, &part, codec, 0);
            let rb = store.row_bytes() as u64;
            let mut caches: Vec<LruCache> =
                (0..3).map(|_| LruCache::with_encoded(500, d, codec)).collect();
            let mut ex = Exchange::new(3);
            let loads = load_cooperative(
                &tildes,
                &reqs,
                &final_owned,
                &part,
                &mut caches,
                &store,
                &mut ex,
            );
            for (q, load) in loads.iter().enumerate() {
                // counts identical to the f32 run (same access sequence)
                let cross =
                    tildes[q].iter().filter(|&&t| part.part_of(t) != q).count() as u64;
                assert_eq!(load.fabric_rows, cross, "{codec:?} PE {q} fabric rows");
                // … but the fabric moved encoded bytes, not dim*4
                assert_eq!(load.fabric_bytes, cross * rb, "{codec:?} PE {q} fabric bytes");
                assert!(rb < (d * 4) as u64);
                assert_eq!(load.misses, final_owned[q].len() as u64);
                assert_eq!(load.bytes_from_storage, load.misses * rb);
                // requester-side decode == owner-side decode, element-wise
                // within codec error of the f32 truth
                let mut truth = Vec::new();
                f32_store.gather(&tildes[q], &mut truth);
                assert_eq!(load.features.len(), truth.len());
                for (a, b) in load.features.iter().zip(&truth) {
                    assert!((a - b).abs() < 0.01, "{codec:?} PE {q}: {a} vs {b}");
                }
            }
            assert_eq!(ex.cross_rows, loads.iter().map(|l| l.fabric_rows).sum::<u64>());
            assert_eq!(ex.cross_row_bytes, loads.iter().map(|l| l.fabric_bytes).sum::<u64>());
        }
    }

    #[test]
    fn threaded_encoded_coop_load_matches_serial() {
        use crate::coop::all_to_all::Fabric;
        use crate::feature::TieredStore;
        let (ds, part, _f32_store) = fixture();
        let d = ds.feat_dim;
        let (tildes, final_owned, reqs) = coop_fixture(&ds, &part);
        let codec = Codec::Int8;
        let store = TieredStore::build(&ds, &part, codec, 0);

        let mut serial_caches: Vec<LruCache> =
            (0..3).map(|_| LruCache::with_encoded(500, d, codec)).collect();
        let mut ex = Exchange::new(3);
        let serial = load_cooperative(
            &tildes,
            &reqs,
            &final_owned,
            &part,
            &mut serial_caches,
            &store,
            &mut ex,
        );

        let endpoints = Fabric::endpoints(3);
        let threaded: Vec<PeLoad> = std::thread::scope(|scope| {
            let (tildes, final_owned, reqs, part, store) =
                (&tildes, &final_owned, &reqs, &part, &store);
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let mut cache = LruCache::with_encoded(500, d, codec);
                        let per_src: Vec<Vec<VertexId>> =
                            (0..3).map(|q| reqs[q][pe].clone()).collect();
                        load_pe_cooperative(
                            &mut ep,
                            part,
                            &tildes[pe],
                            &final_owned[pe],
                            &per_src,
                            &mut cache,
                            store,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, (s, t)) in serial.iter().zip(&threaded).enumerate() {
            assert_eq!(s.misses, t.misses, "PE {q} misses");
            assert_eq!(s.bytes_from_storage, t.bytes_from_storage, "PE {q} storage bytes");
            assert_eq!(s.fabric_bytes, t.fabric_bytes, "PE {q} fabric bytes");
            let bits = |r: &[f32]| r.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&s.features), bits(&t.features), "PE {q} payload bits");
        }
    }

    /// Mirror serving at r=2 on 4 PEs: buffers stay bit-identical to the
    /// flat run, owner-side storage counts do not move, fabric rows drop
    /// to the remote-group share, and serial == threaded on every ledger.
    #[test]
    fn replicated_coop_load_mirror_serves_same_group_rows() {
        use crate::coop::all_to_all::Topology;
        let ds = datasets::build("tiny", 6).unwrap();
        let part = partition::random(&ds.graph, 4, 4);
        let store = PartitionedFeatureStore::build(&ds, &part);
        let d = store.dim();
        let (tildes, final_owned, reqs) = coop_fixture(&ds, &part);
        let topo = Topology::new(4, 2);

        // flat reference
        let mut flat_caches: Vec<LruCache> = (0..4).map(|_| LruCache::with_rows(500, d)).collect();
        let mut flat_ex = Exchange::new(4);
        let flat = load_cooperative(
            &tildes, &reqs, &final_owned, &part, &mut flat_caches, &store, &mut flat_ex,
        );

        // replicated serial
        let mut caches: Vec<LruCache> = (0..4).map(|_| LruCache::with_rows(500, d)).collect();
        let mut ex = Exchange::with_topology(topo);
        let serial =
            load_cooperative(&tildes, &reqs, &final_owned, &part, &mut caches, &store, &mut ex);
        let mut flat_fabric = 0u64;
        let mut repl_fabric = 0u64;
        for (q, (f, s)) in flat.iter().zip(&serial).enumerate() {
            assert_eq!(f.features, s.features, "PE {q}: replication must not change payloads");
            assert_eq!(f.misses, s.misses, "PE {q}: owner pulls unchanged");
            assert_eq!(f.bytes_from_storage, s.bytes_from_storage, "PE {q}");
            // same-group rows no longer touch the fabric
            let remote: u64 = tildes[q]
                .iter()
                .filter(|&&t| !topo.same_group(part.part_of(t), q))
                .count() as u64;
            assert_eq!(s.fabric_rows, remote, "PE {q} fabric rows = remote-group share");
            assert!(s.fabric_rows <= f.fabric_rows);
            flat_fabric += f.fabric_rows;
            repl_fabric += s.fabric_rows;
        }
        assert!(repl_fabric < flat_fabric, "mirror serving must cut fabric rows");
        // inter ≤ cross: duplicate copies into one remote group are
        // relayed intra-group after a single boundary crossing
        assert!(ex.inter_rows <= ex.cross_rows);
        assert_eq!(ex.cross_rows, repl_fabric);
        // flat fabric: every cross row is inter (groups are singletons)
        assert_eq!(flat_ex.inter_rows, flat_ex.cross_rows);

        // threaded == serial on payloads and every ledger
        let endpoints = Fabric::endpoints_with(topo);
        let threaded: Vec<(PeLoad, u64, u64)> = std::thread::scope(|scope| {
            let (tildes, final_owned, reqs, part, store) =
                (&tildes, &final_owned, &reqs, &part, &store);
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let mut cache = LruCache::with_rows(500, d);
                        let per_src: Vec<Vec<VertexId>> =
                            (0..4).map(|q| reqs[q][pe].clone()).collect();
                        let load = load_pe_cooperative(
                            &mut ep,
                            part,
                            &tildes[pe],
                            &final_owned[pe],
                            &per_src,
                            &mut cache,
                            store,
                        );
                        (load, ep.inter_rows, ep.cross_rows)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, (s, (t, _, _))) in serial.iter().zip(&threaded).enumerate() {
            assert_eq!(s.features, t.features, "PE {q} payloads");
            assert_eq!(s.fabric_rows, t.fabric_rows, "PE {q} fabric rows");
            assert_eq!(s.fabric_bytes, t.fabric_bytes, "PE {q} fabric bytes");
            assert_eq!(s.fabric_inter_bytes, t.fabric_inter_bytes, "PE {q} inter bytes");
        }
        assert_eq!(threaded.iter().map(|t| t.1).sum::<u64>(), ex.inter_rows);
        assert_eq!(threaded.iter().map(|t| t.2).sum::<u64>(), ex.cross_rows);
    }

    #[test]
    fn threaded_coop_load_matches_serial_reference() {
        let (ds, part, store) = fixture();
        let d = store.dim();
        let (tildes, final_owned, reqs) = coop_fixture(&ds, &part);

        let mut serial_caches: Vec<LruCache> =
            (0..3).map(|_| LruCache::with_rows(500, d)).collect();
        let mut ex = Exchange::new(3);
        let serial = load_cooperative(
            &tildes,
            &reqs,
            &final_owned,
            &part,
            &mut serial_caches,
            &store,
            &mut ex,
        );

        let endpoints = Fabric::endpoints(3);
        let threaded: Vec<PeLoad> = std::thread::scope(|scope| {
            let (tildes, final_owned, reqs, part, store) =
                (&tildes, &final_owned, &reqs, &part, &store);
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let mut cache = LruCache::with_rows(500, d);
                        // owner pe's per-requester lists = column pe of
                        // the requester-major reqs[q][owner]
                        let per_src: Vec<Vec<VertexId>> =
                            (0..3).map(|q| reqs[q][pe].clone()).collect();
                        load_pe_cooperative(
                            &mut ep,
                            part,
                            &tildes[pe],
                            &final_owned[pe],
                            &per_src,
                            &mut cache,
                            store,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (q, (s, t)) in serial.iter().zip(&threaded).enumerate() {
            assert_eq!(s.requested, t.requested, "PE {q} requested");
            assert_eq!(s.misses, t.misses, "PE {q} misses");
            assert_eq!(s.bytes_from_storage, t.bytes_from_storage, "PE {q} storage bytes");
            assert_eq!(s.fabric_rows, t.fabric_rows, "PE {q} fabric rows");
            assert_eq!(s.fabric_bytes, t.fabric_bytes, "PE {q} fabric bytes");
            assert_eq!(s.features, t.features, "PE {q} payload bytes");
        }
    }
}
