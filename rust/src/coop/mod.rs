//! The multi-PE minibatching engine — the paper's Layer-3 system
//! contribution.
//!
//! * [`indep`] — **Independent Minibatching** (paper §2.3): every PE
//!   samples and processes its own `b`-sized batch; no communication, but
//!   vertices/edges shared across PEs are fetched and computed P times.
//! * [`coop_sampler`] — **Cooperative Minibatching** (paper §3.1,
//!   Algorithm 1): the graph is 1-D partitioned; a single global batch of
//!   size `bP` is sampled layer-by-layer with all-to-all vertex-id
//!   redistribution, eliminating duplicate work entirely.
//! * [`all_to_all`] — the exchange fabric (the NVLink): the serial
//!   [`Exchange`] reference plus the live channel-based [`Fabric`] /
//!   [`PeEndpoint`] used by PE threads. It carries three payload classes
//!   — vertex ids for the sampling rounds, **f32 feature rows** for
//!   cooperative loading, and gradient buffers for the training plane's
//!   all-reduce ([`all_to_all::AllReduceStrategy`]) — and accounts every
//!   byte moved, which the cost model converts into α-bandwidth time. A
//!   [`Topology`] partitions the PEs into replica groups (fast
//!   intra-group links, slow inter-group links): every ledger splits
//!   into cross-PE totals and `inter_*` group-boundary columns, and
//!   with `--replication r` the gradient all-reduce runs hierarchically
//!   (leader chain, bit-identical to the flat sum) while
//!   [`all_to_all::split_send_rows`] classifies which row copies really
//!   cross the slow links.
//! * [`cache`] + [`feature_loader`] — per-PE LRU **row** caches (hits
//!   return bytes from the arena; misses fill from the PE's
//!   [`crate::feature::FeatureStore`] shard, owned behind each PE's
//!   thread boundary in threaded mode) and the loaders that produce each
//!   PE's dense input-feature buffer while accounting storage/fabric
//!   traffic (β vs α in the paper's Table 1) from the actual movement.
//! * [`engine`] — the aggregation layer: [`engine::run`] drains a
//!   [`crate::pipeline::EngineStream`] (which owns the per-PE samplers,
//!   RNG streams, caches, and fabric — thread-per-PE by default,
//!   [`engine::ExecMode::Serial`] as the bit-identical fallback) and
//!   reduces the per-PE work records into the count/traffic reports the
//!   repro harnesses feed into the cost model (Tables 4–7, Fig. 5).
//!   Construct runs through [`crate::pipeline::PipelineBuilder`].
//!
//! ### Determinism note
//! All samplers draw per-vertex/per-edge variates from counter-based
//! hashes keyed by a batch seed shared across PEs, so the union of the
//! cooperatively-sampled per-PE subgraphs is *bit-identical* to sampling
//! the global batch on one PE (tested in `coop_sampler::tests` and
//! `rust/tests/integration_coop.rs`). LABOR-*'s importance weights are
//! computed over PE-local seed sets, a documented approximation.

pub mod all_to_all;
pub mod cache;
pub mod coop_sampler;
pub mod indep;
pub mod feature_loader;
pub mod engine;

pub use all_to_all::{AllReduceStrategy, Exchange, Fabric, PeEndpoint, Topology};
pub use cache::LruCache;
pub use coop_sampler::{sample_cooperative, sample_cooperative_pe, CoopSample, PeCoopSample};
pub use indep::{sample_independent, IndepSample};
pub use engine::{EngineConfig, EngineReport, ExecMode, Mode};
