//! The multi-PE minibatching engine — the paper's Layer-3 system
//! contribution.
//!
//! * [`indep`] — **Independent Minibatching** (paper §2.3): every PE
//!   samples and processes its own `b`-sized batch; no communication, but
//!   vertices/edges shared across PEs are fetched and computed P times.
//! * [`coop_sampler`] — **Cooperative Minibatching** (paper §3.1,
//!   Algorithm 1): the graph is 1-D partitioned; a single global batch of
//!   size `bP` is sampled layer-by-layer with all-to-all vertex-id
//!   redistribution, eliminating duplicate work entirely.
//! * [`all_to_all`] — the exchange fabric (the simulated NVLink): routes
//!   per-PE buckets and accounts every byte moved, which the cost model
//!   converts into α-bandwidth time.
//! * [`cache`] + [`feature_loader`] — per-PE LRU vertex-embedding caches
//!   and the storage/exchange traffic accounting for the feature-loading
//!   stage (β vs α in the paper's Table 1).
//! * [`engine`] — multi-batch drivers producing the count/traffic reports
//!   the repro harnesses feed into the cost model (Tables 4–7, Fig. 5).
//!
//! ### Determinism note
//! All samplers draw per-vertex/per-edge variates from counter-based
//! hashes keyed by a batch seed shared across PEs, so the union of the
//! cooperatively-sampled per-PE subgraphs is *bit-identical* to sampling
//! the global batch on one PE (tested in `coop_sampler::tests` and
//! `rust/tests/integration_coop.rs`). LABOR-*'s importance weights are
//! computed over PE-local seed sets, a documented approximation.

pub mod all_to_all;
pub mod cache;
pub mod coop_sampler;
pub mod indep;
pub mod feature_loader;
pub mod engine;

pub use all_to_all::Exchange;
pub use cache::LruCache;
pub use coop_sampler::{sample_cooperative, CoopSample};
pub use indep::{sample_independent, IndepSample};
pub use engine::{EngineConfig, Mode};
