//! All-to-all exchange fabric — the NVLink of Algorithm 1.
//!
//! Two implementations share the same accounting model:
//!
//! * [`Exchange`] — the single-threaded reference: routes per-(src PE,
//!   dst PE) buckets in one call. Used by the serial engine mode, the
//!   coop-sampler reference implementation, and as the oracle the
//!   threaded fabric is tested against.
//! * [`Fabric`] / [`PeEndpoint`] — the **real** exchange: one endpoint
//!   per PE thread, mpsc channels between all PE pairs, and a barrier per
//!   all-to-all round. Each PE sends its buckets and blocks until it has
//!   received exactly one bucket from every peer, so the exchange runs
//!   with true concurrency while staying deterministic (inboxes are
//!   reassembled in src-major order, matching [`Exchange::route`]).
//!
//! The fabric moves two payload classes, in globally-ordered
//! barrier-delimited rounds:
//!
//! * **vertex ids** (4 bytes each) — the sampling-phase redistribution
//!   of Algorithm 1 ([`PeEndpoint::all_to_all`] / [`Exchange::route`]);
//! * **feature rows** (flat f32, `dim` floats per row) — cooperative
//!   feature loading's α-bandwidth payload
//!   ([`PeEndpoint::all_to_all_rows`] / [`Exchange::route_rows`]): after
//!   the owners pull their rows from storage, the fabric carries the
//!   actual bytes to the requesting PEs. Row traffic is accounted
//!   separately (`cross_rows` / `cross_row_bytes`) from id traffic
//!   (`cross_items` / `cross_bytes`) so Table 1's `c·|S̃|` id column and
//!   the feature-loading row column cannot blur.
//!
//! *Cross-PE* payloads are what the fabric moves at α bandwidth; same-PE
//! buckets are local and free. The cost model ([`crate::costmodel`])
//! turns the recorded counts into time; the engine also measures real
//! wall-clock for the CPU-side data movement.

use crate::graph::VertexId;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// Byte/item accounting for one logical fabric.
#[derive(Clone, Debug, Default)]
pub struct Exchange {
    pub num_pes: usize,
    /// items moved between distinct PEs, by payload class
    pub cross_items: u64,
    /// items "moved" within a PE (no fabric cost)
    pub local_items: u64,
    /// cross bytes (items * item_size accumulated by callers)
    pub cross_bytes: u64,
    /// feature rows moved between distinct PEs.
    pub cross_rows: u64,
    /// feature rows kept local (no fabric cost).
    pub local_rows: u64,
    /// f32 bytes of cross-PE feature rows.
    pub cross_row_bytes: u64,
    /// number of all-to-all rounds executed
    pub rounds: u64,
}

impl Exchange {
    pub fn new(num_pes: usize) -> Self {
        Exchange { num_pes, ..Default::default() }
    }

    /// Route `buckets[src][dst]` to per-destination inboxes
    /// `out[dst] = concat over src of buckets[src][dst]`, accounting
    /// traffic with `item_bytes` per item. Returns the inboxes.
    pub fn route<T: Clone>(&mut self, buckets: &[Vec<Vec<T>>], item_bytes: usize) -> Vec<Vec<T>> {
        assert_eq!(buckets.len(), self.num_pes);
        self.rounds += 1;
        let mut inboxes: Vec<Vec<T>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (src, per_dst) in buckets.iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "bucket row {src} width");
            for (dst, items) in per_dst.iter().enumerate() {
                if src == dst {
                    self.local_items += items.len() as u64;
                } else {
                    self.cross_items += items.len() as u64;
                    self.cross_bytes += (items.len() * item_bytes) as u64;
                }
                inboxes[dst].extend_from_slice(items);
            }
        }
        inboxes
    }

    /// Route feature-row buckets `buckets[src][dst]` (flat f32, `dim`
    /// floats per row). Takes the buckets by value — row payloads are
    /// orders of magnitude larger than id lists, so they are moved, not
    /// copied. Returns per-destination inboxes **indexed by src**
    /// (`out[dst][src]`), matching the per-src inbox shape of
    /// [`PeEndpoint::all_to_all_rows`], because the requester reassembles
    /// its dense buffer by interleaving per-owner streams.
    pub fn route_rows(&mut self, buckets: Vec<Vec<Vec<f32>>>, dim: usize) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(buckets.len(), self.num_pes);
        assert!(dim > 0, "row routing needs a feature dimension");
        self.rounds += 1;
        let mut inboxes: Vec<Vec<Vec<f32>>> =
            (0..self.num_pes).map(|_| vec![Vec::new(); self.num_pes]).collect();
        for (src, per_dst) in buckets.into_iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "row bucket row {src} width");
            for (dst, rows) in per_dst.into_iter().enumerate() {
                debug_assert_eq!(rows.len() % dim, 0, "ragged row bucket {src}->{dst}");
                let n = (rows.len() / dim) as u64;
                if src == dst {
                    self.local_rows += n;
                } else {
                    self.cross_rows += n;
                    self.cross_row_bytes += rows.len() as u64 * 4;
                }
                inboxes[dst][src] = rows;
            }
        }
        inboxes
    }

    /// Account a cross-PE payload without routing real data (used for
    /// activation/gradient traffic whose numeric payload lives inside the
    /// monolithic train-step executable; only its *size* matters here).
    pub fn account_virtual(&mut self, cross_items: u64, item_bytes: usize) {
        self.rounds += 1;
        self.cross_items += cross_items;
        self.cross_bytes += cross_items * item_bytes as u64;
    }

    /// Fraction of routed items that crossed PEs (empirical `c`).
    pub fn cross_ratio(&self) -> f64 {
        let total = self.cross_items + self.local_items;
        if total == 0 {
            0.0
        } else {
            self.cross_items as f64 / total as f64
        }
    }
}

/// One message payload on the threaded fabric. Rounds are globally
/// ordered (barrier per round, every PE runs the same protocol), so a
/// class mismatch on receive is a protocol bug and panics.
enum Payload {
    Ids(Vec<VertexId>),
    Rows(Vec<f32>),
}

/// One message on the threaded fabric: (src PE, payload for the receiver).
type Msg = (usize, Payload);

/// Constructor for the per-PE endpoints of a threaded all-to-all fabric.
pub struct Fabric;

impl Fabric {
    /// Build `num_pes` connected endpoints. Move endpoint `p` into PE
    /// thread `p`; every endpoint must participate in every round (the
    /// per-round barrier synchronizes all of them).
    pub fn endpoints(num_pes: usize) -> Vec<PeEndpoint> {
        assert!(num_pes > 0);
        let barrier = Arc::new(Barrier::new(num_pes));
        let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(num_pes);
        let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(num_pes);
        for _ in 0..num_pes {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(pe, rx)| PeEndpoint {
                pe,
                num_pes,
                txs: txs.clone(),
                rx,
                barrier: Arc::clone(&barrier),
                cross_items: 0,
                local_items: 0,
                cross_bytes: 0,
                cross_rows: 0,
                local_rows: 0,
                cross_row_bytes: 0,
                rounds: 0,
            })
            .collect()
    }
}

/// One PE's handle on the threaded fabric. Accounting fields mirror
/// [`Exchange`] but are *per-endpoint*; summing them across the endpoints
/// of one fabric reproduces the serial totals exactly. Id traffic is
/// accounted at the **sender**; row traffic likewise counts the rows this
/// endpoint ships to other PEs (receivers can count arrivals themselves —
/// globally the two views agree since every cross row has one sender and
/// one receiver).
pub struct PeEndpoint {
    pub pe: usize,
    pub num_pes: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    barrier: Arc<Barrier>,
    pub cross_items: u64,
    pub local_items: u64,
    pub cross_bytes: u64,
    pub cross_rows: u64,
    pub local_rows: u64,
    pub cross_row_bytes: u64,
    pub rounds: u64,
}

impl PeEndpoint {
    /// One id all-to-all round: send `buckets[dst]` to every peer (the
    /// self bucket goes straight into the inbox), receive exactly one
    /// bucket from every peer, and barrier so no message of the next
    /// round can overtake this one. Returns the inbox indexed by src PE
    /// (src-major, the same order [`Exchange::route`] concatenates in).
    pub fn all_to_all(
        &mut self,
        buckets: Vec<Vec<VertexId>>,
        item_bytes: usize,
    ) -> Vec<Vec<VertexId>> {
        assert_eq!(buckets.len(), self.num_pes, "PE {} bucket width", self.pe);
        self.rounds += 1;
        let mut inbox: Vec<Vec<VertexId>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (dst, items) in buckets.into_iter().enumerate() {
            if dst == self.pe {
                // local bucket (often the largest under a good partition):
                // place it straight into the inbox, no channel hop
                self.local_items += items.len() as u64;
                inbox[self.pe] = items;
            } else {
                self.cross_items += items.len() as u64;
                self.cross_bytes += (items.len() * item_bytes) as u64;
                self.txs[dst].send((self.pe, Payload::Ids(items))).expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..self.num_pes - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Ids(items) = payload else {
                panic!("fabric protocol error: PE {} got rows in an id round", self.pe);
            };
            inbox[src] = items;
        }
        self.barrier.wait();
        inbox
    }

    /// One feature-row all-to-all round: `buckets[dst]` is the flat f32
    /// payload (`dim` floats per row) this PE ships to `dst` — the rows
    /// `dst` requested from this PE's storage shard during the sampling
    /// rounds. Returns the inbox indexed by src PE: `inbox[src]` holds
    /// the rows owner `src` sent back, in the order this PE requested
    /// them. Same barrier discipline as the id round.
    pub fn all_to_all_rows(&mut self, buckets: Vec<Vec<f32>>, dim: usize) -> Vec<Vec<f32>> {
        assert_eq!(buckets.len(), self.num_pes, "PE {} row bucket width", self.pe);
        assert!(dim > 0, "row exchange needs a feature dimension");
        self.rounds += 1;
        let mut inbox: Vec<Vec<f32>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (dst, rows) in buckets.into_iter().enumerate() {
            debug_assert_eq!(rows.len() % dim, 0, "PE {} ragged row bucket", self.pe);
            if dst == self.pe {
                self.local_rows += (rows.len() / dim) as u64;
                inbox[self.pe] = rows;
            } else {
                self.cross_rows += (rows.len() / dim) as u64;
                self.cross_row_bytes += rows.len() as u64 * 4;
                self.txs[dst].send((self.pe, Payload::Rows(rows))).expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..self.num_pes - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Rows(rows) = payload else {
                panic!("fabric protocol error: PE {} got ids in a row round", self.pe);
            };
            inbox[src] = rows;
        }
        self.barrier.wait();
        inbox
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_everything_exactly_once() {
        let mut ex = Exchange::new(3);
        // buckets[src][dst]
        let buckets = vec![
            vec![vec![1u32], vec![2, 3], vec![]],
            vec![vec![4], vec![5], vec![6]],
            vec![vec![], vec![], vec![7, 8]],
        ];
        let inboxes = ex.route(&buckets, 4);
        let mut all: Vec<u32> = inboxes.concat();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        // conservation: items in == items out
        let sent: usize = buckets.iter().flatten().map(|b| b.len()).sum();
        let recv: usize = inboxes.iter().map(|b| b.len()).sum();
        assert_eq!(sent, recv);
    }

    #[test]
    fn cross_vs_local_accounting() {
        let mut ex = Exchange::new(2);
        let buckets = vec![
            vec![vec![1u32, 2], vec![3]], // 2 local, 1 cross
            vec![vec![4], vec![5]],       // 1 cross, 1 local
        ];
        ex.route(&buckets, 8);
        assert_eq!(ex.local_items, 3);
        assert_eq!(ex.cross_items, 2);
        assert_eq!(ex.cross_bytes, 16);
        assert!((ex.cross_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn inbox_order_is_src_major_deterministic() {
        let mut ex = Exchange::new(2);
        let buckets = vec![vec![vec![], vec![10u32, 11]], vec![vec![], vec![20]]];
        let inboxes = ex.route(&buckets, 4);
        assert_eq!(inboxes[1], vec![10, 11, 20], "src-major concat order");
    }

    #[test]
    fn virtual_accounting() {
        let mut ex = Exchange::new(4);
        ex.account_virtual(100, 256);
        assert_eq!(ex.cross_bytes, 25_600);
        assert_eq!(ex.rounds, 1);
    }

    #[test]
    fn row_routing_accounts_rows_and_bytes_separately_from_ids() {
        let mut ex = Exchange::new(2);
        let d = 3usize;
        // PE0 keeps one row local and ships two to PE1; PE1 ships one back
        let buckets = vec![
            vec![vec![0.0; d], vec![1.0; 2 * d]],
            vec![vec![2.0; d], vec![]],
        ];
        let inboxes = ex.route_rows(buckets, d);
        assert_eq!(ex.local_rows, 1);
        assert_eq!(ex.cross_rows, 3);
        assert_eq!(ex.cross_row_bytes, 3 * d as u64 * 4);
        // id counters untouched by row rounds
        assert_eq!(ex.cross_items, 0);
        assert_eq!(ex.cross_bytes, 0);
        // inbox[dst][src] carries the exact payloads
        assert_eq!(inboxes[1][0], vec![1.0; 2 * d]);
        assert_eq!(inboxes[0][1], vec![2.0; d]);
        assert_eq!(inboxes[0][0], vec![0.0; d]);
    }

    /// The threaded fabric must reproduce the serial reference exactly:
    /// same inboxes (src-major), same cross/local accounting when summed
    /// over endpoints, over multiple rounds.
    #[test]
    fn threaded_fabric_matches_serial_exchange() {
        use crate::util::rng::Pcg64;
        let p = 4usize;
        let rounds = 3usize;
        // deterministic random buckets per (round, src, dst)
        let mut rng = Pcg64::new(0xFAB);
        let all_buckets: Vec<Vec<Vec<Vec<VertexId>>>> = (0..rounds)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        (0..p)
                            .map(|_| {
                                let k = rng.next_below(30) as usize;
                                (0..k).map(|_| rng.next_u64() as VertexId).collect()
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();

        // serial oracle
        let mut ex = Exchange::new(p);
        let mut serial_inboxes: Vec<Vec<Vec<VertexId>>> = Vec::new();
        for round in &all_buckets {
            serial_inboxes.push(ex.route(round, 4));
        }

        // threaded run: PE thread q routes its own rows of every round
        let endpoints = Fabric::endpoints(p);
        let results: Vec<(Vec<Vec<Vec<VertexId>>>, u64, u64, u64)> =
            std::thread::scope(|scope| {
                let all_buckets = &all_buckets;
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .map(|mut ep| {
                        scope.spawn(move || {
                            let pe = ep.pe;
                            let mut inboxes = Vec::new();
                            for round in all_buckets {
                                let per_src = ep.all_to_all(round[pe].clone(), 4);
                                inboxes.push(per_src);
                            }
                            (inboxes, ep.cross_items, ep.local_items, ep.cross_bytes)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

        // inbox equality: serial concatenates src-major; threaded returns
        // per-src slots
        for (r, serial_round) in serial_inboxes.iter().enumerate() {
            for (q, serial_inbox) in serial_round.iter().enumerate() {
                let threaded: Vec<VertexId> = results[q].0[r].concat();
                assert_eq!(&threaded, serial_inbox, "round {r} PE {q}");
            }
        }
        // accounting equality (summed over endpoints)
        let cross: u64 = results.iter().map(|r| r.1).sum();
        let local: u64 = results.iter().map(|r| r.2).sum();
        let bytes: u64 = results.iter().map(|r| r.3).sum();
        assert_eq!(cross, ex.cross_items);
        assert_eq!(local, ex.local_items);
        assert_eq!(bytes, ex.cross_bytes);
    }

    /// Row rounds over the threaded fabric must match the serial
    /// `route_rows` reference: same per-src inboxes (payload bytes
    /// included) and same row/byte accounting summed over endpoints —
    /// interleaved with id rounds to exercise the shared channels.
    #[test]
    fn threaded_row_fabric_matches_serial_reference() {
        use crate::util::rng::Pcg64;
        let p = 3usize;
        let d = 4usize;
        let mut rng = Pcg64::new(0xFEA7);
        let row_buckets: Vec<Vec<Vec<f32>>> = (0..p)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        let k = rng.next_below(6) as usize;
                        (0..k * d).map(|_| rng.next_f64() as f32).collect()
                    })
                    .collect()
            })
            .collect();
        let id_buckets: Vec<Vec<Vec<VertexId>>> = (0..p)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        let k = rng.next_below(8) as usize;
                        (0..k).map(|_| rng.next_u64() as VertexId).collect()
                    })
                    .collect()
            })
            .collect();

        let mut ex = Exchange::new(p);
        let serial_ids = ex.route(&id_buckets, 4);
        let serial_rows = ex.route_rows(row_buckets.clone(), d);

        let endpoints = Fabric::endpoints(p);
        type RowResult = (Vec<Vec<VertexId>>, Vec<Vec<f32>>, u64, u64, u64);
        let results: Vec<RowResult> = std::thread::scope(|scope| {
            let (ids, rows) = (&id_buckets, &row_buckets);
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let id_inbox = ep.all_to_all(ids[pe].clone(), 4);
                        let row_inbox = ep.all_to_all_rows(rows[pe].clone(), d);
                        (id_inbox, row_inbox, ep.cross_rows, ep.local_rows, ep.cross_row_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (q, res) in results.iter().enumerate() {
            assert_eq!(res.0.concat(), serial_ids[q], "PE {q} id inbox");
            assert_eq!(res.1, serial_rows[q], "PE {q} row inbox");
        }
        let cross: u64 = results.iter().map(|r| r.2).sum();
        let local: u64 = results.iter().map(|r| r.3).sum();
        let bytes: u64 = results.iter().map(|r| r.4).sum();
        assert_eq!(cross, ex.cross_rows);
        assert_eq!(local, ex.local_rows);
        assert_eq!(bytes, ex.cross_row_bytes);
    }

    #[test]
    fn single_pe_fabric_is_local_only() {
        let mut ep = Fabric::endpoints(1).pop().unwrap();
        let inbox = ep.all_to_all(vec![vec![1, 2, 3]], 4);
        assert_eq!(inbox, vec![vec![1, 2, 3]]);
        assert_eq!(ep.cross_items, 0);
        assert_eq!(ep.local_items, 3);
        let rows = ep.all_to_all_rows(vec![vec![0.5; 8]], 4);
        assert_eq!(rows, vec![vec![0.5; 8]]);
        assert_eq!(ep.cross_rows, 0);
        assert_eq!(ep.local_rows, 2);
    }
}
