//! All-to-all exchange fabric — the NVLink of Algorithm 1.
//!
//! Two implementations share the same accounting model:
//!
//! * [`Exchange`] — the single-threaded reference: routes per-(src PE,
//!   dst PE) buckets in one call. Used by the serial engine mode, the
//!   coop-sampler reference implementation, and as the oracle the
//!   threaded fabric is tested against.
//! * [`Fabric`] / [`PeEndpoint`] — the **real** exchange: one endpoint
//!   per PE thread, mpsc channels between all PE pairs, and a barrier per
//!   all-to-all round. Each PE sends its buckets and blocks until it has
//!   received exactly one bucket from every peer, so the exchange runs
//!   with true concurrency while staying deterministic (inboxes are
//!   reassembled in src-major order, matching [`Exchange::route`]).
//!
//! The fabric moves three payload classes, in globally-ordered
//! barrier-delimited rounds:
//!
//! * **vertex ids** (4 bytes each) — the sampling-phase redistribution
//!   of Algorithm 1 ([`PeEndpoint::all_to_all`] / [`Exchange::route`]);
//! * **feature rows** (flat f32, `dim` floats per row) — cooperative
//!   feature loading's α-bandwidth payload
//!   ([`PeEndpoint::all_to_all_rows`] / [`Exchange::route_rows`]): after
//!   the owners pull their rows from storage, the fabric carries the
//!   actual bytes to the requesting PEs. Row traffic is accounted
//!   separately (`cross_rows` / `cross_row_bytes`) from id traffic
//!   (`cross_items` / `cross_bytes`) so Table 1's `c·|S̃|` id column and
//!   the feature-loading row column cannot blur.
//! * **gradients** (flat f32) — the training plane's all-reduce
//!   ([`PeEndpoint::all_reduce_f32`] / [`Exchange::all_reduce_f32`]):
//!   after each PE computes its local gradient, the fabric reduces the
//!   replicas into one globally-summed buffer held identically by every
//!   PE, keeping the replicated optimizer states in lockstep. All
//!   [`AllReduceStrategy`]s share one numeric contract (the canonical
//!   ascending-PE summation order, so results are bit-identical across
//!   strategies and exec modes) and differ only in message pattern and
//!   byte profile; traffic is accounted in its own counters
//!   (`cross_grad_reduce_bytes` / `cross_grad_gather_bytes`), separate
//!   from id and row traffic.
//!
//! ## Replica groups and link classes
//!
//! A [`Topology`] partitions the `P` PEs into `P/r` **replica groups**
//! of `r` consecutive PEs (`r = 1` is the flat fabric every PR before
//! the communication-avoiding one ran on). Links *within* a group are
//! fast (NVLink-class); links *between* groups are slow
//! (IB/PCIe-class), so every cross-PE ledger is split into a total and
//! an `inter_*` column counting only the bytes that crossed a group
//! boundary. Under `--replication r` each group holds a replica of
//! every shard its members own (r× shard memory), so feature rows
//! resolve inside the local group ([`crate::coop::feature_loader`]'s
//! mirror serving), duplicate row sends into one remote group are
//! relayed intra-group after a single boundary crossing
//! ([`split_send_rows`]), and the gradient all-reduce runs
//! hierarchically (intra-group reduce to the leader, a leader chain
//! between groups, intra-group fan-out) with `(P/r - 1)·payload`
//! inter-group bytes per phase — while staying **bit-identical** to the
//! flat canonical sum because the chain folds contributions in exact
//! ascending-PE order.
//!
//! *Cross-PE* payloads are what the fabric moves at α bandwidth; same-PE
//! buckets are local and free. The cost model ([`crate::costmodel`])
//! turns the recorded counts into time — per link class via
//! [`crate::costmodel::FabricModel`] — and
//! [`crate::costmodel::pick_collective`] selects the cheapest
//! all-reduce strategy for a payload size; the engine also measures
//! real wall-clock for the CPU-side data movement.

use crate::graph::VertexId;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// Replica-group topology of a fabric: `num_pes` PEs in groups of
/// `replication` **consecutive** PEs (group `g` = PEs
/// `g·r .. g·r+r-1`, leader = the lowest-indexed member). Links within
/// a group are the fast class, links between groups the slow class;
/// `replication == 1` is the flat all-uniform fabric. The struct is
/// pure shape — bandwidth/latency per link class lives in
/// [`crate::costmodel::FabricModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub num_pes: usize,
    /// PEs per replica group (`r`); must divide `num_pes`.
    pub replication: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { num_pes: 0, replication: 1 }
    }
}

impl Topology {
    pub fn new(num_pes: usize, replication: usize) -> Topology {
        assert!(
            replication >= 1 && num_pes % replication == 0,
            "replication {replication} must divide the PE count {num_pes}"
        );
        Topology { num_pes, replication }
    }

    /// The flat (r = 1) topology: every PE is its own group, so every
    /// cross-PE byte is inter-group.
    pub fn flat(num_pes: usize) -> Topology {
        Topology { num_pes, replication: 1 }
    }

    pub fn groups(&self) -> usize {
        self.num_pes / self.replication
    }

    pub fn group_of(&self, pe: usize) -> usize {
        pe / self.replication
    }

    /// The leader (lowest-indexed member) of `group`.
    pub fn leader(&self, group: usize) -> usize {
        group * self.replication
    }

    pub fn same_group(&self, a: usize, b: usize) -> bool {
        self.group_of(a) == self.group_of(b)
    }
}

/// Classify one owner's outgoing row sends under `topo`: returns the
/// number of rows that must cross a **group boundary**. `per_dst[q]`
/// holds the row keys PE `me` ships to PE `q` (any `Ord` key that
/// identifies a row — vertex ids for feature rows, owned-list positions
/// for activation rows). Destinations in `me`'s own group are
/// intra-group; for a remote group, the *first* copy of each distinct
/// key crosses the boundary once and further copies to other members of
/// that group are modeled as intra-group replica relays. With
/// `replication == 1` every group is a singleton, so the count equals
/// the plain cross-row count.
pub fn split_send_rows<T: Ord + Copy>(topo: &Topology, me: usize, per_dst: &[&[T]]) -> u64 {
    let mut inter = 0u64;
    let mut seen: std::collections::BTreeMap<usize, std::collections::BTreeSet<T>> =
        std::collections::BTreeMap::new();
    for (dst, keys) in per_dst.iter().enumerate() {
        if dst == me || topo.same_group(me, dst) {
            continue;
        }
        let group = seen.entry(topo.group_of(dst)).or_default();
        for &k in keys.iter() {
            if group.insert(k) {
                inter += 1;
            }
        }
    }
    inter
}

/// Byte/item accounting for one logical fabric. The `inter_*` columns
/// count the subset of each cross-PE ledger that crossed a **replica
/// group** boundary under the fabric's [`Topology`] (with `r = 1` they
/// track the cross columns exactly). Id and gradient inter traffic is
/// charged inside the routing/reduce calls; row inter traffic is
/// charged by the classifying call site via
/// [`Exchange::note_inter_rows`] (the owner knows the per-destination
/// request lists — see [`split_send_rows`]), so fabric-wide totals are
/// the contract, not per-call symmetry.
#[derive(Clone, Debug, Default)]
pub struct Exchange {
    pub num_pes: usize,
    /// replica-group shape used to classify intra- vs inter-group bytes.
    pub topo: Topology,
    /// items moved between distinct PEs, by payload class
    pub cross_items: u64,
    /// items "moved" within a PE (no fabric cost)
    pub local_items: u64,
    /// cross bytes (items * item_size accumulated by callers)
    pub cross_bytes: u64,
    /// feature rows moved between distinct PEs.
    pub cross_rows: u64,
    /// feature rows kept local (no fabric cost).
    pub local_rows: u64,
    /// f32 bytes of cross-PE feature rows.
    pub cross_row_bytes: u64,
    /// f32 bytes of cross-PE gradient traffic in all-reduce *reduce*
    /// phases (unreduced contributions on their way to being summed).
    pub cross_grad_reduce_bytes: u64,
    /// f32 bytes of cross-PE gradient traffic in all-reduce *gather*
    /// phases (reduced chunks broadcast back; 0 for [`AllReduceStrategy::Naive`]).
    pub cross_grad_gather_bytes: u64,
    /// id items that crossed a replica-group boundary.
    pub inter_items: u64,
    /// bytes of inter-group id traffic.
    pub inter_bytes: u64,
    /// feature/activation rows that crossed a group boundary (charged by
    /// the classifying call site, not inside the row routes).
    pub inter_rows: u64,
    /// wire bytes of those inter-group rows.
    pub inter_row_bytes: u64,
    /// inter-group share of `cross_grad_reduce_bytes`.
    pub inter_grad_reduce_bytes: u64,
    /// inter-group share of `cross_grad_gather_bytes`.
    pub inter_grad_gather_bytes: u64,
    /// number of all-to-all rounds executed
    pub rounds: u64,
}

/// Message/byte profile of a gradient all-reduce. Every strategy
/// produces the **bit-identical** canonical result (contributions summed
/// in ascending PE order, starting from PE 0's buffer), so the choice is
/// purely a bandwidth/latency trade — [`crate::costmodel::pick_collective`]
/// makes it from the alpha-beta link model — and `Serial` vs `Threaded`
/// trajectories stay exact either way. On a fabric whose
/// [`Topology::replication`] exceeds 1 the strategy is overridden by the
/// hierarchical leader-chain schedule (see
/// [`PeEndpoint::all_reduce_f32`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllReduceStrategy {
    /// Each PE sends its full buffer to every peer and sums all `P`
    /// contributions locally. One round, `(P-1) · payload` bytes sent
    /// *per endpoint* (`P·(P-1)·payload` fabric-wide) — latency-optimal
    /// for small payloads.
    Naive,
    /// Gather-to-root + broadcast: every PE ships its full buffer to PE
    /// 0, which folds canonically and broadcasts the result.
    /// `(P-1) · payload` fabric-wide per phase with logarithmic modeled
    /// latency (the cost model prices it as a binomial tree) — the
    /// mid-size sweet spot between `Naive` and the chunked schedules.
    Tree,
    /// Reduce-scatter + all-gather with the byte profile of a ring
    /// all-reduce: the buffer is split into `P` owner chunks, each PE
    /// ships its contribution of chunk `o` to owner `o` (reduce phase,
    /// `(P-1) · payload` bytes fabric-wide), owners sum canonically, then
    /// broadcast their reduced chunk (gather phase, another
    /// `(P-1) · payload` fabric-wide). The message schedule is
    /// owner-direct rather than neighbor-hopping so the summation order
    /// stays canonical — determinism over topology fidelity.
    Ring,
    /// Recursive reduce-scatter + all-gather: same bandwidth-optimal
    /// byte profile as [`AllReduceStrategy::Ring`] (and the identical
    /// owner-direct message schedule in this fabric), but modeled with
    /// logarithmic latency by the cost model — the large-payload pick.
    Rsag,
}

impl AllReduceStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            AllReduceStrategy::Naive => "naive",
            AllReduceStrategy::Tree => "tree",
            AllReduceStrategy::Ring => "ring",
            AllReduceStrategy::Rsag => "rsag",
        }
    }

    pub fn parse(s: &str) -> Option<AllReduceStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(AllReduceStrategy::Naive),
            "tree" => Some(AllReduceStrategy::Tree),
            "ring" => Some(AllReduceStrategy::Ring),
            "rsag" => Some(AllReduceStrategy::Rsag),
            _ => None,
        }
    }
}

/// The owner chunk of element range a ring all-reduce assigns PE `o`
/// over a `len`-element buffer: contiguous, sizes differing by at most
/// one (`len % p` leading owners get the extra element).
fn ring_chunk(len: usize, p: usize, o: usize) -> std::ops::Range<usize> {
    let base = len / p;
    let rem = len % p;
    let start = o * base + o.min(rem);
    start..start + base + usize::from(o < rem)
}

/// The canonical all-reduce sum: contributions added in ascending PE
/// order, seeded from PE 0's buffer (no zero seed, so `-0.0` and other
/// f32 edge values survive bit-exactly). Both fabric strategies and the
/// serial reference reduce through this one function.
fn canonical_sum(contribs: &[&[f32]]) -> Vec<f32> {
    let mut acc = contribs[0].to_vec();
    for c in &contribs[1..] {
        debug_assert_eq!(c.len(), acc.len(), "ragged all-reduce contribution");
        for (a, &x) in acc.iter_mut().zip(c.iter()) {
            *a += x;
        }
    }
    acc
}

impl Exchange {
    pub fn new(num_pes: usize) -> Self {
        Exchange::with_topology(Topology::flat(num_pes))
    }

    /// An exchange whose ledgers classify intra- vs inter-group traffic
    /// under `topo` ([`Exchange::new`] is the flat r = 1 case).
    pub fn with_topology(topo: Topology) -> Self {
        Exchange { num_pes: topo.num_pes, topo, ..Default::default() }
    }

    /// Charge rows the classifying call site determined to cross a
    /// replica-group boundary (see [`split_send_rows`]; the row routes
    /// themselves only track the cross-PE totals).
    pub fn note_inter_rows(&mut self, rows: u64, bytes: u64) {
        self.inter_rows += rows;
        self.inter_row_bytes += bytes;
    }

    /// Route `buckets[src][dst]` to per-destination inboxes
    /// `out[dst] = concat over src of buckets[src][dst]`, accounting
    /// traffic with `item_bytes` per item. Returns the inboxes.
    pub fn route<T: Clone>(&mut self, buckets: &[Vec<Vec<T>>], item_bytes: usize) -> Vec<Vec<T>> {
        assert_eq!(buckets.len(), self.num_pes);
        self.rounds += 1;
        let mut inboxes: Vec<Vec<T>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (src, per_dst) in buckets.iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "bucket row {src} width");
            for (dst, items) in per_dst.iter().enumerate() {
                if src == dst {
                    self.local_items += items.len() as u64;
                } else {
                    self.cross_items += items.len() as u64;
                    self.cross_bytes += (items.len() * item_bytes) as u64;
                    if !self.topo.same_group(src, dst) {
                        self.inter_items += items.len() as u64;
                        self.inter_bytes += (items.len() * item_bytes) as u64;
                    }
                }
                inboxes[dst].extend_from_slice(items);
            }
        }
        inboxes
    }

    /// Route feature-row buckets `buckets[src][dst]` (flat f32, `dim`
    /// floats per row). Takes the buckets by value — row payloads are
    /// orders of magnitude larger than id lists, so they are moved, not
    /// copied. Returns per-destination inboxes **indexed by src**
    /// (`out[dst][src]`), matching the per-src inbox shape of
    /// [`PeEndpoint::all_to_all_rows`], because the requester reassembles
    /// its dense buffer by interleaving per-owner streams.
    pub fn route_rows(&mut self, buckets: Vec<Vec<Vec<f32>>>, dim: usize) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(buckets.len(), self.num_pes);
        assert!(dim > 0, "row routing needs a feature dimension");
        self.rounds += 1;
        let mut inboxes: Vec<Vec<Vec<f32>>> =
            (0..self.num_pes).map(|_| vec![Vec::new(); self.num_pes]).collect();
        for (src, per_dst) in buckets.into_iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "row bucket row {src} width");
            for (dst, rows) in per_dst.into_iter().enumerate() {
                debug_assert_eq!(rows.len() % dim, 0, "ragged row bucket {src}->{dst}");
                let n = (rows.len() / dim) as u64;
                if src == dst {
                    self.local_rows += n;
                } else {
                    self.cross_rows += n;
                    self.cross_row_bytes += rows.len() as u64 * 4;
                }
                inboxes[dst][src] = rows;
            }
        }
        inboxes
    }

    /// Route *encoded* feature-row buckets `buckets[src][dst]` (raw
    /// codec bytes, `row_bytes` per row) — the compressed twin of
    /// [`Exchange::route_rows`] used when the store's codec is not f32,
    /// so α-bandwidth traffic shrinks by the codec ratio. Accounting
    /// lands in the same `cross_rows` / `cross_row_bytes` counters, now
    /// measuring wire bytes. Inboxes are indexed by src
    /// (`out[dst][src]`), matching the decoded variant.
    pub fn route_encoded_rows(
        &mut self,
        buckets: Vec<Vec<Vec<u8>>>,
        row_bytes: usize,
    ) -> Vec<Vec<Vec<u8>>> {
        assert_eq!(buckets.len(), self.num_pes);
        assert!(row_bytes > 0, "encoded row routing needs a row size");
        self.rounds += 1;
        let mut inboxes: Vec<Vec<Vec<u8>>> =
            (0..self.num_pes).map(|_| vec![Vec::new(); self.num_pes]).collect();
        for (src, per_dst) in buckets.into_iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "encoded bucket row {src} width");
            for (dst, bytes) in per_dst.into_iter().enumerate() {
                debug_assert_eq!(bytes.len() % row_bytes, 0, "ragged encoded bucket {src}->{dst}");
                let n = (bytes.len() / row_bytes) as u64;
                if src == dst {
                    self.local_rows += n;
                } else {
                    self.cross_rows += n;
                    self.cross_row_bytes += bytes.len() as u64;
                }
                inboxes[dst][src] = bytes;
            }
        }
        inboxes
    }

    /// Account a cross-PE payload without routing real data (used for
    /// activation/gradient traffic whose numeric payload lives inside the
    /// monolithic train-step executable; only its *size* matters here).
    pub fn account_virtual(&mut self, cross_items: u64, item_bytes: usize) {
        self.rounds += 1;
        self.cross_items += cross_items;
        self.cross_bytes += cross_items * item_bytes as u64;
    }

    /// Serial reference of the gradient all-reduce: sum every PE's
    /// buffer in canonical (ascending-PE) order and write the result
    /// back into all of them, accounting the bytes the given threaded
    /// strategy would have moved — so a serial training step reports the
    /// identical gradient traffic as its threaded twin, and the threaded
    /// [`PeEndpoint::all_reduce_f32`] is tested against this oracle.
    /// With [`Topology::replication`] > 1 the hierarchical leader-chain
    /// schedule's profile is charged instead of `strategy`'s (the chain
    /// folds in the same ascending-PE order, so the value is unchanged).
    pub fn all_reduce_f32(&mut self, bufs: &mut [Vec<f32>], strategy: AllReduceStrategy) {
        assert_eq!(bufs.len(), self.num_pes, "one buffer per PE");
        let len = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == len), "ragged all-reduce buffers");
        self.rounds += 1;
        let acc = canonical_sum(&bufs.iter().map(|b| b.as_slice()).collect::<Vec<_>>());
        for b in bufs.iter_mut() {
            b.copy_from_slice(&acc);
        }
        let p = self.num_pes as u64;
        let r = self.topo.replication as u64;
        let payload = (len * 4) as u64;
        if self.topo.replication > 1 {
            // hierarchical chain: members→leader intra, (G-1) leader
            // hops inter, then the same profile mirrored on the way back
            let g = self.topo.groups() as u64;
            self.cross_grad_reduce_bytes += (p - 1) * payload;
            self.cross_grad_gather_bytes += (p - 1) * payload;
            self.inter_grad_reduce_bytes += (g - 1) * payload;
            self.inter_grad_gather_bytes += (g - 1) * payload;
            return;
        }
        match strategy {
            // every endpoint ships its full buffer to P-1 peers
            AllReduceStrategy::Naive => {
                self.cross_grad_reduce_bytes += p * (p - 1) * payload;
                self.inter_grad_reduce_bytes += p * (p - r) * payload;
            }
            // gather-to-root + broadcast: full payload crosses once per
            // non-root PE in each phase
            AllReduceStrategy::Tree => {
                self.cross_grad_reduce_bytes += (p - 1) * payload;
                self.cross_grad_gather_bytes += (p - 1) * payload;
                self.inter_grad_reduce_bytes += (p - r) * payload;
                self.inter_grad_gather_bytes += (p - r) * payload;
            }
            // chunked: each element crosses once toward its owner and
            // once per non-owner on the way back
            AllReduceStrategy::Ring | AllReduceStrategy::Rsag => {
                self.cross_grad_reduce_bytes += (p - 1) * payload;
                self.cross_grad_gather_bytes += (p - 1) * payload;
                self.inter_grad_reduce_bytes += (p - r) * payload;
                self.inter_grad_gather_bytes += (p - r) * payload;
            }
        }
    }

    /// Fraction of routed items that crossed PEs (empirical `c`).
    pub fn cross_ratio(&self) -> f64 {
        let total = self.cross_items + self.local_items;
        if total == 0 {
            0.0
        } else {
            self.cross_items as f64 / total as f64
        }
    }
}

/// One message payload on the threaded fabric. Rounds are globally
/// ordered (barrier per round, every PE runs the same protocol), so a
/// class mismatch on receive is a protocol bug and panics.
enum Payload {
    Ids(Vec<VertexId>),
    Rows(Vec<f32>),
    /// codec-encoded feature rows (wire bytes; decoded at the consumer).
    Bytes(Vec<u8>),
    Grads(Vec<f32>),
}

/// One message on the threaded fabric: (src PE, payload for the receiver).
type Msg = (usize, Payload);

/// Constructor for the per-PE endpoints of a threaded all-to-all fabric.
pub struct Fabric;

impl Fabric {
    /// Build `num_pes` connected endpoints on a flat (replication 1)
    /// topology. Move endpoint `p` into PE thread `p`; every endpoint
    /// must participate in every round (the per-round barrier
    /// synchronizes all of them).
    pub fn endpoints(num_pes: usize) -> Vec<PeEndpoint> {
        Fabric::endpoints_with(Topology::flat(num_pes))
    }

    /// Build connected endpoints on an explicit [`Topology`]. With
    /// `topo.replication > 1` the endpoints classify traffic into the
    /// `inter_*` ledgers and [`PeEndpoint::all_reduce_f32`] switches to
    /// the hierarchical leader-chain schedule.
    pub fn endpoints_with(topo: Topology) -> Vec<PeEndpoint> {
        let num_pes = topo.num_pes;
        assert!(num_pes > 0);
        let barrier = Arc::new(Barrier::new(num_pes));
        let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(num_pes);
        let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(num_pes);
        for _ in 0..num_pes {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(pe, rx)| PeEndpoint {
                pe,
                num_pes,
                topo,
                txs: txs.clone(),
                rx,
                barrier: Arc::clone(&barrier),
                cross_items: 0,
                local_items: 0,
                cross_bytes: 0,
                cross_rows: 0,
                local_rows: 0,
                cross_row_bytes: 0,
                cross_grad_reduce_bytes: 0,
                cross_grad_gather_bytes: 0,
                inter_items: 0,
                inter_bytes: 0,
                inter_rows: 0,
                inter_row_bytes: 0,
                inter_grad_reduce_bytes: 0,
                inter_grad_gather_bytes: 0,
                rounds: 0,
            })
            .collect()
    }
}

/// One PE's handle on the threaded fabric. Accounting fields mirror
/// [`Exchange`] but are *per-endpoint*; summing them across the endpoints
/// of one fabric reproduces the serial totals exactly. Id traffic is
/// accounted at the **sender**; row traffic likewise counts the rows this
/// endpoint ships to other PEs (receivers can count arrivals themselves —
/// globally the two views agree since every cross row has one sender and
/// one receiver).
pub struct PeEndpoint {
    pub pe: usize,
    pub num_pes: usize,
    /// Replica-group layout of the fabric this endpoint belongs to.
    /// Id and gradient inter-group traffic is classified here; row
    /// inter traffic is classified by the call site that knows which
    /// copies are first-in-group (see [`Exchange::topo`]) and charged
    /// via [`PeEndpoint::note_inter_rows`].
    pub topo: Topology,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    barrier: Arc<Barrier>,
    pub cross_items: u64,
    pub local_items: u64,
    pub cross_bytes: u64,
    pub cross_rows: u64,
    pub local_rows: u64,
    pub cross_row_bytes: u64,
    /// f32 bytes this endpoint sent in all-reduce reduce phases.
    pub cross_grad_reduce_bytes: u64,
    /// f32 bytes this endpoint sent in all-reduce gather phases.
    pub cross_grad_gather_bytes: u64,
    /// Subset of `cross_items` that crossed a replica-group boundary.
    pub inter_items: u64,
    /// Subset of `cross_bytes` that crossed a replica-group boundary.
    pub inter_bytes: u64,
    /// Inter-group feature/activation rows (call-site classified).
    pub inter_rows: u64,
    /// Inter-group feature/activation row bytes (call-site classified).
    pub inter_row_bytes: u64,
    /// Subset of `cross_grad_reduce_bytes` on inter-group links.
    pub inter_grad_reduce_bytes: u64,
    /// Subset of `cross_grad_gather_bytes` on inter-group links.
    pub inter_grad_gather_bytes: u64,
    pub rounds: u64,
}

impl PeEndpoint {
    /// One id all-to-all round: send `buckets[dst]` to every peer (the
    /// self bucket goes straight into the inbox), receive exactly one
    /// bucket from every peer, and barrier so no message of the next
    /// round can overtake this one. Returns the inbox indexed by src PE
    /// (src-major, the same order [`Exchange::route`] concatenates in).
    pub fn all_to_all(
        &mut self,
        buckets: Vec<Vec<VertexId>>,
        item_bytes: usize,
    ) -> Vec<Vec<VertexId>> {
        assert_eq!(buckets.len(), self.num_pes, "PE {} bucket width", self.pe);
        self.rounds += 1;
        let mut inbox: Vec<Vec<VertexId>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (dst, items) in buckets.into_iter().enumerate() {
            if dst == self.pe {
                // local bucket (often the largest under a good partition):
                // place it straight into the inbox, no channel hop
                self.local_items += items.len() as u64;
                inbox[self.pe] = items;
            } else {
                self.cross_items += items.len() as u64;
                self.cross_bytes += (items.len() * item_bytes) as u64;
                if !self.topo.same_group(self.pe, dst) {
                    self.inter_items += items.len() as u64;
                    self.inter_bytes += (items.len() * item_bytes) as u64;
                }
                self.txs[dst]
                    .send((self.pe, Payload::Ids(items)))
                    .expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..self.num_pes - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Ids(items) = payload else {
                panic!("fabric protocol error: PE {} got rows in an id round", self.pe);
            };
            inbox[src] = items;
        }
        self.barrier.wait();
        inbox
    }

    /// One feature-row all-to-all round: `buckets[dst]` is the flat f32
    /// payload (`dim` floats per row) this PE ships to `dst` — the rows
    /// `dst` requested from this PE's storage shard during the sampling
    /// rounds. Returns the inbox indexed by src PE: `inbox[src]` holds
    /// the rows owner `src` sent back, in the order this PE requested
    /// them. Same barrier discipline as the id round.
    pub fn all_to_all_rows(&mut self, buckets: Vec<Vec<f32>>, dim: usize) -> Vec<Vec<f32>> {
        assert_eq!(buckets.len(), self.num_pes, "PE {} row bucket width", self.pe);
        assert!(dim > 0, "row exchange needs a feature dimension");
        self.rounds += 1;
        let mut inbox: Vec<Vec<f32>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (dst, rows) in buckets.into_iter().enumerate() {
            debug_assert_eq!(rows.len() % dim, 0, "PE {} ragged row bucket", self.pe);
            if dst == self.pe {
                self.local_rows += (rows.len() / dim) as u64;
                inbox[self.pe] = rows;
            } else {
                self.cross_rows += (rows.len() / dim) as u64;
                self.cross_row_bytes += rows.len() as u64 * 4;
                self.txs[dst]
                    .send((self.pe, Payload::Rows(rows)))
                    .expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..self.num_pes - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Rows(rows) = payload else {
                panic!("fabric protocol error: PE {} got ids in a row round", self.pe);
            };
            inbox[src] = rows;
        }
        self.barrier.wait();
        inbox
    }

    /// One *encoded* feature-row all-to-all round — the compressed twin
    /// of [`PeEndpoint::all_to_all_rows`]: `buckets[dst]` is the raw
    /// codec payload (`row_bytes` per row) this PE ships to `dst`, and
    /// the returned inbox is indexed by src. Cross traffic lands in the
    /// same `cross_rows` / `cross_row_bytes` counters, now measuring
    /// wire bytes. Same barrier discipline as every other round.
    pub fn all_to_all_encoded_rows(
        &mut self,
        buckets: Vec<Vec<u8>>,
        row_bytes: usize,
    ) -> Vec<Vec<u8>> {
        assert_eq!(buckets.len(), self.num_pes, "PE {} encoded bucket width", self.pe);
        assert!(row_bytes > 0, "encoded row exchange needs a row size");
        self.rounds += 1;
        let mut inbox: Vec<Vec<u8>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (dst, bytes) in buckets.into_iter().enumerate() {
            debug_assert_eq!(bytes.len() % row_bytes, 0, "PE {} ragged encoded bucket", self.pe);
            if dst == self.pe {
                self.local_rows += (bytes.len() / row_bytes) as u64;
                inbox[self.pe] = bytes;
            } else {
                self.cross_rows += (bytes.len() / row_bytes) as u64;
                self.cross_row_bytes += bytes.len() as u64;
                self.txs[dst]
                    .send((self.pe, Payload::Bytes(bytes)))
                    .expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..self.num_pes - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Bytes(bytes) = payload else {
                panic!(
                    "fabric protocol error: PE {} expected encoded rows this round",
                    self.pe
                );
            };
            inbox[src] = bytes;
        }
        self.barrier.wait();
        inbox
    }

    /// Charge `rows` feature/activation rows (`bytes` on the wire) to
    /// this endpoint's inter-group ledger. Row payloads are opaque to
    /// the fabric — only the call site knows which copies are the
    /// first into a remote replica group (see [`split_send_rows`]) —
    /// so classification happens there and is recorded here.
    pub fn note_inter_rows(&mut self, rows: u64, bytes: u64) {
        self.inter_rows += rows;
        self.inter_row_bytes += bytes;
    }

    /// One gradient all-reduce round: every endpoint calls this with its
    /// local contribution in `buf`; on return every PE's `buf` holds the
    /// **identical** canonical sum (ascending-PE order — bit-equal to
    /// [`Exchange::all_reduce_f32`] and across every strategy). Same
    /// barrier discipline as the id/row rounds, so gradient traffic can
    /// interleave with sampling and feature rounds on one fabric.
    ///
    /// With [`Topology::replication`] > 1 the flat strategy is
    /// overridden by the hierarchical leader-chain schedule, which
    /// moves only `(P/r - 1)·payload` per phase across inter-group
    /// links while folding in the exact same ascending-PE order.
    pub fn all_reduce_f32(&mut self, buf: &mut [f32], strategy: AllReduceStrategy) {
        self.rounds += 1;
        if self.num_pes == 1 {
            return;
        }
        if self.topo.replication > 1 {
            return self.all_reduce_hierarchical(buf);
        }
        match strategy {
            AllReduceStrategy::Naive => self.all_reduce_naive(buf),
            AllReduceStrategy::Tree => self.all_reduce_tree(buf),
            AllReduceStrategy::Ring | AllReduceStrategy::Rsag => self.all_reduce_ring(buf),
        }
    }

    /// Full-buffer broadcast + local canonical sum.
    fn all_reduce_naive(&mut self, buf: &mut [f32]) {
        let p = self.num_pes;
        let payload = (buf.len() * 4) as u64;
        for (dst, tx) in self.txs.iter().enumerate() {
            if dst != self.pe {
                self.cross_grad_reduce_bytes += payload;
                if !self.topo.same_group(self.pe, dst) {
                    self.inter_grad_reduce_bytes += payload;
                }
                tx.send((self.pe, Payload::Grads(buf.to_vec())))
                    .expect("fabric peer hung up (send)");
            }
        }
        let mut contribs: Vec<Option<Vec<f32>>> = (0..p).map(|_| None).collect();
        for _ in 0..p - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(g) = payload else {
                panic!("fabric protocol error: PE {} expected grads in a reduce round", self.pe);
            };
            contribs[src] = Some(g);
        }
        let slices: Vec<&[f32]> = (0..p)
            .map(|src| if src == self.pe { &*buf } else { contribs[src].as_deref().unwrap() })
            .collect();
        let acc = canonical_sum(&slices);
        buf.copy_from_slice(&acc);
        self.barrier.wait();
    }

    /// Owner-direct reduce-scatter + all-gather (the ring byte profile
    /// with canonical summation; see [`AllReduceStrategy::Ring`]). Two
    /// barrier-delimited phases so a fast peer's gather message can never
    /// be mistaken for a straggler's reduce contribution.
    fn all_reduce_ring(&mut self, buf: &mut [f32]) {
        let p = self.num_pes;
        let len = buf.len();
        // reduce phase: ship this PE's contribution of chunk o to owner o
        for (dst, tx) in self.txs.iter().enumerate() {
            if dst != self.pe {
                let r = ring_chunk(len, p, dst);
                self.cross_grad_reduce_bytes += (r.len() * 4) as u64;
                if !self.topo.same_group(self.pe, dst) {
                    self.inter_grad_reduce_bytes += (r.len() * 4) as u64;
                }
                tx.send((self.pe, Payload::Grads(buf[r].to_vec())))
                    .expect("fabric peer hung up (send)");
            }
        }
        let my_range = ring_chunk(len, p, self.pe);
        let mut contribs: Vec<Option<Vec<f32>>> = (0..p).map(|_| None).collect();
        for _ in 0..p - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(g) = payload else {
                panic!("fabric protocol error: PE {} expected grads in a reduce round", self.pe);
            };
            contribs[src] = Some(g);
        }
        let slices: Vec<&[f32]> = (0..p)
            .map(|src| {
                if src == self.pe {
                    &buf[my_range.clone()]
                } else {
                    contribs[src].as_deref().unwrap()
                }
            })
            .collect();
        let acc = canonical_sum(&slices);
        buf[my_range.clone()].copy_from_slice(&acc);
        self.barrier.wait();
        // gather phase: broadcast this PE's reduced chunk
        for (dst, tx) in self.txs.iter().enumerate() {
            if dst != self.pe {
                self.cross_grad_gather_bytes += (acc.len() * 4) as u64;
                if !self.topo.same_group(self.pe, dst) {
                    self.inter_grad_gather_bytes += (acc.len() * 4) as u64;
                }
                tx.send((self.pe, Payload::Grads(acc.clone())))
                    .expect("fabric peer hung up (send)");
            }
        }
        for _ in 0..p - 1 {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(g) = payload else {
                panic!("fabric protocol error: PE {} expected grads in a gather round", self.pe);
            };
            buf[ring_chunk(len, p, src)].copy_from_slice(&g);
        }
        self.barrier.wait();
    }

    /// Gather-to-root + broadcast (see [`AllReduceStrategy::Tree`]).
    /// Root 0 folds every contribution in ascending-PE order, so the
    /// result is bit-equal to the other strategies. One barrier: each
    /// non-root exchanges exactly one message in each direction with
    /// the root, so no cross-phase confusion is possible.
    fn all_reduce_tree(&mut self, buf: &mut [f32]) {
        let p = self.num_pes;
        let payload = (buf.len() * 4) as u64;
        if self.pe == 0 {
            let mut contribs: Vec<Option<Vec<f32>>> = (0..p).map(|_| None).collect();
            for _ in 0..p - 1 {
                let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
                let Payload::Grads(g) = payload else {
                    panic!("fabric protocol error: PE 0 expected grads in a reduce round");
                };
                contribs[src] = Some(g);
            }
            let slices: Vec<&[f32]> = (0..p)
                .map(|src| if src == 0 { &*buf } else { contribs[src].as_deref().unwrap() })
                .collect();
            let acc = canonical_sum(&slices);
            buf.copy_from_slice(&acc);
            for (dst, tx) in self.txs.iter().enumerate() {
                if dst != 0 {
                    self.cross_grad_gather_bytes += payload;
                    if !self.topo.same_group(0, dst) {
                        self.inter_grad_gather_bytes += payload;
                    }
                    tx.send((0, Payload::Grads(acc.clone())))
                        .expect("fabric peer hung up (send)");
                }
            }
        } else {
            self.cross_grad_reduce_bytes += payload;
            if !self.topo.same_group(self.pe, 0) {
                self.inter_grad_reduce_bytes += payload;
            }
            self.txs[0]
                .send((self.pe, Payload::Grads(buf.to_vec())))
                .expect("fabric peer hung up (send)");
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(g) = payload else {
                panic!("fabric protocol error: PE {} expected grads in a gather round", self.pe);
            };
            debug_assert_eq!(src, 0, "tree gather must come from the root");
            buf.copy_from_slice(&g);
        }
        self.barrier.wait();
    }

    /// Hierarchical leader-chain all-reduce for replicated topologies.
    ///
    /// Members ship their raw buffers to the group leader over fast
    /// intra-group links; leader `g` folds (prev-chain partial, own
    /// buffer, members in ascending PE order) and forwards the running
    /// partial to leader `g+1` over the slow link; the last leader owns
    /// the full canonical sum and broadcasts it back (leaders first,
    /// then each leader fans out to its members). Because every fold
    /// preserves the global ascending-PE order — members' buffers are
    /// folded *raw*, never pre-summed — the result is bit-identical to
    /// the flat strategies. Inter-group traffic is `(P/r − 1)·payload`
    /// per phase instead of the flat `(P − r)·payload`.
    ///
    /// Single end-of-round barrier: each (sender, receiver) pair
    /// exchanges at most one message in each direction, every receive
    /// is causally ordered behind the sends it waits for, and the
    /// barrier keeps the next round's messages out.
    fn all_reduce_hierarchical(&mut self, buf: &mut [f32]) {
        let topo = self.topo;
        let r = topo.replication;
        let groups = topo.groups();
        let payload = (buf.len() * 4) as u64;
        let g = topo.group_of(self.pe);
        let leader = topo.leader(g);
        if self.pe != leader {
            // member: raw buffer up to the leader (intra link), final
            // result back from the leader
            self.cross_grad_reduce_bytes += payload;
            self.txs[leader]
                .send((self.pe, Payload::Grads(buf.to_vec())))
                .expect("fabric peer hung up (send)");
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(res) = payload else {
                panic!("fabric protocol error: PE {} expected grads from its leader", self.pe);
            };
            debug_assert_eq!(src, leader, "member result must come from its leader");
            buf.copy_from_slice(&res);
            self.barrier.wait();
            return;
        }
        // leader: collect r-1 member buffers plus (g > 0) the running
        // chain partial from the previous leader. The final broadcast
        // cannot interleave here — it is causally behind this leader's
        // own chain send.
        let expected = (r - 1) + usize::from(g > 0);
        let mut slots: Vec<Option<Vec<f32>>> = (0..self.num_pes).map(|_| None).collect();
        for _ in 0..expected {
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(gr) = payload else {
                panic!("fabric protocol error: leader {} expected grads", self.pe);
            };
            slots[src] = Some(gr);
        }
        let prev = if g > 0 { slots[topo.leader(g - 1)].take() } else { None };
        // global left-fold order: [partial over PEs 0..g·r) ⊕ own buf
        // ⊕ members leader+1 .. leader+r-1 ascending
        let mut contribs: Vec<&[f32]> = Vec::with_capacity(r + 1);
        if let Some(p) = prev.as_deref() {
            contribs.push(p);
        }
        contribs.push(buf);
        for m in leader + 1..leader + r {
            contribs.push(slots[m].as_deref().expect("member contribution missing"));
        }
        let acc = canonical_sum(&contribs);
        let result = if g < groups - 1 {
            // forward the partial up the chain (inter link), then wait
            // for the last leader's broadcast
            self.cross_grad_reduce_bytes += payload;
            self.inter_grad_reduce_bytes += payload;
            self.txs[topo.leader(g + 1)]
                .send((self.pe, Payload::Grads(acc)))
                .expect("fabric peer hung up (send)");
            let (src, payload) = self.rx.recv().expect("fabric peer hung up (recv)");
            let Payload::Grads(res) = payload else {
                panic!("fabric protocol error: leader {} expected the final sum", self.pe);
            };
            debug_assert_eq!(src, topo.leader(groups - 1), "broadcast must come from last leader");
            res
        } else {
            // last leader owns the canonical sum: broadcast to the
            // other leaders (inter links)
            for lg in 0..groups - 1 {
                self.cross_grad_gather_bytes += payload;
                self.inter_grad_gather_bytes += payload;
                self.txs[topo.leader(lg)]
                    .send((self.pe, Payload::Grads(acc.clone())))
                    .expect("fabric peer hung up (send)");
            }
            acc
        };
        // fan the result out to this group's members (intra links)
        for m in leader + 1..leader + r {
            self.cross_grad_gather_bytes += payload;
            self.txs[m]
                .send((self.pe, Payload::Grads(result.clone())))
                .expect("fabric peer hung up (send)");
        }
        buf.copy_from_slice(&result);
        self.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_everything_exactly_once() {
        let mut ex = Exchange::new(3);
        // buckets[src][dst]
        let buckets = vec![
            vec![vec![1u32], vec![2, 3], vec![]],
            vec![vec![4], vec![5], vec![6]],
            vec![vec![], vec![], vec![7, 8]],
        ];
        let inboxes = ex.route(&buckets, 4);
        let mut all: Vec<u32> = inboxes.concat();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        // conservation: items in == items out
        let sent: usize = buckets.iter().flatten().map(|b| b.len()).sum();
        let recv: usize = inboxes.iter().map(|b| b.len()).sum();
        assert_eq!(sent, recv);
    }

    #[test]
    fn cross_vs_local_accounting() {
        let mut ex = Exchange::new(2);
        let buckets = vec![
            vec![vec![1u32, 2], vec![3]], // 2 local, 1 cross
            vec![vec![4], vec![5]],       // 1 cross, 1 local
        ];
        ex.route(&buckets, 8);
        assert_eq!(ex.local_items, 3);
        assert_eq!(ex.cross_items, 2);
        assert_eq!(ex.cross_bytes, 16);
        assert!((ex.cross_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn inbox_order_is_src_major_deterministic() {
        let mut ex = Exchange::new(2);
        let buckets = vec![vec![vec![], vec![10u32, 11]], vec![vec![], vec![20]]];
        let inboxes = ex.route(&buckets, 4);
        assert_eq!(inboxes[1], vec![10, 11, 20], "src-major concat order");
    }

    #[test]
    fn virtual_accounting() {
        let mut ex = Exchange::new(4);
        ex.account_virtual(100, 256);
        assert_eq!(ex.cross_bytes, 25_600);
        assert_eq!(ex.rounds, 1);
    }

    #[test]
    fn row_routing_accounts_rows_and_bytes_separately_from_ids() {
        let mut ex = Exchange::new(2);
        let d = 3usize;
        // PE0 keeps one row local and ships two to PE1; PE1 ships one back
        let buckets = vec![
            vec![vec![0.0; d], vec![1.0; 2 * d]],
            vec![vec![2.0; d], vec![]],
        ];
        let inboxes = ex.route_rows(buckets, d);
        assert_eq!(ex.local_rows, 1);
        assert_eq!(ex.cross_rows, 3);
        assert_eq!(ex.cross_row_bytes, 3 * d as u64 * 4);
        // id counters untouched by row rounds
        assert_eq!(ex.cross_items, 0);
        assert_eq!(ex.cross_bytes, 0);
        // inbox[dst][src] carries the exact payloads
        assert_eq!(inboxes[1][0], vec![1.0; 2 * d]);
        assert_eq!(inboxes[0][1], vec![2.0; d]);
        assert_eq!(inboxes[0][0], vec![0.0; d]);
    }

    /// Encoded-row rounds (wire bytes) must agree between the serial
    /// exchange and the threaded fabric — payloads, accounting, and the
    /// per-src inbox shape.
    #[test]
    fn threaded_encoded_row_fabric_matches_serial_reference() {
        use crate::util::rng::Pcg64;
        let p = 3usize;
        let rb = 9usize; // e.g. int8 with dim 4: 4 + 5 header bytes
        let mut rng = Pcg64::new(0xE9C0);
        let buckets: Vec<Vec<Vec<u8>>> = (0..p)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        let k = rng.next_below(5) as usize;
                        (0..k * rb).map(|_| rng.next_u64() as u8).collect()
                    })
                    .collect()
            })
            .collect();

        let mut ex = Exchange::new(p);
        let serial = ex.route_encoded_rows(buckets.clone(), rb);
        // wire bytes, not decoded f32 bytes
        let cross_expect: u64 = buckets
            .iter()
            .enumerate()
            .flat_map(|(s, row)| {
                row.iter().enumerate().filter(move |(d, _)| *d != s).map(|(_, b)| b.len() as u64)
            })
            .sum();
        assert_eq!(ex.cross_row_bytes, cross_expect);

        let endpoints = Fabric::endpoints(p);
        let results: Vec<(Vec<Vec<u8>>, u64, u64)> = std::thread::scope(|scope| {
            let buckets = &buckets;
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let inbox = ep.all_to_all_encoded_rows(buckets[pe].clone(), rb);
                        (inbox, ep.cross_rows, ep.cross_row_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, res) in results.iter().enumerate() {
            assert_eq!(res.0, serial[q], "PE {q} encoded inbox");
        }
        assert_eq!(results.iter().map(|r| r.1).sum::<u64>(), ex.cross_rows);
        assert_eq!(results.iter().map(|r| r.2).sum::<u64>(), ex.cross_row_bytes);
    }

    /// The threaded fabric must reproduce the serial reference exactly:
    /// same inboxes (src-major), same cross/local accounting when summed
    /// over endpoints, over multiple rounds.
    #[test]
    fn threaded_fabric_matches_serial_exchange() {
        use crate::util::rng::Pcg64;
        let p = 4usize;
        let rounds = 3usize;
        // deterministic random buckets per (round, src, dst)
        let mut rng = Pcg64::new(0xFAB);
        let all_buckets: Vec<Vec<Vec<Vec<VertexId>>>> = (0..rounds)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        (0..p)
                            .map(|_| {
                                let k = rng.next_below(30) as usize;
                                (0..k).map(|_| rng.next_u64() as VertexId).collect()
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();

        // serial oracle
        let mut ex = Exchange::new(p);
        let mut serial_inboxes: Vec<Vec<Vec<VertexId>>> = Vec::new();
        for round in &all_buckets {
            serial_inboxes.push(ex.route(round, 4));
        }

        // threaded run: PE thread q routes its own rows of every round
        let endpoints = Fabric::endpoints(p);
        let results: Vec<(Vec<Vec<Vec<VertexId>>>, u64, u64, u64)> =
            std::thread::scope(|scope| {
                let all_buckets = &all_buckets;
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .map(|mut ep| {
                        scope.spawn(move || {
                            let pe = ep.pe;
                            let mut inboxes = Vec::new();
                            for round in all_buckets {
                                let per_src = ep.all_to_all(round[pe].clone(), 4);
                                inboxes.push(per_src);
                            }
                            (inboxes, ep.cross_items, ep.local_items, ep.cross_bytes)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

        // inbox equality: serial concatenates src-major; threaded returns
        // per-src slots
        for (r, serial_round) in serial_inboxes.iter().enumerate() {
            for (q, serial_inbox) in serial_round.iter().enumerate() {
                let threaded: Vec<VertexId> = results[q].0[r].concat();
                assert_eq!(&threaded, serial_inbox, "round {r} PE {q}");
            }
        }
        // accounting equality (summed over endpoints)
        let cross: u64 = results.iter().map(|r| r.1).sum();
        let local: u64 = results.iter().map(|r| r.2).sum();
        let bytes: u64 = results.iter().map(|r| r.3).sum();
        assert_eq!(cross, ex.cross_items);
        assert_eq!(local, ex.local_items);
        assert_eq!(bytes, ex.cross_bytes);
    }

    /// Row rounds over the threaded fabric must match the serial
    /// `route_rows` reference: same per-src inboxes (payload bytes
    /// included) and same row/byte accounting summed over endpoints —
    /// interleaved with id rounds to exercise the shared channels.
    #[test]
    fn threaded_row_fabric_matches_serial_reference() {
        use crate::util::rng::Pcg64;
        let p = 3usize;
        let d = 4usize;
        let mut rng = Pcg64::new(0xFEA7);
        let row_buckets: Vec<Vec<Vec<f32>>> = (0..p)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        let k = rng.next_below(6) as usize;
                        (0..k * d).map(|_| rng.next_f64() as f32).collect()
                    })
                    .collect()
            })
            .collect();
        let id_buckets: Vec<Vec<Vec<VertexId>>> = (0..p)
            .map(|_| {
                (0..p)
                    .map(|_| {
                        let k = rng.next_below(8) as usize;
                        (0..k).map(|_| rng.next_u64() as VertexId).collect()
                    })
                    .collect()
            })
            .collect();

        let mut ex = Exchange::new(p);
        let serial_ids = ex.route(&id_buckets, 4);
        let serial_rows = ex.route_rows(row_buckets.clone(), d);

        let endpoints = Fabric::endpoints(p);
        type RowResult = (Vec<Vec<VertexId>>, Vec<Vec<f32>>, u64, u64, u64);
        let results: Vec<RowResult> = std::thread::scope(|scope| {
            let (ids, rows) = (&id_buckets, &row_buckets);
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let id_inbox = ep.all_to_all(ids[pe].clone(), 4);
                        let row_inbox = ep.all_to_all_rows(rows[pe].clone(), d);
                        (id_inbox, row_inbox, ep.cross_rows, ep.local_rows, ep.cross_row_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (q, res) in results.iter().enumerate() {
            assert_eq!(res.0.concat(), serial_ids[q], "PE {q} id inbox");
            assert_eq!(res.1, serial_rows[q], "PE {q} row inbox");
        }
        let cross: u64 = results.iter().map(|r| r.2).sum();
        let local: u64 = results.iter().map(|r| r.3).sum();
        let bytes: u64 = results.iter().map(|r| r.4).sum();
        assert_eq!(cross, ex.cross_rows);
        assert_eq!(local, ex.local_rows);
        assert_eq!(bytes, ex.cross_row_bytes);
    }

    // The oracle-equality and byte-closed-form contract of both
    // all-reduce strategies (threaded == serial == sum-then-broadcast,
    // naive per-endpoint and ring fabric-total (P-1)·payload accounting)
    // is covered by the randomized property test
    // `prop_all_reduce_equals_sum_then_broadcast_oracle` in
    // tests/proptests.rs; here only the fabric-specific behaviors that
    // the property test does not exercise are pinned.

    /// All-reduce rounds interleave with id and row rounds on one fabric
    /// without cross-talk, and a buffer shorter than the PE count (empty
    /// ring chunks) still reduces exactly.
    #[test]
    fn all_reduce_interleaves_with_id_and_row_rounds() {
        let p = 3usize;
        let ids: Vec<Vec<Vec<VertexId>>> =
            (0..p).map(|s| (0..p).map(|d| vec![(s * p + d) as VertexId]).collect()).collect();
        let grads: Vec<Vec<f32>> = (0..p).map(|q| vec![q as f32 + 0.5, -(q as f32)]).collect();

        let mut ex = Exchange::new(p);
        let serial_ids = ex.route(&ids, 4);
        let mut serial_grads = grads.clone();
        ex.all_reduce_f32(&mut serial_grads, AllReduceStrategy::Ring);

        let endpoints = Fabric::endpoints(p);
        let results: Vec<(Vec<VertexId>, Vec<f32>)> = std::thread::scope(|scope| {
            let ids = &ids;
            let grads = &grads;
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let pe = ep.pe;
                        let inbox = ep.all_to_all(ids[pe].clone(), 4).concat();
                        let mut buf = grads[pe].clone();
                        ep.all_reduce_f32(&mut buf, AllReduceStrategy::Ring);
                        (inbox, buf)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, (inbox, buf)) in results.iter().enumerate() {
            assert_eq!(inbox, &serial_ids[q], "PE {q} ids");
            assert_eq!(buf, &serial_grads[q], "PE {q} grads");
        }
    }

    #[test]
    fn single_pe_all_reduce_is_identity() {
        let mut ep = Fabric::endpoints(1).pop().unwrap();
        let mut buf = vec![1.5f32, -2.0];
        ep.all_reduce_f32(&mut buf, AllReduceStrategy::Ring);
        assert_eq!(buf, vec![1.5, -2.0]);
        assert_eq!(ep.cross_grad_reduce_bytes + ep.cross_grad_gather_bytes, 0);
    }

    #[test]
    fn single_pe_fabric_is_local_only() {
        let mut ep = Fabric::endpoints(1).pop().unwrap();
        let inbox = ep.all_to_all(vec![vec![1, 2, 3]], 4);
        assert_eq!(inbox, vec![vec![1, 2, 3]]);
        assert_eq!(ep.cross_items, 0);
        assert_eq!(ep.local_items, 3);
        let rows = ep.all_to_all_rows(vec![vec![0.5; 8]], 4);
        assert_eq!(rows, vec![vec![0.5; 8]]);
        assert_eq!(ep.cross_rows, 0);
        assert_eq!(ep.local_rows, 2);
    }

    #[test]
    fn topology_groups_and_leaders() {
        let t = Topology::new(8, 2);
        assert_eq!(t.groups(), 4);
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(1), 0);
        assert_eq!(t.group_of(5), 2);
        assert_eq!(t.leader(2), 4);
        assert!(t.same_group(4, 5));
        assert!(!t.same_group(3, 4));
        // flat: every PE is its own group, leaders are identities
        let f = Topology::flat(3);
        assert_eq!(f.groups(), 3);
        assert!(!f.same_group(0, 1));
        assert!(f.same_group(2, 2));
    }

    /// First copy of a key into a remote group is inter traffic; the
    /// second copy (another member of the same group) could be relayed
    /// over the fast intra link, and same-group destinations never pay
    /// the slow link at all.
    #[test]
    fn split_send_rows_counts_first_copy_per_group_only() {
        let t = Topology::new(4, 2); // groups {0,1} and {2,3}
        // me = 0; dst 1 shares my group (free), dsts 2 and 3 form one
        // remote group: key 7 goes to both but crosses the slow link once
        let per_dst: Vec<&[u32]> = vec![&[], &[1, 2, 3], &[7, 8], &[7, 9]];
        assert_eq!(split_send_rows(&t, 0, &per_dst), 3); // {7, 8, 9}
        // a bucket addressed to myself is never counted
        let own: Vec<&[u32]> = vec![&[], &[], &[5, 5, 6], &[]];
        assert_eq!(split_send_rows(&t, 2, &own), 0);
        // duplicate keys inside one destination list also count once
        let dup: Vec<&[u32]> = vec![&[5, 5, 6], &[], &[], &[]];
        assert_eq!(split_send_rows(&t, 2, &dup), 2); // {5, 6} into group 0
        // flat topology: every remote destination is its own group, so
        // every cross copy is inter
        let f = Topology::flat(3);
        let flat: Vec<&[u32]> = vec![&[], &[4], &[4]];
        assert_eq!(split_send_rows(&f, 0, &flat), 2);
    }

    /// The hierarchical leader-chain all-reduce must be bit-identical
    /// to the flat canonical sum, and its byte profile must follow the
    /// chain closed forms: (P−1)·payload cross per phase with only
    /// (P/r−1)·payload of it on inter-group links — matching the serial
    /// [`Exchange`] accounting exactly.
    #[test]
    fn hierarchical_all_reduce_matches_flat_and_charges_chain_profile() {
        let (p, r, len) = (4usize, 2usize, 6usize);
        let topo = Topology::new(p, r);
        let grads: Vec<Vec<f32>> =
            (0..p).map(|q| (0..len).map(|i| (q * len + i) as f32 * 0.37 - 1.1).collect()).collect();

        // flat oracle: canonical sum over all PEs in ascending order
        let mut flat = Exchange::new(p);
        let mut expect = grads.clone();
        flat.all_reduce_f32(&mut expect, AllReduceStrategy::Ring);

        // serial replicated exchange charges the chain profile
        let mut ex = Exchange::with_topology(topo);
        let mut serial = grads.clone();
        ex.all_reduce_f32(&mut serial, AllReduceStrategy::Ring);
        assert_eq!(serial, expect, "serial hierarchical accounting must not change values");
        let payload = (len * 4) as u64;
        let g = topo.groups() as u64;
        assert_eq!(ex.cross_grad_reduce_bytes, (p as u64 - 1) * payload);
        assert_eq!(ex.cross_grad_gather_bytes, (p as u64 - 1) * payload);
        assert_eq!(ex.inter_grad_reduce_bytes, (g - 1) * payload);
        assert_eq!(ex.inter_grad_gather_bytes, (g - 1) * payload);

        // threaded: the strategy argument is overridden by the topology,
        // so Naive and Ring both take the chain — and stay bit-identical
        for strategy in [AllReduceStrategy::Naive, AllReduceStrategy::Ring] {
            let endpoints = Fabric::endpoints_with(topo);
            let results: Vec<(Vec<f32>, u64, u64, u64, u64)> = std::thread::scope(|scope| {
                let grads = &grads;
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .map(|mut ep| {
                        scope.spawn(move || {
                            let mut buf = grads[ep.pe].clone();
                            ep.all_reduce_f32(&mut buf, strategy);
                            (
                                buf,
                                ep.cross_grad_reduce_bytes,
                                ep.cross_grad_gather_bytes,
                                ep.inter_grad_reduce_bytes,
                                ep.inter_grad_gather_bytes,
                            )
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (q, res) in results.iter().enumerate() {
                assert_eq!(res.0, expect[q], "PE {q} {} hierarchical value", strategy.name());
            }
            assert_eq!(results.iter().map(|t| t.1).sum::<u64>(), ex.cross_grad_reduce_bytes);
            assert_eq!(results.iter().map(|t| t.2).sum::<u64>(), ex.cross_grad_gather_bytes);
            assert_eq!(results.iter().map(|t| t.3).sum::<u64>(), ex.inter_grad_reduce_bytes);
            assert_eq!(results.iter().map(|t| t.4).sum::<u64>(), ex.inter_grad_gather_bytes);
        }
    }

    /// Tree (gather-to-root + broadcast) is bit-identical to the other
    /// strategies and moves (P−1)·payload in each phase.
    #[test]
    fn tree_all_reduce_is_bit_identical_with_accounted_phases() {
        let (p, len) = (3usize, 5usize);
        let grads: Vec<Vec<f32>> =
            (0..p).map(|q| (0..len).map(|i| (i as f32 + 0.25) * (q as f32 - 1.3)).collect()).collect();
        let mut ex = Exchange::new(p);
        let mut serial = grads.clone();
        ex.all_reduce_f32(&mut serial, AllReduceStrategy::Tree);
        let payload = (len * 4) as u64;
        assert_eq!(ex.cross_grad_reduce_bytes, (p as u64 - 1) * payload);
        assert_eq!(ex.cross_grad_gather_bytes, (p as u64 - 1) * payload);

        let endpoints = Fabric::endpoints(p);
        let results: Vec<(Vec<f32>, u64, u64)> = std::thread::scope(|scope| {
            let grads = &grads;
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let mut buf = grads[ep.pe].clone();
                        ep.all_reduce_f32(&mut buf, AllReduceStrategy::Tree);
                        (buf, ep.cross_grad_reduce_bytes, ep.cross_grad_gather_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, res) in results.iter().enumerate() {
            assert_eq!(res.0, serial[q], "PE {q} tree value");
        }
        assert_eq!(results.iter().map(|t| t.1).sum::<u64>(), ex.cross_grad_reduce_bytes);
        assert_eq!(results.iter().map(|t| t.2).sum::<u64>(), ex.cross_grad_gather_bytes);
    }

    /// Id rounds classify each bucket by the (src, dst) group pair:
    /// same-group cross traffic stays off the inter ledger, and serial
    /// and threaded fabrics agree on both ledgers.
    #[test]
    fn id_rounds_classify_inter_group_traffic() {
        let topo = Topology::new(4, 2);
        // src-major buckets: PE q sends q+1 ids to every other PE
        let ids: Vec<Vec<Vec<VertexId>>> = (0..4)
            .map(|s| {
                (0..4)
                    .map(|d| if s == d { vec![] } else { vec![(s * 4 + d) as VertexId; s + 1] })
                    .collect()
            })
            .collect();
        let mut ex = Exchange::with_topology(topo);
        let serial = ex.route(&ids, 4);
        // per src: 3 cross buckets of (s+1) ids, 2 of them inter
        let cross_expect: u64 = (0..4u64).map(|s| 3 * (s + 1)).sum();
        let inter_expect: u64 = (0..4u64).map(|s| 2 * (s + 1)).sum();
        assert_eq!(ex.cross_items, cross_expect);
        assert_eq!(ex.inter_items, inter_expect);
        assert_eq!(ex.inter_bytes, inter_expect * 4);

        let endpoints = Fabric::endpoints_with(topo);
        let results: Vec<(Vec<VertexId>, u64, u64)> = std::thread::scope(|scope| {
            let ids = &ids;
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    scope.spawn(move || {
                        let inbox = ep.all_to_all(ids[ep.pe].clone(), 4).concat();
                        (inbox, ep.inter_items, ep.inter_bytes)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, res) in results.iter().enumerate() {
            assert_eq!(res.0, serial[q], "PE {q} ids");
        }
        assert_eq!(results.iter().map(|t| t.1).sum::<u64>(), ex.inter_items);
        assert_eq!(results.iter().map(|t| t.2).sum::<u64>(), ex.inter_bytes);
    }
}
