//! All-to-all exchange fabric — the simulated NVLink of Algorithm 1.
//!
//! [`Exchange`] routes per-(src PE, dst PE) buckets of items and accounts
//! the traffic: *cross-PE* items (the `c·|S̃|` of the paper's Table 1) are
//! what a real fabric would move at α bandwidth; same-PE buckets are local
//! and free. The cost model ([`crate::costmodel`]) turns the recorded item
//! counts into time; the engine also measures real wall-clock for the
//! CPU-side data movement.

/// Byte/item accounting for one logical fabric.
#[derive(Clone, Debug, Default)]
pub struct Exchange {
    pub num_pes: usize,
    /// items moved between distinct PEs, by payload class
    pub cross_items: u64,
    /// items "moved" within a PE (no fabric cost)
    pub local_items: u64,
    /// cross bytes (items * item_size accumulated by callers)
    pub cross_bytes: u64,
    /// number of all-to-all rounds executed
    pub rounds: u64,
}

impl Exchange {
    pub fn new(num_pes: usize) -> Self {
        Exchange { num_pes, ..Default::default() }
    }

    /// Route `buckets[src][dst]` to per-destination inboxes
    /// `out[dst] = concat over src of buckets[src][dst]`, accounting
    /// traffic with `item_bytes` per item. Returns the inboxes.
    pub fn route<T: Clone>(&mut self, buckets: &[Vec<Vec<T>>], item_bytes: usize) -> Vec<Vec<T>> {
        assert_eq!(buckets.len(), self.num_pes);
        self.rounds += 1;
        let mut inboxes: Vec<Vec<T>> = (0..self.num_pes).map(|_| Vec::new()).collect();
        for (src, per_dst) in buckets.iter().enumerate() {
            assert_eq!(per_dst.len(), self.num_pes, "bucket row {src} width");
            for (dst, items) in per_dst.iter().enumerate() {
                if src == dst {
                    self.local_items += items.len() as u64;
                } else {
                    self.cross_items += items.len() as u64;
                    self.cross_bytes += (items.len() * item_bytes) as u64;
                }
                inboxes[dst].extend_from_slice(items);
            }
        }
        inboxes
    }

    /// Account a cross-PE payload without routing real data (used for
    /// activation/gradient traffic whose numeric payload lives inside the
    /// monolithic train-step executable; only its *size* matters here).
    pub fn account_virtual(&mut self, cross_items: u64, item_bytes: usize) {
        self.rounds += 1;
        self.cross_items += cross_items;
        self.cross_bytes += cross_items * item_bytes as u64;
    }

    /// Fraction of routed items that crossed PEs (empirical `c`).
    pub fn cross_ratio(&self) -> f64 {
        let total = self.cross_items + self.local_items;
        if total == 0 {
            0.0
        } else {
            self.cross_items as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_everything_exactly_once() {
        let mut ex = Exchange::new(3);
        // buckets[src][dst]
        let buckets = vec![
            vec![vec![1u32], vec![2, 3], vec![]],
            vec![vec![4], vec![5], vec![6]],
            vec![vec![], vec![], vec![7, 8]],
        ];
        let inboxes = ex.route(&buckets, 4);
        let mut all: Vec<u32> = inboxes.concat();
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        // conservation: items in == items out
        let sent: usize = buckets.iter().flatten().map(|b| b.len()).sum();
        let recv: usize = inboxes.iter().map(|b| b.len()).sum();
        assert_eq!(sent, recv);
    }

    #[test]
    fn cross_vs_local_accounting() {
        let mut ex = Exchange::new(2);
        let buckets = vec![
            vec![vec![1u32, 2], vec![3]], // 2 local, 1 cross
            vec![vec![4], vec![5]],       // 1 cross, 1 local
        ];
        ex.route(&buckets, 8);
        assert_eq!(ex.local_items, 3);
        assert_eq!(ex.cross_items, 2);
        assert_eq!(ex.cross_bytes, 16);
        assert!((ex.cross_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn inbox_order_is_src_major_deterministic() {
        let mut ex = Exchange::new(2);
        let buckets = vec![vec![vec![], vec![10u32, 11]], vec![vec![], vec![20]]];
        let inboxes = ex.route(&buckets, 4);
        assert_eq!(inboxes[1], vec![10, 11, 20], "src-major concat order");
    }

    #[test]
    fn virtual_accounting() {
        let mut ex = Exchange::new(4);
        ex.account_virtual(100, 256);
        assert_eq!(ex.cross_bytes, 25_600);
        assert_eq!(ex.rounds, 1);
    }
}
