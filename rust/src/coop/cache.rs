//! O(1) LRU cache for vertex embeddings (paper §4.2).
//!
//! The cache stores **rows, not membership**: each arena slot carries the
//! vertex's f32 feature row, so a hit returns bytes from the arena and a
//! miss fills the slot from the local [`crate::feature::FeatureStore`]
//! shard (a β-bandwidth storage read) before returning them. The paper's
//! proxy — "the cache miss rate is proportional to the amount of data
//! that needs to be copied from the vertex embedding storage" — is
//! therefore *derived from* the byte movement here rather than simulated:
//! `bytes_from_storage == misses() * row_bytes` by construction, and the
//! property tests assert it.
//!
//! Structure: classic hashmap + intrusive doubly-linked list arena; the
//! row arena is parallel to the node arena (slot `i` ↔
//! `rows[i*dim..(i+1)*dim]`) and grows lazily with insertions, so a
//! nominally huge capacity costs nothing until rows actually land.
//! [`LruCache::new`] builds a membership-only cache (`dim == 0`, no row
//! arena) for count-only consumers; [`LruCache::with_rows`] is the
//! feature-plane constructor. Capacity 0 means **no cache**: a true
//! pass-through where every access misses straight to storage and
//! nothing is allocated or retained.
//!
//! Hit/miss counters are private — read them through [`LruCache::hits`] /
//! [`LruCache::misses`] and clear them with [`LruCache::reset_counters`]
//! — so no caller can double-count or retro-edit the accounting that
//! Table 1 / Figure 5 numbers are derived from.
//!
//! Concurrency contract: the cache is deliberately **not** shared-state —
//! in the threaded engine every PE thread owns one `LruCache` instance
//! behind its thread boundary (the type is `Send`, not `Sync`-shared),
//! mirroring the paper's private per-GPU caches and keeping hit/miss
//! streams bit-deterministic regardless of scheduling.

use crate::feature::Codec;
use crate::graph::VertexId;
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Node {
    key: VertexId,
    prev: u32,
    next: u32,
}

/// Fixed-capacity LRU row cache with hit/miss accounting.
#[derive(Clone, Debug)]
pub struct LruCache {
    map: HashMap<VertexId, u32>,
    arena: Vec<Node>,
    /// row arena parallel to `arena`: slot i ↔ rows[i*dim..(i+1)*dim].
    rows: Vec<f32>,
    /// encoded-row arena (wire bytes) parallel to `arena`: slot i ↔
    /// enc[i*enc_row_bytes..]. Populated only by [`LruCache::with_encoded`]
    /// caches; the f32 `rows` arena stays empty on those (one arena per
    /// cache, so resident bytes are wire bytes).
    enc: Vec<u8>,
    /// encoded bytes per row; 0 = decoded-f32 (or membership-only) cache.
    enc_row_bytes: usize,
    /// codec used to decode `enc` slots on the way out.
    codec: Codec,
    /// floats per row; 0 = membership-only cache (no row storage).
    dim: usize,
    head: u32, // most recent
    tail: u32, // least recent
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl LruCache {
    /// Membership-only cache (`dim == 0`): [`LruCache::access`] tracks
    /// hits/misses without storing bytes. Kept for count-only consumers
    /// and micro-benchmarks; the feature plane uses [`with_rows`].
    ///
    /// [`with_rows`]: LruCache::with_rows
    pub fn new(capacity: usize) -> Self {
        Self::with_rows(capacity, 0)
    }

    /// Row-storing cache: each slot carries a `dim`-float feature row,
    /// accessed through [`LruCache::access_row`].
    ///
    /// Capacity 0 is a true pass-through — "no cache": every access is a
    /// miss served straight from storage, nothing is inserted, and no
    /// arena is ever allocated (so `--cache 0` stores zero bytes, rather
    /// than silently running a capacity-1 cache as it used to).
    pub fn with_rows(capacity: usize, dim: usize) -> Self {
        LruCache {
            map: HashMap::with_capacity(capacity.min(1 << 22)),
            arena: Vec::with_capacity(capacity.min(1 << 22)),
            rows: Vec::new(),
            enc: Vec::new(),
            enc_row_bytes: 0,
            codec: Codec::F32,
            dim,
            head: NIL,
            tail: NIL,
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Encoded-row cache: each slot carries one `codec`-encoded row
    /// ([`Codec::row_bytes`] wire bytes), filled and decoded through
    /// [`LruCache::access_row_encoded`] — so a 100k-row cache arena
    /// shrinks by the codec ratio just like storage and fabric traffic.
    /// Counter discipline is identical to the other constructors.
    pub fn with_encoded(capacity: usize, dim: usize, codec: Codec) -> Self {
        let mut c = Self::with_rows(capacity, dim);
        c.codec = codec;
        c.enc_row_bytes = codec.row_bytes(dim);
        c
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Floats per cached row (0 for a membership-only cache).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Cache hits since construction / the last [`reset_counters`].
    ///
    /// [`reset_counters`]: LruCache::reset_counters
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses (= rows read from storage) since construction / the
    /// last [`reset_counters`].
    ///
    /// [`reset_counters`]: LruCache::reset_counters
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Access vertex `v`: returns `true` on hit. On miss the vertex is
    /// inserted (evicting the LRU entry if full). Either way `v` becomes
    /// most-recently-used.
    ///
    /// Membership-only discipline: on a row cache (`dim > 0`) a miss
    /// inserted here leaves the slot's row **zeroed**, so count-only and
    /// row-carrying accesses must not be mixed on one cache; the feature
    /// plane always goes through [`LruCache::access_row`].
    pub fn access(&mut self, v: VertexId) -> bool {
        if self.capacity == 0 {
            // pass-through: unconditional miss, nothing retained
            self.misses += 1;
            return false;
        }
        if let Some(&idx) = self.map.get(&v) {
            self.hits += 1;
            self.move_to_front(idx);
            true
        } else {
            self.misses += 1;
            self.insert_front(v);
            false
        }
    }

    /// Access vertex `v` and copy its feature row into `out`
    /// (`out.len() == dim`): on a hit the bytes come from the arena; on
    /// a miss `fill` is called exactly once with the (evicted or fresh)
    /// slot to pull the row from storage, and the bytes are then served
    /// from the arena like a hit. Returns `true` on hit. Counter
    /// discipline is identical to [`LruCache::access`], so row caches
    /// and the legacy membership caches report the same hit/miss stream
    /// for the same access sequence.
    pub fn access_row<F>(&mut self, v: VertexId, out: &mut [f32], fill: F) -> bool
    where
        F: FnOnce(&mut [f32]),
    {
        debug_assert!(self.dim > 0, "access_row needs a row cache (with_rows)");
        debug_assert_eq!(out.len(), self.dim);
        if self.capacity == 0 {
            // pass-through: the storage read lands directly in the
            // caller's buffer, no arena slot exists to fill
            self.misses += 1;
            fill(out);
            return false;
        }
        if let Some(&idx) = self.map.get(&v) {
            self.hits += 1;
            self.move_to_front(idx);
            let i = idx as usize * self.dim;
            out.copy_from_slice(&self.rows[i..i + self.dim]);
            true
        } else {
            self.misses += 1;
            let idx = self.insert_front(v) as usize * self.dim;
            let slot = &mut self.rows[idx..idx + self.dim];
            fill(slot);
            out.copy_from_slice(slot);
            false
        }
    }

    /// Access vertex `v` on an encoded cache (built with
    /// [`LruCache::with_encoded`]) and decode its row into `out`
    /// (`out.len() == dim`): a hit decodes straight out of the encoded
    /// arena; a miss calls `fill` exactly once to pull the *encoded* row
    /// (exactly `codec.row_bytes(dim)` bytes) from storage, parks those
    /// wire bytes in the arena, and decodes them for the caller. Returns
    /// `true` on hit. Counter discipline matches [`LruCache::access_row`].
    pub fn access_row_encoded<F>(&mut self, v: VertexId, out: &mut [f32], fill: F) -> bool
    where
        F: FnOnce(&mut Vec<u8>),
    {
        debug_assert!(self.enc_row_bytes > 0, "access_row_encoded needs with_encoded");
        debug_assert_eq!(out.len(), self.dim);
        let rb = self.enc_row_bytes;
        if self.capacity == 0 {
            // pass-through: decode the storage read straight into the
            // caller's buffer, nothing retained
            self.misses += 1;
            let mut scratch = Vec::with_capacity(rb);
            fill(&mut scratch);
            debug_assert_eq!(scratch.len(), rb, "fill must deliver one encoded row");
            self.codec.decode_row(&scratch, out);
            return false;
        }
        if let Some(&idx) = self.map.get(&v) {
            self.hits += 1;
            self.move_to_front(idx);
            let i = idx as usize * rb;
            self.codec.decode_row(&self.enc[i..i + rb], out);
            true
        } else {
            self.misses += 1;
            let mut scratch = Vec::with_capacity(rb);
            fill(&mut scratch);
            debug_assert_eq!(scratch.len(), rb, "fill must deliver one encoded row");
            let i = self.insert_front(v) as usize * rb;
            self.enc[i..i + rb].copy_from_slice(&scratch);
            self.codec.decode_row(&scratch, out);
            false
        }
    }

    /// Resident arena bytes (wire bytes for encoded caches, f32 bytes
    /// otherwise) — what a byte-budget comparison of cache footprints
    /// should use.
    pub fn arena_bytes(&self) -> usize {
        self.enc.len() + self.rows.len() * 4
    }

    /// Peek membership without updating recency or stats.
    pub fn contains(&self, v: VertexId) -> bool {
        self.map.contains_key(&v)
    }

    /// Peek a cached row without updating recency or stats (`None` when
    /// absent or membership-only).
    pub fn peek_row(&self, v: VertexId) -> Option<&[f32]> {
        if self.dim == 0 {
            return None;
        }
        self.map.get(&v).map(|&idx| {
            let i = idx as usize * self.dim;
            &self.rows[i..i + self.dim]
        })
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    /// Reset the hit/miss counters (not contents) — used between
    /// measurement windows so warmup accesses don't pollute reported
    /// rates.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    fn detach(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.arena[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.arena[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.arena[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn attach_front(&mut self, idx: u32) {
        self.arena[idx as usize].prev = NIL;
        self.arena[idx as usize].next = self.head;
        if self.head != NIL {
            self.arena[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn move_to_front(&mut self, idx: u32) {
        if self.head == idx {
            return;
        }
        self.detach(idx);
        self.attach_front(idx);
    }

    /// Insert `v` as MRU, evicting the LRU entry when full. Returns the
    /// arena slot index so callers can fill the row in place.
    fn insert_front(&mut self, v: VertexId) -> u32 {
        debug_assert!(self.capacity > 0, "pass-through caches never insert");
        if self.map.len() >= self.capacity {
            // evict LRU (tail), reuse its arena slot (and its row slot)
            let idx = self.tail;
            debug_assert_ne!(idx, NIL);
            self.detach(idx);
            let old = self.arena[idx as usize].key;
            self.map.remove(&old);
            self.arena[idx as usize].key = v;
            self.map.insert(v, idx);
            self.attach_front(idx);
            idx
        } else {
            let idx = self.arena.len() as u32;
            self.arena.push(Node { key: v, prev: NIL, next: NIL });
            if self.enc_row_bytes > 0 {
                self.enc.resize(self.enc.len() + self.enc_row_bytes, 0);
            } else if self.dim > 0 {
                self.rows.resize(self.rows.len() + self.dim, 0.0);
            }
            self.map.insert(v, idx);
            self.attach_front(idx);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Each PE thread owns its cache instance in the threaded engine —
    /// the type must stay `Send` (compile-time check).
    #[test]
    fn cache_is_send_for_per_pe_threads() {
        fn assert_send<T: Send>() {}
        assert_send::<LruCache>();
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = LruCache::new(2);
        assert!(!c.access(1)); // miss
        assert!(!c.access(2)); // miss
        assert!(c.access(1)); // hit
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 2);
        assert!((c.miss_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = LruCache::new(2);
        c.access(1);
        c.access(2);
        c.access(1); // 1 now MRU, 2 is LRU
        c.access(3); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut c = LruCache::new(10);
        for v in 0..1000u32 {
            c.access(v % 37);
            assert!(c.len() <= 10);
        }
    }

    #[test]
    fn full_scan_cyclic_worst_case() {
        // classic LRU pathology: cyclic scan of capacity+1 items misses
        // every time
        let mut c = LruCache::new(4);
        for _ in 0..5 {
            for v in 0..5u32 {
                c.access(v);
            }
        }
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 25);
    }

    #[test]
    fn counter_reset_keeps_contents() {
        let mut c = LruCache::new(4);
        c.access(7);
        c.reset_counters();
        assert_eq!(c.misses(), 0);
        assert!(c.access(7), "content survives counter reset");
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn stress_against_reference_model() {
        // Compare against a naive O(n) reference LRU.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(99);
        let mut c = LruCache::new(16);
        let mut reference: Vec<u32> = Vec::new(); // front = MRU
        for _ in 0..5000 {
            let v = rng.next_below(64) as u32;
            let hit = c.access(v);
            let ref_hit = reference.contains(&v);
            assert_eq!(hit, ref_hit, "divergence on {v}");
            reference.retain(|&x| x != v);
            reference.insert(0, v);
            reference.truncate(16);
        }
    }

    /// Row for vertex v in the tests' toy "storage": v, v+1, v+2.
    fn toy_row(v: VertexId) -> [f32; 3] {
        [v as f32, v as f32 + 1.0, v as f32 + 2.0]
    }

    #[test]
    fn row_hits_serve_bytes_from_arena_not_storage() {
        let mut c = LruCache::with_rows(4, 3);
        let mut out = [0f32; 3];
        let mut storage_reads = 0;
        let mut pull = |c: &mut LruCache, v: VertexId, out: &mut [f32; 3], reads: &mut u32| {
            c.access_row(v, out, |slot| {
                slot.copy_from_slice(&toy_row(v));
                *reads += 1;
            })
        };
        assert!(!pull(&mut c, 9, &mut out, &mut storage_reads));
        assert_eq!(out, toy_row(9));
        assert!(pull(&mut c, 9, &mut out, &mut storage_reads), "second access hits");
        assert_eq!(out, toy_row(9), "hit returns the cached bytes");
        assert_eq!(storage_reads, 1, "storage read only on the miss");
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn row_eviction_refetches_from_storage() {
        let mut c = LruCache::with_rows(2, 3);
        let mut out = [0f32; 3];
        for v in [1u32, 2, 3] {
            c.access_row(v, &mut out, |s| s.copy_from_slice(&toy_row(v)));
        }
        // 1 was evicted by 3; its slot now holds 3's bytes
        assert!(c.peek_row(1).is_none());
        assert_eq!(c.peek_row(3).unwrap(), &toy_row(3)[..]);
        let mut refetched = false;
        c.access_row(1, &mut out, |s| {
            s.copy_from_slice(&toy_row(1));
            refetched = true;
        });
        assert!(refetched, "evicted row must come back from storage");
        assert_eq!(out, toy_row(1));
    }

    #[test]
    fn row_cache_counters_match_membership_cache() {
        // identical access sequences ⇒ identical hit/miss streams,
        // whether or not rows are carried (the bit-identity the engine
        // refactor relies on)
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(7);
        let mut membership = LruCache::new(8);
        let mut rows = LruCache::with_rows(8, 3);
        let mut out = [0f32; 3];
        for _ in 0..2000 {
            let v = rng.next_below(40) as u32;
            let a = membership.access(v);
            let b = rows.access_row(v, &mut out, |s| s.copy_from_slice(&toy_row(v)));
            assert_eq!(a, b, "divergence on {v}");
        }
        assert_eq!(membership.hits(), rows.hits());
        assert_eq!(membership.misses(), rows.misses());
    }

    /// Regression: `--cache 0` used to clamp to a capacity-1 cache,
    /// occasionally hitting and under-reporting storage bytes. Capacity
    /// 0 must behave as no cache at all: every access a miss, nothing
    /// resident, no arena bytes.
    #[test]
    fn zero_capacity_is_a_true_pass_through() {
        let mut c = LruCache::new(0);
        let accesses = 100u64;
        for i in 0..accesses {
            // repeated keys included — even back-to-back repeats miss
            assert!(!c.access((i % 3) as u32), "no access may hit at cap 0");
        }
        assert_eq!(c.misses(), accesses, "misses == accesses at cap 0");
        assert_eq!(c.hits(), 0);
        assert_eq!(c.len(), 0, "nothing resident");
        assert_eq!(c.capacity(), 0, "capacity no longer clamped to 1");

        let mut rows = LruCache::with_rows(0, 3);
        let mut out = [0f32; 3];
        let mut storage_reads = 0u64;
        for i in 0..accesses {
            let v = (i % 3) as u32;
            let hit = rows.access_row(v, &mut out, |slot| {
                slot.copy_from_slice(&toy_row(v));
                storage_reads += 1;
            });
            assert!(!hit);
            assert_eq!(out, toy_row(v), "miss must still deliver the row");
        }
        assert_eq!(rows.misses(), accesses);
        assert_eq!(storage_reads, accesses, "every access reads storage");
        assert_eq!(rows.rows.len(), 0, "no arena is ever allocated");
        assert!(rows.peek_row(0).is_none());
    }

    #[test]
    fn encoded_cache_holds_wire_bytes_and_matches_f32_counters() {
        // fill source: int8-encode toy_row(v) once per miss
        let codec = Codec::Int8;
        let dim = 3usize;
        let rb = codec.row_bytes(dim);
        let fill_enc = |v: VertexId, out: &mut Vec<u8>| {
            out.clear();
            codec.encode_row(&toy_row(v), out);
        };
        let mut enc_cache = LruCache::with_encoded(8, dim, codec);
        let mut f32_cache = LruCache::with_rows(8, dim);
        let mut a = [0f32; 3];
        let mut b = [0f32; 3];
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(21);
        for _ in 0..2000 {
            let v = rng.next_below(40) as u32;
            let ha = enc_cache.access_row_encoded(v, &mut a, |o| fill_enc(v, o));
            let hb = f32_cache.access_row(v, &mut b, |s| s.copy_from_slice(&toy_row(v)));
            assert_eq!(ha, hb, "hit/miss divergence on {v}");
            // a == decode(encode(toy_row)) whether served from arena or fill
            let mut want = [0f32; 3];
            let mut enc = Vec::new();
            codec.encode_row(&toy_row(v), &mut enc);
            codec.decode_row(&enc, &mut want);
            assert_eq!(a, want, "decoded bytes diverge on {v}");
        }
        assert_eq!(enc_cache.hits(), f32_cache.hits());
        assert_eq!(enc_cache.misses(), f32_cache.misses());
        // the arena holds wire bytes only — no f32 rows
        assert_eq!(enc_cache.rows.len(), 0, "encoded cache must not hold decoded rows");
        assert_eq!(enc_cache.arena_bytes(), enc_cache.len() * rb);
        assert_eq!(f32_cache.arena_bytes(), f32_cache.len() * dim * 4);
        assert!(enc_cache.arena_bytes() < f32_cache.arena_bytes(), "codec shrinks the arena");
    }

    #[test]
    fn encoded_zero_capacity_is_a_true_pass_through() {
        let codec = Codec::Fp16;
        let mut c = LruCache::with_encoded(0, 3, codec);
        let mut out = [0f32; 3];
        let mut reads = 0u64;
        for i in 0..50u64 {
            let v = (i % 2) as u32;
            let hit = c.access_row_encoded(v, &mut out, |o| {
                o.clear();
                codec.encode_row(&toy_row(v), o);
                reads += 1;
            });
            assert!(!hit);
        }
        assert_eq!(reads, 50, "every access reads storage at cap 0");
        assert_eq!(c.arena_bytes(), 0, "nothing resident");
    }

    #[test]
    fn row_arena_grows_lazily() {
        // a nominally huge capacity must not preallocate rows
        let mut c = LruCache::with_rows(1 << 20, 4);
        assert_eq!(c.rows.len(), 0);
        let mut out = [0f32; 4];
        c.access_row(5, &mut out, |s| s.fill(1.0));
        assert_eq!(c.rows.len(), 4, "one slot per resident row");
    }
}
