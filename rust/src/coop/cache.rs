//! O(1) LRU cache for vertex embeddings (paper §4.2).
//!
//! The paper measures *cache miss rate* as the proxy for vertex-embedding
//! traffic from storage ("the cache miss rate is proportional to the
//! amount of data that needs to be copied from the vertex embedding
//! storage"). We only track membership — the actual feature bytes are
//! regenerated on demand by the dataset — so the cache stores vertex ids
//! in a classic hashmap + intrusive doubly-linked list arena.
//!
//! Concurrency contract: the cache is deliberately **not** shared-state —
//! in the threaded engine every PE thread owns one `LruCache` instance
//! behind its thread boundary (the type is `Send`, not `Sync`-shared),
//! mirroring the paper's private per-GPU caches and keeping hit/miss
//! streams bit-deterministic regardless of scheduling.

use crate::graph::VertexId;
use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Node {
    key: VertexId,
    prev: u32,
    next: u32,
}

/// Fixed-capacity LRU set with hit/miss accounting.
#[derive(Clone, Debug)]
pub struct LruCache {
    map: HashMap<VertexId, u32>,
    arena: Vec<Node>,
    head: u32, // most recent
    tail: u32, // least recent
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    pub fn new(capacity: usize) -> Self {
        LruCache {
            map: HashMap::with_capacity(capacity.min(1 << 22)),
            arena: Vec::with_capacity(capacity.min(1 << 22)),
            head: NIL,
            tail: NIL,
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Access vertex `v`: returns `true` on hit. On miss the vertex is
    /// inserted (evicting the LRU entry if full). Either way `v` becomes
    /// most-recently-used.
    pub fn access(&mut self, v: VertexId) -> bool {
        if let Some(&idx) = self.map.get(&v) {
            self.hits += 1;
            self.move_to_front(idx);
            true
        } else {
            self.misses += 1;
            self.insert_front(v);
            false
        }
    }

    /// Peek membership without updating recency or stats.
    pub fn contains(&self, v: VertexId) -> bool {
        self.map.contains_key(&v)
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }

    /// Reset statistics (not contents) — used between measurement windows
    /// so warmup accesses don't pollute reported rates.
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    fn detach(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.arena[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.arena[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.arena[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn attach_front(&mut self, idx: u32) {
        self.arena[idx as usize].prev = NIL;
        self.arena[idx as usize].next = self.head;
        if self.head != NIL {
            self.arena[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn move_to_front(&mut self, idx: u32) {
        if self.head == idx {
            return;
        }
        self.detach(idx);
        self.attach_front(idx);
    }

    fn insert_front(&mut self, v: VertexId) {
        if self.map.len() >= self.capacity {
            // evict LRU (tail), reuse its arena slot
            let idx = self.tail;
            debug_assert_ne!(idx, NIL);
            self.detach(idx);
            let old = self.arena[idx as usize].key;
            self.map.remove(&old);
            self.arena[idx as usize].key = v;
            self.map.insert(v, idx);
            self.attach_front(idx);
        } else {
            let idx = self.arena.len() as u32;
            self.arena.push(Node { key: v, prev: NIL, next: NIL });
            self.map.insert(v, idx);
            self.attach_front(idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Each PE thread owns its cache instance in the threaded engine —
    /// the type must stay `Send` (compile-time check).
    #[test]
    fn cache_is_send_for_per_pe_threads() {
        fn assert_send<T: Send>() {}
        assert_send::<LruCache>();
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = LruCache::new(2);
        assert!(!c.access(1)); // miss
        assert!(!c.access(2)); // miss
        assert!(c.access(1)); // hit
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 2);
        assert!((c.miss_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = LruCache::new(2);
        c.access(1);
        c.access(2);
        c.access(1); // 1 now MRU, 2 is LRU
        c.access(3); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut c = LruCache::new(10);
        for v in 0..1000u32 {
            c.access(v % 37);
            assert!(c.len() <= 10);
        }
    }

    #[test]
    fn full_scan_cyclic_worst_case() {
        // classic LRU pathology: cyclic scan of capacity+1 items misses
        // every time
        let mut c = LruCache::new(4);
        for _ in 0..5 {
            for v in 0..5u32 {
                c.access(v);
            }
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 25);
    }

    #[test]
    fn stats_reset_keeps_contents() {
        let mut c = LruCache::new(4);
        c.access(7);
        c.reset_stats();
        assert_eq!(c.misses, 0);
        assert!(c.access(7), "content survives stat reset");
    }

    #[test]
    fn stress_against_reference_model() {
        // Compare against a naive O(n) reference LRU.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(99);
        let mut c = LruCache::new(16);
        let mut reference: Vec<u32> = Vec::new(); // front = MRU
        for _ in 0..5000 {
            let v = rng.next_below(64) as u32;
            let hit = c.access(v);
            let ref_hit = reference.contains(&v);
            assert_eq!(hit, ref_hit, "divergence on {v}");
            reference.retain(|&x| x != v);
            reference.insert(0, v);
            reference.truncate(16);
        }
    }
}
