//! Cooperative sampling — Algorithm 1 of the paper.
//!
//! The graph is 1-D partitioned: PE `p` owns vertices `V_p` and their
//! incoming edges. One *global* batch of seed vertices (size `b·P`) is
//! partitioned by ownership; then, layer by layer:
//!
//! 1. each PE samples the in-neighborhoods of its owned layer vertices
//!    `S_p^l`, producing edges `E_p^l` and the requested source set
//!    `S̃_p^{l+1}` (which includes `S_p^l` itself — Eq. 2 self-inclusion);
//! 2. the requested ids are **all-to-all** redistributed by owner, so each
//!    PE receives `S_p^{l+1} ⊆ V_p`, the union of everything any PE needs
//!    from it — deduplicated, hence *zero duplicate work* downstream.
//!
//! Because every sampler draws its variates from counter-based hashes
//! shared across PEs, the union of the per-PE samples is **bit-identical**
//! to sampling the whole global batch on one PE (tested below). This is
//! the mechanism by which cooperative minibatching realizes the concave
//! work curve `E[|S^l(bP)|] ≪ P·E[|S^l(b)|]` (Theorems 3.1/3.2).

use super::all_to_all::Exchange;
use crate::graph::{Csr, Partition, VertexId};
use crate::sampling::{Neighborhoods, Sampler};

/// Per-PE, per-layer sample + traffic record.
///
/// Besides the count/traffic fields, the layer retains the **block
/// structure** the compute plane executes on: the sampled-edge CSR in
/// positions into `tilde`, the self-inclusion positions, and the
/// activation-routing data (who owns each `tilde` entry; which owned
/// ids each peer requested). `pipeline::stream` turns these into a
/// [`crate::model::PeCompute`] so the layered forward/backward never
/// re-derives (or risks diverging from) what was actually sampled.
#[derive(Clone, Debug, Default)]
pub struct PeLayer {
    /// `S_p^l`: owned destination vertices processed by this PE.
    pub owned: Vec<VertexId>,
    /// `S̃_p^{l+1}`: unique source ids this PE's sampled edges reference
    /// (incl. `owned` for self-inclusion), sorted ascending.
    pub tilde: Vec<VertexId>,
    /// |E_p^l|: sampled edges.
    pub edges: usize,
    /// how many of `tilde` live on other PEs (the `c·|S̃|` traffic).
    pub cross: usize,
    /// `[owned.len()+1]` CSR offsets into `nbr_pos` (sampled-edge lists
    /// per owned destination, in `owned` order).
    pub nbr_offsets: Vec<u32>,
    /// sampled-neighbor positions into `tilde` (the block's source row
    /// space), per edge.
    pub nbr_pos: Vec<u32>,
    /// `[owned.len()]` position of each owned destination in `tilde`
    /// (self-inclusion guarantees membership).
    pub self_pos: Vec<u32>,
    /// `[tilde.len()]` owner PE of each `tilde` entry.
    pub tilde_owner: Vec<u32>,
    /// This round's pre-dedup id inbox: `inbox[q]` = the ids PE `q`
    /// requested from this PE, in `q`'s tilde order — the exact lists
    /// activation rows must be shipped back along during layered
    /// compute (mirrors `final_requests` for every layer).
    pub inbox: Vec<Vec<VertexId>>,
}

/// Build the retained block-CSR fields (`nbr_offsets` / `nbr_pos` /
/// `self_pos`) for one PE's layer: sampled neighbors and owned
/// destinations resolved to positions in the sorted `tilde`.
fn block_positions(
    owned: &[VertexId],
    tilde: &[VertexId],
    nbh: &Neighborhoods,
) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
    let nbr_offsets = nbh.offsets.clone();
    let nbr_pos: Vec<u32> = nbh
        .nbrs
        .iter()
        .map(|s| tilde.binary_search(s).expect("sampled nbr in tilde") as u32)
        .collect();
    let self_pos: Vec<u32> = owned
        .iter()
        .map(|v| tilde.binary_search(v).expect("self-inclusion") as u32)
        .collect();
    (nbr_offsets, nbr_pos, self_pos)
}

/// The result of cooperatively sampling one global minibatch.
#[derive(Clone, Debug)]
pub struct CoopSample {
    pub num_pes: usize,
    /// `layers[l][p]` for l in 0..L.
    pub layers: Vec<Vec<PeLayer>>,
    /// `S_p^{L}` per PE: owned input vertices whose features must load.
    pub final_owned: Vec<Vec<VertexId>>,
    /// The last id round's buckets, pre-dedup:
    /// `final_requests[q][owner]` = `S̃_q^L ∩ V_owner` in q's tilde order —
    /// exactly what each owner must ship back as feature rows in the
    /// cooperative loading round
    /// ([`crate::coop::feature_loader::load_cooperative`]); retained so
    /// the loader never recomputes (or risks diverging from) what was
    /// actually routed.
    pub final_requests: Vec<Vec<Vec<VertexId>>>,
    /// id-redistribution fabric traffic (4-byte ids).
    pub exchange: Exchange,
}

impl CoopSample {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// max over PEs of |S_p^l| (the paper's Table 7 reduction).
    pub fn max_owned(&self, l: usize) -> usize {
        if l == self.layers.len() {
            self.final_owned.iter().map(|v| v.len()).max().unwrap_or(0)
        } else {
            self.layers[l].iter().map(|pl| pl.owned.len()).max().unwrap_or(0)
        }
    }

    pub fn max_edges(&self, l: usize) -> usize {
        self.layers[l].iter().map(|pl| pl.edges).max().unwrap_or(0)
    }

    pub fn max_tilde(&self, l: usize) -> usize {
        self.layers[l].iter().map(|pl| pl.tilde.len()).max().unwrap_or(0)
    }

    pub fn max_cross(&self, l: usize) -> usize {
        self.layers[l].iter().map(|pl| pl.cross).max().unwrap_or(0)
    }

    /// Union of owned sets at layer `l` (= the global `S^l`), sorted.
    pub fn union_layer(&self, l: usize) -> Vec<VertexId> {
        let mut v: Vec<VertexId> = if l == self.layers.len() {
            self.final_owned.iter().flatten().copied().collect()
        } else {
            self.layers[l].iter().flat_map(|pl| pl.owned.iter().copied()).collect()
        };
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Σ_l |S^l| summed over the union (global work proxy).
    pub fn total_union_vertices(&self) -> usize {
        (1..=self.layers.len()).map(|l| self.union_layer(l).len()).sum()
    }
}

/// Run Algorithm 1's sampling phase. `per_pe_samplers` must share the
/// same batch seed (and dependent-RNG phase) for cross-PE consistency;
/// `per_pe_seeds[p]` must be owned by PE p under `part`.
pub fn sample_cooperative(
    _graph: &Csr,
    part: &Partition,
    per_pe_samplers: &mut [Sampler<'_>],
    per_pe_seeds: &[Vec<VertexId>],
    layers: usize,
) -> CoopSample {
    let p_count = part.num_parts;
    assert_eq!(per_pe_samplers.len(), p_count);
    assert_eq!(per_pe_seeds.len(), p_count);
    let mut exchange = Exchange::new(p_count);
    let mut current: Vec<Vec<VertexId>> = per_pe_seeds.to_vec();
    let mut out_layers: Vec<Vec<PeLayer>> = Vec::with_capacity(layers);
    let mut final_requests: Vec<Vec<Vec<VertexId>>> = Vec::new();
    let mut nbh = Neighborhoods::default();

    for l in 0..layers {
        let mut buckets: Vec<Vec<Vec<VertexId>>> =
            vec![vec![Vec::new(); p_count]; p_count];
        let mut layer_rec: Vec<PeLayer> = Vec::with_capacity(p_count);
        for p in 0..p_count {
            let owned = std::mem::take(&mut current[p]);
            per_pe_samplers[p].sample_layer(&owned, l, &mut nbh);
            // S̃_p^{l+1} = unique(owned ∪ sampled srcs)
            let mut tilde: Vec<VertexId> = Vec::with_capacity(owned.len() + nbh.nbrs.len());
            tilde.extend_from_slice(&owned);
            tilde.extend_from_slice(&nbh.nbrs);
            tilde.sort_unstable();
            tilde.dedup();
            let mut cross = 0usize;
            let mut tilde_owner: Vec<u32> = Vec::with_capacity(tilde.len());
            for &t in &tilde {
                let owner = part.part_of(t);
                if owner != p {
                    cross += 1;
                }
                tilde_owner.push(owner as u32);
                buckets[p][owner].push(t);
            }
            let (nbr_offsets, nbr_pos, self_pos) = block_positions(&owned, &tilde, &nbh);
            layer_rec.push(PeLayer {
                owned,
                tilde,
                edges: nbh.num_edges(),
                cross,
                nbr_offsets,
                nbr_pos,
                self_pos,
                tilde_owner,
                inbox: Vec::new(),
            });
        }
        // all-to-all: ids travel to their owners
        let inboxes = exchange.route(&buckets, 4);
        for p in 0..p_count {
            // retain the pre-dedup per-requester inbox: the compute
            // plane ships activation rows back along these exact lists
            layer_rec[p].inbox = (0..p_count).map(|q| buckets[q][p].clone()).collect();
            let mut next = inboxes[p].clone();
            next.sort_unstable();
            next.dedup();
            current[p] = next;
        }
        if l == layers - 1 {
            // retain the pre-dedup per-(requester, owner) request lists:
            // the feature loader ships rows back along exactly these
            final_requests = buckets;
        }
        out_layers.push(layer_rec);
    }

    CoopSample {
        num_pes: p_count,
        layers: out_layers,
        final_owned: current,
        final_requests,
        exchange,
    }
}

/// One PE's view of a cooperatively-sampled minibatch, produced by
/// [`sample_cooperative_pe`] running inside that PE's thread.
#[derive(Clone, Debug)]
pub struct PeCoopSample {
    /// `layers[l]` for l in 0..L — identical to `CoopSample.layers[l][pe]`
    /// of the serial reference.
    pub layers: Vec<PeLayer>,
    /// `S_p^L`: owned input vertices whose features must load.
    pub final_owned: Vec<VertexId>,
    /// The last id round's inbox, pre-dedup: `final_requests[q]` =
    /// `S̃_q^L ∩ V_p` in q's tilde order — exactly what owner p must ship
    /// back as feature rows in the cooperative loading round
    /// ([`crate::coop::feature_loader::load_pe_cooperative`]).
    pub final_requests: Vec<Vec<VertexId>>,
}

/// Algorithm 1's sampling phase for **one PE thread**, exchanging ids
/// over a live [`PeEndpoint`] instead of the simulated [`Exchange`].
///
/// Every PE of the fabric must call this concurrently with the same
/// `layers` and a sampler built from the same batch seed; `seeds` must be
/// owned by this endpoint's PE under `part`. The per-PE results are
/// bit-identical to the serial [`sample_cooperative`] (tested below):
/// samplers draw from counter-based hashes, and inboxes are reassembled
/// src-major before the sort+dedup, so thread scheduling cannot leak into
/// the sample.
pub fn sample_cooperative_pe(
    _graph: &Csr,
    part: &Partition,
    sampler: &mut Sampler<'_>,
    ep: &mut crate::coop::all_to_all::PeEndpoint,
    seeds: Vec<VertexId>,
    layers: usize,
) -> PeCoopSample {
    let pe = ep.pe;
    let p_count = ep.num_pes;
    assert_eq!(p_count, part.num_parts, "fabric/partition mismatch");
    let mut current = seeds;
    let mut nbh = Neighborhoods::default();
    let mut out_layers: Vec<PeLayer> = Vec::with_capacity(layers);
    let mut final_requests: Vec<Vec<VertexId>> = Vec::new();

    for l in 0..layers {
        let owned = std::mem::take(&mut current);
        sampler.sample_layer(&owned, l, &mut nbh);
        // S̃_p^{l+1} = unique(owned ∪ sampled srcs)
        let mut tilde: Vec<VertexId> = Vec::with_capacity(owned.len() + nbh.nbrs.len());
        tilde.extend_from_slice(&owned);
        tilde.extend_from_slice(&nbh.nbrs);
        tilde.sort_unstable();
        tilde.dedup();
        let mut buckets: Vec<Vec<VertexId>> = vec![Vec::new(); p_count];
        let mut cross = 0usize;
        let mut tilde_owner: Vec<u32> = Vec::with_capacity(tilde.len());
        for &t in &tilde {
            let owner = part.part_of(t);
            if owner != pe {
                cross += 1;
            }
            tilde_owner.push(owner as u32);
            buckets[owner].push(t);
        }
        let (nbr_offsets, nbr_pos, self_pos) = block_positions(&owned, &tilde, &nbh);
        // live all-to-all: ids travel to their owners
        let inbox = ep.all_to_all(buckets, 4);
        let mut next: Vec<VertexId> = inbox.concat();
        next.sort_unstable();
        next.dedup();
        current = next;
        if l == layers - 1 {
            // retain the pre-dedup per-requester lists: the feature
            // loader ships rows back along exactly these requests
            final_requests = inbox.clone();
        }
        out_layers.push(PeLayer {
            owned,
            tilde,
            edges: nbh.num_edges(),
            cross,
            nbr_offsets,
            nbr_pos,
            self_pos,
            tilde_owner,
            inbox,
        });
    }

    PeCoopSample { layers: out_layers, final_owned: current, final_requests }
}

/// Partition a global seed batch by vertex owner — the "each PE samples
/// its seeds from the training vertices in V_p" step.
pub fn partition_seeds(
    seeds: &[VertexId],
    part: &Partition,
) -> Vec<Vec<VertexId>> {
    let mut per_pe: Vec<Vec<VertexId>> = vec![Vec::new(); part.num_parts];
    for &s in seeds {
        per_pe[part.part_of(s)].push(s);
    }
    per_pe
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, partition};
    use crate::sampling::{SamplerConfig, SamplerKind};

    fn fixture() -> (Csr, Partition) {
        let g = generate::chung_lu(3000, 14.0, 2.4, 21);
        let part = partition::random(&g, 4, 5);
        (g, part)
    }

    fn run_coop(
        g: &Csr,
        part: &Partition,
        kind: SamplerKind,
        seeds: &[u32],
        batch_seed: u64,
    ) -> CoopSample {
        let cfg = SamplerConfig::default();
        let mut samplers: Vec<_> =
            (0..part.num_parts).map(|_| cfg.build(kind, g, batch_seed)).collect();
        let per_pe = partition_seeds(seeds, part);
        sample_cooperative(g, part, &mut samplers, &per_pe, cfg.layers)
    }

    #[test]
    fn union_matches_single_pe_global_sample() {
        // The cooperative union must equal the global sample bit-for-bit
        // for samplers with shared per-vertex/per-edge coins.
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..256).collect();
        for kind in [SamplerKind::Neighbor, SamplerKind::Labor0] {
            let coop = run_coop(&g, &part, kind, &seeds, 777);
            let cfg = SamplerConfig::default();
            let mut global = cfg.build(kind, &g, 777);
            let mfg = global.sample_mfg(&seeds);
            for l in 0..=3 {
                let mut want = mfg.layer_vertices[l].clone();
                want.sort_unstable();
                want.dedup();
                let got = coop.union_layer(l);
                assert_eq!(got, want, "{kind:?} layer {l}");
            }
        }
    }

    #[test]
    fn ownership_invariant() {
        // every vertex in S_p^l must be owned by p
        let (g, part) = fixture();
        let seeds: Vec<u32> = (500..756).collect();
        let coop = run_coop(&g, &part, SamplerKind::Labor0, &seeds, 3);
        for l in 0..coop.num_layers() {
            for (p, pl) in coop.layers[l].iter().enumerate() {
                for &v in &pl.owned {
                    assert_eq!(part.part_of(v), p, "layer {l} PE {p} vertex {v}");
                }
            }
        }
        for (p, owned) in coop.final_owned.iter().enumerate() {
            for &v in owned {
                assert_eq!(part.part_of(v), p);
            }
        }
    }

    #[test]
    fn no_duplicate_work_across_pes() {
        // each union vertex appears in exactly one PE's owned set
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..512).collect();
        let coop = run_coop(&g, &part, SamplerKind::Neighbor, &seeds, 9);
        for l in 1..=coop.num_layers() {
            let union = coop.union_layer(l);
            let total: usize = if l == coop.num_layers() {
                coop.final_owned.iter().map(|v| v.len()).sum()
            } else {
                coop.layers[l].iter().map(|pl| pl.owned.len()).sum()
            };
            assert_eq!(total, union.len(), "layer {l}: owned sets must be disjoint");
        }
    }

    #[test]
    fn cross_ratio_near_random_partition_expectation() {
        // with random partitioning, c ≈ (P-1)/P = 0.75 of requested ids
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..1024).collect();
        let coop = run_coop(&g, &part, SamplerKind::Labor0, &seeds, 11);
        let ratio = coop.exchange.cross_ratio();
        assert!((0.6..0.9).contains(&ratio), "cross ratio {ratio}");
    }

    #[test]
    fn partitioned_graph_reduces_cross_traffic() {
        let g = generate::community(3000, 12.0, 2.4, 12, 0.8, 31);
        let rand_p = partition::random(&g, 4, 1);
        let metis_p = partition::multilevel(&g, 4, 1);
        let seeds: Vec<u32> = (0..512).collect();
        let a = run_coop(&g, &rand_p, SamplerKind::Labor0, &seeds, 13);
        let b = run_coop(&g, &metis_p, SamplerKind::Labor0, &seeds, 13);
        assert!(
            b.exchange.cross_items < a.exchange.cross_items,
            "partitioning should cut cross traffic: {} vs {}",
            b.exchange.cross_items,
            a.exchange.cross_items
        );
    }

    /// The thread-per-PE sampler must be bit-identical to the serial
    /// reference, per PE and per layer, including exchange accounting.
    #[test]
    fn threaded_pe_sampling_matches_serial_reference() {
        use crate::coop::all_to_all::Fabric;
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..300).collect();
        let cfg = SamplerConfig::default();
        let per_pe = partition_seeds(&seeds, &part);
        for kind in [SamplerKind::Neighbor, SamplerKind::Labor0, SamplerKind::LaborStar] {
            // serial oracle
            let mut samplers: Vec<_> =
                (0..part.num_parts).map(|_| cfg.build(kind, &g, 4242)).collect();
            let serial = sample_cooperative(&g, &part, &mut samplers, &per_pe, cfg.layers);

            // one real thread per PE over a live fabric
            let endpoints = Fabric::endpoints(part.num_parts);
            let results: Vec<(PeCoopSample, u64, u64)> = std::thread::scope(|scope| {
                let g = &g;
                let part = &part;
                let per_pe = &per_pe;
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .map(|mut ep| {
                        scope.spawn(move || {
                            let pe = ep.pe;
                            let mut sampler = cfg.build(kind, g, 4242);
                            let ps = sample_cooperative_pe(
                                g,
                                part,
                                &mut sampler,
                                &mut ep,
                                per_pe[pe].clone(),
                                cfg.layers,
                            );
                            (ps, ep.cross_items, ep.local_items)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            for (p, (ps, _, _)) in results.iter().enumerate() {
                for l in 0..cfg.layers {
                    let want = &serial.layers[l][p];
                    assert_eq!(ps.layers[l].owned, want.owned, "{kind:?} L{l} PE{p} owned");
                    assert_eq!(ps.layers[l].tilde, want.tilde, "{kind:?} L{l} PE{p} tilde");
                    assert_eq!(ps.layers[l].edges, want.edges, "{kind:?} L{l} PE{p} edges");
                    assert_eq!(ps.layers[l].cross, want.cross, "{kind:?} L{l} PE{p} cross");
                    // the retained block structure + routing data must
                    // match too: the compute plane executes on these
                    assert_eq!(
                        ps.layers[l].nbr_offsets, want.nbr_offsets,
                        "{kind:?} L{l} PE{p} nbr_offsets"
                    );
                    assert_eq!(
                        ps.layers[l].nbr_pos, want.nbr_pos,
                        "{kind:?} L{l} PE{p} nbr_pos"
                    );
                    assert_eq!(
                        ps.layers[l].self_pos, want.self_pos,
                        "{kind:?} L{l} PE{p} self_pos"
                    );
                    assert_eq!(
                        ps.layers[l].tilde_owner, want.tilde_owner,
                        "{kind:?} L{l} PE{p} tilde_owner"
                    );
                    assert_eq!(
                        ps.layers[l].inbox, want.inbox,
                        "{kind:?} L{l} PE{p} inbox"
                    );
                }
                assert_eq!(ps.final_owned, serial.final_owned[p], "{kind:?} PE{p} final");
                // the retained last-round requests must be each
                // requester's final tilde restricted to this owner, in
                // tilde order — the contract the feature loader ships
                // rows back along
                for q in 0..part.num_parts {
                    let want: Vec<VertexId> = serial.layers[cfg.layers - 1][q]
                        .tilde
                        .iter()
                        .copied()
                        .filter(|&t| part.part_of(t) == p)
                        .collect();
                    assert_eq!(
                        ps.final_requests[q], want,
                        "{kind:?} owner {p} requester {q} final requests"
                    );
                }
            }
            let cross: u64 = results.iter().map(|r| r.1).sum();
            let local: u64 = results.iter().map(|r| r.2).sum();
            assert_eq!(cross, serial.exchange.cross_items, "{kind:?} cross accounting");
            assert_eq!(local, serial.exchange.local_items, "{kind:?} local accounting");
        }
    }

    /// The retained block structure must be internally consistent: CSR
    /// positions resolve into `tilde`, self positions point at the
    /// owned vertices, owners match the partition, and each round's
    /// inbox entries are owned here and cover the next layer's owned
    /// set exactly.
    #[test]
    fn retained_block_structure_is_consistent() {
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..400).collect();
        let coop = run_coop(&g, &part, SamplerKind::Labor0, &seeds, 55);
        let layers = coop.num_layers();
        for l in 0..layers {
            for (p, pl) in coop.layers[l].iter().enumerate() {
                assert_eq!(pl.nbr_offsets.len(), pl.owned.len() + 1, "L{l} PE{p} offsets");
                assert_eq!(*pl.nbr_offsets.last().unwrap() as usize, pl.edges);
                assert_eq!(pl.nbr_pos.len(), pl.edges, "L{l} PE{p} edge positions");
                for &pos in &pl.nbr_pos {
                    assert!((pos as usize) < pl.tilde.len(), "L{l} PE{p} pos range");
                }
                assert_eq!(pl.self_pos.len(), pl.owned.len());
                for (i, &sp) in pl.self_pos.iter().enumerate() {
                    assert_eq!(pl.tilde[sp as usize], pl.owned[i], "L{l} PE{p} self pos");
                }
                assert_eq!(pl.tilde_owner.len(), pl.tilde.len());
                for (i, &o) in pl.tilde_owner.iter().enumerate() {
                    assert_eq!(o as usize, part.part_of(pl.tilde[i]), "L{l} PE{p} owner");
                }
                // inbox[q] = q's tilde restricted to this owner, and the
                // union of inboxes dedups to the next layer's owned set
                let mut union: Vec<VertexId> = Vec::new();
                for (q, req) in pl.inbox.iter().enumerate() {
                    let want: Vec<VertexId> = coop.layers[l][q]
                        .tilde
                        .iter()
                        .copied()
                        .filter(|&t| part.part_of(t) == p)
                        .collect();
                    assert_eq!(req, &want, "L{l} owner {p} requester {q} inbox");
                    union.extend_from_slice(req);
                }
                union.sort_unstable();
                union.dedup();
                let next_owned: &[VertexId] = if l + 1 == layers {
                    &coop.final_owned[p]
                } else {
                    &coop.layers[l + 1][p].owned
                };
                assert_eq!(union, next_owned, "L{l} PE{p} inbox union");
            }
        }
    }

    #[test]
    fn seed_partitioning_is_exact() {
        let (g, part) = fixture();
        let seeds: Vec<u32> = (0..100).collect();
        let per_pe = partition_seeds(&seeds, &part);
        let total: usize = per_pe.iter().map(|v| v.len()).sum();
        assert_eq!(total, seeds.len());
        for (p, vs) in per_pe.iter().enumerate() {
            for &v in vs {
                assert_eq!(part.part_of(v), p);
            }
        }
        let _ = g;
    }
}
